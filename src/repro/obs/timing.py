"""`obs.timeit` — the one best-of-N wall timer for kernels and steps.

Replaces the three hand-rolled warmup/min-of-N loops that used to live
in `kernels/tune.py` (autotune + choose_block_rows) and
`api/engine.py` (`_attn_fc_share`): one warmup call to absorb
compilation, then ``reps`` samples of ``inner`` back-to-back calls with
the best per-call mean kept.  Sub-ms kernels need the inner loop —
single-call samples are noise on a busy host — and min-of-reps is the
standard noise-floor estimator.
"""
from __future__ import annotations

import time


def timeit(fn, *args, reps: int = 3, inner: int = 3,
           warmup: int = 1, **kw) -> float:
    """Best per-call seconds for ``fn(*args, **kw)``.

    ``warmup`` calls run first (blocked on) to absorb compilation; then
    ``reps`` samples of ``inner`` back-to-back calls, blocking once per
    sample, keeping the minimum per-call mean.  Raises whatever the
    first call raises — callers that tolerate failing candidates (the
    autotuner) keep their own try/except.
    """
    import jax
    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn(*args, **kw))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(*args, **kw)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / inner)
    return best
