"""Fig. 5 design-space exploration.

(a) area & energy efficiency vs weight sparsity — both ≈ linear in sparsity
    (EE counted on dense-equivalent work, the paper's relative convention);
(b) area & EE vs arithmetic wordlength — best at binary/ternary, EE drops
    superlinearly with wordlength (bit-serial multiply time is quadratic).
All values are relative to the Table-1 operating point, like the paper's
figure.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import aida_sim as S


def sparsity_sweep(densities=(0.05, 0.09, 0.15, 0.25, 0.5, 1.0),
                   log=print) -> List[Dict]:
    base_density = 0.09
    base = _point(base_density, m=4, n=4, prod_bits=16, mode="coded")
    log(f"{'density':>8s} {'rel_area':>9s} {'rel_EE(dense-eq)':>17s}")
    rows = []
    for d in densities:
        p = _point(d, m=4, n=4, prod_bits=16, mode="coded")
        rel_area = p["area"] / base["area"]
        rel_ee = p["ee_dense_eq"] / base["ee_dense_eq"]
        rows.append({"density": d, "rel_area": rel_area, "rel_ee": rel_ee})
        log(f"{d:8.2f} {rel_area:9.3f} {rel_ee:17.3f}")
    return rows


def precision_sweep(bits=(1, 2, 4, 8, 16), log=print) -> List[Dict]:
    """Bit-serial mode with exact-width accumulators (the wordlength axis
    only exists there; a fixed-16 accumulator would hide the scaling)."""
    import dataclasses
    mc = dataclasses.replace(S.PAPER, kc_fixed=None)
    base = _point(0.09, m=16, n=16, mode="bitserial", mc=mc)
    log(f"{'bits':>5s} {'rel_area':>9s} {'rel_EE':>8s} {'mult_cycles':>12s}")
    rows = []
    for b in bits:
        p = _point(0.09, m=b, n=b, mode="bitserial", mc=mc)
        rows.append({"bits": b, "rel_area": base["area"] / p["area"],
                     "rel_ee": p["ee"] / base["ee"],
                     "mult_cycles": p["mult_cycles"]})
        log(f"{b:5d} {rows[-1]['rel_area']:9.3f} {rows[-1]['rel_ee']:8.3f} "
            f"{p['mult_cycles']:12d}")
    return rows


def _point(density, m, n, prod_bits=None, mode="coded", mc=None):
    layer = S.FCLayerSpec("FC6", 4096, 9216, density, 0.35)
    mc = S.PAPER if mc is None else mc
    ph = S.cycles_fc(layer.n_in, layer.nnz_b, layer.max_row_nnz, mc,
                     mode=mode, m=m, n=n,
                     prod_bits=prod_bits or (m + n))
    t = ph.total(mc) / mc.freq_hz
    nnz = layer.nnz
    dense_ops = 2 * layer.n_out * layer.n_in
    pw = S.power_w(nnz, mc)
    bits_row = 13 + m + n + (prod_bits or m + n) + 17
    return {
        "area": S.area_mm2(nnz, bits_row),
        "ee": (2 * nnz / t / 1e9) / pw,
        "ee_dense_eq": (dense_ops / t / 1e9) / pw,
        "mult_cycles": ph.multiply,
    }


def overlap_scalability(log=print) -> Dict:
    """§4.3: two-subarray broadcast/M×V overlap — 'up to 1.86×' speedup at
    +28% area."""
    import dataclasses
    base_mc = dataclasses.replace(S.PAPER, overlap_broadcast=False)
    over_mc = S.PAPER
    best = 0.0
    for layer in S.alexnet_fc() + S.ctc_lstm():
        ph = S.cycles_fc(layer.n_in, layer.nnz_b, layer.max_row_nnz,
                         base_mc, mode="coded")
        speed = ph.total(base_mc) / ph.total(over_mc)
        best = max(best, speed)
        log(f"  {layer.name:6s} overlap speedup {speed:.2f}x")
    nnz = sum(l.nnz for l in S.alexnet_fc() + S.ctc_lstm())
    bits_row = 2 + 1 + 10 + 4 + 4 + 4 + 16 + 17 + 6
    a1 = S.area_mm2(nnz, bits_row, dual_tag=False)
    a2 = S.area_mm2(nnz, bits_row, dual_tag=True)
    log(f"  best speedup {best:.2f}x (paper: up to 1.86x), "
        f"area +{a2/a1-1:.0%} (paper: +28%)")
    return {"best_speedup": best, "area_overhead": a2 / a1 - 1}


if __name__ == "__main__":
    print("Fig 5(a) — sparsity:")
    sparsity_sweep()
    print("\nFig 5(b) — precision:")
    precision_sweep()
    print("\n§4.3 — broadcast overlap:")
    overlap_scalability()
