"""Train a ~100M-parameter LM with the full production loop: checkpointing,
restart-on-failure supervision, straggler tracking, gradient compression.

Default is a short CPU-friendly run; pass --steps 300 for the full driver.

  PYTHONPATH=src python examples/train_llm.py [--steps N] [--arch qwen1.5-0.5b]
"""
import argparse
import dataclasses

import jax

from repro.api import Engine, Request
from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get, reduced
from repro.data.pipeline import DataIterator, PipelineConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.fault_tolerance import RestartLoop, StragglerDetector
from repro.train import trainer


def build_cfg(arch: str):
    """~100M-param member of the chosen family (CPU-trainable)."""
    base = get(arch)
    return dataclasses.replace(
        reduced(base), name=base.name + "-100m", n_layers=6, d_model=512,
        d_ff=1536, vocab=8192,
        d_head=512 // max(2, min(base.n_heads, 8)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--grad-compression", default=None,
                    choices=[None, "bf16", "int8"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_llm")
    args = ap.parse_args()

    cfg = build_cfg(args.arch)
    print(f"arch {cfg.name}: ~{cfg.params_count()/1e6:.0f}M params")
    tc = trainer.TrainConfig(
        remat="dots", microbatches=2,
        grad_compression=args.grad_compression,
        opt=AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps))
    pc = PipelineConfig(seed=0, global_batch=8, seq_len=256)
    mgr = CheckpointManager(args.ckpt_dir, keep_last=3)
    straggler = StragglerDetector()

    final = {}

    def run_fn(resume_step):
        start = 0
        state = None
        if resume_step is not None:
            template = trainer.init_state(cfg, jax.random.PRNGKey(0))
            state, extra = mgr.restore(
                jax.tree.map(lambda x: x, template))
            start = extra["data"]["step"]
            print(f"[resume] from checkpoint step {resume_step}, "
                  f"data step {start}")
        data = DataIterator(cfg, pc, start_step=start)
        final["state"] = trainer.run(
            cfg, tc, data, n_steps=args.steps - start,
            state=state, key=jax.random.PRNGKey(0), ckpt_mgr=mgr,
            ckpt_every=10, straggler=straggler, log_every=5)

    RestartLoop(mgr, max_restarts=2).supervise(run_fn)
    mgr.wait()
    print(f"done; checkpoints at {mgr.list_steps()}; "
          f"straggler events: {straggler.flags}")

    if final.get("state") is not None and cfg.has_decode:
        # decode smoke on the trained weights through the serving facade
        eng = Engine(cfg, params=final["state"].params)
        res = eng.serve([Request(prompt=[1, 2, 3], max_new=8, rid=0)],
                        batch_slots=1, max_len=32)
        print(f"[api] decode smoke via Engine ({eng.backend.name}): "
              f"{res[0].tokens}")


if __name__ == "__main__":
    main()
