"""Role-aware request router for disaggregated serving.

`DisaggRouter` IS the prefill role's scheduler (a `sched.Scheduler`
subclass — every admission policy, the page-pool admission predicate,
and the sjf aging bound work unchanged) plus the handoff queue that
feeds the decode role.  The one behavioral extension is *back-pressure*:
when the decode side falls behind — more finished prompts waiting in the
handoff queue than ``max_backlog`` — the router refuses to admit new
prompts into prefill slots instead of letting the decode role preempt
running decoders.  Prefill work already in flight keeps running; only
*new* admissions stall, so decode pressure translates into TTFT delay
for queued requests rather than wasted recompute for admitted ones.
"""
from __future__ import annotations

import collections
from typing import Callable, Deque, Optional

from repro.disagg.migrate import Handoff
from repro.sched.scheduler import SchedConfig, SchedEntry, Scheduler


class DisaggRouter(Scheduler):
    def __init__(self, cfg: Optional[SchedConfig] = None,
                 max_backlog: int = 4):
        super().__init__(cfg)
        if max_backlog < 1:
            raise ValueError(f"max_backlog must be >= 1, got {max_backlog}")
        self.max_backlog = max_backlog
        self.handoff: Deque[Handoff] = collections.deque()
        self.stats.update({"handoffs": 0, "backpressure_blocks": 0})

    # ------------------------------------------------------- handoff side
    def push_handoff(self, h: Handoff) -> None:
        """Prefill finished a prompt: queue it for decode admission (FIFO
        — keeps the decode side's admission order deterministic)."""
        self.handoff.append(h)
        self.stats["handoffs"] += 1

    @property
    def backlog(self) -> int:
        return len(self.handoff)

    # ------------------------------------------------------ prefill side
    def next_entry(self, fits: Callable[[SchedEntry], bool],
                   step: Optional[int] = None) -> Optional[SchedEntry]:
        """Like Scheduler.next_entry, but refuse admission while the
        handoff backlog is at the bound — prefilling more prompts the
        decode role cannot absorb would only grow the pile of migrated
        state (and, co-located, steal pool pages decode needs)."""
        if len(self.handoff) >= self.max_backlog:
            if self.queue:
                self.stats["backpressure_blocks"] += 1
                if self.obs is not None:
                    self.obs("sched.block",
                             rid=self.queue[0].req.rid,
                             queued=len(self.queue),
                             backpressure=True,
                             backlog=len(self.handoff))
            return None
        return super().next_entry(fits, step=step)
