"""Codebook / int quantization / CompressedFC modes."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import codebook as cb  # noqa: E402
from repro.core import quant as q  # noqa: E402
from repro.core import sparse_fc as sfc  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 64), seed=st.integers(0, 99))
def test_pack_unpack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 16, size=(4, 2 * n)).astype(np.uint8))
    np.testing.assert_array_equal(np.asarray(cb.unpack4(cb.pack4(codes))),
                                  np.asarray(codes))


def test_kmeans_reduces_error(rng):
    x = jnp.asarray(rng.normal(size=4096).astype(np.float32))
    cents = cb.kmeans_1d(x, k=16, iters=20)
    codes = cb.assign(x, cents)
    err16 = float(jnp.mean((jnp.take(cents, codes.astype(jnp.int32)) - x) ** 2))
    cents4 = cb.kmeans_1d(x, k=4, iters=20)
    codes4 = cb.assign(x, cents4)
    err4 = float(jnp.mean((jnp.take(cents4, codes4.astype(jnp.int32)) - x) ** 2))
    assert err16 < err4 < float(jnp.var(x))
    assert err16 < 0.02  # 16 clusters on a unit gaussian


def test_quantize_dequantize_shapes(rng):
    w = jnp.asarray(rng.normal(size=(32, 64)).astype(np.float32))
    cbq = cb.quantize(w, k=16)
    deq = cb.dequantize(cbq)
    assert deq.shape == w.shape
    assert float(jnp.mean((deq - w) ** 2)) < 0.05


def test_product_lut_is_outer_product(rng):
    cw = jnp.asarray(rng.normal(size=16).astype(np.float32))
    ca = jnp.asarray(rng.normal(size=16).astype(np.float32))
    lut = cb.product_lut(cw, ca)
    for i in (0, 5, 15):
        for j in (0, 7, 15):
            assert np.isclose(float(lut[i, j]), float(cw[i]) * float(ca[j]))


@pytest.mark.parametrize("bits", [8, 4])
def test_int_quant_error_bound(rng, bits):
    w = jnp.asarray(rng.normal(size=(16, 128)).astype(np.float32))
    t = q.quantize_int(w, bits=bits, axis=0)
    err = np.abs(np.asarray(q.dequantize_int(t)) - np.asarray(w))
    step = np.asarray(t.scale).max()
    assert err.max() <= step * 0.500001


def test_ternary(rng):
    w = jnp.asarray(rng.normal(size=(1024,)).astype(np.float32))
    t = q.quantize_ternary(w)
    vals = np.unique(np.asarray(t.q))
    assert set(vals).issubset({-1, 0, 1})


@pytest.mark.parametrize("mode", sfc.MODES)
def test_compressed_fc_self_consistent(rng, mode):
    """apply_fc(x) == x @ dense_equivalent.T for every mode."""
    w = rng.normal(size=(128, 256)).astype(np.float32)
    x = rng.normal(size=(4, 256)).astype(np.float32)
    layer = sfc.compress(w, mode=mode, density=0.25)
    y = np.asarray(sfc.apply_fc(layer, jnp.asarray(x)))
    weq = sfc.dense_equivalent(layer)
    np.testing.assert_allclose(y, x @ weq.T, rtol=2e-3, atol=2e-3)


def test_aida_mode_on_actually_sparse_weights(rng):
    """On genuinely sparse weights the AIDA path is near-exact."""
    w = (rng.normal(size=(128, 256)) * (rng.random((128, 256)) < 0.1)
         ).astype(np.float32)
    x = rng.normal(size=(256,)).astype(np.float32)
    layer = sfc.compress(w, mode="aida", density=1.0)  # keep all nnz
    y = np.asarray(sfc.apply_fc(layer, jnp.asarray(x)))
    rel = np.abs(y - w @ x).max() / (np.abs(w @ x).max() + 1e-9)
    assert rel < 0.15  # codebook-16 quantization error only
