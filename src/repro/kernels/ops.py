"""Public jit'd kernel API — dispatch between Pallas kernels and jnp refs.

On this (CPU) container Pallas runs in interpret mode; on TPU set
``REPRO_PALLAS_INTERPRET=0`` (or rely on the backend auto-detection) to lower
the kernels natively.  Training paths that need autodiff either use a
custom_vjp pairing the fwd/bwd kernels (attention) or a differentiable
lax.scan formulation (recurrences).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.api import env
from repro.kernels import ref as _ref
from repro.kernels import flash_attention as _fa
from repro.kernels import int8_matmul as _i8
from repro.kernels import linear_scan as _ls
from repro.kernels import lut_matmul as _lm
from repro.kernels import acsr_spmv as _sp
from repro.kernels import tune as _tune


def pallas_interpret() -> bool:
    if env.PALLAS_INTERPRET is not None:
        return env.PALLAS_INTERPRET
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------- attention
@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash(q, k, v, causal, window, softcap, scale, bq, bk, interp):
    o, _ = _fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale, bq=bq,
                                   bk=bk, interpret=interp)
    return o.astype(q.dtype)


def _flash_fwd(q, k, v, causal, window, softcap, scale, bq, bk, interp):
    o, lse = _fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                     softcap=softcap, scale=scale, bq=bq,
                                     bk=bk, interpret=interp)
    return o.astype(q.dtype), (q, k, v, o, lse)


def _flash_bwd(causal, window, softcap, scale, bq, bk, interp, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _fa.flash_attention_bwd(
        q, k, v, o, lse, do.astype(jnp.float32), causal=causal,
        window=window, softcap=softcap, scale=scale, bq=bq, bk=bk,
        interpret=interp)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              softcap: Optional[float] = None, scale: Optional[float] = None,
              impl: str = "flash", bq: int = 128, bk: int = 128):
    """Self-attention [B,H,T,D]×[B,Hkv,T,D] -> [B,H,T,D] (training/prefill).

    impl="flash": Pallas fwd/bwd kernels via custom_vjp.
    impl="ref":   pure-jnp oracle (XLA-fused; also the dry-run default, so
                  compiled HLO stays kernel-free and cost-analyzable).
    """
    if impl == "ref":
        return _ref.attention_ref(q, k, v, causal=causal, window=window,
                                  softcap=softcap, scale=scale).astype(q.dtype)
    t = q.shape[2]
    bq_, bk_ = min(bq, t), min(bk, t)
    return _flash(q, k, v, causal, window, softcap, scale, bq_, bk_,
                  pallas_interpret())


# ------------------------------------------------------------- recurrences
def rwkv6(r, k, v, w, u, *, impl: str = "scan", chunk: int = 64):
    """RWKV6 WKV. impl="scan" (differentiable, training/dry-run) or
    impl="kernel" (Pallas, serving)."""
    if impl == "kernel":
        return _ls.rwkv6_fwd(r, k, v, w, u, chunk=chunk,
                             interpret=pallas_interpret())
    return _ref.rwkv6_ref(r, k, v, w, u)


def rwkv6_decode_step(S, r, k, v, w, u):
    """Single-token WKV update. S [B,H,Dk,Dv]; r,k,w [B,H,Dk]; v [B,H,Dv]."""
    kv = k[..., :, None] * v[..., None, :]
    o = jnp.einsum("bhkv,bhk->bhv", S + u[None, :, :, None] * kv, r)
    S = w[..., :, None] * S + kv
    return S, o


def mamba(x, dt, A, B, C):
    """Selective SSM (differentiable lax.scan path)."""
    return _ref.mamba_ref(x, dt, A, B, C)


def mamba_decode_step(h, x, dt, A, B, C):
    """h [B,D,N]; x,dt [B,D]; B,C [B,N] -> (h', y [B,D])."""
    decay = jnp.exp(dt[..., None] * A[None])              # [B,D,N]
    h = decay * h + (dt * x)[..., None] * B[:, None, :]
    return h, jnp.einsum("bdn,bn->bd", h, C)


# --------------------------------------------------------------- quantized
def bias_act_epilogue(y, bias=None, activation=None):
    """The fused kernels' epilogue, replayed in XLA for the ref paths."""
    from repro.kernels.util import apply_activation
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return apply_activation(activation, y)


def lut_matmul(x, codes_packed, centroids, bias=None, activation=None, **kw):
    """Codebook4 FC: dispatches to the Pallas LUT kernel or the XLA ref per
    the autotuned winner for this (shape, batch, backend)."""
    interp = kw.setdefault("interpret", pallas_interpret())
    choice = _tune.get(_tune.lut_key(codes_packed.shape[0],
                                     codes_packed.shape[1] * 2,
                                     x.shape[0], interp))
    if choice is not None and choice.impl == "xla":
        return bias_act_epilogue(
            _ref.lut_matmul_ref(x, codes_packed, centroids),
            bias, activation)
    if choice is not None:
        for t in ("bm", "bn", "bk"):
            if choice.tile(t):
                kw.setdefault(t, choice.tile(t))
    return _lm.lut_matmul(x, codes_packed, centroids, bias=bias,
                          activation=activation, **kw)


def lut_product_matmul(x_codes, codes_packed, lut, **kw):
    kw.setdefault("interpret", pallas_interpret())
    return _lm.lut_product_matmul(x_codes, codes_packed, lut, **kw)


def int8_matmul(x, qt, bias=None, activation=None, **kw):
    """Int8 FC: Pallas kernel with the per-channel dequant folded into the
    epilogue, or the XLA reference when the tuner measured it faster."""
    interp = kw.setdefault("interpret", pallas_interpret())
    choice = _tune.get(_tune.int8_key(qt.q.shape[0], qt.q.shape[1],
                                      x.shape[0], interp))
    if choice is not None and choice.impl == "xla":
        from repro.core import quant as _q
        return bias_act_epilogue(_q.int8_matmul_ref(x, qt), bias,
                                 activation)
    if choice is not None:
        for t in ("bm", "bn", "bk"):
            if choice.tile(t):
                kw.setdefault(t, choice.tile(t))
    return _i8.int8_matmul(x, qt.q, qt.scale, bias=bias,
                           activation=activation, **kw)


def acsr_spmv(blocked, x, bias=None, activation=None, **kw):
    """ACSR / AIDA fused pipeline; (mb, bk) come from the autotuner cache
    when a winner was recorded for this geometry."""
    interp = kw.setdefault("interpret", pallas_interpret())
    choice = _tune.get(_tune.acsr_key(
        blocked.nblocks, blocked.rmax, blocked.block_rows, x.shape[0],
        x.shape[1] if x.ndim == 2 else 1,
        blocked.centroids is not None, interp))
    if choice is not None:
        for t in ("mb", "bk"):
            if choice.tile(t):
                kw.setdefault(t, choice.tile(t))
    return _sp.acsr_spmv(blocked, x, bias=bias, activation=activation, **kw)
