"""AdamW + global-norm clipping + warmup-cosine schedule (pure JAX).

Optimizer state shards exactly like the parameters (m/v inherit the param
PartitionSpecs), which is what makes the FSDP/ZeRO layout work: per chip,
state is (params + m + v) / (data × model).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init(params) -> OptState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.zeros_like, params))


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply(cfg: AdamWConfig, params, opt: OptState,
          grads) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    step = opt.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh, vh = m / b1c, v / b2c
        p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        return p, m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt.m)
    flat_v = tdef.flatten_up_to(opt.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, m=new_m, v=new_v), \
        {"grad_norm": gn, "lr": lr}


def opt_state_specs(param_specs) -> OptState:
    from jax.sharding import PartitionSpec as P
    return OptState(step=P(), m=param_specs,
                    v=jax.tree.map(lambda s: s, param_specs,
                                   is_leaf=lambda x: isinstance(x, P)))
