"""`Engine` — THE entry point to compress, load, run and benchmark a model.

One object, four backends::

    from repro.api import Engine, Request, CompressionSpec

    eng = Engine("llama3-8b-smoke-cfg-or-ArchConfig")      # random init
    eng.compress(CompressionSpec(mode="aida", density=0.25))
    results = eng.serve([Request(prompt=[1, 2, 3], max_new=8)])
    est = eng.estimate(backend="cycle-sim", workload="alexnet-fc")

`compress()` returns the engine for chaining; serving goes through a
continuous-batching `Session` compiled by the active backend; `estimate()`
routes to any cycle-accounting backend (`ap-emulator`, `cycle-sim`).
"""
from __future__ import annotations

import time
from typing import List, Optional, Sequence, Union

import numpy as np

from repro.api import compress as compress_mod
from repro.api.registry import CapabilityError, Executor, get_backend
from repro.api.session import Request, Result, Session
from repro.api.spec import CompressionSpec, FCProblem
from repro.configs.base import ArchConfig


def _spec_modes(spec: CompressionSpec) -> set:
    """Modes a spec actually executes ('skip' leaves leaves dense/raw)."""
    return {spec.mode} | {m for m in spec.overrides.values() if m != "skip"}


class Engine:
    def __init__(self, cfg: Union[ArchConfig, str, None] = None,
                 params=None, *, backend: Optional[str] = None,
                 seed: int = 0):
        if isinstance(cfg, str):
            from repro.configs import get
            cfg = get(cfg)
        self.cfg = cfg
        self._params = params
        self._backend_name = backend
        self._seed = seed
        self.compression: Optional[CompressionSpec] = None
        self.stats: Optional[dict] = None

    # -------------------------------------------------------------- state
    @property
    def params(self):
        """Model params (random-initialized on first access if not given)."""
        if self._params is None:
            if self.cfg is None:
                raise ValueError("Engine has no cfg; pass params explicitly "
                                 "or construct with an ArchConfig")
            import jax
            from repro.models import model as M
            self._params = M.init_params(self.cfg,
                                         jax.random.PRNGKey(self._seed))
        return self._params

    @property
    def backend(self) -> Executor:
        """Active decode backend: explicit choice, else 'pallas' once
        compressed to a non-dense mode, else 'jax-dense'."""
        if self._backend_name:
            return get_backend(self._backend_name)
        if self.compression is not None \
                and _spec_modes(self.compression) - {"dense"}:
            return get_backend("pallas")
        return get_backend("jax-dense")

    # ---------------------------------------------------------- compress
    def compress(self, spec: Union[CompressionSpec, str, None] = None,
                 *, verbose=None, **kw) -> "Engine":
        """Deep-Compression of every eligible projection (prune -> share ->
        pack) per `spec`; keyword shortcuts (mode=, density=, k=) also work.
        Returns self for chaining; stats land in `self.stats`."""
        spec = CompressionSpec.coerce(spec)
        if kw:
            import dataclasses
            spec = dataclasses.replace(spec, **kw)
        if self._backend_name:  # explicit pin: the backend must run the modes
            caps = self.backend.caps
            wanted = _spec_modes(spec)
            if len(wanted) > 1 and not caps.per_layer_override:
                raise CapabilityError(
                    f"backend {self._backend_name!r} does not support "
                    "per-layer mode overrides")
            missing = wanted - set(caps.modes)
            if missing:
                raise CapabilityError(
                    f"backend {self._backend_name!r} cannot execute modes "
                    f"{sorted(missing)}; its modes are {caps.modes} "
                    "(drop the explicit backend= pin to auto-route)")
        self._params, self.stats = compress_mod.compress_params(
            self.params, spec, verbose=verbose)
        self.compression = spec
        return self

    # ------------------------------------------------------------- serve
    def session(self, batch_slots: int = 4, max_len: int = 256,
                seed: int = 0) -> Session:
        """A continuous-batching serving session on the active backend.

        On the Pallas backend, every unique compressed-FC geometry is
        autotuned for this batch width *before* the decode step compiles,
        so the jitted step traces against the winning tiles
        (kernels.tune; disable with REPRO_AUTOTUNE=0)."""
        if self.cfg is None:
            raise ValueError("serving needs an ArchConfig")
        backend = self.backend
        if not backend.caps.batched_decode:
            raise CapabilityError(
                f"backend {backend.name!r} cannot serve (no batched decode)")
        if backend.name == "pallas" and self.compression is not None:
            from repro.kernels import ops, tune
            if tune.enabled():
                tune.tune_params(self.params, batch_slots,
                                 ops.pallas_interpret())
        return Session(self.cfg, self.params, batch_slots=batch_slots,
                       max_len=max_len, seed=seed, backend=backend)

    def serve(self, requests: Sequence[Union[Request, List[int]]],
              *, batch_slots: int = 4, max_len: int = 256,
              max_steps: int = 10_000, seed: int = 0) -> List[Result]:
        """Serve a batch of requests to completion (continuous batching).
        Results come back in deterministic rid order."""
        sess = self.session(batch_slots=batch_slots, max_len=max_len,
                            seed=seed)
        for rid, req in enumerate(requests):
            if not isinstance(req, Request):
                req = Request(prompt=list(req), rid=rid)
            sess.submit(req)
        return sess.run(max_steps=max_steps)

    # ---------------------------------------------------------- estimate
    def estimate(self, backend: str = "cycle-sim",
                 workload: Union[FCProblem, str, Sequence, None] = None,
                 **kw) -> dict:
        """Cycle/perf accounting through a cost-model backend.

        `workload`: an FCProblem (concrete FC instance; 'ap-emulator'
        measures it bit-level, 'cycle-sim' prices it closed-form — the two
        agree exactly under the EMULATOR microcode), or a named network
        ('alexnet-fc', 'ctc-lstm', 'table1') for 'cycle-sim'.
        """
        ex = get_backend(backend)
        if not ex.caps.cycle_accounting:
            raise CapabilityError(
                f"backend {backend!r} has no cycle accounting")
        if workload is None:
            workload = "alexnet-fc"
        return ex.estimate(workload, **kw)

    # --------------------------------------------------------- benchmark
    def benchmark(self, modes: Sequence[str] = ("dense", "aida"),
                  requests: int = 4, max_new: int = 8,
                  batch_slots: int = 2, density: float = 0.25,
                  problem: Optional[FCProblem] = None) -> dict:
        """Serve each mode through the facade and price the cost-model
        backends on one FC instance; returns a JSON-ready dict
        (benchmarks/run.py writes it to BENCH_api.json)."""
        from repro.kernels import tune
        out = {"backends": {}, "modes": {}}
        reqs = [Request(prompt=[1, 2 + i % 7, 3], max_new=max_new, rid=i)
                for i in range(requests)]
        # entries already in the process-global cache were tuned by earlier
        # sessions, not by this benchmark — attribute only new winners
        seen_tiles = set(tune.snapshot())
        for mode in modes:
            eng = Engine(self.cfg, params=self.params)
            if mode != "dense":
                eng.compress(CompressionSpec(mode=mode, density=density))
            sess = eng.session(batch_slots=batch_slots,
                               max_len=max_new + 8)
            sess.submit(Request(prompt=[1], max_new=1, rid=-1))
            sess.run()  # warm the compiled step
            sess.results.clear()
            for r in reqs:
                sess.submit(r)
            t0 = time.perf_counter()
            res = sess.run()
            dt = time.perf_counter() - t0
            n_tok = sum(len(r.tokens) for r in res)
            # tiles the autotuner picked for this mode's layer shapes —
            # recorded so the perf trajectory is reproducible
            snap = tune.snapshot()
            tiles = {k: v for k, v in snap.items() if k not in seen_tiles}
            seen_tiles.update(snap)
            out["modes"][mode] = {
                "backend": eng.backend.name,
                "tokens": n_tok, "seconds": round(dt, 4),
                "tok_per_s": round(n_tok / dt, 2),
                "tiles": tiles,
                "compression_ratio": (round(eng.stats["ratio"], 2)
                                      if eng.stats else 1.0)}
        if problem is None:
            rng = np.random.default_rng(0)
            w = rng.integers(-15, 16, size=(24, 32)) \
                * (rng.random((24, 32)) < 0.3)
            b = rng.integers(-15, 16, size=(32,)) * (rng.random(32) < 0.6)
            problem = FCProblem(w=w, b=b, m=4, n=4)
        emu = self.estimate(backend="ap-emulator", workload=problem)
        sim = self.estimate(backend="cycle-sim", workload=problem)
        alex = self.estimate(backend="cycle-sim", workload="alexnet-fc")
        eie = self.estimate(backend="cycle-sim", workload="alexnet-fc",
                            simulator="eie")
        out["backends"]["ap-emulator"] = {
            "fc_cycles": int(emu["cycles"]), "exact": emu["exact"]}
        out["backends"]["cycle-sim"] = {
            "fc_cycles": int(sim["cycles"]),
            "agrees_with_emulator": int(sim["cycles"]) == int(emu["cycles"]),
            "alexnet_fc_cycles": int(alex["cycles"]),
            "alexnet_fc_inf_per_s": round(alex["inf_per_s"], 1),
            "eie_alexnet_fc_cycles": int(eie["cycles"]),
            "eie_alexnet_fc_inf_per_s": round(eie["inf_per_s"], 1)}
        return out
