"""Multi-device semantics tests.  These spawn subprocesses that set
--xla_force_host_platform_device_count (the main test process must keep 1
device, per the dry-run isolation rule)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get, reduced
from repro.data.pipeline import PipelineConfig, make_batch
from repro.models import model as M
from repro.train import trainer

cfg = reduced(get("llama3-8b"), n_layers=2, d_model=64, d_ff=128, vocab=256)
mesh = jax.make_mesh((2, 4), ("data", "model"))
mdict = dict(zip(mesh.axis_names, mesh.devices.shape))

batch_np = make_batch(cfg, PipelineConfig(seed=0, global_batch=4, seq_len=32), 0)
batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
state = trainer.init_state(cfg, jax.random.PRNGKey(0))

# single-device result
tc = trainer.TrainConfig(remat="none")
s1, m1 = jax.jit(trainer.make_train_step(cfg, tc))(state, batch)

# sharded result on the 2x4 mesh
with mesh:
    sspecs = trainer.state_specs(cfg, mdict)
    named = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                         is_leaf=lambda x: isinstance(x, P))
    state_sh = jax.tree.map(lambda x, s: jax.device_put(x, s), state, named)
    bspec = {k: NamedSharding(mesh, P("data", *([None] * (v.ndim - 1))))
             for k, v in batch.items()}
    batch_sh = {k: jax.device_put(v, bspec[k]) for k, v in batch.items()}
    step = jax.jit(trainer.make_train_step(cfg, tc, dp_spec=("data",)),
                   in_shardings=(named, bspec))
    s2, m2 = step(state_sh, batch_sh)

d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                 s1.params, s2.params)
print(json.dumps({
    "loss1": float(m1["loss"]), "loss2": float(m2["loss"]),
    "max_param_diff": max(jax.tree.leaves(d)),
    "n_devices": jax.device_count(),
}))
"""

DECODE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get, reduced
from repro.models import model as M

cfg = reduced(get("llama3-8b"), n_layers=2, d_model=64, d_ff=128, vocab=256)
mesh = jax.make_mesh((2, 4), ("data", "model"))
params = M.init_params(cfg, jax.random.PRNGKey(0))
B, S = 4, 16
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

# single-device decode
st = M.init_decode_state(cfg, B, S)
outs = []
for t in range(S):
    st, lg = M.decode_step(cfg, params, st, toks[:, t])
    outs.append(lg)
ref = jnp.stack(outs, 1)

# sharded decode: KV cache sequence-sharded over the model axis
with mesh:
    sspecs = M.state_specs(cfg, B, dp_ok=True, dpax=("data",))
    named_st = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                            is_leaf=lambda x: isinstance(x, P))
    pspecs = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          M.param_specs(cfg, dict(data=2, model=4)),
                          is_leaf=lambda x: isinstance(x, P))
    params_sh = jax.tree.map(jax.device_put, params, pspecs)
    st2 = jax.tree.map(jax.device_put, M.init_decode_state(cfg, B, S),
                       named_st)
    step = jax.jit(lambda p, s, t: M.decode_step(cfg, p, s, t),
                   in_shardings=(pspecs, named_st,
                                 NamedSharding(mesh, P("data"))))
    outs2 = []
    for t in range(S):
        st2, lg = step(params_sh, st2,
                       jax.device_put(toks[:, t],
                                      NamedSharding(mesh, P("data"))))
        outs2.append(lg)
got = jnp.stack(outs2, 1)
print(json.dumps({
    "max_diff": float(jnp.max(jnp.abs(got - ref))),
    "scale": float(jnp.max(jnp.abs(ref))),
}))
"""


def run_sub(script):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_train_step_matches_single_device():
    r = run_sub(SCRIPT)
    assert r["n_devices"] == 8
    assert abs(r["loss1"] - r["loss2"]) < 5e-3
    assert r["max_param_diff"] < 5e-3


def test_seq_sharded_decode_matches_single_device():
    r = run_sub(DECODE_SCRIPT)
    assert r["max_diff"] / (r["scale"] + 1e-9) < 0.02
