"""End-to-end AIDA serving driver (the paper's use case: FC-layer inference).

Pipeline: train a small model briefly → Deep-Compression (prune + 16-entry
weight sharing, paper §3 / EIE) every projection → serve batched requests
through the compressed decode path (Pallas ACSR/LUT kernels) → report
compression ratio, logit fidelity and agreement vs the dense model.
Everything runs through the `repro.api.Engine` facade.

  PYTHONPATH=src python examples/serve_aida.py [--mode aida|codebook4|int8]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import CompressionSpec, Engine, Request
from repro.configs import get, reduced
from repro.data.pipeline import DataIterator, PipelineConfig
from repro.models import model as M
from repro.optim.adamw import AdamWConfig
from repro.train import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="aida",
                    choices=["int8", "codebook4", "acsr", "aida"])
    ap.add_argument("--density", type=float, default=0.25)
    ap.add_argument("--train-steps", type=int, default=30)
    args = ap.parse_args()

    cfg = reduced(get("llama3-8b"), n_layers=2, d_model=128, d_ff=256,
                  vocab=512)
    print(f"== train a {cfg.params_count()/1e6:.1f}M model "
          f"({args.train_steps} steps) ==")
    tc = trainer.TrainConfig(remat="none",
                             opt=AdamWConfig(lr=3e-3, warmup_steps=5,
                                             total_steps=args.train_steps))
    data = DataIterator(cfg, PipelineConfig(seed=0, global_batch=8,
                                            seq_len=64))
    state = trainer.run(cfg, tc, data, n_steps=args.train_steps,
                        key=jax.random.PRNGKey(0), log_every=10)

    print(f"\n== Deep-Compression -> {args.mode} "
          f"(density {args.density}) ==")
    eng = Engine(cfg, params=state.params).compress(
        CompressionSpec(mode=args.mode, density=args.density))
    print(f"  projections compressed: {eng.stats['n_compressed']}  "
          f"weight-memory ratio vs bf16: {eng.stats['ratio']:.1f}x  "
          f"(backend: {eng.backend.name})")

    print("\n== fidelity: compressed vs dense decode ==")
    B, S = 4, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    std = M.init_decode_state(cfg, B, S + 1)
    stc = M.init_decode_state(cfg, B, S + 1)
    agree, err = [], []
    for t in range(S):
        std, ld = M.decode_step(cfg, state.params, std, toks[:, t])
        stc, lc = M.decode_step(cfg, eng.params, stc, toks[:, t])
        agree.append(float((ld.argmax(-1) == lc.argmax(-1)).mean()))
        err.append(float(jnp.mean(jnp.abs(ld - lc))))
    print(f"  next-token argmax agreement: {np.mean(agree):.1%}  "
          f"mean |logit delta|: {np.mean(err):.4f}")

    print("\n== batched serving on the compressed model ==")
    reqs = [Request(prompt=[1, 2 + rid, 3, 4], max_new=8, rid=rid)
            for rid in range(8)]
    t0 = time.perf_counter()
    results = eng.serve(reqs, batch_slots=4, max_len=64)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.tokens) for r in results) + 8 * 4
    print(f"  served {len(results)} requests, "
          f"{n_tok/dt:.1f} tok/s (host CPU, interpret-mode kernels)")
    for r in results[:3]:
        print(f"  req {r.rid}: {r.tokens}")


if __name__ == "__main__":
    main()
