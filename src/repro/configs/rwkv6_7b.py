"""RWKV6-7B (Finch) — attention-free, data-dependent decay.
[arXiv:2404.05892]"""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    name="rwkv6-7b", family="rwkv6",
    n_layers=32, d_model=4096, n_heads=64, n_kv=64, d_ff=14336,
    vocab=65536, d_head=64, rwkv_head_dim=64, rope_theta=None,
    tie_embeddings=False, source="arXiv:2404.05892"))
