"""Chunked prefill: C prompt tokens per model call, written straight into
KV pages.

The decode step moves one token per slot per call, so a P-token prompt
costs P model calls before the first generated token.  This step embeds a
[B, C] token block, runs the layer stack ONCE over all C positions, and
writes each position's K/V into the page pool through the shared page
table — first-token latency drops from P calls to ceil(P/C).

Mixed prefill+decode batches fall out of the per-slot ``n_tok`` vector:
a prefilling slot carries up to C prompt tokens, a decoding slot carries
1 (its next token, sampled host-side from the previous step's logits),
an idle slot carries 0 — padding positions are redirected to the garbage
page by ``update(valid=...)`` and their logits ignored, so one fixed
[B, C] shape serves every step and the step jits once per (cfg, C).

Within-chunk causality needs no extra machinery: all C tokens' K/V are
written (in ONE vectorized scatter, `kvstore.update_chunk` — same
two-speed int8 semantics as decode, at chunk granularity) *before* the
chunk attends, and the page-table index IS the absolute position, so the
multi-query chunk mask sees in-chunk keys exactly like history.  The
attention itself dispatches through `kvstore.paged_attention_chunk`
(tuned Pallas chunk kernel or the XLA gather reference), shard-local
over the head axis when a ShardingPlan is active.

Scope: paged KV only (that is the point — prefill writes land in pages),
and architectures without per-token recurrent state (rwkv6/hymba step
their SSM state one token at a time; the Session falls back to
token-by-token prefill there, see `supports_chunked_prefill`).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import kvstore as kvs
from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.layers import COMPUTE_DTYPE, embed, mlp, softcap, unembed
from repro.models.transformer import _norm


def supports_chunked_prefill(cfg: ArchConfig) -> bool:
    """Chunked prefill needs attention-only token mixing: families with a
    per-token recurrent state (rwkv6 time-mix, hymba's mamba branch)
    would have to scan the chunk token-by-token anyway."""
    return cfg.has_decode and cfg.family not in ("rwkv6", "hymba")


def _block_prefill(cfg: ArchConfig, p: Dict, st: Dict, x, positions,
                   valid, window, table, plan=None):
    """One layer over a [B, C, D] chunk: write C tokens' K/V into pages,
    then attend all C queries over the (now-updated) page table."""
    nrm = _norm(cfg)
    scale = (cfg.head_dim ** -0.5) if cfg.attn_scale is None \
        else cfg.attn_scale
    q, k, v = attn._qkv(p["attn"], nrm(x, p["ln1"]), cfg.n_heads,
                        cfg.n_kv, cfg.head_dim, positions, cfg.rope_theta,
                        plan=plan)
    pool = st["kv"]
    pool = kvs.update_chunk(pool, table,
                            k.astype(jnp.float32), v.astype(jnp.float32),
                            positions, valid=valid)
    if plan is not None and plan.tp > 1:
        from repro.shard import paged_attention_chunk_sharded
        o = paged_attention_chunk_sharded(
            plan, q, pool, table, positions,
            jnp.asarray(window, jnp.int32),
            scale=scale, cap=cfg.attn_softcap)
    else:
        o = kvs.paged_attention_chunk(q, pool, table, positions,
                                      jnp.asarray(window, jnp.int32),
                                      scale=scale, cap=cfg.attn_softcap)
    h = attn.dense(attn._merge_heads(o.astype(COMPUTE_DTYPE)),
                   p["attn"]["wo"], plan=plan)
    new_st = dict(st)
    new_st["kv"] = pool
    if cfg.post_norms:
        h = nrm(h, p["ln1p"])
    x = x + h
    if cfg.moe:
        h, _ = moe_mod.moe_apply(
            p["moe"], nrm(x, p["ln2"]), n_experts=cfg.moe.n_experts,
            top_k=cfg.moe.top_k, group_size=cfg.moe.group_size,
            capacity_factor=cfg.moe.capacity_factor)
    else:
        h = mlp(nrm(x, p["ln2"]), p["mlp"], cfg.act, plan=plan)
    if cfg.post_norms:
        h = nrm(h, p["ln2p"])
    return new_st, x + h


def _stack_prefill(cfg: ArchConfig, stacked: Dict, states, x, positions,
                   valid, table, plan=None):
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)

    def body(xc, inp):
        p, st, win = inp
        new_st, xo = _block_prefill(cfg, p, st, xc, positions, valid, win,
                                    table, plan=plan)
        return xo, new_st

    x, new_states = jax.lax.scan(body, x, (stacked, states, windows))
    return new_states, x


def prefill_step(cfg: ArchConfig, params: Dict, state: Dict,
                 tokens: jnp.ndarray, n_tok: jnp.ndarray,
                 plan=None) -> Tuple[Dict, jnp.ndarray]:
    """tokens [B, C], n_tok [B] (0 = idle slot) -> (state', logits
    [B, C, Vpad]).  Slot i's tokens occupy absolute positions
    ``state["pos"][i] .. +n_tok[i]-1``; the caller ensures those
    positions' pages exist in the table and samples from
    ``logits[i, n_tok[i]-1]``.  ``plan`` = serving ShardingPlan (the
    chunk step stays token-identical under it — see tests/test_shard)."""
    if not supports_chunked_prefill(cfg):
        raise ValueError(f"{cfg.name} ({cfg.family}) has per-token "
                         "recurrent state; chunked prefill unsupported")
    table = state.get("page_table")
    if table is None:
        raise ValueError("chunked prefill writes into KV pages; "
                         "state has no page_table (kv_cache='paged' only)")
    b, c = tokens.shape
    offs = jnp.arange(c, dtype=jnp.int32)
    positions = state["pos"][:, None] + offs[None, :]        # [B, C]
    valid = offs[None, :] < n_tok[:, None]                   # [B, C]
    x = embed(tokens, params["embed"])
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, COMPUTE_DTYPE)
    new_layers, x = _stack_prefill(cfg, params["layers"], state["layers"],
                                   x, positions, valid, table, plan=plan)
    x = _norm(cfg)(x, params["final_norm"])
    if cfg.tie_embeddings:
        logits = unembed(x, params["embed"])
    else:
        logits = jnp.matmul(x, params["lm_head"].astype(COMPUTE_DTYPE),
                            preferred_element_type=jnp.float32)
    logits = softcap(logits, cfg.final_softcap)
    new_state = {"layers": new_layers, "pos": state["pos"] + n_tok,
                 "page_table": table}
    return new_state, logits


# Compiled chunk steps keyed by (cfg, C): the step is backend-agnostic
# (compressed FC leaves route through repro.api.dispatch inside dense()),
# so sessions on the same geometry share one jitted step per chunk width.
_PREFILL_CACHE: dict = {}


def make_prefill_step(cfg: ArchConfig, chunk: int, plan=None,
                      in_shardings=None, out_shardings=None):
    """The jitted [B, chunk] prefill step for ``cfg``.

    The decode state (argnum 1) is DONATED — same contract as the
    Session's decode step, so the (possibly sharded) KV pool buffers are
    reused in place instead of silently copied every chunk.  Callers
    must treat the state they pass in as consumed.

    plan=None steps are cached per (cfg, chunk); mesh steps compile per
    session because their in/out shardings depend on the session's
    concrete param/state trees."""
    if plan is not None:
        return jax.jit(
            lambda params, state, tokens, n_tok:
            prefill_step(cfg, params, state, tokens, n_tok, plan=plan),
            in_shardings=in_shardings, out_shardings=out_shardings,
            donate_argnums=(1,))
    key = (cfg, chunk)
    if key not in _PREFILL_CACHE:
        _PREFILL_CACHE[key] = jax.jit(
            lambda params, state, tokens, n_tok:
            prefill_step(cfg, params, state, tokens, n_tok),
            donate_argnums=(1,))
    return _PREFILL_CACHE[key]
