"""`repro.sched` — the continuous-batching serving scheduler subsystem.

The layer between requests and the decode step: a policy object decides
*which* requests occupy batch slots (`scheduler` — FIFO or
shortest-prompt-first, with page-pool admission control and
youngest-first preemption instead of `OutOfPages` crashes), a jitted
chunked-prefill step gets prompts into KV pages C tokens per model call
instead of one (`prefill`), a content-addressed page cache prefills
shared prompt prefixes once (`prefix`, built on `PageAllocator`
refcounts), and `workload` + `metrics` make heterogeneous serving
reproducible and measurable (TTFT / TPOT / p50-p99 / goodput — the
`"serving"` section of BENCH_api.json).

`repro.api.Session` drives all of it; this package holds the policy and
the kernels, the Session holds the device state.
"""
from repro.sched.metrics import percentile, summarize
from repro.sched.prefill import (make_prefill_step, prefill_step,
                                 supports_chunked_prefill)
from repro.sched.prefix import PrefixCache, page_hashes
from repro.sched.scheduler import SchedConfig, Scheduler, SchedEntry
from repro.sched.workload import WorkloadSpec, generate, timed_requests

__all__ = [
    "PrefixCache", "SchedConfig", "SchedEntry", "Scheduler",
    "WorkloadSpec", "generate", "make_prefill_step", "page_hashes",
    "percentile", "prefill_step", "summarize",
    "supports_chunked_prefill", "timed_requests",
]
