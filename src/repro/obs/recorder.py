"""Bounded flight recorder: the last-N trace events, dumped on failure.

A chaos sweep that dies with ``HealthError`` / ``OutOfPages`` / a
``RequestFailed`` used to leave nothing but the exception text; the
flight recorder keeps a ring of the most recent events (every event the
tracer emits passes through it) and writes them to disk with the
failure context, so the ticks *leading up to* the failure are
post-mortem-debuggable.
"""
from __future__ import annotations

import collections
import json
import os
from typing import Optional


class FlightRecorder:
    """Ring buffer of recent trace events with automatic crash dumps.

    ``capacity`` bounds memory; ``out_dir`` is where :meth:`dump`
    writes ``flight_<seq>_<reason>.json`` files (created lazily).
    """

    def __init__(self, capacity: int = 256, out_dir: str = "."):
        self.capacity = int(capacity)
        self.out_dir = out_dir
        self.ring = collections.deque(maxlen=self.capacity)
        self.total = 0          # events ever seen (ring keeps the tail)
        self.dumps = []         # paths written so far
        self._seq = 0

    def record(self, ev: dict) -> None:
        self.ring.append(ev)
        self.total += 1

    def dump(self, reason: str, context: Optional[dict] = None) -> str:
        """Write the current ring + failure context; returns the path.

        Never overwrites: two recorders sharing an ``out_dir`` (e.g. the
        prefill and decode roles of a disaggregated serve dying on the
        same tick) each keep their own ``_seq``, so the sequence number
        alone cannot dedupe — advance past any path already on disk.
        """
        os.makedirs(self.out_dir, exist_ok=True)
        safe = "".join(c if c.isalnum() or c in "-_" else "_"
                       for c in reason)[:48]
        while True:
            path = os.path.join(self.out_dir,
                                f"flight_{self._seq:03d}_{safe}.json")
            self._seq += 1
            if not os.path.exists(path):
                break
        payload = {
            "reason": reason,
            "context": context or {},
            "capacity": self.capacity,
            "events_total": self.total,
            "events": list(self.ring),
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        self.dumps.append(path)
        return path
