"""Unified `repro.api` facade: backend registry, capability routing, the
five FC modes through one interface, and the emulator/cycle-sim cycle-count
agreement (the invariant test_aida_fc.py asserts at module level, here
driven purely through the facade — no hypothesis dependency)."""
import math

import jax
import numpy as np
import pytest

from repro.api import (CapabilityError, CompressionSpec, Engine, FCProblem,
                       MODES, backend_names, get_backend)
from repro.configs import get, reduced
from repro.core import sparse_fc as sfc

CFG = reduced(get("llama3-8b"), n_layers=2, d_model=64, d_ff=128, vocab=256)


# ------------------------------------------------------------- registry
def test_registry_names_and_caps():
    names = backend_names()
    for required in ("jax-dense", "pallas", "ap-emulator", "cycle-sim"):
        assert required in names
    assert get_backend("pallas").caps.batched_decode
    assert get_backend("pallas").caps.per_layer_override
    assert set(get_backend("pallas").caps.modes) == set(MODES)
    assert get_backend("ap-emulator").caps.cycle_accounting
    assert get_backend("cycle-sim").caps.cycle_accounting
    assert not get_backend("cycle-sim").caps.batched_decode


def test_registry_unknown_backend():
    with pytest.raises(KeyError, match="unknown backend"):
        get_backend("tpu-v9")


def test_capability_errors():
    with pytest.raises(CapabilityError):
        get_backend("cycle-sim").make_decode_step(CFG)
    with pytest.raises(CapabilityError):
        Engine().estimate(backend="jax-dense", workload="alexnet-fc")
    with pytest.raises(CapabilityError):
        # ap-emulator only takes concrete FCProblem workloads
        Engine().estimate(backend="ap-emulator", workload="alexnet-fc")
    with pytest.raises(CapabilityError, match="cannot execute modes"):
        # a pinned dense backend must refuse compressed modes, not
        # silently serve them through the Pallas kernels
        Engine(CFG, backend="jax-dense").compress("aida")
    with pytest.raises(CapabilityError, match="FCProblem"):
        # the EIE model has no bit-level FCProblem pricing
        Engine().estimate(backend="cycle-sim", simulator="eie",
                          workload=FCProblem(w=np.eye(4, dtype=np.int64),
                                             b=np.ones(4, np.int64)))


# ----------------------------------------------------------------- spec
def test_compression_spec_coerce_and_overrides():
    assert CompressionSpec.coerce(None).mode == "aida"
    assert CompressionSpec.coerce("int8").mode == "int8"
    spec = CompressionSpec(mode="aida", overrides={"wo": "int8",
                                                   "up": "skip"})
    assert spec.mode_for("layers/attn/wo") == "int8"
    assert spec.mode_for("layers/mlp/up") == "skip"
    assert spec.mode_for("layers/attn/wq") == "aida"
    with pytest.raises(ValueError, match="unknown mode"):
        CompressionSpec(mode="fp4")
    with pytest.raises(ValueError, match="unknown mode"):
        CompressionSpec(overrides={"wo": "fp4"})


# ------------------------------------------- five modes, one interface
def test_pallas_backend_runs_all_five_modes(rng):
    """Every FC operating point runs through the same Executor surface and
    approximates the dense product."""
    w = rng.normal(size=(32, 64)).astype(np.float32)
    x = rng.normal(size=(4, 64)).astype(np.float32)
    ref = x @ w.T
    pallas = get_backend("pallas")
    for mode in MODES:
        layer = sfc.compress(w, mode=mode, density=0.5, k=16)
        y = np.asarray(pallas.run_fc(layer, x))
        assert y.shape == ref.shape, mode
        assert np.isfinite(y).all(), mode
        # the dense-equivalent weights are what the kernel must compute
        weq = sfc.dense_equivalent(layer)
        np.testing.assert_allclose(y, x @ weq.T, rtol=2e-2, atol=2e-2,
                                   err_msg=mode)


def test_jax_dense_backend_rejects_compressed(rng):
    w = rng.normal(size=(16, 32)).astype(np.float32)
    layer = sfc.compress(w, mode="int8")
    with pytest.raises(CapabilityError, match="pallas"):
        get_backend("jax-dense").run_fc(layer, np.zeros((2, 32), np.float32))


def test_engine_compress_per_layer_override():
    eng = Engine(CFG)
    eng.compress(CompressionSpec(mode="aida", density=0.3,
                                 overrides={"wo": "int8", "down": "skip"}))
    layers = eng.params["layers"]
    assert layers["attn"]["wo"].mode == "int8"
    assert layers["attn"]["wq"].mode == "aida"
    assert isinstance(layers["mlp"]["down"], jax.Array)  # skipped -> raw
    assert eng.stats["modes"]["int8"] == CFG.n_layers
    assert eng.backend.name == "pallas"


def test_backend_routing_follows_override_modes():
    # dense base mode + a compressed override still routes to pallas
    eng = Engine(CFG).compress(CompressionSpec(
        mode="dense", density=0.3, overrides={"wo": "int8"}))
    assert eng.backend.name == "pallas"
    # skip-only overrides execute nothing extra: pinned dense backend is OK
    eng2 = Engine(CFG, backend="jax-dense").compress(CompressionSpec(
        mode="dense", overrides={"down": "skip"}))
    assert eng2.backend.name == "jax-dense"
    assert isinstance(eng2.params["layers"]["mlp"]["down"], jax.Array)


@pytest.mark.parametrize("mode", MODES)
def test_engine_serves_every_mode(mode):
    """Engine(cfg).compress(spec).serve(requests) works at all five
    operating points — the facade's core contract."""
    from repro.api import Request
    eng = Engine(CFG)
    if mode != "dense":
        eng.compress(CompressionSpec(mode=mode, density=0.3))
        assert eng.stats["n_compressed"] > 0
    res = eng.serve([Request(prompt=[1, 2, 3], max_new=3, rid=0)],
                    batch_slots=1, max_len=16)
    assert len(res) == 1 and len(res[0].tokens) == 3
    assert all(0 <= t < CFG.vocab for t in res[0].tokens)


# ------------------------------------- emulator == cycle-sim agreement
def test_estimate_agreement_bitserial():
    """`ap-emulator` (measured) and `cycle-sim` (closed form, EMULATOR
    microcode) agree on FC cycle counts bit-for-bit, via the facade."""
    eng = Engine()
    rng = np.random.default_rng(7)
    for _ in range(3):
        n, k = rng.integers(3, 14), rng.integers(3, 14)
        w = rng.integers(-15, 16, size=(n, k)) * (rng.random((n, k)) < 0.5)
        b = rng.integers(-15, 16, size=(k,)) * (rng.random(k) < 0.7)
        prob = FCProblem(w=w, b=b, m=4, n=4)
        emu = eng.estimate(backend="ap-emulator", workload=prob)
        sim = eng.estimate(backend="cycle-sim", workload=prob)
        assert emu["exact"], "emulator must match the integer oracle"
        assert emu["cycles"] == sim["cycles"]
        assert emu["nnz_b"] == sim["nnz_b"] == prob.nnz_b
        assert emu["max_row_nnz"] == sim["max_row_nnz"]


def test_estimate_agreement_coded():
    eng = Engine()
    rng = np.random.default_rng(8)
    cents_w = np.concatenate([[0], rng.integers(-99, 100, 15)])
    cents_a = np.concatenate([[0], rng.integers(-99, 100, 15)])
    for _ in range(2):
        n, k = rng.integers(4, 12), rng.integers(4, 12)
        wc = rng.integers(0, 16, size=(n, k)) * (rng.random((n, k)) < 0.4)
        bc = rng.integers(0, 16, size=(k,)) * (rng.random(k) < 0.6)
        prob = FCProblem(w=wc, b=bc, m=4, n=4, coded=True,
                         cents_w=cents_w, cents_a=cents_a)
        pmax = int(np.abs(np.outer(cents_w, cents_a)).max())
        assert prob.prod_bits == max(1, math.ceil(math.log2(pmax + 1)))
        emu = eng.estimate(backend="ap-emulator", workload=prob)
        sim = eng.estimate(backend="cycle-sim", workload=prob)
        assert emu["exact"]
        assert emu["cycles"] == sim["cycles"]


def test_estimate_named_workloads():
    eng = Engine()
    aida = eng.estimate(backend="cycle-sim", workload="alexnet-fc")
    eie = eng.estimate(backend="cycle-sim", workload="alexnet-fc",
                       simulator="eie")
    assert aida["cycles"] > 0 and eie["cycles"] > 0
    t1 = eng.estimate(backend="cycle-sim", workload="table1")
    assert t1["aida"]["pp_gops"] / t1["eie"]["pp_gops"] > 10  # paper: 14.5x


# --------------------------------------------------- former shim surface
def test_serve_shims_removed():
    """The PR-1 deprecation shims are gone; repro.api is the only entry."""
    with pytest.raises(ImportError):
        import repro.serve.engine  # noqa: F401
    with pytest.raises(ImportError):
        import repro.serve.compress  # noqa: F401
