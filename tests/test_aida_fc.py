"""Paper Fig. 3 algorithm on the bit-level AP emulator vs integer oracle,
plus closed-form cycle-model equality (aida_sim ≡ emulator)."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import aida_sim as S
from repro.core.aida_fc import (aida_fc_layer, aida_fc_layer_coded,
                                fc_reference, fc_reference_coded)


def sparse_int(rng, n, k, m_bits, density):
    w = rng.integers(-(2 ** m_bits - 1), 2 ** m_bits, size=(n, k))
    return w * (rng.random((n, k)) < density)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 999))
def test_bitserial_fc_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    n, k = rng.integers(2, 12), rng.integers(2, 12)
    m = nb = 4
    w = sparse_int(rng, n, k, m, 0.5)
    b = rng.integers(-(2 ** nb - 1), 2 ** nb, size=(k,)) \
        * (rng.random(k) < 0.7)
    for act in ("relu", None):
        res = aida_fc_layer(w, b, m=m, n=nb, activation=act)
        np.testing.assert_array_equal(res.out, fc_reference(w, b, act))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 999))
def test_coded_fc_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    cents_w = np.concatenate([[0], rng.integers(-99, 100, 15)])
    cents_a = np.concatenate([[0], rng.integers(-99, 100, 15)])
    n, k = rng.integers(3, 10), rng.integers(3, 10)
    wc = rng.integers(0, 16, size=(n, k)) * (rng.random((n, k)) < 0.5)
    bc = rng.integers(0, 16, size=(k,)) * (rng.random(k) < 0.6)
    res = aida_fc_layer_coded(wc, bc, cents_w, cents_a)
    np.testing.assert_array_equal(
        res.out, fc_reference_coded(wc, bc, cents_w, cents_a))


def test_cycle_model_exact_bitserial():
    """Closed-form cycle counts == emulator counter, bit for bit."""
    rng = np.random.default_rng(7)
    for _ in range(4):
        n_, k_ = rng.integers(3, 14), rng.integers(3, 14)
        w = sparse_int(rng, n_, k_, 4, 0.5)
        b = rng.integers(-15, 16, size=(k_,)) * (rng.random(k_) < 0.7)
        res = aida_fc_layer(w, b, m=4, n=4)
        ph = S.cycles_fc(k_, res.nnz_b, res.max_row_nnz, S.EMULATOR,
                         mode="bitserial", m=4, n=4)
        assert ph.total(S.EMULATOR) == res.cycles


def test_cycle_model_exact_coded():
    rng = np.random.default_rng(8)
    cents_w = np.concatenate([[0], rng.integers(-99, 100, 15)])
    cents_a = np.concatenate([[0], rng.integers(-99, 100, 15)])
    for _ in range(3):
        n_, k_ = rng.integers(4, 12), rng.integers(4, 12)
        wc = rng.integers(0, 16, size=(n_, k_)) * (rng.random((n_, k_)) < 0.4)
        bc = rng.integers(0, 16, size=(k_,)) * (rng.random(k_) < 0.6)
        res = aida_fc_layer_coded(wc, bc, cents_w, cents_a)
        pmax = int(np.abs(np.outer(cents_w, cents_a)).max())
        ph = S.cycles_fc(k_, res.nnz_b, res.max_row_nnz, S.EMULATOR,
                         mode="coded", m=4, n=4,
                         prod_bits=max(1, math.ceil(math.log2(pmax + 1))))
        assert ph.total(S.EMULATOR) == res.cycles


def test_reduction_rounds_log():
    """Soft reduction is logarithmic in the max row nnz (paper §3)."""
    rng = np.random.default_rng(9)
    w = np.zeros((2, 40), dtype=np.int64)
    w[0, :33] = rng.integers(1, 15, 33)       # 33 nnz -> ceil(log2)=6 rounds
    b = np.ones((40,), np.int64)
    res = aida_fc_layer(w, b, m=4, n=1)
    assert res.rounds == 6


def test_multiply_cycles_quadratic_in_wordlength():
    """Fig. 5(b): bit-serial multiply time grows quadratically."""
    c4 = S.cycles_multiply_bitserial(4, 4, 9, S.EMULATOR)
    c8 = S.cycles_multiply_bitserial(8, 8, 17, S.EMULATOR)
    c16 = S.cycles_multiply_bitserial(16, 16, 33, S.EMULATOR)
    assert 3.2 < c8 / c4 < 4.2
    assert 3.5 < c16 / c8 < 4.2
