"""Heterogeneous serving workload generator — reproducible request mixes.

Serving claims are only as good as the traffic they are measured on; the
one-size prompt loops the launchers used before this module hide every
scheduling effect (admission, preemption, chunked prefill, prefix reuse).
A `WorkloadSpec` draws (prompt_len, max_new, arrival) from seeded
distributions, so `launch/serve.py --workload ...`, the `"serving"`
benchmark section, and the hypothesis sweeps in tests/test_sched.py all
replay byte-identical request schedules.

Arrivals are expressed in decode STEPS, not wall seconds — the serving
loop is step-quantized, so step offsets make schedules deterministic
across hosts of different speed.

Trace record/replay: any serve captured with ``--trace`` (repro.obs) is
itself a workload — :meth:`WorkloadSpec.from_trace` reconstructs the
exact ``(arrival_tick, prompt_len, max_new)`` stream from the trace's
``req.submit`` events into an explicit ``schedule``, which
:func:`generate` replays verbatim (prompt token *values* are
regenerated from the seed; admission, paging, and batching depend only
on lengths and arrival ticks, so the replayed schedule is
scheduling-identical).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

#: named presets for the CLI / benchmarks
PRESETS = ("uniform", "heterogeneous", "shared-prefix", "burst")


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    n_requests: int = 8
    prompt_len: Tuple[int, int] = (4, 16)     # inclusive uniform range
    max_new: Tuple[int, int] = (4, 16)        # inclusive uniform range
    arrival: str = "batch"                    # "batch" | "poisson" | "burst"
    arrival_rate: float = 0.5                 # requests per STEP (poisson)
    burst_every: int = 16                     # steps between bursts
    burst_size: int = 4
    shared_prefix_len: int = 0                # common head on every prompt
    vocab: int = 256
    temperature: float = 0.0
    seed: int = 0
    #: explicit (arrival_step, prompt_len, max_new) schedule — replayed
    #: verbatim by generate(), overriding the arrival process and the
    #: prompt_len/max_new ranges (trace record/replay)
    schedule: Optional[Tuple[Tuple[int, int, int], ...]] = None

    @classmethod
    def preset(cls, name: str, **overrides) -> "WorkloadSpec":
        base = {
            "uniform": dict(prompt_len=(8, 8), max_new=(8, 8)),
            "heterogeneous": dict(prompt_len=(2, 24), max_new=(2, 24),
                                  arrival="poisson"),
            "shared-prefix": dict(prompt_len=(18, 28), max_new=(4, 8),
                                  shared_prefix_len=16),
            "burst": dict(prompt_len=(4, 16), max_new=(4, 16),
                          arrival="burst"),
        }
        if name not in base:
            raise ValueError(f"unknown workload preset {name!r}; "
                             f"choose one of {PRESETS}")
        kw = dict(base[name])
        kw.update(overrides)
        return cls(**kw)

    @classmethod
    def from_trace(cls, trace, *, vocab: int = 256,
                   temperature: float = 0.0, seed: int = 0,
                   include_warmup: bool = False) -> "WorkloadSpec":
        """Reconstruct the request stream a traced serve actually saw.

        ``trace``: a live ``repro.obs.Tracer``, an exported Chrome trace
        path, a parsed Chrome doc, or a raw event list.  Each
        ``req.submit`` event contributes one ``(arrival_tick,
        prompt_len, max_new)`` schedule entry, in submission order with
        the original ticks preserved — replaying the spec through
        ``run_workload`` reproduces the exact admission pressure of the
        recorded run.  Warm-up requests (rid < 0) are dropped unless
        ``include_warmup``.  Prompt token values are regenerated from
        ``seed`` (the trace records lengths, not tokens; scheduling
        depends only on lengths)."""
        from repro.obs.analyze import coerce_events
        subs = [(ev["tick"], ev["args"]["prompt_len"],
                 ev["args"]["max_new"], ev["args"].get("rid"))
                for ev in coerce_events(trace)
                if ev["name"] == "req.submit"]
        if not include_warmup:
            subs = [s for s in subs if s[3] is None or s[3] >= 0]
        if not subs:
            raise ValueError("trace has no req.submit events to replay")
        schedule = tuple((int(t), int(p), int(m)) for t, p, m, _ in subs)
        return cls(n_requests=len(schedule),
                   prompt_len=(min(p for _, p, _ in schedule),
                               max(p for _, p, _ in schedule)),
                   max_new=(min(m for _, _, m in schedule),
                            max(m for _, _, m in schedule)),
                   arrival="trace", vocab=vocab,
                   temperature=temperature, seed=seed,
                   schedule=schedule)


def generate(spec: WorkloadSpec) -> List[Tuple[int, "object"]]:
    """-> [(arrival_step, Request)], sorted by arrival step, rids 0..n-1
    in arrival order.  An explicit ``spec.schedule`` (trace replay) is
    honored verbatim — same ticks, same lengths, seeded token values."""
    from repro.api.session import Request
    rng = np.random.default_rng(spec.seed)
    if spec.schedule is not None:
        out = []
        for rid, (step, plen, mnew) in enumerate(spec.schedule):
            prompt = [int(x) for x in rng.integers(1, spec.vocab,
                                                   int(plen))]
            out.append((int(step),
                        Request(prompt=prompt, max_new=int(mnew),
                                temperature=spec.temperature, rid=rid)))
        return out
    lo_p, hi_p = spec.prompt_len
    lo_n, hi_n = spec.max_new
    shared = list(rng.integers(1, spec.vocab, spec.shared_prefix_len)) \
        if spec.shared_prefix_len else []
    arrivals: List[int] = []
    if spec.arrival == "poisson":
        t = 0.0
        for _ in range(spec.n_requests):
            t += rng.exponential(1.0 / max(spec.arrival_rate, 1e-9))
            arrivals.append(int(t))
    elif spec.arrival == "burst":
        for i in range(spec.n_requests):
            arrivals.append((i // spec.burst_size) * spec.burst_every)
    else:                                     # "batch": all at step 0
        arrivals = [0] * spec.n_requests
    out = []
    for rid, step in enumerate(sorted(arrivals)):
        plen = int(rng.integers(lo_p, hi_p + 1))
        plen = max(plen, spec.shared_prefix_len + 1)  # >=1 unshared token
        tail = [int(x) for x in rng.integers(1, spec.vocab,
                                             plen - len(shared))]
        req = Request(prompt=shared + tail,
                      max_new=int(rng.integers(lo_n, hi_n + 1)),
                      temperature=spec.temperature, rid=rid)
        out.append((step, req))
    return out


def timed_requests(spec_or_name, **overrides) -> List[Tuple[int, "object"]]:
    """Convenience: accept a WorkloadSpec, a preset name, or None."""
    if spec_or_name is None:
        spec = WorkloadSpec(**overrides)
    elif isinstance(spec_or_name, WorkloadSpec):
        spec = dataclasses.replace(spec_or_name, **overrides) \
            if overrides else spec_or_name
    else:
        spec = WorkloadSpec.preset(spec_or_name, **overrides)
    return generate(spec)
