"""KV page migration channel between disaggregated engine roles.

A handoff is the unit of work that crosses the prefill→decode boundary:
the scheduler entry (request + generated-so-far tokens + lifecycle
record) plus the prompt's page-table row and position.  The page ids in
``pages`` are *prefill-pool* ids whose ownership has already been
detached from the prefill slot — they stay refcounted in the prefill
allocator until the migration lands, at which point the orchestrator
frees them (shared prefix pages just drop one owner).

``migrate_kv`` copies the live pages into freshly allocated decode-pool
pages via `kvstore.copy_pages`: bf16 payloads move bit-exact, int8
payloads move codes *and* per-page scales with no requantization — which
is what makes disaggregated greedy decode token-identical to the
co-located engine.  Holes in the row (NO_PAGE, from SWA reclamation)
stay holes: table index == absolute position // page_size on both sides,
so the decode role resumes exactly where prefill stopped.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro import kvstore as kvs


@dataclasses.dataclass
class Handoff:
    """One finished prompt in flight from the prefill role to the decode
    role.  ``pages`` is the full prefill page-table row (NO_PAGE holes
    included — index alignment carries the position mapping); ``pos`` is
    the sequence position the decode role resumes at (== prompt length);
    ``tick`` is the orchestrator tick the handoff was created on."""
    entry: object                  # sched.SchedEntry (record rides along)
    pages: List[int]
    pos: int
    tick: int = 0
    # repro.resil: a fault-dropped/delayed handoff stays queued but is
    # invisible to decode admission until ``ready_tick``; ``drops``
    # counts delivery attempts lost to injected drops.
    ready_tick: int = 0
    drops: int = 0

    def live(self) -> List[Tuple[int, int]]:
        """(table_index, prefill_page_id) for every resident page."""
        return [(j, p) for j, p in enumerate(self.pages) if p >= 0]


def migrate_kv(src_state: dict, dst_state: dict, src_ids: List[int],
               dst_ids: List[int], dst_shardings=None
               ) -> Tuple[dict, int]:
    """Copy pages ``src_ids`` of the prefill serving state's pool into
    pages ``dst_ids`` of the decode state's pool; returns the updated
    decode state and the payload byte count.  Pools must share geometry
    (page size, head/dim layout, quantization) — both roles are built
    from the same ArchConfig, so they do by construction."""
    new_kv, moved = kvs.copy_pages(
        src_state["layers"]["kv"], dst_state["layers"]["kv"],
        src_ids, dst_ids, dst_shardings=dst_shardings)
    layers = dict(dst_state["layers"])
    layers["kv"] = new_kv
    return {**dst_state, "layers": layers}, moved
