"""Training loop: grad-accumulated microbatching, remat policies, metrics,
checkpoint-restart, straggler tracking, optional gradient compression.

`train_step` is the exact function the multi-pod dry-run lowers: it takes
(state, batch) and returns (state, metrics), with all parallelism expressed
through parameter/batch shardings (FSDP×TP via GSPMD) — so the single-host
test path and the 512-chip path are the same code.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.optim import adamw
from repro.runtime.compression import roundtrip


class TrainState(NamedTuple):
    params: Any
    opt: adamw.OptState


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: adamw.AdamWConfig = adamw.AdamWConfig()
    microbatches: int = 1          # gradient accumulation steps
    remat: str = "dots"            # none | dots | full
    attn_impl: str = "einsum"      # einsum | chunked | flash
    grad_compression: Optional[str] = None  # None | bf16 | int8
    streamed_loss: bool = False    # chunked vocab-parallel CE (§Perf)
    loss_chunk: int = 512
    cast_params_bf16: bool = False  # cast-before-gather: FSDP all-gathers
    #                                 move bf16, not f32 (§Perf, 2x wire)


def init_state(cfg: ArchConfig, key) -> TrainState:
    params = M.init_params(cfg, key)
    return TrainState(params=params, opt=adamw.init(params))


def state_specs(cfg: ArchConfig, mesh_shape: Dict[str, int]) -> TrainState:
    ps = M.param_specs(cfg, mesh_shape)
    return TrainState(params=ps, opt=adamw.opt_state_specs(ps))


def make_train_step(cfg: ArchConfig, tc: TrainConfig,
                    dp_spec=None, unroll: bool = False):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss(params, mb):
        if tc.cast_params_bf16:
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if (hasattr(p, "dtype") and p.dtype == jnp.float32
                    and p.ndim >= 2) else p, params)
        return M.loss_fn(cfg, params, mb, remat=tc.remat,
                         attn_impl=tc.attn_impl, dp_spec=dp_spec,
                         unroll=unroll, streamed_loss=tc.streamed_loss,
                         loss_chunk=tc.loss_chunk)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def train_step(state: TrainState, batch: Dict):
        if tc.microbatches > 1:
            # split batch leading dim into microbatches and lax.scan-accumulate
            def resh(x):
                b = x.shape[0]
                assert b % tc.microbatches == 0
                return x.reshape(tc.microbatches, b // tc.microbatches,
                                 *x.shape[1:])
            mbs = jax.tree.map(resh, batch)

            def acc_body(carry, mb):
                gsum, lsum = carry
                (l, _), g = grad_fn(state.params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            gzero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (gzero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / tc.microbatches, gsum)
            lval = lsum / tc.microbatches
        else:
            (lval, _), grads = grad_fn(state.params, batch)

        if tc.grad_compression:
            # cross-replica all-reduce happens on the compressed payload;
            # GSPMD sees the small dtype on the wire (bf16/int8+scales).
            grads = roundtrip(grads, tc.grad_compression)

        params, opt, om = adamw.apply(tc.opt, state.params, state.opt, grads)
        metrics = {"loss": lval, **om}
        return TrainState(params=params, opt=opt), metrics

    return train_step


# --------------------------------------------------------------- run loop
def run(cfg: ArchConfig, tc: TrainConfig, data_iter, n_steps: int,
        state: Optional[TrainState] = None, key=None,
        ckpt_mgr=None, ckpt_every: int = 0,
        straggler=None, log_every: int = 10, log=print) -> TrainState:
    """Single-host training driver (examples + integration tests).

    ckpt_mgr: checkpoint.ckpt.CheckpointManager; straggler:
    runtime.fault_tolerance.StragglerDetector."""
    if state is None:
        state = init_state(cfg, key if key is not None
                           else jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, tc))
    for i in range(n_steps):
        t0 = time.perf_counter()
        batch = jax.tree.map(jnp.asarray, next(data_iter))
        state, metrics = step_fn(state, batch)
        dt = time.perf_counter() - t0
        if straggler is not None:
            straggler.record(dt)
        if log_every and i % log_every == 0:
            log(f"step {i:5d} loss={float(metrics['loss']):.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} "
                f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
        if ckpt_mgr is not None and ckpt_every and (i + 1) % ckpt_every == 0:
            ckpt_mgr.save(int(state.opt.step), state,
                          extra={"data": data_iter.state()})
    return state
