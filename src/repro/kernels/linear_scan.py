"""Chunked linear-recurrence kernel (RWKV6 "Finch" WKV) — serving hot path.

The matrix-valued state S [Dk, Dv] stays RESIDENT IN VMEM for the whole
sequence while time chunks stream through — the AIDA principle (state never
leaves the memory it is processed in) applied to the recurrence:

    S_t = diag(w_t) · S_{t-1} + k_t v_tᵀ
    o_t = (S_{t-1} + diag(u) · k_t v_tᵀ)ᵀ r_t

Grid (B·H, T/C): Pallas iterates the grid sequentially per core, so the
state scratch carries across chunk steps of the same (b,h) row and is
re-initialized when the chunk index wraps to 0.  Inside a chunk the exact
sequential recurrence runs in registers/VMEM (numerically safe for
arbitrarily small decays, unlike cumprod-factorized chunk algebra — see
DESIGN.md).  Training uses the differentiable `ops.rwkv6(..., impl="scan")`
path; this kernel is the inference engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *,
                  chunk: int):
    tb = pl.program_id(1)

    @pl.when(tb == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[...][0]                        # [Dk]

    def step(t, S):
        rt = r_ref[0, t, :].astype(jnp.float32)
        kt = k_ref[0, t, :].astype(jnp.float32)
        vt = v_ref[0, t, :].astype(jnp.float32)
        wt = w_ref[0, t, :].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]                   # [Dk, Dv]
        ot = ((S + u[:, None] * kv) * rt[:, None]).sum(axis=0)
        o_ref[0, t, :] = ot
        return wt[:, None] * S + kv

    s_scr[...] = jax.lax.fori_loop(0, chunk, step, s_scr[...])


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_fwd(r, k, v, w, u, *, chunk: int = 64, interpret: bool = True):
    """r,k,w [B,H,T,Dk], v [B,H,T,Dv], u [H,Dk] -> o [B,H,T,Dv] f32."""
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0
    bh = b * h
    flat = lambda x: x.reshape(bh, t, x.shape[-1])
    o = pl.pallas_call(
        functools.partial(_rwkv6_kernel, chunk=chunk),
        grid=(bh, t // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda i, tb: (i, tb, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, tb: (i, tb, 0)),
            pl.BlockSpec((1, chunk, dv), lambda i, tb: (i, tb, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, tb: (i, tb, 0)),
            pl.BlockSpec((1, dk), lambda i, tb, H=h: (i % H, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda i, tb: (i, tb, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, dv), jnp.float32),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(flat(r), flat(k), flat(v), flat(w), u)
    return o.reshape(b, h, t, dv)
