"""Llama-3-8B — GQA, 128k vocab, rope theta 500k. [arXiv:2407.21783]"""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
    vocab=128256, d_head=128, rope_theta=500_000.0,
    tie_embeddings=False, source="arXiv:2407.21783"))
