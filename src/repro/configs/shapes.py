"""The assigned input-shape set + (arch × shape) cell admissibility.

LM shapes are seq_len × global_batch.  decode_* / long_* cells lower
`serve_step` (one token against a KV cache of seq_len); train lowers
`train_step`; prefill lowers the forward pass.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}


def cell_supported(cfg: ArchConfig, shape: Shape) -> Tuple[bool, str]:
    if shape.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only: no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full attention: 500k decode skipped (DESIGN.md)"
    return True, ""


def all_cells() -> List[Tuple[str, str]]:
    from repro.configs.base import names
    return [(a, s) for a in names() for s in SHAPES]


def input_specs(cfg: ArchConfig, shape: Shape):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.batch, shape.seq
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio":
            batch = {"frames": jax.ShapeDtypeStruct((b, s, cfg.audio_in_dim),
                                                    jnp.bfloat16),
                     "labels": jax.ShapeDtypeStruct((b, s), i32)}
        elif cfg.frontend == "vision":
            batch = {"tokens": jax.ShapeDtypeStruct((b, s - cfg.n_img_tokens),
                                                    i32),
                     "img_embeds": jax.ShapeDtypeStruct(
                         (b, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        return batch
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((b,), i32)}
