"""Request-lifecycle robustness policy: deadlines, retry, shed, degrade.

:class:`ResilConfig` is the user-facing knob bundle accepted by
``Engine.session(resil=...)`` (as a config, a dict, or a bare
``"preset:seed"`` fault-plan string). ``resil=None`` means the layer is
entirely absent — zero behavior change versus PR 6.

:class:`ResilState` is the per-session runtime: the (optional) fault
plan, the degradation ladder, the watchdog, and the counters that
``sched.metrics.summarize`` reports under ``"resil"``.

A request that cannot be served within policy becomes a structured
:class:`RequestFailed` result (never an unhandled exception):

- ``deadline``          — missed its ``deadline_ticks`` budget
- ``shed``              — rejected by load shedding while queued
- ``retries_exhausted`` — re-admitted more than ``max_retries`` times
- ``oversized``         — can never fit the page pool it was routed to
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union

from . import faults


@dataclasses.dataclass
class RequestFailed:
    """Structured terminal result for a request the engine gave up on."""

    rid: int
    reason: str
    tokens: List[int] = dataclasses.field(default_factory=list)
    retries: int = 0

    def __repr__(self):  # compact, log-friendly
        return (
            f"RequestFailed(rid={self.rid}, reason={self.reason!r}, "
            f"n_tokens={len(self.tokens)}, retries={self.retries})"
        )


@dataclasses.dataclass
class ResilConfig:
    """Knobs for the resilience layer. All optional; None disables."""

    # Default per-request completion budget, in ticks from submit.
    deadline_ticks: Optional[int] = None
    # Re-admissions (recompute) allowed before RequestFailed.
    max_retries: int = 2
    # Shed queued work when sum(worst-case page need) exceeds this
    # fraction of the usable pool. None disables shedding.
    shed_watermark: Optional[float] = None
    # Demote new admissions' KV to int8 under sustained page pressure.
    degrade_kv: bool = False
    degrade_low_frac: float = 0.25
    degrade_sustain_ticks: int = 8
    # Disagg: ticks a handoff may wait before falling back to
    # co-located prefill on the decode role. None disables.
    handoff_timeout: Optional[int] = None
    # Disagg: ticks before a dropped handoff is redelivered.
    redeliver_after: int = 3
    # Watchdog audit cadence in ticks (0 disables).
    watchdog_every: int = 0
    # Consecutive faulted steps before a role is drained as wedged.
    wedge_ticks: int = 10
    # FaultPlan, "preset:seed" string, or None.
    fault_plan: Optional[Union[faults.FaultPlan, str]] = None

    def __post_init__(self):
        if isinstance(self.fault_plan, str):
            self.fault_plan = faults.FaultPlan.parse(self.fault_plan)
        elif isinstance(self.fault_plan, dict):
            # {"preset": ..., "seed": ..., <param overrides>} — a nested
            # "params" dict is accepted too and flattened into overrides
            spec = dict(self.fault_plan)
            spec.update(spec.pop("params", {}))
            self.fault_plan = faults.FaultPlan.make(**spec)
        if self.deadline_ticks is not None and self.deadline_ticks < 1:
            raise ValueError("deadline_ticks must be >= 1")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.shed_watermark is not None and not 0.0 < self.shed_watermark:
            raise ValueError("shed_watermark must be > 0")
        if self.handoff_timeout is not None and self.handoff_timeout < 1:
            raise ValueError("handoff_timeout must be >= 1")
        if self.wedge_ticks < 1:
            raise ValueError("wedge_ticks must be >= 1")

    @classmethod
    def coerce(cls, val) -> "ResilConfig":
        if isinstance(val, cls):
            return val
        if isinstance(val, faults.FaultPlan):
            return cls(fault_plan=val)
        if isinstance(val, str):
            return cls(fault_plan=faults.FaultPlan.parse(val))
        if isinstance(val, dict):
            return cls(**val)
        if val is True:
            return cls()
        raise TypeError(f"cannot coerce {type(val).__name__} to ResilConfig")


class DegradeState:
    """Hysteresis ladder for graceful degradation under page pressure.

    Level 0: normal. Level 1: release prefix-cache pins. Level 2: also
    demote *new admissions'* KV to int8 (pool dtype is fixed for a live
    session, so demotion is enforced at the next session boundary via
    ``Engine.session`` consulting :meth:`ResilState.next_kv_dtype`).
    """

    def __init__(self, low_frac: float, sustain: int):
        self.low_frac = low_frac
        self.high_frac = min(1.0, 2.0 * low_frac)
        self.sustain = max(1, sustain)
        self.low_ticks = 0
        self.level = 0
        # observability seam: a ``(name, **args)`` emitter (obs.Tracer
        # .hook); fires only on level TRANSITIONS, never per tick.
        self.obs = None

    def update(self, free_frac: float) -> int:
        if free_frac < self.low_frac:
            self.low_ticks += 1
        elif free_frac > self.high_frac:
            self.low_ticks = 0
        prev = self.level
        if self.low_ticks >= self.sustain:
            self.level = 2
        elif self.low_ticks >= (self.sustain + 1) // 2:
            self.level = 1
        else:
            self.level = 0
        if self.obs is not None and self.level != prev:
            self.obs("resil.degrade", level=self.level, prev=prev,
                     free_frac=round(free_frac, 4))
        return self.level

    @property
    def kv_demote(self) -> bool:
        return self.level >= 2


class ResilState:
    """Per-session runtime state for the resilience layer."""

    COUNTERS = (
        "deadline_miss",
        "shed",
        "retries",
        "failed",
        "degraded_admissions",
        "handoff_fallbacks",
        "fault_steps",
        "wait_ticks",
        "watchdog_audits",
        "watchdog_recoveries",
    )

    def __init__(self, cfg: ResilConfig):
        self.cfg = cfg
        self.plan: Optional[faults.FaultPlan] = cfg.fault_plan
        self.stats: Dict[str, int] = {k: 0 for k in self.COUNTERS}
        self.degrade = (
            DegradeState(cfg.degrade_low_frac, cfg.degrade_sustain_ticks)
            if cfg.degrade_kv
            else None
        )
        from . import health  # local import: health has no deps on policy

        self.watchdog = (
            health.Watchdog(cfg.watchdog_every) if cfg.watchdog_every > 0 else None
        )

    def count(self, key: str, n: int = 1) -> None:
        self.stats[key] = self.stats.get(key, 0) + n

    def deadline_for(self, req, tick: int) -> Optional[int]:
        """Absolute deadline tick for `req` submitted at `tick`."""
        dl = getattr(req, "deadline_ticks", None)
        if dl is None:
            dl = self.cfg.deadline_ticks
        return None if dl is None else tick + dl

    def next_kv_dtype(self, default: str) -> str:
        """KV dtype for the *next* session, honoring the degrade ladder."""
        if self.degrade is not None and self.degrade.kv_demote:
            return "int8"
        return default

    def summary(self) -> Dict[str, int]:
        out = dict(self.stats)
        if self.plan is not None:
            out["fault_plan"] = self.plan.describe()
            out["faults"] = dict(self.plan.stats)
        if self.degrade is not None:
            out["degrade_level"] = self.degrade.level
        return out
