"""Foundational layers: norms, rotary embeddings, MLPs, initializers.

Pure-functional: params are nested dicts of jnp arrays; every `*_init`
returns params and the matching `*_apply` consumes them.  Compute follows a
mixed-precision policy: params f32, matmul compute bf16, norms/softmax f32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.api import dispatch as _dispatch
from repro.api import env as _env

COMPUTE_DTYPE = jnp.bfloat16


def _matmul_out_dtype():
    """§Perf lever: bf16 matmul outputs mean TP partial sums cross the ICI
    in bf16 (half the all-reduce wire bytes).  MXU accumulation is f32
    internally either way; only the psum payload narrows.  Enabled with
    REPRO_BF16_PSUM=1 (measured in the hillclimb; see EXPERIMENTS §Perf)."""
    return COMPUTE_DTYPE if _env.BF16_PSUM else jnp.float32


def dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None):
    scale = (d_in ** -0.5) if scale is None else scale
    return jax.random.normal(key, (d_in, d_out), jnp.float32) * scale


def dense(x, w, bias=None, activation=None, plan=None):
    """act(x @ w + bias).  ``w`` may be a raw [d_in, d_out] matrix OR any
    compressed leaf registered with repro.api.dispatch (e.g. a
    core.sparse_fc.CompressedFC, the AIDA serving mode) — compression is
    transparent to every projection in the model zoo.

    For compressed leaves, bias and activation ride into the kernel
    epilogue (one fused pass, no extra HBM round-trip); the raw-matmul
    path keeps the historical op order bit-for-bit.

    ``plan`` (a shard.ShardingPlan) routes compressed leaves through the
    shard-local tensor-parallel apply — each mesh shard runs its band of
    the compressed matrix through the same kernels (raw matrices are
    GSPMD-partitioned by the plan's param shardings instead, so they
    ignore ``plan`` here)."""
    apply = _dispatch.applier_for(w)
    if apply is not None:
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        y = None
        if plan is not None:
            from repro.core.sparse_fc import CompressedFC
            from repro.shard import apply_fc_sharded
            if isinstance(w, CompressedFC):
                y = apply_fc_sharded(plan, w, x2, bias=bias,
                                     activation=activation)
        if y is None:
            y = apply(w, x2, bias=bias, activation=activation)
        return y.reshape(*lead, y.shape[-1]).astype(COMPUTE_DTYPE)
    y = jnp.matmul(x.astype(COMPUTE_DTYPE), w.astype(COMPUTE_DTYPE),
                   preferred_element_type=_matmul_out_dtype())
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    y = y.astype(COMPUTE_DTYPE)
    if activation is not None:
        y = _act(activation, y.astype(jnp.float32)).astype(COMPUTE_DTYPE)
    return y


def rms_norm_init(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}  # gemma-style (1+scale)


def rms_norm(x, params, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + params["scale"])
    return y.astype(COMPUTE_DTYPE)


def layer_norm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(x, params, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(COMPUTE_DTYPE)


# ------------------------------------------------------------------ rotary
def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 10000.0) -> jnp.ndarray:
    """Rotary embedding. x [B, H, T, D], positions [B, T] (absolute)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # [B,1,T,half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------------- MLPs
def mlp_init(key, d: int, f: int, gated: bool = True, act: str = "silu"):
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d, f), "down": dense_init(ks[1], f, d)}
    if gated:
        p["gate"] = dense_init(ks[2], d, f)
    return p


def _act(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(name)


def mlp(x, p, act: str = "silu", plan=None):
    if "gate" in p:
        # activation fuses into the gate projection's kernel epilogue
        up = dense(x, p["gate"], activation=act, plan=plan) \
            * dense(x, p["up"], plan=plan)
    else:
        up = dense(x, p["up"], activation=act, plan=plan)
    return dense(up, p["down"], plan=plan)


# --------------------------------------------------------------- embedding
def embed_init(key, vocab: int, d: int):
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32)
            * (d ** -0.5)}


def embed(tokens, p):
    return jnp.take(p["table"], tokens, axis=0).astype(COMPUTE_DTYPE)


def unembed(x, p):
    """Tied or untied head: logits = x @ table.T (f32 out, vocab-sharded)."""
    return jnp.matmul(x.astype(COMPUTE_DTYPE),
                      p["table"].T.astype(COMPUTE_DTYPE),
                      preferred_element_type=jnp.float32)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
