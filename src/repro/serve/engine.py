"""DEPRECATED — the serving engine moved to `repro.api` (PR 1).

Use `repro.api.Engine` (facade) or `repro.api.Session` (continuous-batching
session) instead.  This shim keeps old imports working for one PR.
"""
from __future__ import annotations

import warnings

from repro.api.session import Request, Result, Session  # noqa: F401


class ServeEngine(Session):
    """Deprecated alias of `repro.api.Session`."""

    def __init__(self, *args, **kwargs):
        warnings.warn(
            "repro.serve.engine.ServeEngine is deprecated; use "
            "repro.api.Engine (facade) or repro.api.Session",
            DeprecationWarning, stacklevel=2)
        super().__init__(*args, **kwargs)
