"""Paged KV page pool — the AIDA memory model applied to the KV cache.

The dense decode cache materializes ``[B, Hkv, S_max, Dh]`` bf16 per layer
whether a sequence uses 3 tokens or 300.  The pool replaces that with a
shared set of fixed-size pages::

    k_pages / v_pages : [n_pages, Hkv, page_size, Dh]   int8 (or bf16)
    k_scale / v_scale : [n_pages, Hkv]                  f32 (int8 mode only)

plus one per-sequence *page table* ``[B, n_pages_per_seq] int32`` shared by
every layer (each layer owns its own pool arrays but sequence ``b`` uses
the same page ids at the same table index in all of them, so the
scan-over-layers stays homogeneous).  Token ``t`` of sequence ``b`` lives
at ``(page_table[b, t // page_size], t % page_size)`` — the table index IS
the absolute position, so attention masks need no stored positions.

Quantization follows the paper's precision lever (AIDA §IV): int8 codes
against a *per-page, per-head* f32 scale.  The scale is grown online —
when a new token's amax exceeds the page's current scale, the page's
existing codes are requantized against the new scale in the same fused
update (one page of traffic, ≤0.5 LSB added error per rescale).  Page 0
is reserved as a garbage sink: unallocated table entries (-1) clamp to it
so inactive batch slots can write unconditionally inside jit.

Bytes per token (k+v): int8 pages cost ``2·Hkv·Dh + 8·Hkv/page_size``
vs ``4·Hkv·Dh`` for the dense bf16 cache — ~0.50x at Dh=32, ps=16.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

#: table entry meaning "no page allocated here"
NO_PAGE = -1
#: page id reserved as the write sink for unallocated/inactive slots
GARBAGE_PAGE = 0


class PagedKV(NamedTuple):
    """One layer's share of the page pool (clean pytree: arrays only, or
    None scales in the unquantized bf16 mode — None leaves vanish from the
    tree so both modes scan/shard cleanly)."""
    k_pages: jnp.ndarray                   # [n_pages, Hkv, ps, Dh]
    v_pages: jnp.ndarray                   # [n_pages, Hkv, ps, Dh]
    k_scale: Optional[jnp.ndarray] = None  # [n_pages, Hkv] f32 (int8 mode)
    v_scale: Optional[jnp.ndarray] = None

    @property
    def page_size(self) -> int:
        return int(self.k_pages.shape[2])

    @property
    def n_pages(self) -> int:
        return int(self.k_pages.shape[0])

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None


def init_pool(n_pages: int, n_kv: int, page_size: int, d_head: int,
              kv_dtype: str = "int8") -> PagedKV:
    """A fresh pool. ``kv_dtype``: "int8" (quantized) or "bf16" (exact)."""
    if kv_dtype == "int8":
        shape = (n_pages, n_kv, page_size, d_head)
        return PagedKV(k_pages=jnp.zeros(shape, jnp.int8),
                       v_pages=jnp.zeros(shape, jnp.int8),
                       k_scale=jnp.zeros((n_pages, n_kv), jnp.float32),
                       v_scale=jnp.zeros((n_pages, n_kv), jnp.float32))
    if kv_dtype == "bf16":
        shape = (n_pages, n_kv, page_size, d_head)
        return PagedKV(k_pages=jnp.zeros(shape, jnp.bfloat16),
                       v_pages=jnp.zeros(shape, jnp.bfloat16))
    raise ValueError(f"unknown kv_dtype {kv_dtype!r}; "
                     "choose 'int8' or 'bf16'")


def init_table(batch: int, max_len: int, page_size: int) -> jnp.ndarray:
    """Empty per-sequence page table [B, n_pages_per_seq]."""
    npp = -(-max_len // page_size)
    return jnp.full((batch, npp), NO_PAGE, jnp.int32)


def _quantize(new, s):
    """int8 codes of ``new`` [B, Hkv, Dh] against scales ``s`` [B, Hkv]."""
    codes = jnp.where(s[..., None] > 0,
                      new / jnp.maximum(s[..., None], 1e-30), 0.0)
    return jnp.clip(jnp.round(codes), -127, 127).astype(jnp.int8)


def _write_page_rescale(pages, scale, new, new_s, safe_page, slot):
    """Slow path: grow the per-page scale, requantize the page's existing
    codes against it, and write the new token's codes at ``slot``.
    Batched gather/scatter on the page index; the allocator guarantees
    active sequences never share a page, so scatter collisions only
    happen on the garbage page."""
    b = new.shape[0]
    ps = pages.shape[2]
    old_s = scale[safe_page]                              # [B, Hkv]
    # ratio <= 1; a fresh page has old_s == 0, so any stale codes are
    # wiped by ratio == 0
    ratio = jnp.where(new_s > 0, old_s / jnp.maximum(new_s, 1e-30), 0.0)
    pg = pages[safe_page].astype(jnp.float32)             # [B, Hkv, ps, Dh]
    pg = jnp.round(pg * ratio[..., None, None])
    hot = (jax.lax.broadcasted_iota(jnp.int32, (b, ps), 1)
           == slot[:, None])                              # [B, ps]
    pg = jnp.where(hot[:, None, :, None],
                   _quantize(new, new_s).astype(jnp.float32)[:, :, None, :],
                   pg)
    pages = pages.at[safe_page].set(pg.astype(jnp.int8))
    scale = scale.at[safe_page].set(new_s)
    return pages, scale


def update(pool: PagedKV, table: jnp.ndarray, k_new: jnp.ndarray,
           v_new: jnp.ndarray, cur_pos: jnp.ndarray,
           valid: Optional[jnp.ndarray] = None) -> PagedKV:
    """Insert one token's k/v ([B, Hkv, Dh]) at absolute position
    ``cur_pos`` [B] through the page table.  Pure function of array
    inputs — safe inside the jitted, scanned decode step.

    ``valid`` [B] bool (optional) redirects invalid rows to the garbage
    sink — the chunked-prefill step uses it for the padding tail of a
    short chunk, so one fixed-width step serves mixed prefill+decode
    batches without conditional writes.

    int8 mode is two-speed: when every page's current scale already
    covers the new token (the steady state — scales grow only a handful
    of times per page), the write is a plain scatter of fresh codes; only
    a genuine scale growth pays the gather-requantize-scatter round trip
    (lax.cond, so the fast path skips the page traffic entirely)."""
    ps = pool.page_size
    npp = table.shape[1]
    pi = jnp.clip(cur_pos // ps, 0, npp - 1)
    slot = cur_pos % ps
    page = table[jnp.arange(table.shape[0]), pi]          # [B]
    if valid is not None:
        page = jnp.where(valid, page, NO_PAGE)
    safe = jnp.maximum(page, GARBAGE_PAGE)                # -1 -> sink page
    if not pool.quantized:
        dt = pool.k_pages.dtype
        kp = pool.k_pages.at[safe, :, slot].set(k_new.astype(dt))
        vp = pool.v_pages.at[safe, :, slot].set(v_new.astype(dt))
        return PagedKV(kp, vp)
    kf = k_new.astype(jnp.float32)
    vf = v_new.astype(jnp.float32)
    k_amax = jnp.max(jnp.abs(kf), axis=-1) / 127.0        # [B, Hkv]
    v_amax = jnp.max(jnp.abs(vf), axis=-1) / 127.0
    if valid is not None:
        # a padded token must never grow a real page's scale
        k_amax = jnp.where(valid[:, None], k_amax, 0.0)
        v_amax = jnp.where(valid[:, None], v_amax, 0.0)
    old_ks = pool.k_scale[safe]
    old_vs = pool.v_scale[safe]
    new_ks = jnp.maximum(old_ks, k_amax)
    new_vs = jnp.maximum(old_vs, v_amax)
    grow = jnp.any((k_amax > old_ks) | (v_amax > old_vs))

    def fast(pool):
        kp = pool.k_pages.at[safe, :, slot].set(_quantize(kf, old_ks))
        vp = pool.v_pages.at[safe, :, slot].set(_quantize(vf, old_vs))
        return PagedKV(kp, vp, pool.k_scale, pool.v_scale)

    def slow(pool):
        kp, ks = _write_page_rescale(pool.k_pages, pool.k_scale, kf,
                                     new_ks, safe, slot)
        vp, vs = _write_page_rescale(pool.v_pages, pool.v_scale, vf,
                                     new_vs, safe, slot)
        return PagedKV(kp, vp, ks, vs)

    return jax.lax.cond(grow, slow, fast, pool)


def update_chunk(pool: PagedKV, table: jnp.ndarray, k_new: jnp.ndarray,
                 v_new: jnp.ndarray, positions: jnp.ndarray,
                 valid: Optional[jnp.ndarray] = None) -> PagedKV:
    """Insert a whole chunk's k/v ([B, Hkv, C, Dh]) at absolute positions
    ``positions`` [B, C] through the page table — the multi-token
    generalization of :func:`update`, ONE scatter per chunk instead of a
    scan of C single-token writes (the chunked-prefill hot path).

    ``valid`` [B, C] bool redirects padding tokens to the garbage sink
    exactly like :func:`update`'s per-token flag.  bf16 pools are
    bit-identical to the equivalent scan (same values land in the same
    distinct (page, slot) cells).  int8 pools keep the two-speed
    semantics at chunk granularity: per-page scales grow to cover the
    chunk's max |amax| landing on each page (a segment-max scatter), and
    only a genuine growth pays the gather-requantize-scatter round trip
    — under one ``lax.cond`` for the whole chunk.  Chunk tokens are
    quantized directly against the final page scale, so a chunk write
    never pays the intra-chunk rescale random walk the scan did (error
    stays within the same ~1 LSB bound, from above)."""
    ps = pool.page_size
    b, c = positions.shape
    npp = table.shape[1]
    pi = jnp.clip(positions // ps, 0, npp - 1)            # [B, C]
    slot = positions % ps
    page = jnp.take_along_axis(table, pi, axis=1)         # [B, C]
    if valid is not None:
        page = jnp.where(valid, page, NO_PAGE)
    safe = jnp.maximum(page, GARBAGE_PAGE)
    # token-major layout: [B, C, Hkv, Dh] matches the scatter index shape
    kf = k_new.astype(jnp.float32).transpose(0, 2, 1, 3)
    vf = v_new.astype(jnp.float32).transpose(0, 2, 1, 3)
    if not pool.quantized:
        dt = pool.k_pages.dtype
        kp = pool.k_pages.at[safe, :, slot].set(kf.astype(dt))
        vp = pool.v_pages.at[safe, :, slot].set(vf.astype(dt))
        return PagedKV(kp, vp)
    k_amax = jnp.max(jnp.abs(kf), axis=-1) / 127.0        # [B, C, Hkv]
    v_amax = jnp.max(jnp.abs(vf), axis=-1) / 127.0
    if valid is not None:
        # a padded token must never grow a real page's scale
        k_amax = jnp.where(valid[..., None], k_amax, 0.0)
        v_amax = jnp.where(valid[..., None], v_amax, 0.0)
    old_ks = pool.k_scale[safe]                           # [B, C, Hkv]
    old_vs = pool.v_scale[safe]
    # final per-page scale: old scale vs the chunk's per-page amax peak
    # (segment max over however many chunk tokens land on each page —
    # elementwise-max scatter, so duplicate page ids are well-defined)
    new_ks_full = pool.k_scale.at[safe].max(k_amax)       # [n_pages, Hkv]
    new_vs_full = pool.v_scale.at[safe].max(v_amax)
    new_ks = new_ks_full[safe]                            # [B, C, Hkv]
    new_vs = new_vs_full[safe]
    grow = jnp.any((k_amax > old_ks) | (v_amax > old_vs))

    def _quant_tok(xf, s):
        codes = jnp.where(s[..., None] > 0,
                          xf / jnp.maximum(s[..., None], 1e-30), 0.0)
        return jnp.clip(jnp.round(codes), -127, 127).astype(jnp.int8)

    def fast(pool):
        kp = pool.k_pages.at[safe, :, slot].set(_quant_tok(kf, old_ks))
        vp = pool.v_pages.at[safe, :, slot].set(_quant_tok(vf, old_vs))
        return PagedKV(kp, vp, pool.k_scale, pool.v_scale)

    def _rescale_pages(pages, old_s, new_s, xf):
        # 1) requantize each WRITTEN page's existing codes old -> new
        #    scale.  ratio is a page-level value gathered per token, so
        #    duplicate page ids scatter identical full-page content —
        #    order-independent by construction.
        ratio = jnp.where(new_s > 0,
                          old_s / jnp.maximum(new_s, 1e-30), 0.0)
        pg = pages[safe].astype(jnp.float32)          # [B, C, Hkv, ps, Dh]
        pg = jnp.round(pg * ratio[..., None, None])
        pages = pages.at[safe].set(pg.astype(jnp.int8))
        # 2) land the chunk's codes, quantized against the final scale
        #    (distinct (page, slot) cells for every valid token)
        return pages.at[safe, :, slot].set(_quant_tok(xf, new_s))

    def slow(pool):
        kp = _rescale_pages(pool.k_pages, old_ks, new_ks, kf)
        vp = _rescale_pages(pool.v_pages, old_vs, new_vs, vf)
        return PagedKV(kp, vp, new_ks_full, new_vs_full)

    return jax.lax.cond(grow, slow, fast, pool)


def gather_kv(pool: PagedKV, table: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Materialize per-sequence K/V from the pool (XLA reference path):
    [B, npp] table -> dequantized ([B, Hkv, npp*ps, Dh] f32) k, v."""
    b, npp = table.shape
    _, hkv, ps, dh = pool.k_pages.shape
    safe = jnp.maximum(table, GARBAGE_PAGE)
    k = jnp.take(pool.k_pages, safe, axis=0)   # [B, npp, Hkv, ps, Dh]
    v = jnp.take(pool.v_pages, safe, axis=0)
    if pool.quantized:
        ks = jnp.take(pool.k_scale, safe, axis=0)         # [B, npp, Hkv]
        vs = jnp.take(pool.v_scale, safe, axis=0)
        k = k.astype(jnp.float32) * ks[..., None, None]
        v = v.astype(jnp.float32) * vs[..., None, None]
    else:
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)
    k = k.transpose(0, 2, 1, 3, 4).reshape(b, hkv, npp * ps, dh)
    v = v.transpose(0, 2, 1, 3, 4).reshape(b, hkv, npp * ps, dh)
    return k, v


def attention_mask(table: jnp.ndarray, cur_pos: jnp.ndarray,
                   window: jnp.ndarray, page_size: int) -> jnp.ndarray:
    """[B, npp*ps] bool: positions a query at cur_pos may attend to.
    Table index is absolute position; window < 0 means full causal."""
    b, npp = table.shape
    pos = jnp.arange(npp * page_size)[None, :]            # [1, npp*ps]
    alloc = jnp.repeat(table >= 0, page_size, axis=1)     # [B, npp*ps]
    ok = alloc & (pos <= cur_pos[:, None])
    win_lo = jnp.where(window < 0, jnp.int32(-1),
                       cur_pos[:, None] - window)
    return ok & (pos > win_lo)


def chunk_attention_mask(table: jnp.ndarray, q_pos: jnp.ndarray,
                         window: jnp.ndarray,
                         page_size: int) -> jnp.ndarray:
    """[B, C, npp*ps] bool: positions each of C chunk queries (at absolute
    positions ``q_pos`` [B, C]) may attend to — the multi-query
    generalization of :func:`attention_mask` for the chunked-prefill
    step.  Every key position <= a query's position has been written by
    the time the chunk attends (writes happen first, in position order),
    so plain causality over table-index positions is sufficient."""
    b, npp = table.shape
    pos = jnp.arange(npp * page_size)[None, None, :]      # [1, 1, npp*ps]
    alloc = jnp.repeat(table >= 0, page_size,
                       axis=1)[:, None, :]                # [B, 1, npp*ps]
    ok = alloc & (pos <= q_pos[:, :, None])
    win_lo = jnp.where(window < 0, jnp.int32(-1),
                       q_pos[:, :, None] - window)
    return ok & (pos > win_lo)


# ---------------------------------------------------------- cross-pool copy
def _page_axis(leaf) -> int:
    """Page axis of a pool leaf: 0 for a single layer's [n_pages, ...]
    arrays, 1 for the scan-stacked [L, n_pages, ...] serving layout."""
    return leaf.ndim - 4 if leaf.ndim >= 4 else leaf.ndim - 2


def _same_devices(a, b) -> bool:
    sa, sb = getattr(a, "sharding", None), getattr(b, "sharding", None)
    if sa is None or sb is None:
        return True
    return sa.device_set == sb.device_set


def copy_pages(src: PagedKV, dst: PagedKV, src_ids, dst_ids,
               dst_shardings: Optional[PagedKV] = None
               ) -> Tuple[PagedKV, int]:
    """Copy pages ``src_ids`` of ``src`` into pages ``dst_ids`` of ``dst``
    (another pool of the same geometry) and return ``(new_dst, bytes)``.

    The payload moves verbatim: bf16 pages are bit-exact, int8 pages move
    codes *and* per-page scales with no requantization (zero added error).
    Works on single-layer pools and the scan-stacked [L, n_pages, ...]
    serving layout alike.  When the two pools live on different device
    sets (disaggregated roles on disjoint mesh subsets) the payload is
    staged through the host; same-device copies stay on device.
    ``dst_shardings`` (a PagedKV of NamedShardings) re-commits the updated
    leaves so a jitted step with explicit in_shardings sees no surprise
    placement."""
    if src.page_size != dst.page_size or \
            src.k_pages.shape[-2:] != dst.k_pages.shape[-2:] or \
            src.quantized != dst.quantized:
        raise ValueError(
            f"pool geometry mismatch: src {src.k_pages.shape} "
            f"({src.k_pages.dtype}) vs dst {dst.k_pages.shape} "
            f"({dst.k_pages.dtype})")
    si = jnp.asarray(src_ids, jnp.int32)
    di = jnp.asarray(dst_ids, jnp.int32)
    if si.shape != di.shape:
        raise ValueError(f"{si.shape[0]} source pages for "
                         f"{di.shape[0]} destinations")
    moved = 0

    def copy_leaf(s, d, sh):
        nonlocal moved
        if s is None:
            return None
        ax = _page_axis(s)
        block = jnp.take(s, si, axis=ax)
        moved += block.size * block.dtype.itemsize
        if not _same_devices(s, d):
            block = jnp.asarray(jax.device_get(block))
        idx = (slice(None),) * ax + (di,)
        out = d.at[idx].set(block.astype(d.dtype))
        if sh is not None:
            out = jax.device_put(out, sh)
        return out

    shs = dst_shardings or PagedKV(None, None, None, None)
    if si.shape[0] == 0:
        return dst, 0
    return PagedKV(
        k_pages=copy_leaf(src.k_pages, dst.k_pages, shs.k_pages),
        v_pages=copy_leaf(src.v_pages, dst.v_pages, shs.v_pages),
        k_scale=copy_leaf(src.k_scale, dst.k_scale, shs.k_scale),
        v_scale=copy_leaf(src.v_scale, dst.v_scale, shs.v_scale)), moved


# ------------------------------------------------------------- accounting
def kv_bytes_per_token(n_kv: int, d_head: int, page_size: int,
                       kv_dtype: str = "int8") -> float:
    """Steady-state pool bytes per cached token (k+v, scales amortized)."""
    if kv_dtype == "int8":
        return 2 * n_kv * d_head + 2 * n_kv * 4 / page_size
    return 2 * n_kv * d_head * 2          # bf16 pages


def dense_kv_bytes_per_token(n_kv: int, d_head: int) -> float:
    """The dense bf16 cache burns this per *slot* whether used or not."""
    return 2 * n_kv * d_head * 2
