"""repro.sched: the serving scheduler subsystem.

Covers: chunked prefill correctness (token-for-token vs the serial
one-request-at-a-time baseline, bf16 pages — bit-exact attention),
admission policies (FIFO head-of-line vs shortest-prompt-first),
deterministic preemption/requeue under page pressure, shared-prefix page
caching with allocator refcounts (no leak, no double-free), workload
generation determinism, metrics, and the no-silent-drop contract of
Session.run.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import kvstore as kvs
from repro import sched as schd
from repro.api import Engine, Request
from repro.api.session import Session, resolve_kv_cache
from repro.configs import get, reduced
from repro.models import model as M
from repro.sched.scheduler import page_need

CFG = reduced(get("llama3-8b"), n_layers=2, d_model=64, d_ff=128, vocab=256)
PS = 4          # page size: small, so short prompts still span pages
ML = 48         # max_len


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def serial_baseline(params, reqs):
    """Each request alone, one token at a time — the oracle schedule."""
    out = {}
    for r in reqs:
        sess = Session(CFG, params, batch_slots=1, max_len=ML,
                       page_size=PS)
        sess.submit(dataclasses.replace(r, rid=0))
        out[r.rid] = sess.run()[0].tokens
    return [out[r.rid] for r in sorted(reqs, key=lambda r: r.rid)]


def alloc_invariant(alloc: kvs.PageAllocator):
    """Free list and used set partition the pool exactly once — any
    double-free would duplicate a free-list entry."""
    assert len(set(alloc._free)) == len(alloc._free)
    assert not set(alloc._free) & alloc._used
    assert len(alloc._free) + alloc.in_use == alloc.n_pages - 1


# ------------------------------------------------------------ kv defaults
def test_kv_cache_auto_resolution():
    assert resolve_kv_cache(None, CFG) in ("paged", "full")
    assert resolve_kv_cache("auto", CFG) == "paged"
    assert resolve_kv_cache("full", CFG) == "full"
    assert resolve_kv_cache("auto", get("rwkv6-7b")) == "full"


def test_default_session_is_paged(params):
    sess = Session(CFG, params, batch_slots=2, max_len=32)
    if resolve_kv_cache(None, CFG) == "paged":    # env may force full
        assert sess.kv_cache == "paged"
        assert sess.alloc is not None


# -------------------------------------------------------- chunked prefill
def test_chunked_prefill_matches_serial(params):
    prompts = [list(range(1, 20)), list(range(30, 41)), [7, 8, 9]]
    reqs = [Request(prompt=p, max_new=5, rid=i)
            for i, p in enumerate(prompts)]
    base = serial_baseline(params, reqs)
    sess = Session(CFG, params, batch_slots=2, max_len=ML, page_size=PS,
                   scheduler={"chunk": 8})
    for r in reqs:
        sess.submit(r)
    got = sess.run()
    assert [r.tokens for r in got] == base
    assert sess.alloc.in_use == 0
    alloc_invariant(sess.alloc)


def test_chunked_prefill_first_token_call_bound(params):
    """First token within ceil(P/C) model calls of admission (the
    acceptance bound is ceil(P/C)+1; the implementation meets ceil)."""
    P, C = 19, 8
    sess = Session(CFG, params, batch_slots=1, max_len=ML, page_size=PS,
                   scheduler={"chunk": C})
    sess.submit(Request(prompt=list(range(1, P + 1)), max_new=2, rid=0))
    sess.run()
    rec = sess.records[0]
    calls = rec["first_token_step"] - rec["admit_step"]
    assert calls <= -(-P // C) + 1
    assert calls < P                  # strictly beats one-token prefill


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "gemma2-2b",
                                  "mixtral-8x7b"])
def test_chunked_prefill_arch_variants_match(arch):
    """Chunk attention across block features: per-layer SWA windows
    (danube), local/global + softcaps + post-norms + embed/attn scaling
    (gemma2), MoE routing over the chunk (mixtral)."""
    cfg = reduced(get(arch))
    eng = Engine(cfg)
    reqs = lambda: [Request(prompt=list(range(2, 22)), max_new=6, rid=0)]  # noqa: E731
    base = eng.serve(reqs(), batch_slots=1, max_len=64,
                     scheduler={"chunk": 1})
    got = eng.serve(reqs(), batch_slots=1, max_len=64,
                    scheduler={"chunk": 8})
    assert [r.tokens for r in base] == [r.tokens for r in got]


def test_chunk_falls_back_where_unsupported(params):
    """rwkv6/hymba have per-token recurrent state: chunk clamps to 1."""
    assert not schd.supports_chunked_prefill(get("rwkv6-7b"))
    assert not schd.supports_chunked_prefill(get("hymba-1.5b"))
    sess = Session(CFG, params, batch_slots=1, max_len=32,
                   kv_cache="full", scheduler={"chunk": 8})
    assert sess.chunk == 1            # no pages to write into


# ------------------------------------------------------- policies / queue
def test_sjf_policy_orders_by_prompt_length():
    s = schd.Scheduler(schd.SchedConfig(policy="sjf"))
    for rid, n in enumerate([9, 3, 6]):
        s.submit(Request(prompt=[1] * n, rid=rid))
    order = [s.next_entry(lambda e: True).req.rid for _ in range(3)]
    assert order == [1, 2, 0]


def test_fifo_head_of_line_blocks():
    s = schd.Scheduler(schd.SchedConfig(policy="fifo"))
    s.submit(Request(prompt=[1] * 9, rid=0))
    s.submit(Request(prompt=[1], rid=1))
    assert s.next_entry(lambda e: len(e.req.prompt) < 5) is None
    assert s.stats["admission_blocks"] == 1
    assert len(s) == 2                # nothing popped


def test_admission_blocks_oversized_request(params):
    """Worst-case page need > pool: refused up front, OutOfPages."""
    sess = Session(CFG, params, batch_slots=2, max_len=ML, page_size=PS,
                   kv_pool_pages=3)
    sess.submit(Request(prompt=[1, 2, 3, 4, 5], max_new=8, rid=0))
    with pytest.raises(kvs.OutOfPages):
        sess.run()


# ------------------------------------------------------------- preemption
def pressure_session(params, **kw):
    """3 slots sharing a pool sized below 3x worst-case need."""
    need = page_need(PS, 2 * PS, ML, PS)
    sess = Session(CFG, params, batch_slots=3, max_len=ML, page_size=PS,
                   kv_pool_pages=1 + 3 * need - 2, **kw)
    for i in range(5):
        sess.submit(Request(prompt=[2 + i] * PS, max_new=2 * PS, rid=i))
    return sess


def test_preemption_completes_and_matches_serial(params):
    reqs = [Request(prompt=[2 + i] * PS, max_new=2 * PS, rid=i)
            for i in range(5)]
    base = serial_baseline(params, reqs)
    sess = pressure_session(params)
    got = sess.run()
    assert sess.stats["preemptions"] >= 1
    assert [r.tokens for r in got] == base
    assert sess.alloc.in_use == 0
    alloc_invariant(sess.alloc)


def test_preemption_is_deterministic(params):
    a = pressure_session(params)
    ra = a.run()
    b = pressure_session(params)
    rb = b.run()
    assert [r.tokens for r in ra] == [r.tokens for r in rb]
    assert a.stats["preemptions"] == b.stats["preemptions"]
    assert [r["preemptions"] for r in a.records] == \
        [r["preemptions"] for r in b.records]


def test_preemption_evicts_youngest(params):
    """The victim is the most recently admitted request; the oldest
    runner is never evicted (progress guarantee)."""
    sess = pressure_session(params)
    sess.run()
    recs = {r["rid"]: r for r in sess.records}
    preempted = [rid for rid, r in recs.items() if r["preemptions"]]
    assert preempted, "pressure workload must preempt"
    # rid 0 was admitted first and must never have been evicted
    assert 0 not in preempted


# ----------------------------------------------------------- prefix cache
def prefix_reqs(n=4, shared=8, tail=3):
    head = list(range(1, shared + 1))
    return [Request(prompt=head + [50 + i] * tail, max_new=4, rid=i)
            for i in range(n)]


def test_prefix_cache_reuses_pages_and_matches(params):
    reqs = prefix_reqs()
    base = serial_baseline(params, reqs)
    sess = Session(CFG, params, batch_slots=2, max_len=ML, page_size=PS,
                   scheduler={"chunk": 4, "prefix_cache": True})
    for r in reqs:
        sess.submit(r)
    got = sess.run()
    assert [r.tokens for r in got] == base
    # 8-token shared head at ps=4 -> 2 cacheable pages, hit by every
    # request admitted after the first wave filled the cache (the two
    # concurrently-admitted openers both miss: first writer wins)
    assert sess.stats["prefix_pages_reused"] >= 2 * (len(reqs) - 2)
    assert sess.prefix.hits >= len(reqs) - 2
    # drained: only the cache pins remain, and they account exactly
    assert sess.alloc.in_use == sess.prefix.pages
    alloc_invariant(sess.alloc)
    sess.prefix.clear(sess.alloc)
    assert sess.alloc.in_use == 0
    alloc_invariant(sess.alloc)


def test_prefix_refcounts_no_double_free():
    alloc = kvs.PageAllocator(8)
    cache = schd.PrefixCache()
    pid = alloc.alloc()
    assert cache.insert(b"h", pid, alloc)
    assert not cache.insert(b"h", pid, alloc)   # first writer wins
    assert alloc.refcount(pid) == 2
    alloc.free([pid])                           # sequence done
    assert alloc.in_use == 1                    # pin keeps it alive
    got = cache.lookup(b"h")
    assert got == pid
    alloc.ref(pid)                              # second sequence attaches
    cache.release(alloc, 1)                     # pressure drops the pin
    assert cache.peek(b"h") is None
    assert alloc.in_use == 1                    # sequence still owns it
    alloc.free([pid])
    assert alloc.in_use == 0
    alloc.free([pid])                           # double free: no-op
    alloc_invariant(alloc)
    with pytest.raises(ValueError):
        alloc.ref(pid)                          # can't resurrect


def test_prefix_never_shares_last_prompt_token_page():
    assert schd.prefix.usable_prefix_pages(8, 4) == 1   # exact fit: page
    assert schd.prefix.usable_prefix_pages(9, 4) == 2   # 1 holds token 8
    assert schd.prefix.usable_prefix_pages(3, 4) == 0
    h1 = schd.page_hashes([1, 2, 3, 4, 5, 6, 7, 8], 4)
    h2 = schd.page_hashes([1, 2, 3, 4, 9, 9, 9, 9], 4)
    assert h1[0] == h2[0]            # same first page
    assert h1[1] != h2[1]            # chain: identity includes prefix
    assert schd.page_hashes([1, 2, 3], 4) == []


# ------------------------------------------------------ workload / metrics
def test_workload_generation_is_deterministic():
    spec = schd.WorkloadSpec.preset("heterogeneous", n_requests=6, seed=3)
    a, b = schd.generate(spec), schd.generate(spec)
    assert [(s, r.prompt, r.max_new) for s, r in a] == \
        [(s, r.prompt, r.max_new) for s, r in b]
    steps = [s for s, _ in a]
    assert steps == sorted(steps)
    assert len({len(r.prompt) for _, r in a}) > 1    # heterogeneous
    spec2 = schd.WorkloadSpec.preset("shared-prefix", n_requests=4, seed=0)
    head = None
    for _, r in schd.generate(spec2):
        h = tuple(r.prompt[:spec2.shared_prefix_len])
        assert head is None or h == head
        head = h


def test_run_workload_timed_arrivals(params):
    arrivals = schd.timed_requests("burst", n_requests=4, seed=1,
                                   vocab=CFG.vocab)
    sess = Session(CFG, params, batch_slots=2, max_len=ML, page_size=PS,
                   scheduler={"chunk": 4})
    res = sess.run_workload(arrivals)
    assert len(res) == 4
    assert sess.alloc.in_use == 0
    m = schd.summarize(sess.records, 1.0, sess.stats["steps"])
    assert m["completed"] == 4
    assert m["ttft_s"] and m["first_token_calls"]


def test_metrics_percentiles():
    assert schd.percentile([], 50) is None
    assert schd.percentile([3.0], 99) == 3.0
    xs = list(map(float, range(1, 101)))
    assert schd.percentile(xs, 50) == 51.0
    assert schd.percentile(xs, 99) == 99.0


# --------------------------------------------------------- no silent drop
def test_run_raises_on_unfinished(params):
    sess = Session(CFG, params, batch_slots=1, max_len=ML, page_size=PS)
    for i in range(3):
        sess.submit(Request(prompt=[1, 2], max_new=20, rid=i))
    with pytest.raises(RuntimeError, match="unfinished"):
        sess.run(max_steps=5)


def test_run_warn_reports_partial(params):
    sess = Session(CFG, params, batch_slots=1, max_len=ML, page_size=PS)
    sess.submit(Request(prompt=[1, 2], max_new=2, rid=0))
    sess.submit(Request(prompt=[3, 4], max_new=50, rid=1))
    with pytest.warns(RuntimeWarning, match="unfinished"):
        res = sess.run(max_steps=6, on_incomplete="warn")
    assert [r.rid for r in res] == [0]           # partial, not silent


def test_run_workload_counts_future_arrivals_as_unfinished(params):
    """A not-yet-submitted timed arrival is still a dropped request when
    max_steps runs out — no silent drop through the arrival queue."""
    arrivals = [(0, Request(prompt=[1, 2], max_new=2, rid=0)),
                (50, Request(prompt=[3], max_new=2, rid=1))]
    sess = Session(CFG, params, batch_slots=1, max_len=ML, page_size=PS)
    with pytest.raises(RuntimeError, match="unfinished"):
        sess.run_workload(arrivals, max_steps=4)


def test_idle_fast_forward_keeps_step_count_honest(params):
    """stats['steps'] counts executed model calls only; the arrival
    clock jumps idle gaps without inflating it."""
    arrivals = [(0, Request(prompt=[1, 2], max_new=2, rid=0)),
                (30, Request(prompt=[3], max_new=2, rid=1))]
    sess = Session(CFG, params, batch_slots=1, max_len=ML, page_size=PS)
    res = sess.run_workload(arrivals)
    assert len(res) == 2
    assert sess.stats["steps"] == 5   # 3 calls for rid 0 + 2 for rid 1


# ---------------------------------------------------------- sjf aging
def test_sjf_aging_promotes_starved_long_prompt():
    """A long prompt waiting past ``sjf_age_limit`` steps jumps the
    shortest-first order — deterministic promotion, oldest first."""
    s = schd.Scheduler(schd.SchedConfig(policy="sjf", sjf_age_limit=5))
    s.submit(Request(prompt=[1] * 9, rid=0), step=0)
    s.submit(Request(prompt=[1] * 2, rid=1), step=1)
    # inside the bound: plain shortest-prompt-first
    assert s.next_entry(lambda e: True, step=3).req.rid == 1
    s.submit(Request(prompt=[1] * 2, rid=2), step=4)
    # rid 0 has now waited 6 > 5 steps: promoted over the shorter rid 2
    assert s.next_entry(lambda e: True, step=6).req.rid == 0
    assert s.next_entry(lambda e: True, step=6).req.rid == 2


def test_sjf_aged_head_blocks_like_fifo():
    """An over-age entry that does not fit must BLOCK admission (like a
    fifo head) — otherwise short prompts starve it forever."""
    s = schd.Scheduler(schd.SchedConfig(policy="sjf", sjf_age_limit=2))
    s.submit(Request(prompt=[1] * 9, rid=0), step=0)
    s.submit(Request(prompt=[1], rid=1), step=0)
    fits = lambda e: len(e.req.prompt) < 5
    assert s.next_entry(fits, step=1).req.rid == 1   # not yet aged
    s.submit(Request(prompt=[1], rid=2), step=1)
    assert s.next_entry(fits, step=5) is None        # aged head blocks
    assert len(s) == 2
    assert s.stats["admission_blocks"] == 1


def test_sjf_age_limit_none_never_promotes():
    s = schd.Scheduler(schd.SchedConfig(policy="sjf",
                                        sjf_age_limit=None))
    s.submit(Request(prompt=[1] * 9, rid=0), step=0)
    s.submit(Request(prompt=[1], rid=1), step=10_000)
    assert s.next_entry(lambda e: True, step=10_000).req.rid == 1
    with pytest.raises(ValueError, match="sjf_age_limit"):
        schd.SchedConfig(policy="sjf", sjf_age_limit=0)


def test_metrics_zero_span_reports_none():
    """Zero-span / zero-step summaries report None rates instead of
    raising ZeroDivisionError (empty workloads, instant drains)."""
    m = schd.summarize([], 0.0, 0)
    assert m["tok_per_s"] is None
    assert m["goodput_req_per_s"] is None
    assert m["requests"] == 0
    m2 = schd.summarize([], 0.0, 0,
                        roles={"prefill": {"steps": 0, "busy_ticks": 0},
                               "_ticks": 0})
    assert m2["roles"]["prefill"]["utilization"] is None


# ------------------------------------------------------------ resil churn
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_churn_preemption_faults_no_leaks(params, seed):
    """Interleave admission, page-pressure preemption, and injected
    page-spike faults on one pool: every request still completes with
    oracle tokens, the allocator drains to zero with exact refcounts,
    and the audit finds nothing — the leak-freedom contract under churn."""
    from repro import resil as rsl
    rng = np.random.default_rng(seed)
    wl = schd.WorkloadSpec(n_requests=8, prompt_len=(3, 12),
                           max_new=(1, 8), arrival="poisson",
                           vocab=CFG.vocab, seed=seed)
    arrivals = schd.generate(wl)
    base = serial_baseline(params, [r for _, r in arrivals])
    need = page_need(12, 8, ML, PS)
    sess = Session(CFG, params, batch_slots=3, max_len=ML, page_size=PS,
                   kv_pool_pages=1 + 2 * need,   # below 3x worst case
                   scheduler={"chunk": int(rng.integers(1, 6))},
                   resil={"fault_plan": f"page-spike:{seed}",
                          "watchdog_every": 3, "max_retries": 2})
    got = sess.run_workload(arrivals)
    assert [r.tokens for r in got] == base
    assert not sess.failed
    assert sess.alloc.in_use == 0
    alloc_invariant(sess.alloc)
    assert rsl.audit_session(sess) == []


# ------------------------------------------------------- hypothesis sweep
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYP = False

if HAVE_HYP:
    @pytest.fixture(scope="module")
    def hyp_params():
        return M.init_params(CFG, jax.random.PRNGKey(0))

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 9999),
           chunk=st.sampled_from([2, 5, 8]),
           policy=st.sampled_from(["fifo", "sjf"]),
           arrival=st.sampled_from(["batch", "poisson"]),
           n=st.integers(1, 5))
    def test_prop_scheduler_matches_serial(hyp_params, seed, chunk,
                                           policy, arrival, n):
        """Any (prompt_len, max_new, arrival) schedule x policy x chunk:
        batched scheduled output == serial one-at-a-time baseline."""
        spec = schd.WorkloadSpec(n_requests=n, prompt_len=(1, 20),
                                 max_new=(1, 10), arrival=arrival,
                                 vocab=CFG.vocab, seed=seed)
        arrivals = schd.generate(spec)
        base = serial_baseline(hyp_params, [r for _, r in arrivals])
        sess = Session(CFG, hyp_params, batch_slots=3, max_len=ML,
                       page_size=PS,
                       scheduler={"chunk": chunk, "policy": policy})
        got = sess.run_workload(arrivals)
        assert [r.tokens for r in got] == base
        assert sess.alloc.in_use == 0
        alloc_invariant(sess.alloc)

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 9999),
           preset=st.sampled_from(["page-spike", "straggler",
                                   "role-stall"]),
           chunk=st.sampled_from([2, 4, 8]))
    def test_prop_churn_faults_drain_clean(hyp_params, seed, preset,
                                           chunk):
        """Any fault preset x seed x chunk over a pressured pool:
        completes with oracle tokens, drains with zero leaks and exact
        refcounts."""
        from repro import resil as rsl
        spec = schd.WorkloadSpec(n_requests=6, prompt_len=(2, 12),
                                 max_new=(1, 8), arrival="poisson",
                                 vocab=CFG.vocab, seed=seed)
        arrivals = schd.generate(spec)
        base = serial_baseline(hyp_params, [r for _, r in arrivals])
        need = page_need(12, 8, ML, PS)
        sess = Session(CFG, hyp_params, batch_slots=3, max_len=ML,
                       page_size=PS, kv_pool_pages=1 + 2 * need,
                       scheduler={"chunk": chunk},
                       resil={"fault_plan": f"{preset}:{seed}",
                              "watchdog_every": 4, "max_retries": 2})
        got = sess.run_workload(arrivals)
        assert [r.tokens for r in got] == base
        assert sess.alloc.in_use == 0
        alloc_invariant(sess.alloc)
        assert rsl.audit_session(sess) == []
