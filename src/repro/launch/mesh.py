"""Production meshes.  Defined as FUNCTIONS so importing never touches jax
device state (jax locks the device count on first backend init)."""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16 data × 16 model).  Multi-pod: 2 × 256."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_pp_mesh():
    """Optional pipeline-parallel mesh (4 stages × 8 data × 8 model)."""
    return jax.make_mesh((4, 8, 8), ("pipe", "data", "model"))


def mesh_shape_dict(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh) -> Tuple[str, ...]:
    """The batch-sharding axes for this mesh (pod folds into data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    d = mesh_shape_dict(mesh)
    out = 1
    for a in dp_axes(mesh):
        out *= d[a]
    return out
