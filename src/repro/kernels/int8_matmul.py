"""Fused int8 weight matmul — the MXU-native wordlength point of Fig. 5(b).

Weights live in HBM as int8 with a per-output-channel f32 scale (8x less
HBM traffic than f32, 2x less than bf16).  Each kernel instance feeds the
MXU an int8 [bn x bk] weight tile cast next to the compute unit, and the
epilogue folds the per-channel dequant scale (plus optional bias and
activation) into the final K step — the dequantized weight matrix never
exists in HBM, and y never round-trips for the bias/activation.

Odd shapes are padded up to the tile grid and the output sliced back, so
callers never see the MXU's 128-alignment.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import apply_activation as _act
from repro.kernels.util import cdiv as _cdiv


def _int8_kernel(x_ref, q_ref, scale_ref, *opt_refs, n_k_blocks: int,
                 has_bias: bool, activation: Optional[str]):
    """Grid (m, n, k): acc[bm,bn] += x[bm,bk] @ q[bn,bk].T; epilogue
    applies the per-channel scale (+ bias, activation) on the last K step.
    """
    refs = list(opt_refs)
    bias_ref = refs.pop(0) if has_bias else None
    o_ref, acc_ref = refs
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = q_ref[...].astype(jnp.float32)                   # int8 cast in VMEM
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kb == n_k_blocks - 1)
    def _done():
        y = acc_ref[...] * scale_ref[...]                # [bm,bn] * [1,bn]
        if has_bias:
            y = y + bias_ref[...]
        o_ref[...] = _act(activation, y)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk",
                                             "activation", "interpret"))
def int8_matmul(x: jnp.ndarray, q: jnp.ndarray, scale: jnp.ndarray, *,
                bias: Optional[jnp.ndarray] = None,
                activation: Optional[str] = None,
                bm: int = 8, bn: int = 128, bk: int = 512,
                interpret: bool = True) -> jnp.ndarray:
    """act(x [B,K] @ (q [N,K] * scale [N,1|1,1]).T + bias [N]) -> [B,N] f32.

    BlockSpecs: x tiles [bm,bk] f32, weight tiles [bn,bk] int8 (1 byte/
    weight of VMEM), scale/bias replicated per n tile.  All dims are
    padded to the tile grid and the output sliced back.
    """
    b, k = x.shape
    n, k2 = q.shape
    assert k2 == k, "weight K must match activation K"
    bm, bn, bk = min(bm, _cdiv(b, 8) * 8), min(bn, n), min(bk, k)
    bp, np_, kp = _cdiv(b, bm) * bm, _cdiv(n, bn) * bn, _cdiv(k, bk) * bk
    if (bp, kp) != (b, k):
        x = jnp.pad(x, ((0, bp - b), (0, kp - k)))
    if (np_, kp) != (n, k):
        q = jnp.pad(q, ((0, np_ - n), (0, kp - k)))
    scale2d = jnp.broadcast_to(scale.astype(jnp.float32).reshape(1, -1),
                               (1, n))
    scale2d = jnp.pad(scale2d, ((0, 0), (0, np_ - n)))
    grid = (bp // bm, np_ // bn, kp // bk)
    has_bias = bias is not None
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
        pl.BlockSpec((bn, bk), lambda i, j, kb: (j, kb)),
        pl.BlockSpec((1, bn), lambda i, j, kb: (0, j)),
    ]
    args = [x, q, scale2d]
    if has_bias:
        bias2d = jnp.pad(bias.astype(jnp.float32).reshape(1, -1),
                         ((0, 0), (0, np_ - n)))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kb: (0, j)))
        args.append(bias2d)
    out = pl.pallas_call(
        functools.partial(_int8_kernel, n_k_blocks=grid[2],
                          has_bias=has_bias, activation=activation),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*args)
    return out[:b, :n]
