"""Seeded, deterministic fault injection for the serving stack.

A :class:`FaultPlan` is a pure function of ``(seed, preset)``: every
decision it makes — drop this handoff, stall that role for a tick, hold
back a fraction of the page pool — is derived by hashing the decision's
coordinates (role, tick, rid, attempt) together with the seed. There is
no internal RNG state, so replaying the same workload under the same
plan yields byte-identical decisions regardless of call order, and two
independently constructed plans with the same ``(seed, preset)`` agree.

Injection seams (callers, not this module, own the semantics):

- ``check_step(role, tick)`` — called at the top of a Session advance;
  raises :class:`InjectedFault` to burn the tick (role-stall, straggler).
- ``drop_handoff(rid, attempt)`` / ``handoff_delay(rid)`` — consulted by
  the disagg orchestrator when a prefill->decode handoff is enqueued.
- ``page_holdback(usable, tick, role)`` — number of pages the allocator
  should pretend are unavailable this tick (page-spike).

Decisions are deterministic; the per-class counters in ``stats`` are a
convenience for attribution and are equally deterministic for a fixed
workload.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional


class InjectedFault(RuntimeError):
    """A deliberately injected fault (not a bug). Carries its class and
    the (role, tick) coordinates of the decision so catchers can trace
    the injection without re-deriving them."""

    def __init__(self, fault_class: str, msg: str,
                 role: Optional[str] = None, tick: Optional[int] = None):
        super().__init__(msg)
        self.fault_class = fault_class
        self.role = role
        self.tick = tick


# Built-in presets. Window starts get a small seed-derived jitter so
# different seeds exercise different phases of the workload.
PRESETS: Dict[str, Dict] = {
    "none": {},
    # Drop or delay prefill->decode handoffs at the router seam.
    "drop-handoff": {
        "drop_p": 0.35,
        "max_drops": 2,
        "delay_p": 0.35,
        "max_delay": 3,
        "redeliver_after": 3,
    },
    # One role fails every step for a contiguous window of ticks.
    "role-stall": {"role": "decode", "start": 5, "span": 6, "jitter": 4},
    # A fraction of the page pool becomes unavailable for a window.
    "page-spike": {"role": "decode", "start": 4, "span": 8, "frac": 0.6, "jitter": 4},
    # Scattered single-tick stalls on one role (tail latency).
    "straggler": {"role": "prefill", "p": 0.3},
}


def _role_match(target: str, role: str) -> bool:
    # A co-located session (role "engine") embodies every role.
    return role == target or role == "engine" or target == "any"


@dataclasses.dataclass
class FaultPlan:
    preset: str
    seed: int = 0
    params: Dict = dataclasses.field(default_factory=dict)
    stats: Dict[str, int] = dataclasses.field(default_factory=dict)

    @classmethod
    def make(cls, preset: str, seed: int = 0, **overrides) -> "FaultPlan":
        if preset not in PRESETS:
            raise ValueError(
                f"unknown fault preset {preset!r}; choose from {sorted(PRESETS)}"
            )
        params = dict(PRESETS[preset])
        params.update(overrides)
        return cls(preset=preset, seed=int(seed), params=params)

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse ``"preset"`` or ``"preset:seed"`` (e.g. ``drop-handoff:3``)."""
        if isinstance(spec, FaultPlan):
            return spec
        name, _, seed_s = str(spec).partition(":")
        seed = 0
        if seed_s:
            try:
                seed = int(seed_s)
            except ValueError:
                raise ValueError(f"bad fault plan seed in {spec!r} (want PRESET:SEED)")
        return cls.make(name, seed)

    def describe(self) -> str:
        return f"{self.preset}:{self.seed}"

    # ---- deterministic decision primitive -------------------------------
    def _unit(self, *keys) -> float:
        """Uniform [0, 1) from a stable hash of (seed, preset, keys)."""
        payload = f"{self.seed}|{self.preset}|" + "|".join(str(k) for k in keys)
        h = hashlib.blake2b(payload.encode(), digest_size=8).digest()
        return int.from_bytes(h, "little") / 2.0**64

    def _count(self, key: str, n: int = 1) -> None:
        self.stats[key] = self.stats.get(key, 0) + n

    def _window(self) -> Optional[range]:
        if "start" not in self.params:
            return None
        start = self.params["start"]
        jitter = self.params.get("jitter", 0)
        if jitter:
            start += int(self._unit("window") * (jitter + 1))
        return range(start, start + self.params["span"])

    # ---- step seam ------------------------------------------------------
    def step_fault(self, role: str, tick: int) -> Optional[str]:
        """Fault class that hits `role` stepping at `tick`, or None."""
        p = self.params
        if self.preset == "role-stall" and _role_match(p["role"], role):
            if tick in self._window():
                return "role-stall"
        if self.preset == "straggler" and _role_match(p["role"], role):
            if self._unit("straggle", role, tick) < p["p"]:
                return "straggler"
        return None

    def check_step(self, role: str, tick: int) -> None:
        """Raise InjectedFault if this role's step faults at this tick."""
        cls = self.step_fault(role, tick)
        if cls is not None:
            self._count(cls)
            raise InjectedFault(cls, f"{cls}: role={role} tick={tick}",
                                role=role, tick=tick)

    # ---- handoff seam ---------------------------------------------------
    def drop_handoff(self, rid: int, attempt: int) -> bool:
        """Whether delivery `attempt` (0-based) of rid's handoff is dropped."""
        p = self.params
        if self.preset != "drop-handoff":
            return False
        if attempt >= p["max_drops"]:  # guarantee eventual delivery
            return False
        if self._unit("drop", rid, attempt) < p["drop_p"]:
            self._count("drop-handoff")
            return True
        return False

    def handoff_delay(self, rid: int) -> int:
        """Extra ticks before rid's handoff becomes visible to decode."""
        p = self.params
        if self.preset != "drop-handoff":
            return 0
        if self._unit("delay", rid) < p["delay_p"]:
            d = 1 + int(self._unit("delay-n", rid) * p["max_delay"])
            self._count("delay-handoff")
            return d
        return 0

    @property
    def redeliver_after(self) -> int:
        return self.params.get("redeliver_after", 3)

    # ---- allocator seam -------------------------------------------------
    def page_holdback(self, usable: int, tick: int, role: str = "engine") -> int:
        """Pages to hold out of `role`'s pool at `tick` (page-spike)."""
        p = self.params
        if self.preset != "page-spike" or not _role_match(p["role"], role):
            return 0
        if tick in self._window():
            n = int(usable * p["frac"])
            if n > 0:
                self._count("page-spike-ticks")
            return n
        return 0

    def any_window_active(self, tick: int) -> bool:
        """True if a windowed fault (stall/spike) is active at `tick`."""
        w = self._window()
        return w is not None and tick in w
