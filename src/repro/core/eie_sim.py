"""EIE baseline model — the paper's comparison point (Han et al., ISCA'16).

AIDA's Table 1 compares against EIE, so the reproduction needs an EIE model.
Built from EIE's published microarchitecture: 64 processing elements (PEs),
800 MHz (28nm-scaled figure used by the AIDA paper), CSC-striped weight
storage, one MAC per PE per cycle on nonzero (weight × activation) pairs,
leading-nonzero-detect broadcast of nonzero activations.

Performance model:
  peak  = 2 ops × 64 PEs × f                           = 102.4 GOP/s ✓
  layer cycles ≈ (nnz touched by nonzero activations) / 64 × load_imbalance
  (EIE paper reports ~63% average PE utilization on real layers → default
   imbalance 1.6).

Energy convention (reverse-engineered from Table 1, see aida_sim docstring):
EIE's listed 2756 GOP/J counts DENSE-EQUIVALENT ops (≈10× weight sparsity) —
102.4 GOPs × 10 / 0.37 W = 2768 ≈ 2756.  We reproduce both conventions.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

from repro.core.aida_sim import FCLayerSpec, alexnet_fc, ctc_lstm  # noqa: F401


@dataclasses.dataclass(frozen=True)
class EIEConfig:
    n_pe: int = 64
    freq_hz: float = 800e6          # Table 1 (28nm scaled)
    power_w: float = 0.37           # Table 1 (28nm scaled)
    load_imbalance: float = 1.6     # ≈1/0.63 PE utilization (EIE paper)
    act_queue_overhead: float = 1.05  # broadcast FIFO stalls


def layer_cycles(l: FCLayerSpec, cfg: EIEConfig = EIEConfig()) -> float:
    """Cycles for one sparse M×V on EIE.

    Work = nonzeros in the columns selected by nonzero activations
         ≈ nnz × a_density, spread over 64 PEs with imbalance.
    """
    work = l.nnz * l.a_density
    return work / cfg.n_pe * cfg.load_imbalance * cfg.act_queue_overhead


@dataclasses.dataclass
class EIEReport:
    name: str
    cycles_total: float
    peak_gops: float
    effective_gops: float
    inf_per_s: float
    power_w: float
    ee_sparse_gop_j: float
    ee_dense_equiv_gop_j: float


def evaluate_network(name: str, layers: Sequence[FCLayerSpec],
                     cfg: EIEConfig = EIEConfig()) -> EIEReport:
    cyc = sum(layer_cycles(l, cfg) for l in layers)
    t = cyc / cfg.freq_hz
    ops = 2 * sum(l.nnz * l.a_density for l in layers)  # MACs actually done
    dense_ops = 2 * sum(l.n_out * l.n_in for l in layers)
    peak = 2 * cfg.n_pe * cfg.freq_hz / 1e9
    eff = ops / t / 1e9
    return EIEReport(
        name=name, cycles_total=cyc, peak_gops=peak,
        effective_gops=eff, inf_per_s=1.0 / t, power_w=cfg.power_w,
        ee_sparse_gop_j=peak / cfg.power_w,
        ee_dense_equiv_gop_j=(dense_ops / t / 1e9) / cfg.power_w)


def eie_table1(cfg: EIEConfig = EIEConfig()) -> dict:
    alex = evaluate_network("AlexNet-FC", alexnet_fc(), cfg)
    ctc = evaluate_network("CTC-3L-421H-UNI", ctc_lstm(), cfg)
    return dict(alexnet=alex, ctc=ctc,
                pp_gops=alex.peak_gops,
                thrpt_inf_s=ctc.inf_per_s,
                power_w=cfg.power_w,
                ee_gop_per_j=2756.0,  # EIE's listed (dense-equivalent) figure
                ee_model_dense_equiv=ctc.ee_dense_equiv_gop_j)
