"""`repro.shard` — explicit sharding plans for the serving stack.

The AIDA scaling story is partitioning FC weight matrices across many
associative-memory ICs that compute shard-locally and in parallel (EIE
distributes its CSC-interleaved matrix across PEs the same way).  This
package is that idea applied to the serving stack: a `ShardingPlan`
built from a mesh + model config assigns a `NamedSharding` to every
param / decode-state / KV leaf, decides the gather-vs-psum combine
policy per compressed mode, and drives shard-local compressed SpMV
through `shard_map` so tensor-parallel FC is real per-device kernel
work, not GSPMD replication.

* `plan`      — ShardingPlan: per-leaf NamedShardings, combine policy
* `partition` — shard-aware re-stacking / per-shard padding of
                compressed containers, param placement, local views
* `apply`     — shard-local compressed FC (`shard_map` SpMV + combine)

`Engine.session(mesh=...)` builds a plan and threads it through
`models/{layers,attention,transformer}` and `sched.prefill`; with no
mesh every entry point behaves exactly as before (plan=None).
"""
from repro.shard.apply import (apply_fc_sharded,
                               paged_attention_chunk_sharded,
                               paged_attention_sharded)
from repro.shard.partition import (local_view, pad_params_for_plan,
                                   prepare_params, tune_local_views)
from repro.shard.plan import ShardingPlan, make_plan

__all__ = [
    "ShardingPlan", "apply_fc_sharded", "local_view", "make_plan",
    "pad_params_for_plan", "paged_attention_chunk_sharded",
    "paged_attention_sharded", "prepare_params", "tune_local_views",
]
