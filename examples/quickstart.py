"""Quickstart: train a tiny llama-family model, checkpoint it, generate.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.api import Engine, Request
from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get, reduced
from repro.data.pipeline import DataIterator, PipelineConfig
from repro.optim.adamw import AdamWConfig
from repro.train import trainer


def main():
    cfg = reduced(get("llama3-8b"), n_layers=2, d_model=128, d_ff=256,
                  vocab=512)
    print(f"arch: {cfg.name}  params ~{cfg.params_count()/1e6:.1f}M")

    tc = trainer.TrainConfig(
        remat="none",
        opt=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60))
    data = DataIterator(cfg, PipelineConfig(seed=0, global_batch=8,
                                            seq_len=64))
    mgr = CheckpointManager("/tmp/repro_quickstart", keep_last=2)
    state = trainer.run(cfg, tc, data, n_steps=40,
                        key=jax.random.PRNGKey(0), ckpt_mgr=mgr,
                        ckpt_every=20, log_every=10)
    mgr.wait()
    print(f"checkpoints: steps {mgr.list_steps()}")

    eng = Engine(cfg, params=state.params)
    reqs = [Request(prompt=[1, 2 + rid, 3], max_new=8, rid=rid)
            for rid in range(3)]
    for r in eng.serve(reqs, batch_slots=2, max_len=64):
        print(f"request {r.rid}: {r.tokens}")


if __name__ == "__main__":
    main()
