"""ArchConfig — one schema covering all 10 assigned architectures.

Per-layer attention windows are encoded as an int vector (−1 = full causal)
so alternating local/global stacks (gemma2, hymba) fit a homogeneous
scan-over-layers.  Vocab is padded to a 128 multiple internally (TP
divisibility); the loss masks padded ids.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

_REGISTRY = {}


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    group_size: int = 1024
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hymba | rwkv6 | encoder
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: Optional[float] = 10000.0
    window: int = -1               # SWA width for local layers (-1 = full)
    local_global_period: int = 0   # gemma2: every k-th layer is global
    full_attn_layers: Tuple[int, ...] = ()  # hymba: these layers are global
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    attn_scale: Optional[float] = None      # gemma2 query_pre_attn_scalar
    post_norms: bool = False                # gemma2 post-block norms
    act: str = "silu"
    gated_mlp: bool = True
    embed_scale: bool = False               # gemma2: x *= sqrt(d)
    tie_embeddings: bool = True
    causal: bool = True
    moe: Optional[MoECfg] = None
    ssm_state: int = 16
    rwkv_head_dim: int = 64
    frontend: Optional[str] = None          # vision | audio
    n_img_tokens: int = 576
    audio_in_dim: int = 512
    norm: str = "rms"                       # rms | layer
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return ((self.vocab + 127) // 128) * 128

    @property
    def sub_quadratic(self) -> bool:
        """May this arch run long_500k decode? True for SSM/hybrid and
        bounded-window (SWA) attention; gemma2's alternating stack counts
        (local layers ring-cached; sparse global layers sequence-sharded)."""
        if self.family in ("rwkv6", "hymba"):
            return True
        if self.family == "encoder":
            return False
        return self.window > 0  # SWA (incl. gemma2 local/global)

    @property
    def has_decode(self) -> bool:
        return self.family != "encoder"

    def layer_windows(self) -> Tuple[int, ...]:
        """Static per-layer window vector."""
        out = []
        for i in range(self.n_layers):
            w = self.window
            if self.local_global_period and \
                    (i % self.local_global_period ==
                     self.local_global_period - 1):
                w = -1                       # global layer
            if i in self.full_attn_layers:
                w = -1
            out.append(w)
        return tuple(out)

    def params_count(self) -> int:
        """Approximate parameter count (reporting / roofline MODEL_FLOPS)."""
        d, f, L = self.d_model, self.d_ff, self.n_layers
        dh, h, hkv = self.head_dim, self.n_heads, self.n_kv
        emb = self.vocab_padded * d * (1 if self.tie_embeddings else 2)
        if self.family == "rwkv6":
            per = 4 * d * d + d * d + (d * f + f * d + d * d)  # tm + cm
        else:
            attn = d * h * dh + 2 * d * hkv * dh + h * dh * d
            if self.moe:
                ffn = self.moe.n_experts * 3 * d * f + d * self.moe.n_experts
            else:
                ffn = (3 if self.gated_mlp else 2) * d * f
            per = attn + ffn
            if self.family == "hymba":
                per += 2 * d * 2 * d  # mamba in/out projections (approx)
        return emb + L * per

    def active_params_count(self) -> int:
        """Active (per-token) params — MoE counts top_k experts only."""
        if not self.moe:
            return self.params_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        dh, h, hkv = self.head_dim, self.n_heads, self.n_kv
        attn = d * h * dh + 2 * d * hkv * dh + h * dh * d
        ffn = self.moe.top_k * 3 * d * f
        emb = self.vocab_padded * d * (1 if self.tie_embeddings else 2)
        return emb + L * (attn + ffn)


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        import repro.configs  # noqa: F401  (populates registry)
    return _REGISTRY[name]


def names():
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


def reduced(cfg: ArchConfig, n_layers: int = 2, d_model: int = 128,
            d_ff: int = 256, vocab: int = 512) -> ArchConfig:
    """Smoke-test variant: same family/topology, tiny dims."""
    n_heads = max(2, min(cfg.n_heads, 4))
    n_kv = max(1, min(cfg.n_kv, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    kw = dict(
        name=cfg.name + "-smoke", n_layers=n_layers, d_model=d_model,
        n_heads=n_heads, n_kv=n_kv, d_ff=d_ff, vocab=vocab,
        d_head=d_model // n_heads,
        full_attn_layers=tuple(i for i in cfg.full_attn_layers
                               if i < n_layers))
    if cfg.moe:
        kw["moe"] = MoECfg(n_experts=min(cfg.moe.n_experts, 4),
                           top_k=min(cfg.moe.top_k, 2), group_size=64,
                           capacity_factor=2.0)
    if cfg.window > 0:
        kw["window"] = 32
    return dataclasses.replace(cfg, **kw)
