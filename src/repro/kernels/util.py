"""Shared helpers for the FC kernel family (Pallas bodies + XLA refs)."""
from __future__ import annotations

from typing import Optional

import jax


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def apply_activation(name: Optional[str], y):
    """The fused-epilogue activation table.  One definition, shared by the
    Pallas kernel epilogues and the XLA reference paths, so the two can
    never drift apart."""
    if name is None or name == "none":
        return y
    if name == "relu":
        return jax.nn.relu(y)
    if name == "silu":
        return jax.nn.silu(y)
    if name == "gelu":
        return jax.nn.gelu(y, approximate=True)
    raise ValueError(f"unknown fused activation {name!r}")
