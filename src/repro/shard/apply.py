"""Shard-local compressed FC: the paper's multi-IC partitioning, executed.

`apply_fc_sharded` runs one compressed projection tensor-parallel over
the plan's model axis via `shard_map`: every shard holds a band of the
compressed matrix (a contiguous run of ACSR row blocks, or of
int8/codebook output channels) and runs the *existing* kernel —
Pallas fused SpMV, int8, LUT — on its local band only.  Combine policy:

* ``"gather"`` (default, every mode): row partitioning.  Each output
  element is produced entirely on one shard (identical arithmetic to
  the single-device kernel, so results are bit-identical), and the
  shard outputs concatenate along the feature axis — the all-gather is
  materialized lazily by GSPMD only where a consumer needs the full
  vector.
* ``"psum"`` (int8 only): input partitioning.  Shards hold a band of
  *columns*, contract against their slice of the activation, and
  all-reduce partial sums; the per-channel dequant scale + bias/act
  epilogue runs once on the reduced result.  ACSR modes cannot split
  columns (col_idx addresses the full input vector), which is why
  gather is the default policy everywhere.

Leaves whose partition axis does not divide the tp degree fall back to
the plain (replicated) apply — `partition.pad_params_for_plan` exists
so that fallback never triggers for plan-prepared params.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import sparse_fc as sfc
from repro.kernels import ops
from repro.shard import partition


def _local_layer(leaf: sfc.CompressedFC) -> sfc.CompressedFC:
    """Rebuild a CompressedFC whose static ``shape`` matches the local
    array shards shard_map handed us (the pytree aux still carries the
    global shape)."""
    n_in = leaf.shape[1]
    if leaf.mode in ("acsr", "aida"):
        b = leaf.blocked
        rows = b.values.shape[0] * b.block_rows
        blocked = dataclasses.replace(b, shape=(rows, n_in))
        return dataclasses.replace(leaf, blocked=blocked,
                                   shape=(rows, n_in))
    rows = partition.row_axis_len(leaf)
    return dataclasses.replace(leaf, shape=(rows, n_in))


def _row_specs(leaf: sfc.CompressedFC, tp_axis: str) -> sfc.CompressedFC:
    """shard_map in_specs for a single-layer leaf, row-partitioned."""
    from repro.core import quant as q
    from repro.kernels import acsr_spmv as sp
    if leaf.mode in ("acsr", "aida"):
        b = leaf.blocked
        blocked = sp.BlockedACSR(
            values=P(tp_axis, None, None), col_idx=P(tp_axis, None, None),
            row_nnz=P(tp_axis, None), shape=b.shape,
            block_rows=b.block_rows, nnz=b.nnz,
            centroids=None if b.centroids is None else P())
        return sfc.CompressedFC(leaf.mode, leaf.shape, blocked=blocked)
    if leaf.mode == "int8":
        qt = q.QTensor(q=P(tp_axis, None), scale=P(tp_axis, None),
                       bits=leaf.qt.bits)
        return sfc.CompressedFC(leaf.mode, leaf.shape, qt=qt)
    if leaf.mode == "codebook4":
        return sfc.CompressedFC(leaf.mode, leaf.shape,
                                codes_packed=P(tp_axis, None),
                                centroids=P())
    return sfc.CompressedFC(leaf.mode, leaf.shape, dense=P(tp_axis, None))


def _padded_rows(leaf: sfc.CompressedFC) -> int:
    if leaf.mode in ("acsr", "aida"):
        return leaf.blocked.values.shape[-3] * leaf.blocked.block_rows
    return partition.row_axis_len(leaf)


def apply_fc_sharded(plan, layer: sfc.CompressedFC, x: jnp.ndarray,
                     bias: Optional[jnp.ndarray] = None,
                     activation: Optional[str] = None) -> jnp.ndarray:
    """y = act(x @ W.T + bias) for a single-layer compressed leaf,
    computed shard-locally over ``plan``'s model axis.  x: [B, n_in]."""
    tp, ax = plan.tp, plan.tp_axis
    n_out = layer.shape[0]
    if tp == 1 or not partition.shardable(layer, tp):
        return sfc.apply_fc(layer, x, bias=bias, activation=activation)
    policy = plan.policy_for(layer.mode)

    if policy == "psum" and layer.mode == "int8" \
            and layer.shape[1] % tp == 0:
        def local_psum(q_band, x_band):
            acc = jnp.matmul(x_band, q_band.astype(jnp.float32).T,
                             preferred_element_type=jnp.float32)
            return jax.lax.psum(acc, ax)

        acc = shard_map(local_psum, mesh=plan.mesh,
                        in_specs=(P(None, ax), P(None, ax)),
                        out_specs=P(None, None),
                        check_rep=False)(layer.qt.q, x)
        # slice padded rows off BEFORE the epilogue: bias carries the
        # true n_out, the padded q/scale rows are inert
        y = acc[:, :n_out] * layer.qt.scale.reshape(1, -1)[:, :n_out]
        return ops.bias_act_epilogue(y, bias, activation)

    # ------------------------------------------------ gather (default)
    rows_pad = _padded_rows(layer)
    bias_p = None
    if bias is not None:
        bias_p = jnp.pad(bias.astype(jnp.float32),
                         (0, rows_pad - bias.shape[0]))

    if bias_p is None:
        def local(lay, xx):
            return sfc.apply_fc(_local_layer(lay), xx,
                                activation=activation)
        y = shard_map(local, mesh=plan.mesh,
                      in_specs=(_row_specs(layer, ax), P(None, None)),
                      out_specs=P(None, ax), check_rep=False)(layer, x)
    else:
        def local(lay, xx, bb):
            return sfc.apply_fc(_local_layer(lay), xx, bias=bb,
                                activation=activation)
        y = shard_map(local, mesh=plan.mesh,
                      in_specs=(_row_specs(layer, ax), P(None, None),
                                P(ax)),
                      out_specs=P(None, ax),
                      check_rep=False)(layer, x, bias_p)
    return y[:, :n_out]
