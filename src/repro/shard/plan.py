"""ShardingPlan — one object that knows where every serving leaf lives.

A plan is built from a mesh + model config (`make_plan`) and owns three
decisions the serving stack used to hard-code as "everything replicated,
one device":

1. **Param placement** (`param_shardings`): tensor-parallel specs for
   every param leaf, *including compressed containers* — a BlockedACSR
   shards its row-block axis over the model axis (each shard owns a
   contiguous band of output rows, exactly the paper's per-IC matrix
   partitioning), int8/codebook4 shard their output-channel axis (the
   per-row quant scales ride along), norms/biases replicate.  Raw
   (uncompressed) matrices reuse the training-path Megatron specs from
   `models.model._layer_specs` with FSDP turned off (serving replicates
   over the data axis; data-parallel batch slots are the next PR).

2. **State placement** (`state_shardings`): KV pools (paged or dense)
   shard their *head* axis over the model axis — the same devices that
   own a head's wq/wk/wv columns own its cache — while the page table,
   positions and per-slot recurrent state replicate (host-side page
   allocation keeps writing the table with plain `.at[]` updates).

3. **Combine policy** (`policy_for`): per compressed mode, how shard
   partials become the global activation — ``"gather"`` (row/output
   partitioning: every output element is computed entirely on one
   shard, so results are bit-identical to single-device) or ``"psum"``
   (input partitioning: shard-local partial products all-reduced).
   Row partitioning is the default everywhere because ACSR column
   indices address the full input vector — it is also what keeps the
   mesh path token-identical.

Plans are frozen/hashable so they can key step caches and be closed
over by jitted decode steps.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

#: default combine policy per compressed mode ("gather" = row-partitioned
#: shard-local output bands; "psum" = input-partitioned partial sums)
DEFAULT_POLICY: Tuple[Tuple[str, str], ...] = (
    ("dense", "gather"), ("int8", "gather"), ("codebook4", "gather"),
    ("acsr", "gather"), ("aida", "gather"),
)


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    mesh: Mesh
    tp_axis: str = "model"
    dp_axes: Tuple[str, ...] = ("data",)
    policy: Tuple[Tuple[str, str], ...] = DEFAULT_POLICY

    # ------------------------------------------------------------ basics
    @property
    def tp(self) -> int:
        """Model-parallel degree."""
        return int(self.mesh.shape[self.tp_axis])

    @property
    def dp(self) -> int:
        out = 1
        for a in self.dp_axes:
            if a in self.mesh.axis_names:
                out *= int(self.mesh.shape[a])
        return out

    def policy_for(self, mode: str) -> str:
        return dict(self.policy).get(mode, "gather")

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    # ----------------------------------------------------------- fitting
    def _axis_size(self, entry) -> int:
        if entry is None:
            return 1
        axes = entry if isinstance(entry, tuple) else (entry,)
        out = 1
        for a in axes:
            out *= int(self.mesh.shape[a])
        return out

    def fit(self, spec: P, shape: Tuple[int, ...]) -> P:
        """Drop sharded axes that do not divide the actual dim — a leaf
        whose head/row count is not a multiple of the mesh degree
        replicates instead of erroring (padding is partition.py's job
        for the leaves where it pays)."""
        if spec is None:
            return P()
        ents = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
        out = []
        for dim, entry in zip(shape, ents):
            size = self._axis_size(entry)
            out.append(entry if (size > 1 and dim % size == 0) or size == 1
                       else None)
        return P(*out)

    # ------------------------------------------------------------ params
    def _fc_spec_tree(self, leaf):
        """A CompressedFC-shaped pytree of PartitionSpecs (leading axis =
        the scan-over-layers L; row/output axes shard over tp)."""
        from repro.core import quant as q
        from repro.core import sparse_fc as sfc
        from repro.kernels import acsr_spmv as sp
        tp = self.tp_axis
        stk = leaf.dense is not None and leaf.dense.ndim == 3 \
            or leaf.qt is not None and leaf.qt.q.ndim == 3 \
            or leaf.codes_packed is not None and leaf.codes_packed.ndim == 3 \
            or leaf.blocked is not None and leaf.blocked.values.ndim == 4
        lead = (None,) if stk else ()
        if leaf.mode == "dense":
            return sfc.CompressedFC(leaf.mode, leaf.shape,
                                    dense=P(*lead, tp, None))
        if leaf.mode == "int8":
            qt = q.QTensor(q=P(*lead, tp, None), scale=P(*lead, tp, None),
                           bits=leaf.qt.bits)
            return sfc.CompressedFC(leaf.mode, leaf.shape, qt=qt)
        if leaf.mode == "codebook4":
            return sfc.CompressedFC(
                leaf.mode, leaf.shape, codes_packed=P(*lead, tp, None),
                centroids=P())
        if leaf.mode in ("acsr", "aida"):
            b = leaf.blocked
            blocked = sp.BlockedACSR(
                values=P(*lead, tp, None, None),
                col_idx=P(*lead, tp, None, None),
                row_nnz=P(*lead, tp, None),
                shape=b.shape, block_rows=b.block_rows, nnz=b.nnz,
                centroids=None if b.centroids is None else P())
            return sfc.CompressedFC(leaf.mode, leaf.shape, blocked=blocked)
        raise ValueError(leaf.mode)

    def param_specs(self, cfg: ArchConfig, params: Dict):
        """Pytree of PartitionSpecs congruent with ``params`` (compressed
        leaves expand into container-shaped spec subtrees).

        Raw (uncompressed) matrices shard their LAST (output) axis over
        the model axis — column-parallel everywhere.  Unlike the
        training specs (Megatron row-parallel wo/down with psum
        combine), serving never shards a contraction dim: every output
        element is computed with single-device arithmetic, which is
        what keeps mesh decode *token-identical* (psum reduction order
        is the one thing GSPMD may not preserve).  Router/norm/scalar
        leaves replicate (a sharded router softmax would re-order its
        reduction too)."""
        from repro.core import sparse_fc as sfc

        def rule(path, leaf):
            names = tuple(str(getattr(k, "key", k)) for k in path)
            if isinstance(leaf, sfc.CompressedFC):
                return self._fc_spec_tree(leaf)
            if names[0] == "embed":
                return P(self.tp_axis, None)        # vocab rows
            if names[0] == "lm_head":
                return P(None, self.tp_axis)
            if names[0] == "layers" and getattr(leaf, "ndim", 0) >= 3 \
                    and names[-1] != "router":
                # stacked [L, ..., d_out]: output features over model
                return P(*([None] * (leaf.ndim - 1)), self.tp_axis)
            return P()          # norms, biases, routers, scalar leaves

        return jax.tree_util.tree_map_with_path(
            rule, params,
            is_leaf=lambda x: isinstance(x, sfc.CompressedFC))

    def param_shardings(self, cfg: ArchConfig, params: Dict):
        specs = self.param_specs(cfg, params)
        return jax.tree.map(
            lambda leaf, sp: self.named(self.fit(sp, leaf.shape)),
            params, specs)

    # ------------------------------------------------------------- state
    def state_specs(self, state: Dict):
        """Pytree of PartitionSpecs congruent with a decode-state tree:
        KV head axes shard over tp, everything host-managed (page table,
        positions, recurrent slot state) replicates."""
        def rule(path, leaf):
            names = tuple(str(getattr(k, "key", k)) for k in path)
            in_kv = "kv" in names
            if in_kv and leaf.ndim == 5:
                # [L, n_pages|B, Hkv, ps|S, Dh]: heads over model
                return P(None, None, self.tp_axis, None, None)
            if in_kv and leaf.ndim == 3 and \
                    jax.numpy.issubdtype(leaf.dtype, jax.numpy.floating):
                return P(None, None, self.tp_axis)   # per-page/head scales
            return P()
        return jax.tree_util.tree_map_with_path(rule, state)

    def state_shardings(self, state: Dict):
        specs = self.state_specs(state)
        return jax.tree.map(
            lambda leaf, sp: self.named(self.fit(sp, leaf.shape)),
            state, specs)


def make_plan(mesh: Mesh, cfg: Optional[ArchConfig] = None,
              policy=None) -> ShardingPlan:
    """Build a serving ShardingPlan from a mesh (must carry a ``model``
    axis; a ``data`` axis, if present, replicates for now — batch-slot
    data parallelism is the documented next step)."""
    if "model" not in mesh.axis_names:
        raise ValueError(
            f"serving mesh needs a 'model' axis; got {mesh.axis_names}")
    pol = DEFAULT_POLICY if policy is None else tuple(sorted(policy.items()))
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return ShardingPlan(mesh=mesh, tp_axis="model", dp_axes=dp_axes,
                        policy=pol)
