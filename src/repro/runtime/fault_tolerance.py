"""Fault tolerance & elasticity for long multi-pod jobs.

Components (all host-side control plane; the data plane is checkpoint/ckpt):

  HeartbeatRegistry   — workers ping; a monitor marks nodes dead after a
                        timeout and triggers job-level restart decisions.
  StragglerDetector   — robust z-score over step times; persistent outliers
                        are flagged for eviction/replacement (at scale the
                        scheduler swaps the host and the job restarts from
                        the last checkpoint with the same mesh).
  ElasticPlan         — given the surviving chip count, picks the largest
                        admissible mesh (data axis shrinks first, model axis
                        preserved so TP weight shards stay intact) and the
                        adjusted per-shard batch; checkpoint restore onto the
                        new mesh is handled by CheckpointManager.restore
                        (logical-array checkpoints are mesh-agnostic).
  RestartLoop         — supervise(train_fn): run → on failure restore latest
                        checkpoint → resume, with bounded retries.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Tuple


class HeartbeatRegistry:
    def __init__(self, timeout_s: float = 60.0, clock=time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        self.last_seen: Dict[str, float] = {}

    def ping(self, node_id: str) -> None:
        self.last_seen[node_id] = self.clock()

    def dead_nodes(self) -> List[str]:
        now = self.clock()
        return [n for n, t in self.last_seen.items()
                if now - t > self.timeout]

    def healthy(self) -> bool:
        return not self.dead_nodes()


class StragglerDetector:
    """Median/MAD z-score over a sliding window of step times."""

    def __init__(self, window: int = 50, z_thresh: float = 4.0,
                 min_samples: int = 10):
        self.window = window
        self.z = z_thresh
        self.min_samples = min_samples
        self.times: List[float] = []
        self.flags = 0

    def record(self, dt: float) -> bool:
        """Returns True if this step is a straggler event."""
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) < self.min_samples:
            return False
        med = sorted(self.times)[len(self.times) // 2]
        mad = sorted(abs(t - med) for t in self.times)[len(self.times) // 2]
        sigma = 1.4826 * max(mad, 1e-9)
        if (dt - med) / sigma > self.z:
            self.flags += 1
            return True
        return False

    def chronic(self, k: int = 3) -> bool:
        return self.flags >= k


@dataclasses.dataclass
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    global_batch: int
    note: str


def plan_elastic_mesh(surviving_chips: int, model_parallel: int,
                      global_batch: int,
                      pods: int = 1) -> ElasticPlan:
    """Shrink the data axis to the largest power of two that fits, keep the
    model axis (so TP shards of every weight remain valid), and round the
    global batch down to a multiple of the new dp size."""
    assert surviving_chips >= model_parallel, \
        "fewer chips than one model-parallel group"
    dp = surviving_chips // model_parallel
    dp = 2 ** int(math.floor(math.log2(dp)))
    chips = dp * model_parallel
    gb = max(dp, (global_batch // dp) * dp)
    if pods > 1 and dp % pods == 0:
        return ElasticPlan((pods, dp // pods, model_parallel),
                           ("pod", "data", "model"), gb,
                           f"{chips} chips, {pods} pods")
    return ElasticPlan((dp, model_parallel), ("data", "model"), gb,
                       f"{chips} chips, single group")


class RestartLoop:
    """supervise(run_fn): restart from latest checkpoint on failure."""

    def __init__(self, ckpt_mgr, max_restarts: int = 3, log=print):
        self.mgr = ckpt_mgr
        self.max_restarts = max_restarts
        self.log = log
        self.restarts = 0

    def supervise(self, run_fn: Callable[[Optional[int]], None]) -> int:
        """run_fn(resume_step) should raise on failure. Returns restarts."""
        while True:
            try:
                run_fn(self.mgr.latest_step())
                return self.restarts
            except Exception as e:  # noqa: BLE001 — any worker fault
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                self.log(f"[ft] failure: {e!r}; restart "
                         f"{self.restarts}/{self.max_restarts} from step "
                         f"{self.mgr.latest_step()}")
