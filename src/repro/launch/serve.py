"""Serving launcher: batched decode through the `repro.api.Engine` facade,
optionally AIDA-compressed weights.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --compress aida --density 0.1 --requests 16
(Full-size archs need a checkpoint; without one this initializes random
weights at a REDUCED size for a functional smoke serve.)
"""
from __future__ import annotations

import argparse
import time

from repro.api import CompressionSpec, Engine, Request
from repro.configs import get, reduced


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--compress", default=None,
                    choices=[None, "int8", "codebook4", "acsr", "aida"])
    ap.add_argument("--density", type=float, default=0.1)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--kv-cache", default=None,
                    choices=[None, "full", "paged"],
                    help="paged = int8 page-pool KV cache (repro.kvstore)")
    args = ap.parse_args()

    cfg = get(args.arch) if args.full_size else reduced(get(args.arch))
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no serving")
    print(f"[serve] {cfg.name}: ~{cfg.params_count()/1e6:.1f}M params")
    eng = Engine(cfg)
    if args.compress:
        eng.compress(CompressionSpec(mode=args.compress,
                                     density=args.density))
        print(f"[serve] {args.compress}: {eng.stats['n_compressed']} "
              f"projections, {eng.stats['ratio']:.1f}x weight memory "
              f"(backend: {eng.backend.name})")

    reqs = [Request(prompt=[1, 2 + rid % 7, 3], rid=rid,
                    max_new=args.max_new) for rid in range(args.requests)]
    t0 = time.perf_counter()
    results = eng.serve(reqs, batch_slots=args.slots, max_len=128,
                        kv_cache=args.kv_cache)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.tokens) for r in results)
    print(f"[serve] {len(results)} requests, {n_tok} tokens, "
          f"{n_tok/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
