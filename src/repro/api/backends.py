"""The four built-in execution backends behind the `Executor` protocol.

  jax-dense    XLA matmul decode (raw params or dense CompressedFC leaves)
  pallas       compressed decode: int8 / codebook4 / acsr / aida leaves run
               through the Pallas LUT / ACSR-SpMV kernels (via dispatch)
  ap-emulator  bit-level CAM emulator of the paper's Fig. 3 algorithm
               (exact outputs AND exact cycle counts)
  cycle-sim    closed-form analytical cost models (aida_sim + eie_sim)

`ap-emulator` and `cycle-sim` agree on FC cycle counts by construction:
`cycle-sim` with the EMULATOR microcode reproduces the emulator's counter
exactly (the invariant tests/test_aida_fc.py asserts at module level, and
tests/test_api.py asserts through the facade).
"""
from __future__ import annotations

import numpy as np

from repro.api.registry import (Capabilities, CapabilityError, Executor,
                                register_backend)
from repro.api.spec import MODES, FCProblem, WORKLOADS


# --------------------------------------------------------------- decoders
class JaxDenseBackend(Executor):
    """Baseline XLA decode; FC layers as plain (bf16) matmuls."""
    name = "jax-dense"
    caps = Capabilities(batched_decode=True, modes=("dense",))

    def make_decode_step(self, cfg, unroll: bool = False, plan=None):
        from repro.models import model as M

        def step(params, state, tokens):
            return M.decode_step(cfg, params, state, tokens, unroll=unroll,
                                 plan=plan)
        return step

    def run_fc(self, layer, x):
        import jax.numpy as jnp
        if type(layer).__name__ == "CompressedFC":
            if layer.mode not in self.caps.modes:
                raise CapabilityError(
                    f"{self.name!r} only runs modes {self.caps.modes}; "
                    f"got {layer.mode!r} (use 'pallas')")
            w = layer.dense
        else:
            w = layer
        return jnp.matmul(x, w.T, preferred_element_type=jnp.float32)


class PallasBackend(JaxDenseBackend):
    """Compressed decode: CompressedFC leaves dispatch to the Pallas
    codebook-LUT / ACSR-SpMV kernels inside the same jitted step."""
    name = "pallas"
    caps = Capabilities(batched_decode=True, per_layer_override=True,
                        modes=MODES)

    def run_fc(self, layer, x):
        from repro.core.sparse_fc import apply_fc
        if type(layer).__name__ != "CompressedFC":
            raise CapabilityError(
                f"{self.name!r}.run_fc expects a CompressedFC layer")
        return apply_fc(layer, x)


# --------------------------------------------------------------- emulator
class APEmulatorBackend(Executor):
    """Bit-level associative-processor emulator (core.aida_fc): runs the
    paper's Fig. 3 FC algorithm op-by-op and measures exact cycles."""
    name = "ap-emulator"
    caps = Capabilities(cycle_accounting=True, modes=("aida",))

    def estimate(self, workload: FCProblem, **kw) -> dict:
        from repro.core import aida_fc
        if not isinstance(workload, FCProblem):
            raise CapabilityError(
                f"{self.name!r} estimates concrete FCProblem workloads; "
                f"use 'cycle-sim' for named workloads {WORKLOADS}")
        p = workload
        if p.coded:
            res = aida_fc.aida_fc_layer_coded(
                p.w, p.b, p.cents_w, p.cents_a, activation=p.activation)
            ref = aida_fc.fc_reference_coded(p.w, p.b, p.cents_w, p.cents_a,
                                             activation=p.activation)
        else:
            res = aida_fc.aida_fc_layer(p.w, p.b, m=p.m, n=p.n,
                                        activation=p.activation)
            ref = aida_fc.fc_reference(p.w, p.b, activation=p.activation)
        return {"backend": self.name, "cycles": res.cycles,
                "out": res.out, "reference": ref,
                "exact": bool(np.array_equal(res.out, ref)),
                "rounds": res.rounds, "nnz_b": res.nnz_b,
                "max_row_nnz": res.max_row_nnz,
                "counters": dict(res.counters)}


# -------------------------------------------------------------- cost model
class CycleSimBackend(Executor):
    """Closed-form analytical simulators: AIDA (aida_sim) and the EIE
    baseline (eie_sim).  Workloads: an FCProblem (per-layer cycle count,
    EMULATOR microcode by default — bit-exact vs 'ap-emulator'), a named
    network ('alexnet-fc' / 'ctc-lstm' / 'table1'), or a list of
    FCLayerSpec (PAPER microcode by default)."""
    name = "cycle-sim"
    caps = Capabilities(cycle_accounting=True, modes=("aida",))

    @staticmethod
    def _microcode(mc):
        from repro.core import aida_sim as S
        if mc is None or mc == "paper":
            return S.PAPER
        if mc == "emulator":
            return S.EMULATOR
        return mc  # a Microcode instance

    def estimate(self, workload, simulator: str = "aida",
                 microcode=None, **kw) -> dict:
        from repro.core import aida_sim as S
        from repro.core import eie_sim as E
        if isinstance(workload, FCProblem):
            if simulator != "aida":
                raise CapabilityError(
                    f"simulator {simulator!r} cannot price a bit-level "
                    "FCProblem; the EIE model takes FCLayerSpec networks")
            p = workload
            mc = self._microcode(microcode or "emulator")
            ph = S.cycles_fc(p.w.shape[1], p.nnz_b, p.max_row_nnz, mc,
                             mode="coded" if p.coded else "bitserial",
                             m=p.m, n=p.n, prod_bits=p.prod_bits)
            return {"backend": self.name, "simulator": simulator,
                    "cycles": ph.total(mc),
                    "phases": {"broadcast": ph.broadcast,
                               "multiply": ph.multiply,
                               "reduce": ph.reduce, "act": ph.act},
                    "nnz_b": p.nnz_b, "max_row_nnz": p.max_row_nnz}
        mc = self._microcode(microcode)
        if workload == "table1":
            return {"backend": self.name,
                    "aida": S.aida_table1(mc), "eie": E.eie_table1()}
        if isinstance(workload, str):
            if workload not in ("alexnet-fc", "ctc-lstm"):
                raise CapabilityError(
                    f"unknown workload {workload!r}; named workloads: "
                    f"{WORKLOADS}")
            layers = (S.alexnet_fc() if workload == "alexnet-fc"
                      else S.ctc_lstm())
            name = workload
        else:
            layers, name = list(workload), "custom"
        if simulator == "aida":
            rep = S.evaluate_network(name, layers, mc, **kw)
        elif simulator == "eie":
            rep = E.evaluate_network(name, layers, **kw)
        else:
            raise CapabilityError(
                f"unknown simulator {simulator!r}; choose 'aida' or 'eie'")
        return {"backend": self.name, "simulator": simulator,
                "report": rep,
                "cycles": rep.cycles_total,
                "inf_per_s": rep.inf_per_s}


register_backend(JaxDenseBackend())
register_backend(PallasBackend())
register_backend(APEmulatorBackend())
register_backend(CycleSimBackend())
