"""Serving launcher: batched decode through the `repro.api.Engine` facade,
optionally AIDA-compressed weights, with reproducible heterogeneous
workloads driven by `repro.sched.workload`.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --compress aida --density 0.1 --requests 16 \
      --workload heterogeneous --chunk 8 --policy sjf
(Full-size archs need a checkpoint; without one this initializes random
weights at a REDUCED size for a functional smoke serve.)
"""
from __future__ import annotations

import argparse
import time

from repro.api import CompressionSpec, Engine
from repro.configs import get, reduced
from repro.sched import SchedConfig, WorkloadSpec, generate, summarize
from repro.sched.workload import PRESETS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--compress", default=None,
                    choices=[None, "int8", "codebook4", "acsr", "aida"])
    ap.add_argument("--density", type=float, default=0.1)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--kv-cache", default=None,
                    choices=[None, "auto", "full", "paged"],
                    help="None/auto = paged page-pool KV wherever the "
                         "arch has attention (repro.kvstore)")
    ap.add_argument("--workload", default="uniform", choices=list(PRESETS),
                    help="request-mix preset (sched.workload): prompt "
                         "lengths, max_new, arrival process")
    ap.add_argument("--prompt-len", type=int, default=None,
                    help="override the preset's prompt-length range with "
                         "a fixed length")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload RNG seed (schedules replay exactly)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="prefill tokens per model call (1 = token-by-"
                         "token; paged KV + attention-only archs)")
    ap.add_argument("--policy", default="fifo", choices=["fifo", "sjf"],
                    help="admission order: FIFO or shortest-prompt-first")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share full prompt-prefix pages across requests")
    ap.add_argument("--kv-pool-pages", type=int, default=None,
                    help="page-pool size (small pools exercise admission "
                         "control + preemption instead of crashing)")
    args = ap.parse_args()

    cfg = get(args.arch) if args.full_size else reduced(get(args.arch))
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no serving")
    print(f"[serve] {cfg.name}: ~{cfg.params_count()/1e6:.1f}M params")
    eng = Engine(cfg)
    if args.compress:
        eng.compress(CompressionSpec(mode=args.compress,
                                     density=args.density))
        print(f"[serve] {args.compress}: {eng.stats['n_compressed']} "
              f"projections, {eng.stats['ratio']:.1f}x weight memory "
              f"(backend: {eng.backend.name})")

    overrides = dict(n_requests=args.requests, max_new=(1, args.max_new),
                     vocab=cfg.vocab, seed=args.seed)
    if args.prompt_len is not None:
        overrides["prompt_len"] = (args.prompt_len, args.prompt_len)
    spec = WorkloadSpec.preset(args.workload, **overrides)
    arrivals = generate(spec)
    max_len = 128

    sess = eng.session(batch_slots=args.slots, max_len=max_len,
                       kv_cache=args.kv_cache,
                       kv_pool_pages=args.kv_pool_pages,
                       scheduler=SchedConfig(
                           policy=args.policy, chunk=args.chunk,
                           prefix_cache=args.prefix_cache))
    print(f"[serve] workload={args.workload} seed={args.seed} "
          f"kv={sess.kv_cache} chunk={sess.chunk} policy={args.policy}")
    t0 = time.perf_counter()
    results = sess.run_workload(arrivals)
    dt = time.perf_counter() - t0
    m = summarize(sess.records, dt, sess.stats["steps"])
    print(f"[serve] {m['completed']}/{m['requests']} requests, "
          f"{m['tokens']} tokens, {m['tok_per_s']:.1f} tok/s, "
          f"goodput {m['goodput_req_per_s']:.2f} req/s "
          f"({m['steps']} model calls)")
    if m["ttft_s"]:
        print(f"[serve] TTFT p50 {m['ttft_s']['p50']*1e3:.0f} ms / "
              f"p99 {m['ttft_s']['p99']*1e3:.0f} ms; "
              f"preemptions {m['preemptions']}, "
              f"prefix pages reused {m['prefix_pages_reused']}")
    if sess.kv_cache == "paged":
        print(f"[serve] pages: peak {sess.stats['pages_peak']}, "
              f"allocs {sess.stats['page_allocs']}, "
              f"reclaimed(SWA) {sess.stats['pages_reclaimed_swa']}")


if __name__ == "__main__":
    main()
