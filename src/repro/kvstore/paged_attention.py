"""Paged-attention decode kernel: page-table gather + inline dequant +
flash-style online softmax, one grid step per block of pages.

The Pallas kernel uses the canonical TPU paged-attention trick: the page
table rides in as a *scalar-prefetch* argument, so the K/V BlockSpec
index maps can read it and DMA exactly the pages a sequence owns —
``index_map=(table[b, i·pb+j], h, 0, 0)`` — no dense [B, S, ...] tensor
ever exists.  Each grid step covers ``pb`` table slots (pb separate
BlockSpecs per operand; a tuner-searchable tile), dequantizes them
against their per-page scales in VMEM, and folds them into the running
(m, l, acc) online-softmax state; the output block is finalized on the
last page block, exactly like kernels/flash_attention.py.

Both serving shapes are covered: the decode kernel takes one query per
sequence (``[B, H, Dh]``) and the chunked-prefill kernel
(:func:`paged_attention_pallas_chunk`) takes a whole chunk
(``[B, H, C, Dh]``) at absolute positions ``q_pos`` — same scalar
prefetch, same inline dequant, with a ``qt``-query tile folded into the
online-softmax state per grid step and the in-chunk causal mask
(table-index position vs. per-query absolute position) computed
in-kernel.  A ``C=1`` chunk is bit-identical to the decode kernel.

The XLA paths (`impl="xla"`) are the same math as gather + masked
softmax — the correctness oracle, the autodiff-free reference, and (on
interpret-mode hosts) usually the faster choice; `paged_attention()` and
`paged_attention_chunk()` dispatch per the kernels.tune cache like the
FC ops do.  Page tables are padded to an `npp_bucket` multiple of the
largest tuner `pb` so a growing table reuses one compiled kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kvstore import pool as poolmod
from repro.kvstore.pool import PagedKV

NEG_INF = -1e30

# Largest page-block candidate the tuner searches.  Page tables are
# padded (and tune keys bucketed) to the next PB_MAX multiple so a table
# that grows 17 -> 18 -> ... pages hits one compiled kernel + one tune
# entry instead of recompiling per npp.
PB_MAX = 4


def npp_bucket(npp: int) -> int:
    """Round a page-table width up to the next PB_MAX multiple."""
    return -(-npp // PB_MAX) * PB_MAX


def _softcap(s, cap: Optional[float]):
    return s if cap is None else cap * jnp.tanh(s / cap)


# ------------------------------------------------------------------- xla
def paged_attention_xla(q: jnp.ndarray, pool: PagedKV, table: jnp.ndarray,
                        cur_pos: jnp.ndarray, window, *,
                        scale: Optional[float] = None,
                        cap: Optional[float] = None) -> jnp.ndarray:
    """Reference path: q [B, H, Dh] against the paged pool -> [B, H, Dh].

    GQA by grouping query heads (no k/v repeat), masks from table-index
    positions — mirrors models.attention._core over gathered pages."""
    b, h, dh = q.shape
    _, hkv, ps, _ = pool.k_pages.shape
    g = h // hkv
    npp = table.shape[1]
    scale = (dh ** -0.5) if scale is None else scale
    safe = jnp.maximum(table, poolmod.GARBAGE_PAGE)
    k = jnp.take(pool.k_pages, safe, axis=0)       # [B, P, Hkv, ps, Dh]
    v = jnp.take(pool.v_pages, safe, axis=0)
    # unquantized pages mirror _core's mixed precision (bf16 operands,
    # f32 accumulate/softmax) so paged bf16 == full cache up to reduction
    # order; int8 pages contract in f32 (dequant headroom)
    cdt = jnp.float32 if pool.quantized else k.dtype
    qg = q.reshape(b, hkv, g, dh).astype(cdt)
    # page axes stay in the einsum (no transposed [B,Hkv,S,Dh] copy); the
    # per-page dequant scales fold into the [.., p, c] score/prob tensors
    # instead of elementwise-dequantizing whole pages (Dh x less work)
    s = jnp.einsum("bkgd,bpkcd->bkgpc", qg, k.astype(cdt),
                   preferred_element_type=jnp.float32) * scale
    if pool.quantized:
        ks = jnp.take(pool.k_scale, safe, axis=0)  # [B, P, Hkv]
        s = s * ks.transpose(0, 2, 1)[:, :, None, :, None]
    s = _softcap(s, cap)
    mask = poolmod.attention_mask(table, cur_pos,
                                  jnp.asarray(window, jnp.int32),
                                  pool.page_size).reshape(b, npp, ps)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s.reshape(b, hkv, g, npp * ps), axis=-1)
    p = p.reshape(b, hkv, g, npp, ps)
    if pool.quantized:
        vs = jnp.take(pool.v_scale, safe, axis=0)
        p = p * vs.transpose(0, 2, 1)[:, :, None, :, None]
    o = jnp.einsum("bkgpc,bpkcd->bkgd", p.astype(cdt), v.astype(cdt),
                   preferred_element_type=jnp.float32)
    return o.reshape(b, h, dh)


def paged_attention_xla_chunk(q: jnp.ndarray, pool: PagedKV,
                              table: jnp.ndarray, q_pos: jnp.ndarray,
                              window, *, scale: Optional[float] = None,
                              cap: Optional[float] = None) -> jnp.ndarray:
    """Multi-query variant for the chunked-prefill step: q [B, H, C, Dh]
    at absolute positions ``q_pos`` [B, C] against the paged pool ->
    [B, H, C, Dh].

    Same einsum/precision structure as :func:`paged_attention_xla` with a
    query axis threaded through (bf16 pools keep operands bf16 with f32
    accumulation, so a C=1 chunk is bit-identical to the decode path) —
    chunk tokens see each other through the pool because their K/V are
    written before the chunk attends."""
    b, h, c, dh = q.shape
    _, hkv, ps, _ = pool.k_pages.shape
    g = h // hkv
    npp = table.shape[1]
    scale = (dh ** -0.5) if scale is None else scale
    safe = jnp.maximum(table, poolmod.GARBAGE_PAGE)
    k = jnp.take(pool.k_pages, safe, axis=0)       # [B, P, Hkv, ps, Dh]
    v = jnp.take(pool.v_pages, safe, axis=0)
    cdt = jnp.float32 if pool.quantized else k.dtype
    qg = q.reshape(b, hkv, g, c, dh).astype(cdt)
    s = jnp.einsum("bkgqd,bpkcd->bkgqpc", qg, k.astype(cdt),
                   preferred_element_type=jnp.float32) * scale
    if pool.quantized:
        ks = jnp.take(pool.k_scale, safe, axis=0)  # [B, P, Hkv]
        s = s * ks.transpose(0, 2, 1)[:, :, None, None, :, None]
    s = _softcap(s, cap)
    mask = poolmod.chunk_attention_mask(
        table, q_pos, jnp.asarray(window, jnp.int32),
        pool.page_size).reshape(b, c, npp, ps)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s.reshape(b, hkv, g, c, npp * ps), axis=-1)
    p = p.reshape(b, hkv, g, c, npp, ps)
    if pool.quantized:
        vs = jnp.take(pool.v_scale, safe, axis=0)
        p = p * vs.transpose(0, 2, 1)[:, :, None, None, :, None]
    o = jnp.einsum("bkgqpc,bpkcd->bkgqd", p.astype(cdt), v.astype(cdt),
                   preferred_element_type=jnp.float32)
    return o.reshape(b, h, c, dh)


# ---------------------------------------------------------------- pallas
def _paged_kernel(table_ref, pos_ref, win_ref, q_ref, *refs,
                  scale, cap, quantized, pb, ps, nblk):
    """One grid step = ``pb`` pages of one (sequence, kv-head) folded into
    the online softmax.  refs order: k_0..k_{pb-1}, v_0..v_{pb-1},
    [ks_0..ks_{pb-1}, vs_0..vs_{pb-1}], o_ref, m/l/acc scratch."""
    refs = list(refs)
    k_refs = [refs.pop(0) for _ in range(pb)]
    v_refs = [refs.pop(0) for _ in range(pb)]
    if quantized:
        ks_refs = [refs.pop(0) for _ in range(pb)]
        vs_refs = [refs.pop(0) for _ in range(pb)]
    o_ref, m_scr, l_scr, acc_scr = refs
    bi, i = pl.program_id(0), pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # [G, Dh]
    cur = pos_ref[bi]
    win = win_ref[0]
    ks, vs, masks = [], [], []
    for j in range(pb):                                    # static unroll
        t = i * pb + j                                     # table index
        kj = k_refs[j][0, 0].astype(jnp.float32)           # [ps, Dh]
        vj = v_refs[j][0, 0].astype(jnp.float32)
        if quantized:
            kj = kj * ks_refs[j][0, 0]                     # per-page scale
            vj = vj * vs_refs[j][0, 0]
        base = t * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        valid = (table_ref[bi, t] >= 0) & (base <= cur)
        valid &= (win < 0) | (base > cur - win)
        ks.append(kj)
        vs.append(vj)
        masks.append(valid)
    k = jnp.concatenate(ks, axis=0)                        # [pb*ps, Dh]
    v = jnp.concatenate(vs, axis=0)
    mask = jnp.concatenate(masks, axis=1)                  # [1, pb*ps]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = _softcap(s, cap)
    s = jnp.where(mask, s, NEG_INF)                        # [G, pb*ps]
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(i == nblk - 1)
    def _done():
        o_ref[...] = (acc_scr[...] /
                      jnp.maximum(l_scr[...], 1e-30))[None, None]


@functools.partial(jax.jit, static_argnames=("scale", "cap", "pb",
                                             "interpret"))
def paged_attention_pallas(q, pool: PagedKV, table, cur_pos, window, *,
                           scale: Optional[float] = None,
                           cap: Optional[float] = None,
                           pb: int = 2, interpret: bool = True):
    """Pallas paged attention. q [B, H, Dh] -> [B, H, Dh] f32."""
    b, h, dh = q.shape
    n_pages, hkv, ps, _ = pool.k_pages.shape
    g = h // hkv
    npp = table.shape[1]
    scale = (dh ** -0.5) if scale is None else scale
    npp_b = npp_bucket(npp)   # bucketed width: growing tables reuse one kernel
    pb = max(1, min(pb, npp_b))
    nblk = -(-npp_b // pb)
    if nblk * pb != npp:   # pad table; -1 entries are masked in-kernel
        table = jnp.pad(table, ((0, 0), (0, nblk * pb - npp)),
                        constant_values=poolmod.NO_PAGE)
    qg = q.reshape(b, hkv, g, dh)
    quantized = pool.quantized

    # scalar-prefetch index maps: pick each page straight from the table
    def page_map(j):
        return lambda bi, hi, i, tbl, pos, win: (
            jnp.maximum(tbl[bi, i * pb + j], 0), hi, 0, 0)

    def scale_map(j):
        return lambda bi, hi, i, tbl, pos, win: (
            jnp.maximum(tbl[bi, i * pb + j], 0), hi)

    in_specs = [pl.BlockSpec((1, 1, g, dh),
                             lambda bi, hi, i, tbl, pos, win: (bi, hi, 0, 0))]
    args = [qg]
    for j in range(pb):
        in_specs.append(pl.BlockSpec((1, 1, ps, dh), page_map(j)))
        args.append(pool.k_pages)
    for j in range(pb):
        in_specs.append(pl.BlockSpec((1, 1, ps, dh), page_map(j)))
        args.append(pool.v_pages)
    if quantized:
        for j in range(pb):
            in_specs.append(pl.BlockSpec((1, 1), scale_map(j)))
            args.append(pool.k_scale)
        for j in range(pb):
            in_specs.append(pl.BlockSpec((1, 1), scale_map(j)))
            args.append(pool.v_scale)
    kern = functools.partial(_paged_kernel, scale=scale, cap=cap,
                             quantized=quantized, pb=pb, ps=ps, nblk=nblk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, nblk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda bi, hi, i, tbl, pos, win:
                               (bi, hi, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, dh), jnp.float32)],
    )
    o = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dh), jnp.float32),
        interpret=interpret,
    )(table, jnp.asarray(cur_pos, jnp.int32),
      jnp.asarray(window, jnp.int32).reshape(1), *args)
    return o.reshape(b, h, dh)


def _paged_chunk_kernel(table_ref, pos_ref, win_ref, q_ref, *refs,
                        scale, cap, quantized, pb, ps, nblk, qt, g):
    """Chunked-prefill grid step: ``pb`` pages × a ``qt``-query tile of one
    (sequence, kv-head) folded into the online softmax.  Rows are the
    flattened [G, qt] query block, so with qt=1 every array and every op
    below is the decode kernel's — a C=1 chunk is bit-identical.  refs
    order matches `_paged_kernel`."""
    refs = list(refs)
    k_refs = [refs.pop(0) for _ in range(pb)]
    v_refs = [refs.pop(0) for _ in range(pb)]
    if quantized:
        ks_refs = [refs.pop(0) for _ in range(pb)]
        vs_refs = [refs.pop(0) for _ in range(pb)]
    o_ref, m_scr, l_scr, acc_scr = refs
    bi, qi, i = pl.program_id(0), pl.program_id(2), pl.program_id(3)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32).reshape(g * qt, -1)  # [G*qt, Dh]
    win = win_ref[0]
    ks, vs, masks = [], [], []
    for j in range(pb):                                    # static unroll
        t = i * pb + j                                     # table index
        kj = k_refs[j][0, 0].astype(jnp.float32)           # [ps, Dh]
        vj = v_refs[j][0, 0].astype(jnp.float32)
        if quantized:
            kj = kj * ks_refs[j][0, 0]                     # per-page scale
            vj = vj * vs_refs[j][0, 0]
        base = t * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        tvalid = table_ref[bi, t] >= 0
        rows = []
        for ti in range(qt):   # in-chunk causality: each query its own cur
            cur = pos_ref[bi, qi * qt + ti]
            valid = tvalid & (base <= cur)
            valid &= (win < 0) | (base > cur - win)
            rows.append(valid)
        ks.append(kj)
        vs.append(vj)
        masks.append(jnp.concatenate(rows, axis=0))        # [qt, ps]
    k = jnp.concatenate(ks, axis=0)                        # [pb*ps, Dh]
    v = jnp.concatenate(vs, axis=0)
    mask = jnp.tile(jnp.concatenate(masks, axis=1), (g, 1))  # [G*qt, pb*ps]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = _softcap(s, cap)
    s = jnp.where(mask, s, NEG_INF)                        # [G*qt, pb*ps]
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(i == nblk - 1)
    def _done():
        o = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = o.reshape(g, qt, -1)[None, None]


@functools.partial(jax.jit, static_argnames=("scale", "cap", "pb", "qt",
                                             "interpret"))
def paged_attention_pallas_chunk(q, pool: PagedKV, table, q_pos, window, *,
                                 scale: Optional[float] = None,
                                 cap: Optional[float] = None,
                                 pb: int = 2, qt: Optional[int] = None,
                                 interpret: bool = True):
    """Pallas chunked-prefill paged attention.  q [B, H, C, Dh] at
    absolute positions ``q_pos`` [B, C] -> [B, H, C, Dh] f32.

    Grid (B, Hkv, C/qt, nblk): each step DMAs ``pb`` pages straight from
    the table (scalar prefetch) and folds them into the [G·qt]-row
    online-softmax state — the chunk never materializes a dense
    [B, P, Hkv, ps, Dh] gather.  ``qt`` must divide C (falls back to a
    single C-wide tile otherwise)."""
    b, h, c, dh = q.shape
    n_pages, hkv, ps, _ = pool.k_pages.shape
    g = h // hkv
    npp = table.shape[1]
    scale = (dh ** -0.5) if scale is None else scale
    npp_b = npp_bucket(npp)
    pb = max(1, min(pb, npp_b))
    nblk = -(-npp_b // pb)
    if nblk * pb != npp:   # pad table; -1 entries are masked in-kernel
        table = jnp.pad(table, ((0, 0), (0, nblk * pb - npp)),
                        constant_values=poolmod.NO_PAGE)
    qt = c if qt is None or c % qt != 0 else qt
    nq = c // qt
    qg = q.reshape(b, hkv, g, c, dh)
    quantized = pool.quantized

    def page_map(j):
        return lambda bi, hi, qi, i, tbl, pos, win: (
            jnp.maximum(tbl[bi, i * pb + j], 0), hi, 0, 0)

    def scale_map(j):
        return lambda bi, hi, qi, i, tbl, pos, win: (
            jnp.maximum(tbl[bi, i * pb + j], 0), hi)

    in_specs = [pl.BlockSpec((1, 1, g, qt, dh),
                             lambda bi, hi, qi, i, tbl, pos, win:
                             (bi, hi, 0, qi, 0))]
    args = [qg]
    for j in range(pb):
        in_specs.append(pl.BlockSpec((1, 1, ps, dh), page_map(j)))
        args.append(pool.k_pages)
    for j in range(pb):
        in_specs.append(pl.BlockSpec((1, 1, ps, dh), page_map(j)))
        args.append(pool.v_pages)
    if quantized:
        for j in range(pb):
            in_specs.append(pl.BlockSpec((1, 1), scale_map(j)))
            args.append(pool.k_scale)
        for j in range(pb):
            in_specs.append(pl.BlockSpec((1, 1), scale_map(j)))
            args.append(pool.v_scale)
    kern = functools.partial(_paged_chunk_kernel, scale=scale, cap=cap,
                             quantized=quantized, pb=pb, ps=ps, nblk=nblk,
                             qt=qt, g=g)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, nq, nblk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, qt, dh),
                               lambda bi, hi, qi, i, tbl, pos, win:
                               (bi, hi, 0, qi, 0)),
        scratch_shapes=[pltpu.VMEM((g * qt, 1), jnp.float32),
                        pltpu.VMEM((g * qt, 1), jnp.float32),
                        pltpu.VMEM((g * qt, dh), jnp.float32)],
    )
    o = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, c, dh), jnp.float32),
        interpret=interpret,
    )(table, jnp.asarray(q_pos, jnp.int32),
      jnp.asarray(window, jnp.int32).reshape(1), *args)
    return o.reshape(b, h, c, dh)


# ------------------------------------------------------------- dispatch
def resolve_paged(batch: int, h: int, d_head: int, pool: PagedKV,
                  npp: int, interpret: Optional[bool] = None):
    """Resolve the tuned decode choice -> (impl, pb, interpret).

    Pure host-side cache lookup, so shard_map wrappers can resolve with
    the *global* geometry outside the mesh and pass (impl, pb) in
    explicitly — mesh and single-device runs then execute the identical
    kernel (same accumulation order, token-identical output)."""
    from repro.kernels import ops as _ops
    from repro.kernels import tune as _tune
    interp = _ops.pallas_interpret() if interpret is None else interpret
    hkv = pool.k_pages.shape[1]
    choice = _tune.get(_tune.paged_key(hkv, h // hkv, d_head,
                                       pool.page_size, npp, batch,
                                       pool.quantized, interp))
    if choice is not None:
        return choice.impl, (choice.tile("pb") or 2), interp
    # untuned default: native kernel on TPU, XLA on interpret hosts
    return ("xla" if interp else "pallas"), 2, interp


def resolve_paged_chunk(batch: int, h: int, chunk: int, d_head: int,
                        pool: PagedKV, npp: int,
                        interpret: Optional[bool] = None):
    """Resolve the tuned chunk choice -> (impl, pb, qt, interpret)."""
    from repro.kernels import ops as _ops
    from repro.kernels import tune as _tune
    interp = _ops.pallas_interpret() if interpret is None else interpret
    hkv = pool.k_pages.shape[1]
    choice = _tune.get(_tune.paged_chunk_key(hkv, h // hkv, d_head,
                                             pool.page_size, npp, batch,
                                             chunk, pool.quantized, interp))
    if choice is not None:
        return (choice.impl, (choice.tile("pb") or 2),
                (choice.tile("qt") or chunk), interp)
    return ("xla" if interp else "pallas"), 2, chunk, interp


def paged_attention(q, pool: PagedKV, table, cur_pos, window, *,
                    scale: Optional[float] = None,
                    cap: Optional[float] = None,
                    impl: Optional[str] = None,
                    pb: Optional[int] = None,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Autotuned entry point: Pallas kernel or the XLA gather reference
    per the kernels.tune winner for this (geometry, batch, backend).
    Pass ``impl``/``pb`` to pin a choice (the shard wrappers do, with the
    globally-resolved one)."""
    if impl is None:
        b, h, dh = q.shape
        impl, pb, interpret = resolve_paged(b, h, dh, pool,
                                            table.shape[1], interpret)
    elif interpret is None:
        from repro.kernels import ops as _ops
        interpret = _ops.pallas_interpret()
    if impl == "xla":
        return paged_attention_xla(q, pool, table, cur_pos, window,
                                   scale=scale, cap=cap)
    return paged_attention_pallas(q, pool, table, cur_pos, window,
                                  scale=scale, cap=cap,
                                  pb=pb or 2, interpret=interpret)


def paged_attention_chunk(q, pool: PagedKV, table, q_pos, window, *,
                          scale: Optional[float] = None,
                          cap: Optional[float] = None,
                          impl: Optional[str] = None,
                          pb: Optional[int] = None,
                          qt: Optional[int] = None,
                          interpret: Optional[bool] = None) -> jnp.ndarray:
    """Autotuned chunked-prefill entry point: q [B, H, C, Dh] at absolute
    positions ``q_pos`` [B, C] -> [B, H, C, Dh].  Dispatches between
    :func:`paged_attention_pallas_chunk` and the XLA gather reference per
    the kernels.tune winner for this (geometry, batch, chunk, backend)."""
    if impl is None:
        b, h, c, dh = q.shape
        impl, pb, qt, interpret = resolve_paged_chunk(
            b, h, c, dh, pool, table.shape[1], interpret)
    elif interpret is None:
        from repro.kernels import ops as _ops
        interpret = _ops.pallas_interpret()
    if impl == "xla":
        return paged_attention_xla_chunk(q, pool, table, q_pos, window,
                                         scale=scale, cap=cap)
    return paged_attention_pallas_chunk(q, pool, table, q_pos, window,
                                        scale=scale, cap=cap, pb=pb or 2,
                                        qt=qt, interpret=interpret)
