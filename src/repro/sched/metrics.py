"""Serving metrics: TTFT / TPOT / latency percentiles / goodput.

The Session records one lifecycle dict per request (submit/admit/first
token/finish, in both wall seconds and model-call steps); `summarize`
folds them into the JSON-ready `"serving"` record that
`Engine.benchmark` writes to BENCH_api.json and
`benchmarks/check_regression.py` gates.

Step-denominated numbers (`first_token_calls`, preemptions, prefix
pages) are deterministic for a given workload — those carry the hard CI
assertions; wall-clock numbers (TTFT seconds, tok/s, goodput) are the
host-noisy trajectory signal and get the usual dual-unit tolerance.

Rate fields guard their denominators: a zero-span or zero-step run (a
tiny CI workload that completes inside one clock quantum, or an empty
request list) reports ``None`` for tok/s / goodput / utilization instead
of raising or fabricating an absurd rate.

Disaggregated serving adds two record families: per-request *handoff*
fields (``handoff_latency_s``, ``migrated_pages``, ``migrated_bytes``,
stamped by the decode role when it admits a migrated prompt) are folded
into a ``"handoff"`` sub-record, and a ``roles=`` dict of per-role step
counters becomes ``"roles"`` with per-role utilization (busy ticks over
total ticks).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence


def percentile(values: Sequence[float], q: float) -> Optional[float]:
    """Nearest-rank percentile (q in [0, 100]); None on empty input."""
    if not values:
        return None
    xs = sorted(values)
    k = max(0, min(len(xs) - 1, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[k]


def _dist(values: Sequence[float], scale: float = 1.0) -> Optional[dict]:
    if not values:
        return None
    vs = [v * scale for v in values]
    return {"mean": round(sum(vs) / len(vs), 4),
            "p50": round(percentile(vs, 50), 4),
            "p99": round(percentile(vs, 99), 4)}


def _rate(num: float, denom: float, digits: int = 2) -> Optional[float]:
    """num/denom, or None when the denominator is degenerate (zero-span
    runs must not crash or report infinite rates)."""
    if denom is None or denom <= 0:
        return None
    return round(num / denom, digits)


def _handoff(records: Sequence[Dict]) -> Optional[dict]:
    """Fold the disagg handoff fields (absent on co-located runs)."""
    hs = [r for r in records if r.get("handoff_latency_s") is not None]
    if not hs:
        return None
    n = len(hs)
    return {
        "count": n,
        "latency_s": _dist([r["handoff_latency_s"] for r in hs]),
        "latency_ticks": _dist([r["handoff_ticks"] for r in hs
                                if r.get("handoff_ticks") is not None]),
        "migrated_pages": sum(r.get("migrated_pages", 0) for r in hs),
        "migrated_bytes": sum(r.get("migrated_bytes", 0) for r in hs),
        "bytes_per_request": _rate(
            sum(r.get("migrated_bytes", 0) for r in hs), n, 1),
    }


def _outcomes(records: Sequence[Dict]) -> Optional[dict]:
    """Terminal-state census (absent when no record carries a state —
    pre-resil callers).  ``failed_by_reason`` attributes every
    structured failure (deadline / shed / retries_exhausted /
    oversized) so denominators stay honest under faults."""
    states = [r.get("state") for r in records if r.get("state")]
    if not states:
        return None
    out: Dict[str, int] = {}
    for s in states:
        out[s] = out.get(s, 0) + 1
    reasons: Dict[str, int] = {}
    for r in records:
        if r.get("state") == "failed" and r.get("failed_reason"):
            why = r["failed_reason"]
            reasons[why] = reasons.get(why, 0) + 1
    if reasons:
        out["failed_by_reason"] = reasons
    return out


def summarize(records: Sequence[Dict], span_seconds: float,
              steps: int, roles: Optional[Dict[str, Dict]] = None,
              resil: Optional[Dict] = None) -> dict:
    """Fold per-request lifecycle records into the serving summary.

    records: dicts with prompt_len, max_new, n_generated, submit_time,
    first_token_time, finish_time, submit_step, admit_step,
    first_token_step, preemptions, prefix_pages (absent fields skipped).

    roles: optional per-role counters for disaggregated serving —
    ``{"prefill": {"steps": n, "busy_ticks": b}, "decode": {...}}`` plus
    a ``"ticks"`` total under the key ``"_ticks"``; folded into a
    ``"roles"`` record with per-role utilization.

    resil: optional resilience-layer counters (``Session.resil_summary``)
    — shed/retry/deadline-miss/degraded plus per-fault-class injection
    counts; folded through as a ``"resil"`` record.
    """
    done = [r for r in records if r.get("finish_time") is not None]
    ttft = [r["first_token_time"] - r["submit_time"] for r in records
            if r.get("first_token_time") is not None]
    tpot: List[float] = []
    for r in done:
        if r["n_generated"] > 1 and r.get("first_token_time") is not None:
            tpot.append((r["finish_time"] - r["first_token_time"])
                        / (r["n_generated"] - 1))
    first_calls = [r["first_token_step"] - r["admit_step"] for r in records
                   if r.get("first_token_step") is not None
                   and r.get("admit_step") is not None]
    # scheduling-clock TTFT, comparable across engine shapes: a
    # disaggregated run stamps submit/first-token in orchestrator ticks
    # (one tick = one scheduling opportunity per role); a co-located run
    # falls back to the model-call step clock, which is its tick
    ttft_sched = [r["first_token_tick"] - r["submit_tick"] for r in records
                  if r.get("first_token_tick") is not None
                  and r.get("submit_tick") is not None] or \
                 [r["first_token_step"] - r["submit_step"] for r in records
                  if r.get("first_token_step") is not None
                  and r.get("submit_step") is not None]
    n_tok = sum(r["n_generated"] for r in done)
    out = {
        "requests": len(records),
        "completed": len(done),
        "tokens": n_tok,
        "seconds": round(span_seconds, 4),
        "steps": steps,
        "tok_per_s": _rate(n_tok, span_seconds),
        "goodput_req_per_s": _rate(len(done), span_seconds, 3),
        "ttft_s": _dist(ttft),
        "ttft_sched": _dist(ttft_sched),
        "tpot_s": _dist(tpot),
        "first_token_calls": _dist(first_calls) if first_calls else None,
        "preemptions": sum(r.get("preemptions", 0) for r in records),
        "prefix_pages_reused": sum(r.get("prefix_pages", 0)
                                   for r in records),
    }
    outcomes = _outcomes(records)
    if outcomes is not None:
        out["outcomes"] = outcomes
    if resil is not None:
        out["resil"] = dict(resil)
    hand = _handoff(records)
    if hand is not None:
        out["handoff"] = hand
    if roles:
        ticks = roles.get("_ticks")
        out["roles"] = {
            name: {"steps": rec.get("steps"),
                   "utilization": _rate(rec.get("busy_ticks", 0),
                                        ticks, 3)}
            for name, rec in roles.items() if name != "_ticks"}
    return out
