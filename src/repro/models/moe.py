"""Mixture-of-Experts FFN (mixtral 8e/top-2, dbrx 16e/top-4).

GShard/Switch-style capacity-bounded dispatch in einsum form: tokens are
grouped, each group dispatches at most ``capacity`` tokens per expert via
one-hot tensors, and the expert FFNs run as batched einsums over stacked
expert weights [E, ...].  Under pjit the expert dimension shards over the
``model`` mesh axis when divisible (true EP — dbrx 16e on 16-way model
axis), else experts replicate and the FFN shards internally (mixtral 8e).
The overflow-dropped-token fraction and the Switch load-balancing aux loss
are returned for logging/optimization.

This is also where AIDA's sparsity story meets MoE: expert FFN weight
matrices are exactly the sparse-FC serving surface (see core/sparse_fc).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DTYPE, dense_init


def moe_init(key, d: int, f: int, n_experts: int):
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], d, n_experts),
        "gate": jax.random.normal(ks[1], (n_experts, d, f), jnp.float32)
        * (d ** -0.5),
        "up": jax.random.normal(ks[2], (n_experts, d, f), jnp.float32)
        * (d ** -0.5),
        "down": jax.random.normal(ks[3], (n_experts, f, d), jnp.float32)
        * (f ** -0.5),
    }


def moe_apply(p, x, *, n_experts: int, top_k: int, group_size: int = 1024,
              capacity_factor: float = 1.25) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, T, D] -> (y [B, T, D], aux_loss scalar)."""
    b, t, d = x.shape
    tokens = x.reshape(b * t, d)
    n_tok = b * t
    gs = min(group_size, n_tok)
    assert n_tok % gs == 0
    groups = n_tok // gs
    xg = tokens.reshape(groups, gs, d)
    capacity = max(1, int(gs * top_k * capacity_factor / n_experts))

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                 # [g, s, e]
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)       # [g, s, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: frac-of-tokens × frac-of-probability per expert
    me = probs.mean(axis=(0, 1))
    ce_mask = jax.nn.one_hot(gate_idx[..., 0], n_experts).mean(axis=(0, 1))
    aux = n_experts * jnp.sum(me * ce_mask)

    # position of each (token, k) within its expert's capacity buffer
    sel = jax.nn.one_hot(gate_idx, n_experts, dtype=jnp.int32)  # [g,s,k,e]
    flat_sel = sel.reshape(groups, gs * top_k, n_experts)
    pos_in_e = jnp.cumsum(flat_sel, axis=1) * flat_sel - 1      # [g, s*k, e]
    pos_in_e = pos_in_e.reshape(groups, gs, top_k, n_experts)
    keep = (pos_in_e >= 0) & (pos_in_e < capacity)

    # dispatch / combine tensors [g, s, e, c]
    pos_oh = jax.nn.one_hot(jnp.clip(pos_in_e, 0, capacity - 1), capacity,
                            dtype=jnp.float32) * keep[..., None]
    dispatch = pos_oh.sum(axis=2)                               # [g,s,e,c]
    combine = (pos_oh * gate_vals[..., None, None]).sum(axis=2)

    ein = xg.astype(COMPUTE_DTYPE)
    expert_in = jnp.einsum("gsec,gsd->gecd", dispatch.astype(COMPUTE_DTYPE),
                           ein)                                 # [g,e,c,d]
    gate_h = jnp.einsum("gecd,edf->gecf", expert_in,
                        p["gate"].astype(COMPUTE_DTYPE))
    up_h = jnp.einsum("gecd,edf->gecf", expert_in,
                      p["up"].astype(COMPUTE_DTYPE))
    h = jax.nn.silu(gate_h.astype(jnp.float32)).astype(COMPUTE_DTYPE) * up_h
    expert_out = jnp.einsum("gecf,efd->gecd", h,
                            p["down"].astype(COMPUTE_DTYPE))
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(COMPUTE_DTYPE),
                   expert_out)
    return y.reshape(b, t, d), aux.astype(jnp.float32)
