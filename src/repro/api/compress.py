"""Model-level Deep-Compression pipeline: turn trained dense params into
AIDA serving format (prune -> k-means share -> pack), per projection.

Stacked layer weights [L, d_in, d_out] become stacked CompressedFC pytrees
(uniform padded nnz across layers so the scan-over-layers decode still
works); `models.layers.dense` dispatches on the leaf type via
`repro.api.dispatch`, so EVERY architecture's projections can serve
compressed — the paper's "FC layers of DNN" surface, generalized to the zoo.

This is the facade-owned implementation (the old `repro.serve.compress`
shim was removed in PR 2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import env
from repro.api.spec import CompressionSpec
from repro.core import sparse_fc as sfc
from repro.kernels import acsr_spmv as sp

# projection leaves eligible for compression (2D per layer, stacked to 3D)
TARGET_SUFFIXES = ("wq", "wk", "wv", "wo", "up", "down", "gate",
                   "wr", "wg", "in_proj", "out_proj")
SKIP_SUBSTR = ("ln", "mu", "bq", "bk", "bv", "conv", "A_log", "dt",
               "router", "x_db", "w_A", "w_B", "embed")


def _stack_compressed(per_layer: List[sfc.CompressedFC]) -> sfc.CompressedFC:
    """Stack per-layer CompressedFC into one scan-compatible pytree."""
    mode = per_layer[0].mode
    if mode in ("acsr", "aida"):
        # uniform slot depth across layers (pad the rmax axis; padding
        # slots are masked by row_nnz, so values/cols just zero-pad);
        # per-layer nnz may differ, so the stacked aux records nnz=-1
        rmax = max(c.blocked.rmax for c in per_layer)
        bs = [c.blocked for c in per_layer]

        def stk(arrs, pad_slots=True):
            if pad_slots:
                arrs = [jnp.pad(a, ((0, 0), (0, rmax - a.shape[1]),
                                    (0, 0))) for a in arrs]
            return jnp.stack(arrs)

        b0 = bs[0]
        blocked = sp.BlockedACSR(
            values=stk([b.values for b in bs]),
            col_idx=stk([b.col_idx for b in bs]),
            row_nnz=stk([b.row_nnz for b in bs], pad_slots=False),
            shape=b0.shape, block_rows=b0.block_rows, nnz=-1,
            centroids=(None if b0.centroids is None
                       else jnp.stack([b.centroids for b in bs])))
        return sfc.CompressedFC(mode=mode, shape=per_layer[0].shape,
                                blocked=blocked)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)


def compress_params(params: Dict, spec: CompressionSpec = None, *,
                    mode: str = None, density: float = None, k: int = None,
                    verbose=print) -> Tuple[Dict, Dict]:
    """Replace every eligible stacked projection in params['layers'] with a
    stacked CompressedFC per `spec`.  Returns (new_params, stats).

    `spec` may be a CompressionSpec, a bare mode string, or None; the
    keyword shortcuts (mode/density/k) override the matching spec fields.
    """
    spec = CompressionSpec.coerce(mode if spec is None and mode else spec)
    updates = {kk: v for kk, v in
               [("mode", mode), ("density", density), ("k", k)]
               if v is not None}
    if updates:
        spec = dataclasses.replace(spec, **updates)
    stats = {"n_compressed": 0, "bytes_dense": 0, "bytes_compressed": 0,
             "modes": {}, "spec": spec}

    def leaf_bytes(tree):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))

    def transform(path, leaf):
        name = str(path[-1].key if hasattr(path[-1], "key") else path[-1])
        pstr = "/".join(str(getattr(p, "key", p)) for p in path)
        if leaf.ndim != 3 or not name.endswith(TARGET_SUFFIXES):
            return leaf
        if any(s in pstr for s in SKIP_SUBSTR):
            return leaf
        leaf_mode = spec.mode_for(pstr)
        if leaf_mode == "skip":
            return leaf
        L = leaf.shape[0]
        block_rows = spec.block_rows
        if leaf_mode in ("acsr", "aida") and env.TUNE_BLOCK_ROWS:
            # encode-time tile search: pick the row-block height by timing
            # the fused kernel on this projection's pruned layer-0 weights
            from repro.core import acsr as acsr_mod
            from repro.kernels import ops, tune
            w0 = acsr_mod.prune_topk(np.asarray(leaf[0]).T, spec.density)
            block_rows = tune.choose_block_rows(
                w0, leaf_mode, spec.density, default=spec.block_rows,
                interpret=ops.pallas_interpret())
        per = [sfc.compress(np.asarray(leaf[i]).T, mode=leaf_mode,
                            density=spec.density, k=spec.k,
                            block_rows=block_rows,
                            kmeans_iters=spec.kmeans_iters,
                            dtype=spec.dtype)
               for i in range(L)]
        out = _stack_compressed(per)
        if spec.shards > 1:
            # shard-aware stacking: pad the partition axis now so a
            # ShardingPlan with tp == shards splits it with zero
            # session-time re-stacking (padded rows are inert)
            from repro.shard.partition import pad_leaf
            out = pad_leaf(out, spec.shards)
        stats["n_compressed"] += L
        stats["modes"][leaf_mode] = stats["modes"].get(leaf_mode, 0) + L
        stats["bytes_dense"] += leaf.size * 2  # bf16-serving baseline
        stats["bytes_compressed"] += leaf_bytes(out)
        if verbose:
            verbose(f"  compressed {pstr} {tuple(leaf.shape)} [{leaf_mode}]")
        return out

    new_layers = jax.tree_util.tree_map_with_path(transform,
                                                  params["layers"])
    out = dict(params)
    out["layers"] = new_layers
    stats["ratio"] = (stats["bytes_dense"]
                      / max(stats["bytes_compressed"], 1))
    return out, stats
