"""Health auditing: allocator/slot invariant checks and a watchdog.

The audits are exact, not heuristic — every page in a session's pool
must be accounted for by slot page-table references, prefix-cache pins,
or externally held refs (in-flight disagg handoffs). Any discrepancy is
a leak or a double-free in the making, so the watchdog surfaces it as a
:class:`HealthError` rather than a counter that nobody reads.

The watchdog also powers wedged-role recovery in the disagg
orchestrator: the orchestrator tracks consecutive faulted steps per
role and, past ``wedge_ticks``, drains the role's slots back through the
retry path (see ``disagg.session``); this module only owns the audit
cadence and the invariant checks themselves.
"""

from __future__ import annotations

from typing import Dict, List, Optional


class HealthError(RuntimeError):
    """An allocator/slot invariant was violated (a real bug, not a fault)."""


def audit_allocator(alloc) -> List[str]:
    """Structural invariants of a PageAllocator: free list is duplicate-
    free and disjoint from the used set, and every page is exactly one
    of free/used (page 0 excluded — it is the garbage sink)."""
    issues: List[str] = []
    free = list(alloc._free)
    if len(set(free)) != len(free):
        issues.append("allocator free list contains duplicates")
    inter = set(free) & alloc._used
    if inter:
        issues.append(f"pages both free and used: {sorted(inter)}")
    if len(free) + len(alloc._used) != alloc.n_pages - 1:
        issues.append(
            f"page accounting off: {len(free)} free + "
            f"{len(alloc._used)} used != {alloc.n_pages - 1} usable")
    for pid in alloc._used:
        if alloc.refcount(pid) < 1:
            issues.append(f"used page {pid} has refcount < 1")
    return issues


def audit_session(sess, extra_refs: Optional[Dict[int, int]] = None
                  ) -> List[str]:
    """Exact refcount accounting for a paged Session: every allocated
    page's refcount must equal its slot-table references plus its prefix
    pin (if cached) plus any externally held refs (``extra_refs``, e.g.
    pages owned by in-flight handoffs on the prefill side)."""
    if getattr(sess, "alloc", None) is None:
        return []
    issues = audit_allocator(sess.alloc)
    expected: Dict[int, int] = dict(extra_refs or {})
    for i in range(sess.slots):
        for pid in sess.host_table[i]:
            pid = int(pid)
            if pid < 0:
                continue
            if pid not in sess.alloc._used:
                issues.append(
                    f"slot {i} references unallocated page {pid}")
                continue
            expected[pid] = expected.get(pid, 0) + 1
    if sess.prefix is not None:
        for pid in sess.prefix._entries.values():
            expected[pid] = expected.get(pid, 0) + 1
    for pid in sess.alloc._used:
        want = expected.get(pid, 0)
        have = sess.alloc.refcount(pid)
        if want != have:
            issues.append(
                f"page {pid} refcount {have}, expected {want} "
                "(slot refs + prefix pin + external)")
    for pid in expected:
        if pid not in sess.alloc._used:
            issues.append(f"referenced page {pid} is not allocated")
    # slot liveness: an entry-less slot must own no pages
    for i in range(sess.slots):
        if sess.slot_entry[i] is None and (sess.host_table[i] >= 0).any():
            issues.append(f"empty slot {i} still holds pages")
    return issues


class Watchdog:
    """Periodic invariant auditor. ``due(tick)`` gates the cadence;
    ``audit`` raises HealthError on the first violation found."""

    def __init__(self, every: int):
        if every < 1:
            raise ValueError("watchdog cadence must be >= 1 tick")
        self.every = every
        self.audits = 0
        # observability seam: a ``(name, **args)`` emitter (obs.Tracer
        # .hook); every audit emits health.audit with its issue count.
        self.obs = None

    def due(self, tick: int) -> bool:
        return tick > 0 and tick % self.every == 0

    def audit(self, sess, extra_refs: Optional[Dict[int, int]] = None
              ) -> None:
        self.audits += 1
        issues = audit_session(sess, extra_refs=extra_refs)
        if self.obs is not None:
            self.obs("health.audit",
                     target=getattr(sess, "role", "engine"),
                     issues=len(issues))
        if issues:
            raise HealthError(
                "watchdog audit failed: " + "; ".join(issues[:5]))
