"""Fused codebook-dequant matmul — AIDA's perfect induction on the MXU.

Weights live in HBM as packed 4-bit codebook indices (2 codes/byte, 4× less
HBM traffic than bf16, 8× less than f32).  Each kernel instance expands its
[bn × bk] code tile against the 16-entry centroid table *inside VMEM* and
feeds the MXU — the dense weight matrix never exists in HBM.  This is the
TPU realization of "the bulk of data never leaves the confines of the memory
arrays": compressed weights are only expanded next to the compute unit,
multiplying effective memory bandwidth (decode is memory-bound, so the
roofline's memory term drops ≈4×).

Two modes:
* ``lut_matmul``         — codes × real activations (weights-only coding):
  VMEM dequant-gather then MXU matmul.
* ``lut_product_matmul`` — codes × coded activations through an arbitrary
  16×16 LUT (bit-parallel perfect induction verbatim).  Supports
  non-multiplicative induction tables; gather-based (VPU), sized for decode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import apply_activation as _act
from repro.kernels.util import cdiv as _cdiv


def _unpack4(packed: jnp.ndarray) -> jnp.ndarray:
    lo = packed & 0xF
    hi = packed >> 4
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


# ------------------------------------------------------- weights-coded
def _lut_matmul_kernel(x_ref, codes_ref, cents_ref, *opt_refs,
                       n_k_blocks: int, has_bias: bool,
                       activation: Optional[str]):
    """Grid (m, n, k): acc[bm,bn] += x[bm,bk] @ dequant(codes[bn,bk/2]).T."""
    refs = list(opt_refs)
    bias_ref = refs.pop(0) if has_bias else None
    o_ref, acc_ref = refs
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack4(codes_ref[...]).astype(jnp.int32)       # [bn, bk]
    w = jnp.take(cents_ref[0], codes, axis=0)                # VMEM dequant
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kb == n_k_blocks - 1)
    def _done():
        y = acc_ref[...]
        if has_bias:
            y = y + bias_ref[...]
        o_ref[...] = _act(activation, y)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk",
                                             "activation", "interpret"))
def lut_matmul(x: jnp.ndarray, codes_packed: jnp.ndarray,
               centroids: jnp.ndarray, *,
               bias: Optional[jnp.ndarray] = None,
               activation: Optional[str] = None,
               bm: int = 128, bn: int = 128,
               bk: int = 512, interpret: bool = True) -> jnp.ndarray:
    """act(x [B,K] @ dequant(codes [N,K/2], centroids).T + bias) -> [B,N].

    BlockSpecs: x tiles [bm,bk], code tiles [bn,bk/2] (uint8 — ½ byte/weight
    of VMEM), centroid table replicated (64 B).  MXU dims are 128-aligned.
    VMEM/instance ≈ bm·bk·4 + bn·bk/2 + 2·bm·bn·4 ≈ 0.5 MB at defaults.
    Odd b/n/k are padded up to the tile grid and the output sliced back
    (k padding adds zero activations, so padded code columns are inert).
    """
    b, k = x.shape
    n, k2 = codes_packed.shape
    assert k2 * 2 == k, "packed codes must cover K"
    bm, bn = min(bm, _cdiv(b, 8) * 8), min(bn, n)
    bk = min(bk, k)
    bk += bk % 2  # code tiles hold bk/2 packed bytes
    bp, np_ = _cdiv(b, bm) * bm, _cdiv(n, bn) * bn
    kp = _cdiv(k, bk) * bk
    if (bp, kp) != (b, k):
        x = jnp.pad(x, ((0, bp - b), (0, kp - k)))
    if (np_, kp) != (n, k):
        codes_packed = jnp.pad(codes_packed, ((0, np_ - n),
                                              (0, (kp - k) // 2)))
    grid = (bp // bm, np_ // bn, kp // bk)
    cents2d = centroids.reshape(1, -1).astype(jnp.float32)
    has_bias = bias is not None
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
        pl.BlockSpec((bn, bk // 2), lambda i, j, kb: (j, kb)),
        pl.BlockSpec((1, cents2d.shape[1]), lambda i, j, kb: (0, 0)),
    ]
    args = [x, codes_packed, cents2d]
    if has_bias:
        bias2d = jnp.pad(bias.astype(jnp.float32).reshape(1, -1),
                         ((0, 0), (0, np_ - n)))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kb: (0, j)))
        args.append(bias2d)
    out = pl.pallas_call(
        functools.partial(_lut_matmul_kernel, n_k_blocks=grid[2],
                          has_bias=has_bias, activation=activation),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*args)
    return out[:b, :n]


# ---------------------------------------------------------- fully-coded
def _lut_product_kernel(xc_ref, codes_ref, lut_ref, o_ref, acc_ref, *,
                        n_k_blocks: int, n_codes: int):
    """Grid (m, n, k): every multiply is LUT[w_code, x_code] (VPU gather)."""
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    wc = _unpack4(codes_ref[...]).astype(jnp.int32)          # [bn, bk]
    xc = xc_ref[...].astype(jnp.int32)                       # [bm, bk]
    flat_idx = wc[None, :, :] * n_codes + xc[:, None, :]     # [bm, bn, bk]
    prods = jnp.take(lut_ref[0], flat_idx.reshape(-1), axis=0)
    acc_ref[...] += prods.reshape(flat_idx.shape).sum(axis=-1)

    @pl.when(kb == n_k_blocks - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def lut_product_matmul(x_codes: jnp.ndarray, codes_packed: jnp.ndarray,
                       lut: jnp.ndarray, *, bm: int = 8, bn: int = 128,
                       bk: int = 128, interpret: bool = True) -> jnp.ndarray:
    """Fully-coded matmul via an arbitrary product LUT (perfect induction).

    x_codes [B,K] uint8, codes_packed [N,K/2] uint8, lut [nc,nc] f32 ->
    [B,N] f32.  Small bm (decode batches): the [bm,bn,bk] index tensor must
    fit VMEM (defaults → 8·128·128·4 B = 512 KiB).
    """
    b, k = x_codes.shape
    n, k2 = codes_packed.shape
    assert k2 * 2 == k
    nc = lut.shape[0]
    bm, bn = min(bm, _cdiv(b, 8) * 8), min(bn, n)
    bk = min(bk, k)
    bk += bk % 2
    bp, np_ = _cdiv(b, bm) * bm, _cdiv(n, bn) * bn
    kp = _cdiv(k, bk) * bk
    if (bp, kp) != (b, k) or (np_, kp) != (n, k):
        x_codes = jnp.pad(x_codes, ((0, bp - b), (0, kp - k)))
        codes_packed = jnp.pad(codes_packed, ((0, np_ - n),
                                              (0, (kp - k) // 2)))
    grid = (bp // bm, np_ // bn, kp // bk)
    lut_flat = lut.reshape(1, -1).astype(jnp.float32)
    out = pl.pallas_call(
        functools.partial(_lut_product_kernel, n_k_blocks=grid[2],
                          n_codes=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((bn, bk // 2), lambda i, j, kb: (j, kb)),
            pl.BlockSpec((1, nc * nc), lambda i, j, kb: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x_codes, codes_packed, lut_flat)
    out = out[:b, :n]
    if kp != k:
        # every padded column contributed lut[0, 0] once per column
        out = out - (kp - k) * lut[0, 0]
    return out
