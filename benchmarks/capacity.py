"""Capacity planner: replay a workload (or a recorded trace) across a
config sweep and name the smallest config meeting a declared SLO.

  PYTHONPATH=src python benchmarks/capacity.py --smoke --out capacity.json
  PYTHONPATH=src python benchmarks/capacity.py --from-trace trace_a.json \
      --slo "ttft_p99=20,goodput=1.0"
  PYTHONPATH=src python benchmarks/capacity.py \
      --sweep "slots=2,pages=16,chunk=4,policy=fifo;slots=4,pages=24,chunk=4,policy=fifo"

The sweep drives `Engine.capacity_benchmark`: each (slots,
kv_pool_pages, chunk, policy) point serves the same request stream —
a `sched.workload` preset, or the exact (arrival_tick, prompt_len,
max_new) stream reconstructed from a `--trace` export via
`WorkloadSpec.from_trace` — with a live tracer, and each run's trace is
fed through `repro.obs.analyze` for the SLO verdict.  Every number in
the output is tick-denominated, so the whole report (including which
config is "chosen") is deterministic: CI runs this twice and diffs the
bytes.

Exit status: 0 when some swept config meets the SLO, 1 when none does
(the sweep is undersized for the workload — add capacity).
"""
from __future__ import annotations

import argparse
import json
import sys

sys.path.insert(0, "src")

from repro.api.engine import (CAPACITY_SLO, CAPACITY_SMOKE_SWEEP,  # noqa: E402
                              Engine)
from repro.configs import get, reduced  # noqa: E402
from repro.obs.analyze import SLOSpec  # noqa: E402
from repro.sched import WorkloadSpec  # noqa: E402
from repro.sched.workload import PRESETS  # noqa: E402

#: the default full sweep: slots x pool x policy around the smoke points
FULL_SWEEP = tuple(
    {"slots": s, "kv_pool_pages": p, "chunk": c, "policy": pol}
    for s in (1, 2, 4)
    for p in (16, 32)
    for c in (4, 8)
    for pol in ("fifo", "sjf"))

_KEYS = {"slots": "slots", "pages": "kv_pool_pages",
         "kv_pool_pages": "kv_pool_pages", "chunk": "chunk",
         "policy": "policy"}


def parse_sweep(arg: str):
    """``"slots=2,pages=16,chunk=4,policy=fifo;slots=4,..."`` — one
    config per ``;``-separated group."""
    out = []
    for group in arg.split(";"):
        group = group.strip()
        if not group:
            continue
        c = {}
        for term in group.split(","):
            k, sep, v = term.partition("=")
            k = k.strip().lower()
            if not sep or k not in _KEYS:
                raise ValueError(f"bad sweep term {term!r} (keys: "
                                 f"{sorted(set(_KEYS))})")
            key = _KEYS[k]
            c[key] = v.strip() if key == "policy" else \
                (None if v.strip().lower() == "none" else int(v))
        out.append(c)
    if not out:
        raise ValueError(f"empty sweep {arg!r}")
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--workload", default="burst", choices=list(PRESETS),
                    help="request-mix preset to replay (ignored with "
                         "--from-trace)")
    ap.add_argument("--from-trace", default=None, metavar="PATH",
                    help="replay the exact (arrival_tick, prompt_len, "
                         "max_new) stream recorded in a serve --trace "
                         "export (WorkloadSpec.from_trace)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slo", default=CAPACITY_SLO, metavar="SPEC",
                    help="declared SLO, scheduler-tick units "
                         f"(default: {CAPACITY_SLO})")
    ap.add_argument("--smoke", action="store_true",
                    help="2-point sweep (one under-provisioned, one "
                         "adequate) — the CI gate configuration")
    ap.add_argument("--sweep", default=None, metavar="SPEC",
                    help="explicit sweep: "
                         "'slots=2,pages=16,chunk=4,policy=fifo;...'")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write {'capacity': section} JSON "
                         "(deterministic bytes — CI diffs two runs)")
    args = ap.parse_args()

    try:
        slo = SLOSpec.parse(args.slo)
    except ValueError as e:
        ap.error(str(e))
    sweep = None
    if args.sweep is not None:
        try:
            sweep = parse_sweep(args.sweep)
        except ValueError as e:
            ap.error(str(e))
    elif args.smoke:
        sweep = [dict(c) for c in CAPACITY_SMOKE_SWEEP]
    else:
        sweep = [dict(c) for c in FULL_SWEEP]

    cfg = get(args.arch) if args.full_size else reduced(get(args.arch))
    eng = Engine(cfg)
    if args.from_trace is not None:
        workload = WorkloadSpec.from_trace(
            args.from_trace, vocab=cfg.vocab, seed=args.seed)
        src = f"trace:{args.from_trace} ({workload.n_requests} requests)"
    else:
        workload = args.workload
        src = f"preset:{args.workload} ({args.requests} requests)"
    print(f"[capacity] {cfg.name}: {src}, slo {slo.describe()}, "
          f"{len(sweep)} configs")
    section = eng.capacity_benchmark(
        workload=workload, n_requests=args.requests, sweep=sweep,
        slo=slo, page_size=args.page_size, max_len=args.max_len,
        seed=args.seed)
    for e in section["sweep"]:
        m = e["metrics"]
        parts = [f"{name} {rec['value']}"
                 + ("" if rec["pass"] else
                    f" > {rec['bound']}" if name != "goodput"
                    else f" < {rec['bound']}")
                 for name, rec in sorted(m.items())]
        mark = "PASS" if e["slo_pass"] else "fail"
        print(f"  {e['label']:45s} {mark}  " + "  ".join(parts)
              + f"  ({e['completed']}/{e['requests']} done, "
                f"{e['span_ticks']} ticks)")
    chosen = section["chosen"]
    print(f"[capacity] chosen: "
          f"{chosen or 'NONE — no swept config meets the SLO'}; "
          f"replay deterministic: {section['deterministic_replay']}")
    if args.out is not None:
        with open(args.out, "w") as f:
            json.dump({"capacity": section}, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"[capacity] -> {args.out}")
    return 0 if chosen is not None else 1


if __name__ == "__main__":
    sys.exit(main())
