"""System integration: training convergence, checkpoint-restart, serving,
fault tolerance, gradient compression, data determinism."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get, reduced
from repro.data.pipeline import DataIterator, PipelineConfig, make_batch
from repro.models import model as M
from repro.optim import adamw
from repro.runtime import compression
from repro.runtime.fault_tolerance import (HeartbeatRegistry, RestartLoop,
                                           StragglerDetector,
                                           plan_elastic_mesh)
from repro.api.session import Request, Session
from repro.train import trainer

CFG = reduced(get("llama3-8b"), n_layers=2, d_model=64, d_ff=128, vocab=128)


def make_iter(cfg, b=4, s=32, start=0):
    return DataIterator(cfg, PipelineConfig(seed=1, global_batch=b,
                                            seq_len=s), start_step=start)


# ------------------------------------------------------------- training
def test_loss_decreases():
    tc = trainer.TrainConfig(remat="none",
                             opt=adamw.AdamWConfig(lr=3e-3, warmup_steps=2,
                                                   total_steps=40))
    state = trainer.init_state(CFG, jax.random.PRNGKey(0))
    step = jax.jit(trainer.make_train_step(CFG, tc))
    it = make_iter(CFG)
    losses = []
    for _ in range(30):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_grad_accum_equivalence():
    """microbatches=4 must equal microbatches=1 on the same global batch."""
    it = make_iter(CFG, b=8)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    s0 = trainer.init_state(CFG, jax.random.PRNGKey(0))
    out = {}
    for mb in (1, 4):
        tc = trainer.TrainConfig(remat="none", microbatches=mb)
        step = jax.jit(trainer.make_train_step(CFG, tc))
        s1, m = step(s0, batch)
        out[mb] = (s1, float(m["loss"]))
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     out[1][0].params, out[4][0].params)
    assert max(jax.tree.leaves(d)) < 3e-3
    assert abs(out[1][1] - out[4][1]) < 1e-2


@pytest.mark.parametrize("scheme", ["bf16", "int8"])
def test_grad_compression_training_still_converges(scheme):
    tc = trainer.TrainConfig(remat="none", grad_compression=scheme,
                             opt=adamw.AdamWConfig(lr=3e-3, warmup_steps=2,
                                                   total_steps=40))
    state = trainer.init_state(CFG, jax.random.PRNGKey(0))
    step = jax.jit(trainer.make_train_step(CFG, tc))
    it = make_iter(CFG)
    losses = []
    for _ in range(20):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_int8_compression_error_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64))
                          .astype(np.float32))}
    ef = compression.ErrorFeedback(g)
    dec = ef.apply(g)
    err1 = float(jnp.max(jnp.abs(dec["w"] - g["w"])))
    assert err1 > 0  # int8 is lossy...
    # ...but error feedback keeps the accumulated bias bounded
    total = jnp.zeros_like(g["w"])
    for _ in range(10):
        total = total + ef.apply(g)["w"]
    bias = float(jnp.max(jnp.abs(total / 10 - g["w"])))
    assert bias < err1 * 0.5


# ------------------------------------------------------- checkpoint / FT
def test_checkpoint_roundtrip_and_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tc = trainer.TrainConfig(remat="none")
    it = make_iter(CFG)
    state = trainer.run(CFG, tc, it, n_steps=3, key=jax.random.PRNGKey(0),
                        ckpt_mgr=mgr, ckpt_every=1, log_every=0)
    mgr.wait()
    assert mgr.latest_step() == 3
    assert len(mgr.list_steps()) == 2  # retention

    template = jax.tree.map(np.zeros_like, state)
    restored, extra = mgr.restore(template)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     restored.params, state.params)
    assert max(jax.tree.leaves(d)) == 0.0
    assert extra["data"]["step"] == 3  # exact data resume point

    # deterministic resume: batch at restored step == original batch
    it2 = DataIterator.restore(CFG, PipelineConfig(seed=1, global_batch=4,
                                                   seq_len=32),
                               extra["data"])
    np.testing.assert_array_equal(next(it2)["tokens"],
                                  make_batch(CFG, it2.pc, 3)["tokens"])


def test_checkpoint_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.ones((4,))}
    mgr.save(1, state, blocking=True)
    # a torn write (no .COMMITTED) must be invisible
    os.makedirs(tmp_path / "step_00000002")
    (tmp_path / "step_00000002" / "manifest.json").write_text("{}")
    assert mgr.latest_step() == 1


def test_restart_loop_recovers(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, {"w": jnp.ones((2,))}, blocking=True)
    calls = []

    def run_fn(resume_step):
        calls.append(resume_step)
        if len(calls) < 3:
            raise RuntimeError("node died")

    loop = RestartLoop(mgr, max_restarts=5, log=lambda *a: None)
    assert loop.supervise(run_fn) == 2
    assert calls == [5, 5, 5]


def test_heartbeat_and_straggler():
    t = [0.0]
    hb = HeartbeatRegistry(timeout_s=10, clock=lambda: t[0])
    hb.ping("n0")
    hb.ping("n1")
    t[0] = 5
    hb.ping("n0")
    t[0] = 12
    assert hb.dead_nodes() == ["n1"]

    sd = StragglerDetector(window=20, z_thresh=4.0, min_samples=5)
    r = np.random.default_rng(0)
    for _ in range(15):
        assert not sd.record(1.0 + float(r.normal()) * 1e-3)
    assert sd.record(3.0)  # 3x median step time -> straggler
    assert not sd.chronic()


def test_elastic_plan():
    p = plan_elastic_mesh(512, model_parallel=16, global_batch=256, pods=2)
    assert p.mesh_shape == (2, 16, 16)
    # lose a host: 504 chips survive -> dp shrinks to 16, batch stays 256
    p2 = plan_elastic_mesh(504, model_parallel=16, global_batch=256)
    assert p2.mesh_shape == (16, 16)
    assert p2.global_batch == 256
    p3 = plan_elastic_mesh(100, model_parallel=16, global_batch=256)
    assert p3.mesh_shape == (4, 16)


def test_elastic_restore_onto_smaller_mesh(tmp_path):
    """Checkpoint written by one topology restores into another (here:
    1-device 'mesh', exercising the logical-array reshard path)."""
    mgr = CheckpointManager(str(tmp_path))
    state = trainer.init_state(CFG, jax.random.PRNGKey(0))
    mgr.save(1, state, blocking=True)
    restored, _ = mgr.restore(jax.tree.map(np.zeros_like, state))
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     restored.params, state.params)
    assert max(jax.tree.leaves(d)) == 0.0


# --------------------------------------------------------------- serving
def test_serve_engine_continuous_batching():
    cfg = CFG
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Session(cfg, params, batch_slots=2, max_len=64)
    for rid in range(5):  # more requests than slots -> continuous batching
        eng.submit(Request(prompt=[1 + rid, 2, 3], max_new=4, rid=rid))
    results = eng.run()
    assert sorted(r.rid for r in results) == [0, 1, 2, 3, 4]
    assert all(len(r.tokens) == 4 for r in results)
    assert all(0 <= t < cfg.vocab for r in results for t in r.tokens)


def test_serve_engine_matches_manual_decode():
    cfg = CFG
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = [5, 9, 2]
    eng = Session(cfg, params, batch_slots=1, max_len=32)
    eng.submit(Request(prompt=prompt, max_new=3, rid=0))
    got = eng.run()[0].tokens

    state = M.init_decode_state(cfg, 1, 32)
    toks = list(prompt)
    for t in toks:
        state, logits = M.decode_step(cfg, params, state,
                                      jnp.asarray([t], jnp.int32))
    out = []
    for _ in range(3):
        nxt = int(logits[0, : cfg.vocab].argmax())
        out.append(nxt)
        state, logits = M.decode_step(cfg, params, state,
                                      jnp.asarray([nxt], jnp.int32))
    assert got == out


# ------------------------------------------------------------------ data
def test_data_deterministic_and_sharded():
    pc0 = PipelineConfig(seed=3, global_batch=8, seq_len=16, n_shards=2,
                         shard_id=0)
    pc1 = dataclasses.replace(pc0, shard_id=1)
    a0 = make_batch(CFG, pc0, 7)["tokens"]
    a0b = make_batch(CFG, pc0, 7)["tokens"]
    a1 = make_batch(CFG, pc1, 7)["tokens"]
    np.testing.assert_array_equal(a0, a0b)
    assert a0.shape == (4, 16)
    assert not np.array_equal(a0, a1)
