"""KV caches: full (static max length) and ring (bounded, for SWA layers).

The ring cache is what makes `long_500k` decode tractable on SWA archs
(danube/mixtral/hymba): a sliding-window layer never needs more than
`window` entries, so its cache is O(window), not O(sequence).  Stored
entries carry their absolute positions; masks are computed from positions,
so RoPE applied at write time stays consistent (scores depend only on
position deltas).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.api import env

#: update strategy, resolved ONCE at import (repro.api.env) — update()
#: runs inside traced decode steps, where a per-call os.environ read is
#: pure host overhead (and useless: the trace bakes in whatever value the
#: first call saw).  Override per call with update(..., strategy=...).
KV_UPDATE_DEFAULT = env.KV_UPDATE


class KVCache(NamedTuple):
    """ring-ness is a static property of the arch (all leaves stay arrays:
    the cache must be a clean pytree for scan/sharding); callers pass
    ``ring=`` explicitly to update()."""
    k: jnp.ndarray        # [B, Hkv, S_slots, Dh]
    v: jnp.ndarray        # [B, Hkv, S_slots, Dh]
    pos: jnp.ndarray      # [B, S_slots] int32 absolute position, -1 = empty


def init_cache(batch: int, n_kv: int, slots: int, d_head: int,
               dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, n_kv, slots, d_head), dtype),
        v=jnp.zeros((batch, n_kv, slots, d_head), dtype),
        pos=jnp.full((batch, slots), -1, jnp.int32))


def update(cache: KVCache, k_new: jnp.ndarray, v_new: jnp.ndarray,
           cur_pos: jnp.ndarray, ring: bool = False,
           strategy: Optional[str] = None) -> KVCache:
    """Insert one token's k/v ([B, Hkv, 1, Dh]) at absolute pos [B].

    Two strategies (§Perf-measured; default from REPRO_KV_UPDATE at
    import, explicit ``strategy=`` wins):
    * scatter (default) — in-place batched dynamic update; cheapest when
      GSPMD shards it (llama3 decode: 148 ms vs 211 ms memory term);
    * select — one-hot jnp.where; full-cache rewrite, but immune to the
      SPMD 'involuntary full rematerialization' replication that batched
      scatters trigger on some sharded layouts (gemma2/hymba local+global
      stacks).
    """
    strategy = KV_UPDATE_DEFAULT if strategy is None else strategy
    slots = cache.k.shape[2]
    slot = (cur_pos % slots) if ring else cur_pos
    if strategy == "select":
        hot = (jax.lax.broadcasted_iota(
            jnp.int32, (cache.k.shape[0], slots), 1) == slot[:, None])
        hot_kv = hot[:, None, :, None]                     # [B,1,S,1]
        k = jnp.where(hot_kv, k_new.astype(cache.k.dtype), cache.k)
        v = jnp.where(hot_kv, v_new.astype(cache.v.dtype), cache.v)
        pos = jnp.where(hot, cur_pos[:, None], cache.pos)
        return cache._replace(k=k, v=v, pos=pos)
    bidx = jnp.arange(cache.k.shape[0])
    k = cache.k.at[bidx, :, slot].set(k_new[:, :, 0].astype(cache.k.dtype))
    v = cache.v.at[bidx, :, slot].set(v_new[:, :, 0].astype(cache.v.dtype))
    pos = cache.pos.at[bidx, slot].set(cur_pos)
    return cache._replace(k=k, v=v, pos=pos)


def prefill(cache: KVCache, k_seq: jnp.ndarray, v_seq: jnp.ndarray,
            lengths: jnp.ndarray) -> KVCache:
    """Bulk-load a [B, Hkv, T, Dh] prefix (T <= slots; non-ring only)."""
    t = k_seq.shape[2]
    k = cache.k.at[:, :, :t].set(k_seq.astype(cache.k.dtype))
    v = cache.v.at[:, :, :t].set(v_seq.astype(cache.v.dtype))
    ar = jnp.arange(t)[None, :]
    pos = cache.pos.at[:, :t].set(
        jnp.where(ar < lengths[:, None], ar, -1))
    return cache._replace(k=k, v=v, pos=pos)


def attention_mask(cache: KVCache, cur_pos: jnp.ndarray,
                   window: jnp.ndarray) -> jnp.ndarray:
    """[B, S_slots] bool: which slots a query at cur_pos may attend to.

    window < 0 means unbounded (full causal).
    """
    p = cache.pos
    ok = (p >= 0) & (p <= cur_pos[:, None])
    win_lo = jnp.where(window < 0, jnp.int32(-1),
                       cur_pos[:, None] - window)
    return ok & (p > win_lo)
