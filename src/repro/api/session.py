"""Serving session: continuous batching over a fixed-slot decode batch.

Requests occupy slots, finished slots are refilled from the queue without
stopping the batch (continuous batching).  Prefill is chunk-free
(token-by-token through the decode path) to keep one compiled step;
prompts for a slot are fed before its generation starts.  Greedy or
temperature sampling.

With ``kv_cache="paged"`` (or REPRO_KV_CACHE=paged) the session swaps the
dense per-slot KV cache for the kvstore page pool: pages are allocated
host-side the step a sequence crosses a page boundary, freed the moment
its request completes (not lazily on refill), and — on pure-SWA
architectures — reclaimed as soon as they slide fully behind the
attention window, so resident KV memory tracks *live* tokens, not
batch·max_len.

Sessions are created by `repro.api.Engine.session()` (or directly); the
compiled decode step comes from the engine's backend, so dense and
compressed (Pallas) serving share one code path.
"""
from __future__ import annotations

import collections
import dataclasses
import os
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import kvstore as kvs
from repro.api.registry import Executor, get_backend
from repro.configs.base import ArchConfig

# env knobs resolved ONCE at import (traced code must not read os.environ);
# per-session override via the kv_cache= / kv_dtype= constructor args
KV_CACHE_DEFAULT = os.environ.get("REPRO_KV_CACHE", "full")
KV_DTYPE_DEFAULT = os.environ.get("REPRO_KV_DTYPE", "int8")

# Compiled decode steps keyed by (backend, cfg): sessions on the same
# config reuse one jitted step (its trace cache handles dense vs
# compressed param structures), so spinning up a Session is cheap.
_STEP_CACHE: dict = {}


def _jitted_step(backend: Executor, cfg: ArchConfig):
    key = (backend.name, cfg)
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = jax.jit(backend.make_decode_step(cfg))
    return _STEP_CACHE[key]


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new: int = 16
    temperature: float = 0.0
    rid: int = 0


@dataclasses.dataclass
class Result:
    rid: int
    tokens: List[int]


class Session:
    def __init__(self, cfg: ArchConfig, params, batch_slots: int = 4,
                 max_len: int = 256, seed: int = 0,
                 backend: Optional[Executor] = None,
                 kv_cache: Optional[str] = None, page_size: int = 16,
                 kv_pool_pages: Optional[int] = None,
                 kv_dtype: Optional[str] = None):
        assert cfg.has_decode, "encoder archs don't serve autoregressively"
        from repro.models import model as M
        self.cfg, self.params = cfg, params
        self.slots = batch_slots
        self.max_len = max_len
        kv_cache = KV_CACHE_DEFAULT if kv_cache is None else kv_cache
        if cfg.family == "rwkv6":
            kv_cache = "full"      # attention-free: nothing to page
        self.kv_cache = kv_cache
        self.page_size = page_size
        self.kv_dtype = kv_dtype or KV_DTYPE_DEFAULT
        if kv_cache == "paged":
            self.state = M.init_decode_state(
                cfg, batch_slots, max_len, kv_cache="paged",
                page_size=page_size, kv_pool_pages=kv_pool_pages,
                kv_dtype=self.kv_dtype)
            n_pages = jax.tree.leaves(
                self.state["layers"]["kv"])[0].shape[1]
            self.alloc = kvs.PageAllocator(n_pages)
            # host mirror of the device page table (allocation decisions
            # never read device memory back)
            self.host_table = np.full(
                (batch_slots, self.state["page_table"].shape[1]), -1,
                np.int64)
            self.slot_pos = [0] * batch_slots
            wins = cfg.layer_windows()
            # page reclamation is safe only when EVERY layer is windowed
            # (one global layer pins the whole history, like the dense
            # path's ring-vs-full split)
            self._swa_window = max(wins) if wins and all(
                w > 0 for w in wins) else None
        else:
            self.state = M.init_decode_state(cfg, batch_slots, max_len)
            self.alloc = None
        self.key = jax.random.PRNGKey(seed)
        if backend is None or isinstance(backend, str):
            backend = get_backend(backend or "jax-dense")
        self.backend = backend
        self._step = _jitted_step(backend, cfg)
        # per-slot bookkeeping (host side)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pending: List[List[int]] = [[] for _ in range(batch_slots)]
        self.slot_out: List[List[int]] = [[] for _ in range(batch_slots)]
        self.queue: Deque[Request] = collections.deque()
        self.results: List[Result] = []
        self.stats = {"steps": 0, "fills": 0}
        if kv_cache == "paged":
            self.stats.update({"page_allocs": 0, "pages_in_use": 0,
                               "pages_peak": 0, "pages_reclaimed_swa": 0})

    # ------------------------------------------------------------ public
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Result]:
        """Drain the queue; returns all results in deterministic rid order."""
        for _ in range(max_steps):
            self._fill_slots()
            if all(r is None for r in self.slot_req):
                break
            self._advance()
        return sorted(self.results, key=lambda r: r.rid)

    # ----------------------------------------------------------- internals
    def _fill_slots(self):
        for i in range(self.slots):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.popleft()
                self.slot_req[i] = req
                self.slot_pending[i] = list(req.prompt)
                self.slot_out[i] = []
                self._reset_slot_state(i)
                self.stats["fills"] += 1

    def _reset_slot_state(self, i: int):
        def zero_slot(x):
            if x.ndim >= 2 and x.shape[1] == self.slots:  # [L, B, ...]
                return x.at[:, i].set(jnp.zeros_like(x[:, i]))
            return x
        if self.kv_cache == "paged":
            # pool pages are shared, not slot-indexed: release the slot's
            # pages (idempotent — already freed at request completion) and
            # zero only the slot-shaped leaves (mamba conv/h etc.).  Stale
            # page contents are harmless: the position mask never reaches
            # unwritten slots and scales reset on re-allocation.
            self._release_slot_pages(i)
            layers = dict(self.state["layers"])
            kv = layers.pop("kv")
            layers = jax.tree.map(zero_slot, layers)
            layers["kv"] = kv
            self.state = {"layers": layers,
                          "pos": self.state["pos"].at[i].set(0),
                          "page_table": self.state["page_table"]}
            self.slot_pos[i] = 0
            return
        layers = jax.tree.map(zero_slot, self.state["layers"])
        pos = self.state["pos"].at[i].set(0)
        # empty cache slots must read as "never written": pos fields are -1
        if self.cfg.family not in ("rwkv6",):
            layers = dict(layers)
            kv = layers["kv"]
            layers["kv"] = kv._replace(
                pos=kv.pos.at[:, i].set(-jnp.ones_like(kv.pos[:, i])))
        self.state = {"layers": layers, "pos": pos}

    # ------------------------------------------------------ paged KV admin
    def _release_slot_pages(self, i: int) -> None:
        """Free every page owned by slot ``i`` (request done / slot reset)."""
        pages = [int(p) for p in self.host_table[i] if p >= 0]
        if not pages:
            return
        self.alloc.free(pages)
        self.host_table[i] = -1
        self.state["page_table"] = self.state["page_table"].at[i].set(
            jnp.int32(kvs.NO_PAGE))
        self.stats["pages_in_use"] = self.alloc.in_use

    def _ensure_pages(self) -> None:
        """Host-side page faults: before a step, make sure each active
        slot owns the page its next token lands in; fresh pages get their
        quantization scales cleared so stale maxima can't poison them."""
        npp = self.host_table.shape[1]
        events = []
        try:
            for i, req in enumerate(self.slot_req):
                if req is None:
                    continue
                pi = self.slot_pos[i] // self.page_size
                if pi >= npp or self.host_table[i, pi] >= 0:
                    continue  # beyond max_len (clamped, like dense cache)
                pid = self.alloc.alloc()
                self.host_table[i, pi] = pid
                events.append((i, pi, pid))
        except kvs.OutOfPages:
            # transactional: roll back this round's host-side grants so a
            # caller that drains requests and retries never sees a page
            # recorded host-side but absent from the device table
            for i, pi, pid in events:
                self.host_table[i, pi] = -1
            self.alloc.free(pid for _, _, pid in events)
            raise
        if not events:
            return
        si, pi, pids = (jnp.asarray([e[n] for e in events], jnp.int32)
                        for n in range(3))
        self.state["page_table"] = \
            self.state["page_table"].at[si, pi].set(pids)
        kv = self.state["layers"]["kv"]
        if kv.k_scale is not None:
            kv = kv._replace(k_scale=kv.k_scale.at[:, pids].set(0.0),
                             v_scale=kv.v_scale.at[:, pids].set(0.0))
            layers = dict(self.state["layers"])
            layers["kv"] = kv
            self.state["layers"] = layers
        self.stats["page_allocs"] = self.alloc.total_allocs
        self.stats["pages_in_use"] = self.alloc.in_use
        self.stats["pages_peak"] = self.alloc.peak

    def _reclaim_swa_pages(self) -> None:
        """On pure-SWA archs, free pages that slid fully behind the widest
        layer window — decode memory stays O(window), page-granular."""
        if self._swa_window is None:
            return
        events = []
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            dead = kvs.reclaimable_prefix(self.slot_pos[i],
                                          self._swa_window, self.page_size)
            for pi in range(min(dead, self.host_table.shape[1])):
                pid = int(self.host_table[i, pi])
                if pid >= 0:
                    self.alloc.free([pid])
                    self.host_table[i, pi] = -1
                    events.append((i, pi))
        if not events:
            return
        si = jnp.asarray([e[0] for e in events], jnp.int32)
        pi = jnp.asarray([e[1] for e in events], jnp.int32)
        self.state["page_table"] = self.state["page_table"].at[si, pi].set(
            jnp.int32(kvs.NO_PAGE))
        self.stats["pages_reclaimed_swa"] += len(events)
        self.stats["pages_in_use"] = self.alloc.in_use

    def _advance(self):
        tokens = np.zeros((self.slots,), np.int32)
        stepped = []
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            stepped.append(i)
            if self.slot_pending[i]:
                tokens[i] = self.slot_pending[i][0]
            elif self.slot_out[i]:
                tokens[i] = self.slot_out[i][-1]
            else:
                tokens[i] = req.prompt[-1]
        if self.kv_cache == "paged":
            self._ensure_pages()
        self.state, logits = self._step(self.params, self.state,
                                        jnp.asarray(tokens))
        self.stats["steps"] += 1
        if self.kv_cache == "paged":
            for i in stepped:
                self.slot_pos[i] += 1
            self._reclaim_swa_pages()
        logits = np.asarray(logits[:, : self.cfg.vocab])
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.slot_pending[i]:
                self.slot_pending[i].pop(0)
                if self.slot_pending[i]:
                    continue  # still prefilling
            # sample the next token from this step's logits
            if req.temperature > 0:
                self.key, sub = jax.random.split(self.key)
                nxt = int(jax.random.categorical(
                    sub, jnp.asarray(logits[i]) / req.temperature))
            else:
                nxt = int(logits[i].argmax())
            self.slot_out[i].append(nxt)
            if len(self.slot_out[i]) >= req.max_new:
                self.results.append(Result(req.rid, self.slot_out[i]))
                self.slot_req[i] = None
                if self.kv_cache == "paged":
                    # return pages eagerly — don't wait for a refill
                    self._release_slot_pages(i)
