"""Architecture registry — import every config module to register it."""
from repro.configs import (h2o_danube_1_8b, qwen1_5_0_5b, gemma2_2b,  # noqa
                           llama3_8b, phi3_vision_4_2b, dbrx_132b,    # noqa
                           mixtral_8x7b, hymba_1_5b, hubert_xlarge,   # noqa
                           rwkv6_7b)                                  # noqa
from repro.configs.base import ArchConfig, get, names, reduced  # noqa
from repro.configs.shapes import SHAPES, Shape, cell_supported, all_cells  # noqa
