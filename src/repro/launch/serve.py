"""Serving launcher: batched decode through the `repro.api.Engine` facade,
optionally AIDA-compressed weights, with reproducible heterogeneous
workloads driven by `repro.sched.workload`.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --compress aida --density 0.1 --requests 16 \
      --workload heterogeneous --chunk 8 --policy sjf
(Full-size archs need a checkpoint; without one this initializes random
weights at a REDUCED size for a functional smoke serve.)

Mesh serving (tensor-parallel over an explicit ShardingPlan): ``--mesh
MODELxDATA`` (e.g. ``--mesh 4x2``) builds a host mesh through
`launch.mesh.make_host_mesh`; on a laptop/CI host export
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` first.

Disaggregated serving (repro.disagg): ``--disagg`` splits the engine
into a prefill role and a decode role with KV page migration between
their pools; ``--prefill-devices N --decode-devices M`` additionally
puts the roles on disjoint device subsets (each role needs >= 1
device — the launcher force-emulates N+M host devices when XLA_FLAGS
is not already set).
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _parse_mesh(arg: str):
    """"4" -> model=4; "4x2" -> model=4, data=2."""
    parts = arg.lower().split("x")
    try:
        n_model = int(parts[0])
        n_data = int(parts[1]) if len(parts) > 1 else None
    except ValueError:
        sys.exit(f"--mesh wants MODEL or MODELxDATA, got {arg!r}")
    return n_model, n_data


def _early_arg(name: str):
    """Scan argv for ``--name VALUE`` / ``--name=VALUE`` BEFORE argparse
    runs — mesh/device degrees must be known before jax locks the
    process's device count on import."""
    for i, arg in enumerate(sys.argv):
        if arg == name and i + 1 < len(sys.argv):
            return sys.argv[i + 1]
        if arg.startswith(name + "="):
            return arg.split("=", 1)[1]
    return None


# argv scan + XLA_FLAGS mutation ONLY when run as a program (python -m
# repro.launch.serve): importing this module must never read argv, call
# sys.exit, or change the process's jax device count.
if __name__ == "__main__":
    _mesh_arg = _early_arg("--mesh")
    if _mesh_arg is not None and "XLA_FLAGS" not in os.environ:
        n_model, n_data = _parse_mesh(_mesh_arg)
        n_dev = n_model * (n_data or 1)
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={n_dev}"
    _pre_arg = _early_arg("--prefill-devices")
    _dec_arg = _early_arg("--decode-devices")
    if _pre_arg is not None and _dec_arg is not None \
            and "XLA_FLAGS" not in os.environ:
        try:
            _n_role = int(_pre_arg) + int(_dec_arg)
        except ValueError:
            _n_role = 0            # argparse will reject it properly
        if _n_role > 0:
            os.environ["XLA_FLAGS"] = \
                f"--xla_force_host_platform_device_count={_n_role}"

from repro.api import CompressionSpec, Engine, FaultPlan
from repro.configs import get, reduced
from repro.launch.mesh import make_host_mesh
from repro.resil import PRESETS as RESIL_PRESETS
from repro.sched import SchedConfig, WorkloadSpec, generate, summarize
from repro.sched.workload import PRESETS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--compress", default=None,
                    choices=[None, "int8", "codebook4", "acsr", "aida"])
    ap.add_argument("--density", type=float, default=0.1)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--kv-cache", default=None,
                    choices=[None, "auto", "full", "paged"],
                    help="None/auto = paged page-pool KV wherever the "
                         "arch has attention (repro.kvstore)")
    ap.add_argument("--workload", default="uniform", choices=list(PRESETS),
                    help="request-mix preset (sched.workload): prompt "
                         "lengths, max_new, arrival process")
    ap.add_argument("--prompt-len", type=int, default=None,
                    help="override the preset's prompt-length range with "
                         "a fixed length")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload RNG seed (schedules replay exactly)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="prefill tokens per model call (1 = token-by-"
                         "token; paged KV + attention-only archs)")
    ap.add_argument("--policy", default="fifo", choices=["fifo", "sjf"],
                    help="admission order: FIFO or shortest-prompt-first")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share full prompt-prefix pages across requests")
    ap.add_argument("--kv-pool-pages", type=int, default=None,
                    help="page-pool size (small pools exercise admission "
                         "control + preemption instead of crashing)")
    ap.add_argument("--mesh", default=None,
                    help="tensor-parallel serving mesh, MODEL or "
                         "MODELxDATA (e.g. 4x2); sized via "
                         "launch.mesh.make_host_mesh")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated serving: a prefill role and a "
                         "decode role with KV page migration "
                         "(repro.disagg)")
    ap.add_argument("--prefill-slots", type=int, default=4,
                    help="prefill-role batch slots (with --disagg)")
    ap.add_argument("--decode-slots", type=int, default=4,
                    help="decode-role batch slots (with --disagg)")
    ap.add_argument("--prefill-devices", type=int, default=None,
                    help="devices for the prefill role's mesh (with "
                         "--disagg; requires --decode-devices)")
    ap.add_argument("--decode-devices", type=int, default=None,
                    help="devices for the decode role's mesh (with "
                         "--disagg; requires --prefill-devices)")
    ap.add_argument("--fault-plan", default=None, metavar="PRESET:SEED",
                    help="inject deterministic faults (repro.resil): "
                         "one of " + ", ".join(
                             sorted(k for k in RESIL_PRESETS if k != "none"))
                         + "; e.g. drop-handoff:3")
    ap.add_argument("--deadline-ticks", type=int, default=None,
                    help="per-request completion budget in scheduler "
                         "ticks; missed deadlines become structured "
                         "RequestFailed results, not hangs")
    ap.add_argument("--max-retries", type=int, default=None,
                    help="recompute re-admissions allowed per request "
                         "before it fails with 'retries_exhausted' "
                         "(default 2 when the resil layer is on)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace_event JSON "
                         "timeline of every serving seam (repro.obs); "
                         "tick-clock timestamps, so two same-seed runs "
                         "produce byte-identical traces")
    ap.add_argument("--trace-ring", type=int, default=None, metavar="N",
                    help="keep the last N events in a flight-recorder "
                         "ring; dumped to disk automatically on a "
                         "terminal HealthError/OutOfPages/RequestFailed")
    ap.add_argument("--profile-dir", default=None, metavar="PATH",
                    help="wrap the serve in a jax.profiler trace "
                         "(TensorBoard-loadable device profile)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the run's metrics + resil + role summary "
                         "as machine-readable JSON (with provenance)")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="write a repro.obs.analyze TraceReport of this "
                         "serve: per-request critical paths, queueing "
                         "split, per-role utilization, page pressure; "
                         "tick-denominated, so two same-seed runs "
                         "produce byte-identical reports")
    ap.add_argument("--slo", default=None, metavar="SPEC",
                    help="evaluate the run against an SLO, e.g. "
                         "'ttft_p99=40,tpot_p99=4,goodput=0.95' "
                         "(scheduler-tick units); verdict is printed "
                         "and embedded in --report")
    args = ap.parse_args()

    slo = None
    if args.slo is not None:
        from repro.obs import SLOSpec
        try:
            slo = SLOSpec.parse(args.slo)
        except ValueError as e:
            ap.error(str(e))

    resil = None
    if (args.fault_plan is not None or args.deadline_ticks is not None
            or args.max_retries is not None):
        if args.deadline_ticks is not None and args.deadline_ticks < 1:
            ap.error("--deadline-ticks must be >= 1")
        if args.max_retries is not None and args.max_retries < 0:
            ap.error("--max-retries must be >= 0")
        resil = {"watchdog_every": 8}
        if args.fault_plan is not None:
            try:
                resil["fault_plan"] = FaultPlan.parse(args.fault_plan)
            except ValueError as e:
                ap.error(str(e))
        if args.deadline_ticks is not None:
            resil["deadline_ticks"] = args.deadline_ticks
        if args.max_retries is not None:
            resil["max_retries"] = args.max_retries

    if (args.prefill_devices is not None) != (args.decode_devices is not None):
        ap.error("--prefill-devices and --decode-devices go together")
    if args.prefill_devices is not None:
        if not args.disagg:
            ap.error("--prefill-devices/--decode-devices need --disagg")
        if args.prefill_devices < 1 or args.decode_devices < 1:
            ap.error("each disaggregated role needs at least one device "
                     f"(got prefill={args.prefill_devices}, "
                     f"decode={args.decode_devices})")
    if args.disagg and args.mesh is not None:
        ap.error("--mesh and --disagg are mutually exclusive; give the "
                 "roles devices via --prefill-devices/--decode-devices")

    mesh = None
    if args.mesh is not None:
        n_model, n_data = _parse_mesh(args.mesh)
        mesh = make_host_mesh(n_model=n_model, n_data=n_data)
        print(f"[serve] mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    cfg = get(args.arch) if args.full_size else reduced(get(args.arch))
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no serving")
    print(f"[serve] {cfg.name}: ~{cfg.params_count()/1e6:.1f}M params")
    eng = Engine(cfg)
    if args.compress:
        eng.compress(CompressionSpec(mode=args.compress,
                                     density=args.density))
        print(f"[serve] {args.compress}: {eng.stats['n_compressed']} "
              f"projections, {eng.stats['ratio']:.1f}x weight memory "
              f"(backend: {eng.backend.name})")

    overrides = dict(n_requests=args.requests, max_new=(1, args.max_new),
                     vocab=cfg.vocab, seed=args.seed)
    if args.prompt_len is not None:
        overrides["prompt_len"] = (args.prompt_len, args.prompt_len)
    spec = WorkloadSpec.preset(args.workload, **overrides)
    arrivals = generate(spec)
    max_len = 128

    disagg = None
    if args.disagg:
        disagg = {"prefill_slots": args.prefill_slots,
                  "decode_slots": args.decode_slots,
                  "prefill_devices": args.prefill_devices,
                  "decode_devices": args.decode_devices}
    tracer = None
    # trace analysis (--report / --slo) runs over the same tick-clock
    # event stream the --trace export writes, so any of the four flags
    # turns the tracer on; capture stays off for a pure flight-recorder
    # ring (--trace-ring alone), which only needs the bounded buffer
    need_capture = (args.trace is not None or args.report is not None
                    or slo is not None)
    if need_capture or args.trace_ring is not None:
        from repro.obs import FlightRecorder, Tracer
        recorder = None
        if args.trace_ring is not None:
            if args.trace_ring < 1:
                ap.error("--trace-ring must be >= 1")
            # dump destination, most explicit wins: --profile-dir (the
            # run's artifact dir) > the --trace file's dir > cwd
            if args.profile_dir is not None:
                out_dir = args.profile_dir
                os.makedirs(out_dir, exist_ok=True)
            elif args.trace is not None:
                out_dir = os.path.dirname(os.path.abspath(args.trace))
            else:
                out_dir = "."
            recorder = FlightRecorder(capacity=args.trace_ring,
                                      out_dir=out_dir)
        tracer = Tracer(capture=need_capture, recorder=recorder)
    sess = eng.session(batch_slots=args.slots, max_len=max_len,
                       kv_cache=args.kv_cache,
                       kv_pool_pages=args.kv_pool_pages,
                       scheduler=SchedConfig(
                           policy=args.policy, chunk=args.chunk,
                           prefix_cache=args.prefix_cache),
                       mesh=mesh, disagg=disagg, resil=resil,
                       obs=tracer)
    pre = sess.pre if args.disagg else sess
    print(f"[serve] workload={args.workload} seed={args.seed} "
          f"kv={pre.kv_cache} chunk={pre.chunk} policy={args.policy}"
          + (" disagg" if args.disagg else ""))
    if resil is not None:
        print(f"[serve] resil: fault_plan="
              f"{args.fault_plan or 'none'} "
              f"deadline_ticks={args.deadline_ticks} "
              f"max_retries={resil.get('max_retries', 2)}")
    from repro.obs import profile_trace
    t0 = time.perf_counter()
    # injected faults / deadlines make partial completion an expected
    # outcome — report it instead of raising
    with profile_trace(args.profile_dir):
        results = sess.run_workload(
            arrivals, on_incomplete="warn" if resil is not None else "raise")
    dt = time.perf_counter() - t0
    rsumm = sess.resil_summary() if resil is not None else None
    if args.disagg:
        steps = sess.pre.stats["steps"] + sess.dec.stats["steps"]
        m = summarize(sess.records, dt, steps, roles=sess.role_stats(),
                      resil=rsumm)
    else:
        m = summarize(sess.records, dt, sess.stats["steps"], resil=rsumm)
    print(f"[serve] {m['completed']}/{m['requests']} requests, "
          f"{m['tokens']} tokens, {m['tok_per_s']:.1f} tok/s, "
          f"goodput {m['goodput_req_per_s']:.2f} req/s "
          f"({m['steps']} model calls)")
    if rsumm is not None:
        n_failed = len(sess.failed)
        line = (f"[serve] resil: shed {rsumm['shed']}, retries "
                f"{rsumm['retries']}, deadline misses "
                f"{rsumm['deadline_miss']}, failed {n_failed}")
        if rsumm.get("faults"):
            line += ", injected " + ", ".join(
                f"{k}={v}" for k, v in sorted(rsumm["faults"].items()))
        print(line)
        for f in sess.failed:
            print(f"[serve]   {f!r}")
    if m["ttft_s"]:
        print(f"[serve] TTFT p50 {m['ttft_s']['p50']*1e3:.0f} ms / "
              f"p99 {m['ttft_s']['p99']*1e3:.0f} ms; "
              f"preemptions {m['preemptions']}, "
              f"prefix pages reused {m['prefix_pages_reused']}")
    if args.disagg:
        roles, hand = m["roles"], m.get("handoff")
        line = (f"[serve] roles: prefill {roles['prefill']['steps']} "
                f"steps ({roles['prefill']['utilization'] or 0:.0%} busy),"
                f" decode {roles['decode']['steps']} steps "
                f"({roles['decode']['utilization'] or 0:.0%} busy)")
        if hand:
            line += (f"; handoffs {hand['count']}, mean latency "
                     f"{hand['latency_s']['mean']*1e3:.1f} ms, "
                     f"{hand['migrated_bytes']} bytes migrated")
        print(line)
        print(f"[serve] pages: prefill peak {sess.pre.stats['pages_peak']}"
              f" / decode peak {sess.dec.stats['pages_peak']}, "
              f"leaked {sess.pre.alloc.in_use + sess.dec.alloc.in_use}")
    elif sess.kv_cache == "paged":
        print(f"[serve] pages: peak {sess.stats['pages_peak']}, "
              f"allocs {sess.stats['page_allocs']}, "
              f"reclaimed(SWA) {sess.stats['pages_reclaimed_swa']}")
    if args.trace is not None:
        tracer.export(args.trace)
        wall = tracer.wall.summary()
        line = f"[serve] trace: {len(tracer.events)} events -> {args.trace}"
        if wall:
            line += "; wall " + ", ".join(
                f"{k} {v['seconds']:.2f}s/{v['calls']}" for k, v
                in wall.items())
        print(line)
    if args.report is not None or slo is not None:
        from repro.obs import analyze
        rep = analyze(tracer, slo=slo)
        shares = ", ".join(
            f"{ph} {rec['share']:.0%}" for ph, rec
            in rep.critical_path.items() if rec["ticks"])
        print(f"[serve] critical path ({rep.ticks['span']} ticks): "
              + (shares or "idle"))
        if not rep.segments_consistent():
            print("[serve] WARNING: per-request segments do not sum to "
                  "request spans — trace is incomplete or corrupt")
        if rep.slo is not None:
            verdict = "PASS" if rep.slo["pass"] else "FAIL"
            print(f"[serve] slo {verdict}: " + ", ".join(
                f"{name} {rec['value']} vs {rec['bound']} "
                f"({'ok' if rec['pass'] else 'VIOLATED'})"
                for name, rec in sorted(rep.slo["metrics"].items())))
            for name, rec in sorted(rep.slo["metrics"].items()):
                if rec["violators"]:
                    print(f"[serve]   {name} violators: rids "
                          f"{rec['violators']}")
        if args.report is not None:
            rep.write(args.report)
            print(f"[serve] report: trace analysis -> {args.report}")
    if args.profile_dir is not None:
        print(f"[serve] profile: jax trace -> {args.profile_dir}")
    if args.json is not None:
        import json

        from repro.obs import provenance
        if args.disagg:
            pages = {"prefill_peak": sess.pre.stats["pages_peak"],
                     "decode_peak": sess.dec.stats["pages_peak"],
                     "leaked": sess.pre.alloc.in_use
                     + sess.dec.alloc.in_use}
        elif sess.kv_cache == "paged":
            pages = {"peak": sess.stats["pages_peak"],
                     "allocs": sess.stats["page_allocs"],
                     "leaked": sess.alloc.in_use}
        else:
            pages = None
        dump = {
            "provenance": provenance(
                config=cfg.name, mode=args.compress or "dense",
                seed=args.seed, backend=eng.backend.name,
                workload=args.workload, disagg=bool(args.disagg)),
            "metrics": m,
            "failed": [{"rid": f.rid, "reason": f.reason,
                        "retries": f.retries}
                       for f in (sess.failed if resil is not None else [])],
            "pages": pages,
        }
        if tracer is not None:
            dump["wall_phases"] = tracer.wall.summary()
        with open(args.json, "w") as f:
            json.dump(dump, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"[serve] json: metrics -> {args.json}")


if __name__ == "__main__":
    main()
