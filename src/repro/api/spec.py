"""Facade value types: compression specs and FC workload descriptions.

This module is import-light (numpy + stdlib only) so that anything — tests,
`models.layers`, launch scripts — can import it without dragging in jax or
the model zoo.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Optional, Tuple

import numpy as np

#: The five FC operating points (paper §3–§5; ``aida`` = the paper's full
#: configuration).  Mirrors core.sparse_fc.MODES without importing it.
MODES: Tuple[str, ...] = ("dense", "int8", "codebook4", "acsr", "aida")


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """Offline Deep-Compression recipe (prune -> k-means share -> pack).

    ``overrides`` maps projection-name substrings to modes, e.g.
    ``{"wo": "int8", "embed": "skip"}`` — backends advertising the
    ``per_layer_override`` capability honour it; ``"skip"`` leaves the
    projection as a raw dense array.
    """
    mode: str = "aida"
    density: float = 0.10
    k: int = 16
    block_rows: int = 128
    kmeans_iters: int = 25
    #: value storage dtype for acsr-mode nonzeros: "f32" (exact) or
    #: "bf16" (halves value bytes — acsr's honest compression ratio
    #: finally wins vs the bf16-serving baseline; aida/int8/codebook4
    #: already store sub-f32 values, so they ignore this)
    dtype: str = "f32"
    #: shard-aware stacking: pad each packed container's partition axis
    #: (ACSR row blocks / output channels) to a multiple of this count,
    #: so a `shard.ShardingPlan` with tp == shards partitions it with no
    #: session-time re-stacking.  1 = no padding; plans also pad lazily,
    #: so this is an encode-time optimization, not a requirement.
    shards: int = 1
    overrides: Mapping[str, str] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"unknown mode {self.mode!r}; choose from {MODES}")
        if self.dtype not in ("f32", "bf16"):
            raise ValueError(
                f"unknown value dtype {self.dtype!r}; 'f32' or 'bf16'")
        if not isinstance(self.shards, int) or self.shards < 1:
            raise ValueError(
                f"shards must be a positive int, got {self.shards!r}")
        for name, mode in self.overrides.items():
            if mode not in MODES + ("skip",):
                raise ValueError(
                    f"override {name!r}: unknown mode {mode!r}")

    def mode_for(self, projection: str) -> str:
        """Mode for one projection leaf (first matching override wins)."""
        for sub, mode in self.overrides.items():
            if sub in projection:
                return mode
        return self.mode

    @classmethod
    def coerce(cls, spec) -> "CompressionSpec":
        """Accept a CompressionSpec, a bare mode string, or None (default)."""
        if spec is None:
            return cls()
        if isinstance(spec, cls):
            return spec
        if isinstance(spec, str):
            return cls(mode=spec)
        raise TypeError(f"cannot coerce {type(spec).__name__} "
                        "to CompressionSpec")


@dataclasses.dataclass
class FCProblem:
    """One concrete FC-layer instance (the paper's C = f(W x B) primitive).

    ``coded=False``: ``w``/``b`` are signed integers with |w| < 2^m,
    |b| < 2^n (bit-serial Fig. 3 mode).  ``coded=True``: ``w``/``b`` are
    codebook indices (0 = structural zero) and ``cents_w``/``cents_a`` are
    the integer codebooks (bit-parallel perfect-induction mode).
    """
    w: np.ndarray
    b: np.ndarray
    m: int = 4
    n: int = 4
    activation: Optional[str] = "relu"
    coded: bool = False
    cents_w: Optional[np.ndarray] = None
    cents_a: Optional[np.ndarray] = None

    def __post_init__(self):
        self.w = np.asarray(self.w, np.int64)
        self.b = np.asarray(self.b, np.int64)
        if self.coded and (self.cents_w is None or self.cents_a is None):
            raise ValueError("coded FCProblem needs cents_w and cents_a")

    # Derived quantities shared by the emulator and the closed-form model —
    # kept here so both backends agree on them by construction.
    @property
    def nnz_b(self) -> int:
        return int((self.b != 0).sum())

    @property
    def max_row_nnz(self) -> int:
        return max(1, int((self.w != 0).sum(axis=1).max(initial=0)))

    @property
    def prod_bits(self) -> int:
        """Coded-mode product wordlength from the codebook outer product."""
        if not self.coded:
            return self.m + self.n
        pmax = int(np.abs(np.outer(np.asarray(self.cents_w, np.int64),
                                   np.asarray(self.cents_a, np.int64))).max())
        return max(1, math.ceil(math.log2(pmax + 1)))


#: Named cycle-model workloads understood by Engine.estimate.
WORKLOADS: Tuple[str, ...] = ("alexnet-fc", "ctc-lstm", "table1")
