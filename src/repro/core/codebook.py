"""Codebook quantization — the TPU realization of *perfect induction* (§2.1).

The paper: when operands take at most 2^m values, any function of them can be
evaluated by matching every input combination and writing the precomputed
output — O(2^m) cycles *independent of the dataset size*. Compressed DNNs
(EIE / Deep Compression) cluster weights to 16 shared values, so AIDA applies
perfect induction *bit-parallel*: traverse the 16×16 multiplier×multiplicand
combinations and substitute products.

On TPU the same idea becomes: weights live in HBM as packed 4-bit codebook
indices; the kernel expands them against a 16-entry centroid table held in
VMEM (weights-only mode), or looks products up in a 16×16 *product LUT*
(weights+activations mode — literally the paper's induction table).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Codebook:
    centroids: jnp.ndarray  # [k] float32, sorted ascending
    codes: jnp.ndarray      # packed uint8 (two 4-bit codes per byte) or raw uint8
    shape: Tuple[int, ...]  # original tensor shape
    packed: bool

    @property
    def k(self) -> int:
        return int(self.centroids.shape[0])


def kmeans_1d(x: jnp.ndarray, k: int = 16, iters: int = 25,
              seed: int = 0) -> jnp.ndarray:
    """Lloyd's k-means on a flat array; returns sorted centroids [k].

    Initialization is linear between min/max (standard for weight sharing —
    Deep Compression found linear init best for this use).
    """
    x = x.reshape(-1).astype(jnp.float32)
    lo, hi = jnp.min(x), jnp.max(x)
    cents = lo + (hi - lo) * (jnp.arange(k, dtype=jnp.float32) + 0.5) / k

    def step(cents, _):
        d = jnp.abs(x[:, None] - cents[None, :])        # [n, k]
        assign = jnp.argmin(d, axis=1)
        sums = jax.ops.segment_sum(x, assign, num_segments=k)
        cnts = jax.ops.segment_sum(jnp.ones_like(x), assign, num_segments=k)
        new = jnp.where(cnts > 0, sums / jnp.maximum(cnts, 1.0), cents)
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=iters)
    return jnp.sort(cents)


def assign(x: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    """Nearest-centroid code for every element; uint8 [x.shape]."""
    d = jnp.abs(x[..., None] - centroids)
    return jnp.argmin(d, axis=-1).astype(jnp.uint8)


def pack4(codes: jnp.ndarray) -> jnp.ndarray:
    """Pack 4-bit codes two-per-byte along the last axis (even length)."""
    assert codes.shape[-1] % 2 == 0, "last axis must be even to pack"
    lo = codes[..., 0::2].astype(jnp.uint8)
    hi = codes[..., 1::2].astype(jnp.uint8)
    return lo | (hi << 4)


def unpack4(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack4`; doubles the last axis."""
    lo = packed & 0xF
    hi = packed >> 4
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


def quantize(w: jnp.ndarray, k: int = 16, iters: int = 25,
             pack: bool = True) -> Codebook:
    """Cluster a weight tensor to a k-entry codebook; optionally pack 4-bit."""
    cents = kmeans_1d(w, k=k, iters=iters)
    codes = assign(w, cents)
    if pack:
        assert k <= 16, "packing assumes 4-bit codes"
        flat = codes.reshape(w.shape[0], -1) if w.ndim > 1 else codes[None, :]
        codes = pack4(flat.reshape(codes.shape))
    return Codebook(centroids=cents, codes=codes, shape=tuple(w.shape),
                    packed=pack)


def dequantize(cb: Codebook) -> jnp.ndarray:
    codes = unpack4(cb.codes) if cb.packed else cb.codes
    codes = codes.reshape(cb.shape)
    return jnp.take(cb.centroids, codes.astype(jnp.int32), axis=0)


def product_lut(w_centroids: jnp.ndarray,
                a_centroids: jnp.ndarray) -> jnp.ndarray:
    """The perfect-induction table: LUT[i, j] = w_centroids[i]*a_centroids[j].

    16×16 f32 = 1 KiB — it lives in VMEM (in AIDA it lives in the microcode).
    """
    return jnp.outer(w_centroids, a_centroids)


def lut_matvec_ref(w_codes: jnp.ndarray, lut: jnp.ndarray,
                   a_codes: jnp.ndarray) -> jnp.ndarray:
    """Matvec where *every* multiply is a table lookup (both operands coded).

    w_codes: [n, k_in] uint8, a_codes: [k_in] uint8, lut: [kw, ka] f32.
    This is AIDA's bit-parallel perfect-induction multiply, array form.
    """
    prods = lut[w_codes.astype(jnp.int32), a_codes.astype(jnp.int32)[None, :]]
    return prods.sum(axis=1)
