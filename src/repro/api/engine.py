"""`Engine` — THE entry point to compress, load, run and benchmark a model.

One object, four backends::

    from repro.api import Engine, Request, CompressionSpec

    eng = Engine("llama3-8b-smoke-cfg-or-ArchConfig")      # random init
    eng.compress(CompressionSpec(mode="aida", density=0.25))
    results = eng.serve([Request(prompt=[1, 2, 3], max_new=8)])
    est = eng.estimate(backend="cycle-sim", workload="alexnet-fc")

`compress()` returns the engine for chaining; serving goes through a
continuous-batching `Session` compiled by the active backend; `estimate()`
routes to any cycle-accounting backend (`ap-emulator`, `cycle-sim`).
"""
from __future__ import annotations

import time
from typing import List, Optional, Sequence, Union

import numpy as np

from repro.api import compress as compress_mod
from repro.api.registry import CapabilityError, Executor, get_backend
from repro.api.session import Request, Result, Session
from repro.api.spec import CompressionSpec, FCProblem
from repro.configs.base import ArchConfig

#: the declared SLO the `capacity` BENCH section gates against —
#: scheduler-tick units (deterministic), calibrated so the burst preset
#: separates under-provisioned from adequate configs: 2 slots queues to
#: ttft_p99≈36 ticks, 4 slots reaches ≈3
CAPACITY_SLO = "ttft_p99=20,tpot_p99=4,goodput=1.0"

#: the 2-point smoke sweep (capacity.py --smoke and the BENCH section):
#: an under-provisioned config the SLO rejects and an adequate one
CAPACITY_SMOKE_SWEEP = (
    {"slots": 2, "kv_pool_pages": 16, "chunk": 4, "policy": "fifo"},
    {"slots": 4, "kv_pool_pages": 24, "chunk": 4, "policy": "fifo"},
)


def _spec_modes(spec: CompressionSpec) -> set:
    """Modes a spec actually executes ('skip' leaves leaves dense/raw)."""
    return {spec.mode} | {m for m in spec.overrides.values() if m != "skip"}


class Engine:
    def __init__(self, cfg: Union[ArchConfig, str, None] = None,
                 params=None, *, backend: Optional[str] = None,
                 seed: int = 0):
        if isinstance(cfg, str):
            from repro.configs import get
            cfg = get(cfg)
        self.cfg = cfg
        self._params = params
        self._backend_name = backend
        self._seed = seed
        self.compression: Optional[CompressionSpec] = None
        self.stats: Optional[dict] = None

    # -------------------------------------------------------------- state
    @property
    def params(self):
        """Model params (random-initialized on first access if not given)."""
        if self._params is None:
            if self.cfg is None:
                raise ValueError("Engine has no cfg; pass params explicitly "
                                 "or construct with an ArchConfig")
            import jax
            from repro.models import model as M
            self._params = M.init_params(self.cfg,
                                         jax.random.PRNGKey(self._seed))
        return self._params

    @property
    def backend(self) -> Executor:
        """Active decode backend: explicit choice, else 'pallas' once
        compressed to a non-dense mode, else 'jax-dense'."""
        if self._backend_name:
            return get_backend(self._backend_name)
        if self.compression is not None \
                and _spec_modes(self.compression) - {"dense"}:
            return get_backend("pallas")
        return get_backend("jax-dense")

    # ---------------------------------------------------------- compress
    def compress(self, spec: Union[CompressionSpec, str, None] = None,
                 *, verbose=None, **kw) -> "Engine":
        """Deep-Compression of every eligible projection (prune -> share ->
        pack) per `spec`; keyword shortcuts (mode=, density=, k=) also work.
        Returns self for chaining; stats land in `self.stats`."""
        spec = CompressionSpec.coerce(spec)
        if kw:
            import dataclasses
            spec = dataclasses.replace(spec, **kw)
        if self._backend_name:  # explicit pin: the backend must run the modes
            caps = self.backend.caps
            wanted = _spec_modes(spec)
            if len(wanted) > 1 and not caps.per_layer_override:
                raise CapabilityError(
                    f"backend {self._backend_name!r} does not support "
                    "per-layer mode overrides")
            missing = wanted - set(caps.modes)
            if missing:
                raise CapabilityError(
                    f"backend {self._backend_name!r} cannot execute modes "
                    f"{sorted(missing)}; its modes are {caps.modes} "
                    "(drop the explicit backend= pin to auto-route)")
        self._params, self.stats = compress_mod.compress_params(
            self.params, spec, verbose=verbose)
        self.compression = spec
        return self

    # ------------------------------------------------------------- serve
    def _pretune(self, batch_slots: int, max_len: int, page_size: int,
                 kv_dtype: Optional[str], kv_cache: Optional[str],
                 plan, scheduler=None) -> None:
        """Autotune the kernels a session at this batch width will trace:
        compressed-FC geometries (shard-local under a plan) and the
        paged-attention decode + chunked-prefill impl/tile choices."""
        from repro.kernels import ops, tune
        tp = plan.tp if plan is not None else 1
        if self.backend.name == "pallas" and self.compression is not None:
            if tune.enabled():
                if tp > 1:
                    # the sharded step only looks up shard-LOCAL
                    # geometries; tuning the global ones would be
                    # wasted session-startup wall-clock
                    from repro import shard as shardmod
                    shardmod.tune_local_views(self.params, plan,
                                              batch_slots,
                                              ops.pallas_interpret())
                else:
                    tune.tune_params(self.params, batch_slots,
                                     ops.pallas_interpret())
        import repro.api.session as sess_mod
        resolved_kv = sess_mod.resolve_kv_cache(kv_cache, self.cfg)
        # mesh sessions resolve the paged kernels with the GLOBAL
        # geometry (shard.paged_attention_*_sharded pins the choice
        # before entering shard_map), so the same global tune applies
        # whether heads are sharded or whole
        if resolved_kv == "paged" and self.cfg.family != "rwkv6" \
                and tune.enabled():
            kvd = kv_dtype or sess_mod.KV_DTYPE_DEFAULT
            interp = ops.pallas_interpret()
            tune.tune_paged(self.cfg, batch_slots, max_len, page_size,
                            kvd, interp)
            from repro import sched as schd
            chunk = schd.SchedConfig.coerce(scheduler).chunk
            if chunk > 1 and schd.supports_chunked_prefill(self.cfg):
                tune.tune_paged_chunk(self.cfg, batch_slots, max_len,
                                      page_size, chunk, kvd, interp)

    def session(self, batch_slots: int = 4, max_len: int = 256,
                seed: int = 0, kv_cache: Optional[str] = None,
                page_size: int = 16,
                kv_pool_pages: Optional[int] = None,
                kv_dtype: Optional[str] = None,
                scheduler=None, mesh=None, disagg=None, resil=None,
                obs=None):
        """A continuous-batching serving session on the active backend.

        ``scheduler``: a sched.SchedConfig (or dict / policy name) —
        admission policy, prefill chunk width, prefix caching.

        ``mesh``: a jax Mesh with a ``model`` axis — serving goes
        tensor-parallel on an explicit `repro.shard.ShardingPlan`:
        compressed FC runs shard-local (each device owns a band of row
        blocks / output channels), KV pools shard their head axis, and
        the decode / chunked-prefill steps compile with input/output
        shardings.  ``mesh=None`` (default) is the unchanged
        single-device path.

        ``disagg``: True / dict / `repro.disagg.DisaggConfig` — build a
        disaggregated prefill/decode session pair instead (returns a
        `repro.disagg.DisaggSession` with the same submit/run surface):
        two roles sharing this engine's params, each with its own slots
        and page pool, connected by the page-migration channel.  With
        ``prefill_devices``/``decode_devices`` set, the roles run
        tensor-parallel on disjoint device meshes
        (launch.mesh.make_role_meshes); ``batch_slots`` and
        ``kv_pool_pages`` are ignored in favor of the per-role knobs.
        Mutually exclusive with ``mesh``.

        On the Pallas backend, every unique compressed-FC geometry is
        autotuned for this batch width *before* the decode step compiles,
        so the jitted step traces against the winning tiles
        (kernels.tune; disable with REPRO_AUTOTUNE=0).  A paged-KV
        session additionally pre-tunes the paged-attention decode and
        chunked-prefill impl/tile choices for this (geometry, batch,
        chunk, backend) — mesh sessions included, since the shard_map
        wrappers pin the globally-resolved choice — and a mesh session
        tunes the *shard-local* FC geometries its shard_map kernels run.

        ``resil``: a `repro.resil.ResilConfig` (or dict / ``"preset:seed"``
        fault-plan string) — deterministic fault injection, request
        deadlines, bounded retry, load shedding, and graceful
        degradation.  Passing a live `ResilState` carries the degradation
        ladder across session generations: when sustained page pressure
        has pushed it to L2, this session's KV pool is demoted to int8.
        ``resil=None`` (default) is the exact pre-resil serving path.

        ``obs``: a `repro.obs.Tracer` — structured event tracing across
        every serving seam (admission, preemption, prefill/decode steps,
        handoffs, allocator, prefix cache, fault injections), exportable
        as a Chrome/Perfetto timeline.  ``obs=None`` (default) traces
        nothing at zero cost.
        """
        if self.cfg is None:
            raise ValueError("serving needs an ArchConfig")
        if resil is not None:
            from repro import resil as rsl
            if isinstance(resil, rsl.ResilState):
                # next-session degradation boundary: pool dtype is fixed
                # for a live session, so L2 demotion lands here
                kv_dtype = resil.next_kv_dtype(kv_dtype)
        backend = self.backend
        if not backend.caps.batched_decode:
            raise CapabilityError(
                f"backend {backend.name!r} cannot serve (no batched decode)")
        if disagg is not None and disagg is not False:
            if mesh is not None:
                raise ValueError(
                    "mesh= and disagg= are mutually exclusive — give the "
                    "roles their own devices via DisaggConfig."
                    "prefill_devices/decode_devices")
            if kv_cache not in (None, "auto", "paged"):
                raise ValueError(
                    "disaggregated serving migrates KV pages; it cannot "
                    f"run on kv_cache={kv_cache!r}")
            from repro.disagg import DisaggConfig, DisaggSession
            d = DisaggConfig.coerce(disagg)
            pre_plan = dec_plan = None
            if d.prefill_devices is not None:
                from repro import shard as shardmod
                from repro.launch.mesh import make_role_meshes
                pre_mesh, dec_mesh = make_role_meshes(
                    d.prefill_devices, d.decode_devices)
                pre_plan = shardmod.make_plan(pre_mesh, self.cfg)
                dec_plan = shardmod.make_plan(dec_mesh, self.cfg)
            self._pretune(d.prefill_slots, max_len, page_size, kv_dtype,
                          "paged", pre_plan, scheduler=scheduler)
            if d.decode_slots != d.prefill_slots or \
                    dec_plan is not pre_plan:
                self._pretune(d.decode_slots, max_len, page_size,
                              kv_dtype, "paged", dec_plan,
                              scheduler=scheduler)
            return DisaggSession(
                self.cfg, self.params, disagg=d, max_len=max_len,
                seed=seed, backend=backend, page_size=page_size,
                kv_dtype=kv_dtype, scheduler=scheduler,
                prefill_plan=pre_plan, decode_plan=dec_plan, resil=resil,
                obs=obs)
        plan = None
        if mesh is not None:
            from repro import shard as shardmod
            plan = shardmod.make_plan(mesh, self.cfg)
        self._pretune(batch_slots, max_len, page_size, kv_dtype,
                      kv_cache, plan, scheduler=scheduler)
        return Session(self.cfg, self.params, batch_slots=batch_slots,
                       max_len=max_len, seed=seed, backend=backend,
                       kv_cache=kv_cache, page_size=page_size,
                       kv_pool_pages=kv_pool_pages, kv_dtype=kv_dtype,
                       scheduler=scheduler, plan=plan, resil=resil,
                       obs=obs)

    def serve(self, requests: Sequence[Union[Request, List[int]]],
              *, batch_slots: int = 4, max_len: int = 256,
              max_steps: int = 10_000, seed: int = 0,
              kv_cache: Optional[str] = None,
              scheduler=None, disagg=None, resil=None,
              obs=None) -> List[Result]:
        """Serve a batch of requests to completion (continuous batching).
        Results come back in deterministic rid order.  ``disagg`` routes
        through a disaggregated prefill/decode session pair — greedy
        results are token-identical either way.  ``resil`` activates the
        resilience layer (deadlines/retry/fault injection)."""
        sess = self.session(batch_slots=batch_slots, max_len=max_len,
                            seed=seed, kv_cache=kv_cache,
                            scheduler=scheduler, disagg=disagg,
                            resil=resil, obs=obs)
        for rid, req in enumerate(requests):
            if not isinstance(req, Request):
                req = Request(prompt=list(req), rid=rid)
            sess.submit(req)
        return sess.run(max_steps=max_steps)

    # ---------------------------------------------------------- estimate
    def estimate(self, backend: str = "cycle-sim",
                 workload: Union[FCProblem, str, Sequence, None] = None,
                 **kw) -> dict:
        """Cycle/perf accounting through a cost-model backend.

        `workload`: an FCProblem (concrete FC instance; 'ap-emulator'
        measures it bit-level, 'cycle-sim' prices it closed-form — the two
        agree exactly under the EMULATOR microcode), or a named network
        ('alexnet-fc', 'ctc-lstm', 'table1') for 'cycle-sim'.
        """
        ex = get_backend(backend)
        if not ex.caps.cycle_accounting:
            raise CapabilityError(
                f"backend {backend!r} has no cycle accounting")
        if workload is None:
            workload = "alexnet-fc"
        return ex.estimate(workload, **kw)

    # --------------------------------------------------------- benchmark
    def kv_benchmark(self, mode: str = "aida", requests: int = 8,
                     max_new: int = 24, batch_slots: int = 2,
                     max_len: int = 64, page_size: int = 16,
                     density: float = 0.25) -> dict:
        """Paged-vs-dense KV cache comparison on one compressed mode:
        serve the same request mix through both cache kinds (step-time
        parity check), record KV bytes/token, and micro-time the
        attention-vs-FC split of a decode step (the share the paged
        subsystem exists to attack)."""
        from repro import kvstore as kvs
        from repro.kernels import tune
        cfg = self.cfg
        if cfg is None or cfg.family == "rwkv6":
            raise CapabilityError(
                "kv_benchmark needs an attention arch (rwkv6 has no KV "
                "cache to page)")
        eng = Engine(cfg, params=self.params)
        if mode != "dense":
            eng.compress(CompressionSpec(mode=mode, density=density),
                         verbose=None)
        reqs = [Request(prompt=[1, 2 + i % 7, 3], max_new=max_new, rid=i)
                for i in range(requests)]
        out = {"mode": mode, "page_size": page_size, "max_len": max_len,
               "batch_slots": batch_slots}
        seen_tiles = set(tune.snapshot())
        # interleaved best-of rounds: the paged/full ratio is only
        # host-speed-invariant if both sides see the same load, so
        # alternate them and keep each side's best pass
        for rnd in range(3):
            for kind in ("full", "paged"):
                # int8 pages explicitly: this section reports
                # "paged_int8" bytes/token, so the measured pool must be
                # int8 regardless of the (bf16) serving default
                sess = eng.session(batch_slots=batch_slots,
                                   max_len=max_len, kv_cache=kind,
                                   page_size=page_size, kv_dtype="int8")
                sess.submit(Request(prompt=[1], max_new=1, rid=-1))
                sess.run()  # warm the compiled step
                sess.results.clear()
                for r in reqs:
                    sess.submit(r)
                t0 = time.perf_counter()
                res = sess.run()
                dt = time.perf_counter() - t0
                n_tok = sum(len(r.tokens) for r in res)
                if kind in out and out[kind]["tok_per_s"] >= n_tok / dt:
                    continue
                rec = {"tokens": n_tok, "seconds": round(dt, 4),
                       "tok_per_s": round(n_tok / dt, 2)}
                if kind == "paged":
                    rec["pages_peak"] = sess.stats["pages_peak"]
                    rec["page_allocs"] = sess.stats["page_allocs"]
                    snap = tune.snapshot()
                    rec["tiles"] = {k: v for k, v in snap.items()
                                    if k not in seen_tiles}
                out[kind] = rec
        out["paged_over_full"] = round(
            out["paged"]["tok_per_s"] / out["full"]["tok_per_s"], 3)
        pbt = kvs.kv_bytes_per_token(cfg.n_kv, cfg.head_dim,
                                     page_size) * cfg.n_layers
        dbt = kvs.dense_kv_bytes_per_token(cfg.n_kv,
                                           cfg.head_dim) * cfg.n_layers
        out["kv_bytes_per_token"] = {
            "paged_int8": round(pbt, 1), "dense_bf16": round(dbt, 1),
            "ratio": round(pbt / dbt, 4)}
        out["attn_time_share"] = self._attn_fc_share(
            eng, batch_slots, max_len, page_size)
        return out

    def _attn_fc_share(self, eng: "Engine", batch: int, max_len: int,
                       page_size: int) -> dict:
        """Micro-decomposition of a decode step at full cache occupancy:
        attention term (cache update + attend, per layer x L) vs the FC
        term (every compressed projection at this batch width).  Shares
        are from best-of timings of the jitted pieces — the honest signal
        behind 'attention is now the dominant share' (ROADMAP)."""
        import functools

        import jax
        from repro import kvstore as kvs
        from repro.core import sparse_fc as sfc
        from repro.kernels import tune
        from repro.models import attention as attn
        from repro.models import kvcache as kvc
        from repro.obs import timeit as _timeit
        import jax.numpy as jnp
        cfg = self.cfg
        rng = np.random.default_rng(0)
        timeit = functools.partial(_timeit, reps=5, inner=3)

        hkv, h, dh = cfg.n_kv, cfg.n_heads, cfg.head_dim
        scale = dh ** -0.5
        q = jnp.asarray(rng.normal(size=(batch, h, 1, dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(batch, hkv, 1, dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(batch, hkv, 1, dh)), jnp.float32)
        cur = jnp.full((batch,), max_len - 1, jnp.int32)
        cache = kvc.init_cache(batch, hkv, max_len, dh)
        cache = cache._replace(pos=jnp.broadcast_to(
            jnp.arange(max_len, dtype=jnp.int32)[None], (batch, max_len)))
        t_full = timeit(jax.jit(
            lambda c, qq, kk, vv, p: attn.decode_attend(
                c, qq, kk, vv, p, window=jnp.int32(-1), scale=scale)[1]),
            cache, q, k, v, cur)
        npp = -(-max_len // page_size)
        pool = kvs.init_pool(1 + batch * npp, hkv, page_size, dh)
        pool = pool._replace(
            k_scale=jnp.ones_like(pool.k_scale),
            v_scale=jnp.ones_like(pool.v_scale),
            k_pages=jnp.asarray(rng.integers(
                -127, 128, pool.k_pages.shape), jnp.int8),
            v_pages=jnp.asarray(rng.integers(
                -127, 128, pool.v_pages.shape), jnp.int8))
        table = jnp.asarray(
            1 + np.arange(batch * npp).reshape(batch, npp), jnp.int32)
        t_paged = timeit(jax.jit(
            lambda pl, qq, kk, vv, p: attn.decode_attend_paged(
                pl, table, qq, kk, vv, p, window=jnp.int32(-1),
                scale=scale)[1]),
            pool, q, k, v, cur)
        # FC term: every compressed projection leaf, layer-0 view x L
        t_fc = 0.0

        def visit(leaf):
            nonlocal t_fc
            if isinstance(leaf, sfc.CompressedFC):
                lay = tune._layer0_view(leaf)
                x = jnp.asarray(rng.normal(size=(batch, lay.shape[1])),
                                jnp.float32)
                t_fc += timeit(jax.jit(
                    lambda xx: sfc.apply_fc(lay, xx)), x) * cfg.n_layers
            elif getattr(leaf, "ndim", 0) == 3:   # raw [L, d_in, d_out]
                w = leaf[0]
                x = jnp.asarray(rng.normal(size=(batch, w.shape[0])),
                                jnp.float32)
                t_fc += timeit(jax.jit(
                    lambda xx, ww: jnp.matmul(xx, ww)), x, w) \
                    * cfg.n_layers
            return leaf

        jax.tree_util.tree_map(
            visit, eng.params["layers"],
            is_leaf=lambda x: isinstance(x, sfc.CompressedFC))
        a_full, a_paged = t_full * cfg.n_layers, t_paged * cfg.n_layers
        return {"attn_us_full": round(a_full * 1e6, 1),
                "attn_us_paged": round(a_paged * 1e6, 1),
                "fc_us": round(t_fc * 1e6, 1),
                "full": round(a_full / max(a_full + t_fc, 1e-12), 4),
                "paged": round(a_paged / max(a_paged + t_fc, 1e-12), 4)}

    def serving_benchmark(self, mode: str = "aida", density: float = 0.25,
                          chunk: int = 8, page_size: int = 8,
                          max_len: int = 64) -> dict:
        """The `"serving"` section of BENCH_api.json: what the sched
        subsystem buys, measured on one compressed mode.

        Four sub-benches (deterministic step-count facts carry the CI
        assertions; wall-clock numbers are the host-noisy trajectory):

        * ``prefill`` — model calls to first token for one long prompt,
          chunked vs token-by-token (the ceil(P/C)+1 acceptance bound);
        * ``throughput`` — heterogeneous continuous batching (poisson
          arrivals, mixed lengths): tok/s, goodput, TTFT/TPOT p50-p99;
        * ``prefix`` — shared-prefix workload through the prefix cache:
          page hits and zero-leak drain;
        * ``preemption`` — a pool sized below the workload's worst case:
          completes via youngest-first preemption instead of OutOfPages.
        """
        import math

        from repro import sched as schd
        from repro.kernels import tune
        cfg = self.cfg
        if cfg is None or cfg.family == "rwkv6":
            raise CapabilityError(
                "serving_benchmark needs a paged-KV arch (rwkv6 is "
                "attention-free)")
        seen_tiles = set(tune.snapshot())
        eng = Engine(cfg, params=self.params)
        if mode != "dense":
            eng.compress(CompressionSpec(mode=mode, density=density),
                         verbose=None)
        out = {"mode": mode, "chunk": chunk, "page_size": page_size,
               "policy": "fifo"}

        def run_session(arrivals, *, slots=4, pool=None, sched_cfg=None):
            sess = eng.session(batch_slots=slots, max_len=max_len,
                               kv_cache="paged", page_size=page_size,
                               kv_pool_pages=pool, scheduler=sched_cfg)
            t0 = time.perf_counter()
            res = sess.run_workload(arrivals)
            dt = time.perf_counter() - t0
            return sess, res, dt

        # warm the compiled steps at the prefill section's batch shape so
        # recorded TTFT measures scheduling, not XLA compilation
        run_session([(0, Request(prompt=[1] * (chunk + 1), max_new=1,
                                 rid=-1))],
                    slots=2, sched_cfg={"chunk": chunk})

        # --- chunked prefill: calls to first token, long prompt --------
        plen = 3 * chunk
        prompt = [1 + (i % (self.cfg.vocab - 1)) for i in range(plen)]
        pf = {"prompt_len": plen,
              "bound_calls": math.ceil(plen / chunk) + 1}
        for label, c in (("chunked", chunk), ("one_token", 1)):
            sess, _, dt = run_session(
                [(0, Request(prompt=list(prompt), max_new=4, rid=0))],
                slots=2, sched_cfg={"chunk": c})
            rec = sess.records[0]
            pf[label] = {
                "first_token_calls":
                    rec["first_token_step"] - rec["admit_step"],
                "ttft_s": round(rec["first_token_time"]
                                - rec["submit_time"], 4)}
        out["prefill"] = pf
        # paged decode + chunked-prefill winners tuned by these sessions
        # (Engine.session pre-tunes both) — recorded like the FC tiles so
        # the serving perf trajectory names the kernels behind it
        snap = tune.snapshot()
        out["tiles"] = {k: v for k, v in snap.items()
                        if k not in seen_tiles}

        # --- heterogeneous continuous batching (best-of-3) -------------
        wl = schd.WorkloadSpec.preset(
            "heterogeneous", n_requests=10, vocab=cfg.vocab, seed=0)
        best = None
        for _ in range(3):
            sess, _, dt = run_session(schd.generate(wl),
                                      sched_cfg={"chunk": chunk})
            summ = schd.summarize(sess.records, dt, sess.stats["steps"])
            if best is None or summ["tok_per_s"] > best["tok_per_s"]:
                best = summ
        out["throughput"] = best

        # --- shared-prefix page reuse ----------------------------------
        wl = schd.WorkloadSpec.preset(
            "shared-prefix", n_requests=6, vocab=cfg.vocab, seed=1)
        sess, res, dt = run_session(
            schd.generate(wl),
            sched_cfg={"chunk": chunk, "prefix_cache": True})
        cache = sess.prefix
        out["prefix"] = {
            "requests": len(res),
            "page_hits": sess.stats["prefix_pages_reused"],
            "cache": cache.stats(),
            "pages_leaked": sess.alloc.in_use - cache.pages,
        }
        cache.clear(sess.alloc)
        out["prefix"]["pages_leaked_after_clear"] = sess.alloc.in_use

        # --- preemption under page pressure ----------------------------
        reqs = [(0, Request(prompt=[2 + i] * page_size, max_new=2 *
                            page_size, rid=i)) for i in range(6)]
        need = schd.scheduler.page_need(page_size, 2 * page_size,
                                        max_len, page_size)
        sess, res, dt = run_session(reqs, slots=3,
                                    pool=1 + 3 * need - 2,
                                    sched_cfg={"chunk": chunk})
        out["preemption"] = {
            "requests": len(reqs), "completed": len(res),
            "preemptions": sess.stats["preemptions"],
            "pages_leaked": sess.alloc.in_use,
        }
        return out

    def disagg_benchmark(self, mode: str = "aida", density: float = 0.25,
                         chunk: int = 8, page_size: int = 8,
                         max_len: int = 64, n_requests: int = 12) -> dict:
        """The `"disagg"` section of BENCH_api.json: disaggregated
        prefill/decode vs the co-located engine on the same ``burst``
        workload (the arrival pattern disaggregation exists for — a
        burst of prompts stalls a co-located batch's decoders).

        Deterministic facts carry the CI gate: token parity between the
        two engine shapes, handoff count == decode-bound requests, zero
        pages leaked on any allocator.  Wall-clock TTFT-p99 and tok/s
        are the dual-unit trajectory signal."""
        from repro import sched as schd
        cfg = self.cfg
        if cfg is None or not schd.supports_chunked_prefill(cfg):
            raise CapabilityError(
                "disagg_benchmark needs an arch whose per-request state "
                "is entirely KV pages (sched.supports_chunked_prefill)")
        eng = Engine(cfg, params=self.params)
        if mode != "dense":
            eng.compress(CompressionSpec(mode=mode, density=density),
                         verbose=None)
        wl = schd.WorkloadSpec.preset("burst", n_requests=n_requests,
                                      vocab=cfg.vocab, seed=0)
        arrivals = schd.generate(wl)

        def replay():
            return [(t, Request(prompt=list(r.prompt), max_new=r.max_new,
                                rid=r.rid)) for t, r in arrivals]

        # matched slot widths: the comparison isolates role separation
        # itself (decoders never occupying prompt-admission slots), not a
        # capacity difference
        sched_cfg = {"chunk": chunk}
        dcfg = {"prefill_slots": 4, "decode_slots": 4}
        out = {"mode": mode, "chunk": chunk, "workload": "burst",
               "requests": n_requests}
        # warm both engine shapes so TTFT measures scheduling, not XLA
        for dis in (None, dict(dcfg)):
            s = eng.session(max_len=max_len, kv_cache="paged",
                            page_size=page_size, scheduler=sched_cfg,
                            disagg=dis)
            s.submit(Request(prompt=[1] * (chunk + 1), max_new=2, rid=-1))
            s.run()
        for label, dis in (("colocated", None), ("disagg", dict(dcfg))):
            best = None
            for _ in range(3):
                sess = eng.session(batch_slots=4, max_len=max_len,
                                   kv_cache="paged", page_size=page_size,
                                   scheduler=sched_cfg, disagg=dis)
                t0 = time.perf_counter()
                res = sess.run_workload(replay())
                dt = time.perf_counter() - t0
                if dis is None:
                    summ = schd.summarize(sess.records, dt,
                                          sess.stats["steps"])
                    leaked = sess.alloc.in_use
                else:
                    summ = schd.summarize(
                        sess.records, dt,
                        sess.pre.stats["steps"] + sess.dec.stats["steps"],
                        roles=sess.role_stats())
                    leaked = sess.pre.alloc.in_use + sess.dec.alloc.in_use
                summ["pages_leaked"] = leaked
                summ["tokens_by_rid"] = {r.rid: r.tokens for r in res}
                if best is None or (summ["tok_per_s"] or 0) > \
                        (best["tok_per_s"] or 0):
                    best = summ
            out[label] = best
        out["token_parity"] = \
            out["colocated"].pop("tokens_by_rid") == \
            out["disagg"].pop("tokens_by_rid")
        return out

    def resil_benchmark(self, mode: str = "aida", density: float = 0.25,
                        chunk: int = 8, page_size: int = 8,
                        max_len: int = 64, n_requests: int = 8,
                        seed: int = 0) -> dict:
        """The `"resil"` section of BENCH_api.json: the burst workload
        through the disaggregated engine under every built-in FaultPlan
        preset, against a fault-free baseline.

        Deterministic facts carry the CI gate: every request completes,
        completed token streams are identical to the fault-free run,
        zero pages leak on either role's allocator, and the
        shed/retry/deadline-miss/fault counters are identical across two
        replays of the same ``(seed, preset)``.  The goodput ratio vs
        clean is the wall-clock trajectory signal."""
        from repro import sched as schd
        cfg = self.cfg
        if cfg is None or not schd.supports_chunked_prefill(cfg):
            raise CapabilityError(
                "resil_benchmark drives the disaggregated engine; it "
                "needs an arch whose per-request state is entirely KV "
                "pages (sched.supports_chunked_prefill)")
        eng = Engine(cfg, params=self.params)
        if mode != "dense":
            eng.compress(CompressionSpec(mode=mode, density=density),
                         verbose=None)
        wl = schd.WorkloadSpec.preset("burst", n_requests=n_requests,
                                      vocab=cfg.vocab, seed=0)
        arrivals = schd.generate(wl)

        def replay():
            return [(t, Request(prompt=list(r.prompt), max_new=r.max_new,
                                rid=r.rid)) for t, r in arrivals]

        sched_cfg = {"chunk": chunk}
        dcfg = {"prefill_slots": 2, "decode_slots": 4}

        def run(resil):
            sess = eng.session(max_len=max_len, kv_cache="paged",
                               page_size=page_size, scheduler=sched_cfg,
                               disagg=dict(dcfg), resil=resil)
            t0 = time.perf_counter()
            res = sess.run_workload(replay(), on_incomplete="warn")
            dt = time.perf_counter() - t0
            n_tok = sum(len(r.tokens) for r in res)
            counters = None
            if resil is not None:
                s = sess.resil_summary()
                counters = {k: s.get(k, 0) for k in
                            ("deadline_miss", "shed", "retries", "failed",
                             "fault_steps", "handoff_fallbacks")}
                counters["faults"] = s.get("faults", {})
            return {"tokens_by_rid": {r.rid: r.tokens for r in res},
                    "completed": len(res),
                    "failed": sorted(f.rid for f in sess.failed),
                    "tok_per_s": round(n_tok / dt, 2) if dt > 0 else None,
                    "pages_leaked": sess.pre.alloc.in_use
                    + sess.dec.alloc.in_use,
                    "counters": counters}

        # warm the compiled steps once so wall-clock ratios measure
        # scheduling under faults, not XLA compilation
        warm = eng.session(max_len=max_len, kv_cache="paged",
                           page_size=page_size, scheduler=sched_cfg,
                           disagg=dict(dcfg))
        warm.submit(Request(prompt=[1] * (chunk + 1), max_new=2, rid=-1))
        warm.run()
        clean = run(None)
        out = {"mode": mode, "workload": "burst", "requests": n_requests,
               "seed": seed,
               "clean": {"completed": clean["completed"],
                         "tok_per_s": clean["tok_per_s"],
                         "pages_leaked": clean["pages_leaked"]},
               "presets": {}}
        for preset in ("drop-handoff", "role-stall", "page-spike",
                       "straggler"):
            rcfg = {"fault_plan": f"{preset}:{seed}", "max_retries": 2,
                    "watchdog_every": 4}
            a = run(dict(rcfg))
            b = run(dict(rcfg))   # replay: counters must be identical
            parity = all(clean["tokens_by_rid"].get(rid) == toks
                         for rid, toks in a["tokens_by_rid"].items())
            out["presets"][preset] = {
                "completed": a["completed"],
                "failed": a["failed"],
                "token_parity": parity,
                "pages_leaked": a["pages_leaked"],
                "deterministic": (a["counters"] == b["counters"]
                                  and a["tokens_by_rid"]
                                  == b["tokens_by_rid"]),
                "counters": a["counters"],
                "goodput_vs_clean": (
                    round(a["tok_per_s"] / clean["tok_per_s"], 3)
                    if a["tok_per_s"] and clean["tok_per_s"] else None),
            }
        return out

    def capacity_benchmark(self, workload="burst", n_requests: int = 8,
                           sweep: Optional[Sequence[dict]] = None,
                           slo=None, page_size: int = 8,
                           max_len: int = 64, max_steps: int = 4000,
                           seed: int = 0) -> dict:
        """The `"capacity"` section of BENCH_api.json: trace-driven
        capacity planning (ROADMAP item 4's "how many AIDA-class devices
        serve N users at p99 < X?" in single-engine form).

        Replays one workload — a preset name or a ``WorkloadSpec``
        (e.g. ``WorkloadSpec.from_trace`` of a recorded serve) — across
        a sweep of ``(slots, kv_pool_pages, chunk, policy)`` configs,
        feeds each run's live trace through ``repro.obs.analyze``, and
        names the smallest config meeting the declared ``slo``
        (smallest = first in ascending (slots, kv_pool_pages, chunk,
        policy) order).

        Everything in the section is tick-denominated and therefore
        deterministic: no wall-clock numbers, and the chosen config is
        re-run once to assert its ``TraceReport`` replays
        byte-identically — both facts gate in CI
        (benchmarks/check_regression.py)."""
        import warnings

        from repro import sched as schd
        from repro.obs import Tracer
        from repro.obs.analyze import PHASES, SLOSpec, analyze
        if slo is None:
            slo = CAPACITY_SLO
        if isinstance(slo, str):
            slo = SLOSpec.parse(slo)
        if isinstance(workload, schd.WorkloadSpec):
            wl, wl_name = workload, \
                ("trace" if workload.schedule is not None else "spec")
        else:
            wl_name = workload
            wl = schd.WorkloadSpec.preset(
                workload, n_requests=n_requests,
                vocab=self.cfg.vocab if self.cfg else 256, seed=seed)
        arrivals = schd.generate(wl)
        if sweep is None:
            sweep = [dict(c) for c in CAPACITY_SMOKE_SWEEP]

        def norm(c: dict) -> dict:
            return {"slots": int(c.get("slots", 4)),
                    "kv_pool_pages": c.get("kv_pool_pages"),
                    "chunk": int(c.get("chunk", 8)),
                    "policy": c.get("policy", "fifo")}

        def key(c: dict):
            # "smallest config": fewest slots, then smallest pool
            # (None = the session default pool, largest), then chunk,
            # then policy name — a total deterministic order
            pool = c["kv_pool_pages"]
            return (c["slots"], pool if pool is not None else 10 ** 9,
                    c["chunk"], c["policy"])

        def label(c: dict) -> str:
            return (f"slots={c['slots']},pages={c['kv_pool_pages']},"
                    f"chunk={c['chunk']},policy={c['policy']}")

        def run(c: dict):
            tracer = Tracer()
            sess = self.session(
                batch_slots=c["slots"], max_len=max_len,
                kv_cache="paged", page_size=page_size,
                kv_pool_pages=c["kv_pool_pages"],
                scheduler={"chunk": c["chunk"], "policy": c["policy"]},
                obs=tracer)
            replay = [(t, Request(prompt=list(r.prompt),
                                  max_new=r.max_new, rid=r.rid))
                      for t, r in arrivals]
            with warnings.catch_warnings():
                # an under-provisioned sweep point SHOULD fail its SLO,
                # not crash or warn-spam: partial completion is data here
                warnings.simplefilter("ignore")
                sess.run_workload(replay, max_steps=max_steps,
                                  on_incomplete="warn")
            return analyze(tracer, slo=slo)

        configs = sorted((norm(c) for c in sweep), key=key)
        out = {"workload": wl_name, "requests": wl.n_requests,
               "seed": seed, "page_size": page_size,
               "slo": slo.describe(),
               "order": "ascending (slots, kv_pool_pages, chunk, policy)",
               "sweep": [], "chosen": None}
        reports = {}
        for c in configs:
            rep = run(c)
            lbl = label(c)
            reports[lbl] = (c, rep)
            n_req = len(rep.requests)
            completed = sum(1 for r in rep.requests.values()
                            if r["outcome"] == "completed")
            out["sweep"].append({
                "config": c, "label": lbl,
                "slo_pass": rep.slo["pass"],
                "metrics": rep.slo["metrics"],
                "requests": n_req, "completed": completed,
                "span_ticks": rep.ticks["span"],
                "critical_path_ticks": {
                    p: rep.critical_path[p]["ticks"] for p in PHASES},
                "segments_ok": rep.segments_consistent(),
            })
            if out["chosen"] is None and rep.slo["pass"]:
                out["chosen"] = lbl
        # replay gate: the named config's report must be a pure function
        # of the (workload, config) — rerun it and diff the bytes
        probe = out["chosen"] or (out["sweep"][0]["label"]
                                  if out["sweep"] else None)
        if probe is not None:
            c, rep = reports[probe]
            out["deterministic_replay"] = \
                run(c).to_json() == rep.to_json()
        else:
            out["deterministic_replay"] = False
        return out

    def benchmark(self, modes: Sequence[str] = ("dense", "aida"),
                  requests: int = 4, max_new: int = 8,
                  batch_slots: int = 2, density: float = 0.25,
                  problem: Optional[FCProblem] = None,
                  kv_mode: Optional[str] = "aida") -> dict:
        """Serve each mode through the facade and price the cost-model
        backends on one FC instance; returns a JSON-ready dict
        (benchmarks/run.py writes it to BENCH_api.json)."""
        from repro.kernels import tune
        from repro.obs import provenance
        out = {
            # run provenance rides at the top of every BENCH_api.json so
            # a regression report names the exact setup that produced it
            "provenance": provenance(
                config=getattr(self.cfg, "name", None),
                mode=",".join(modes), seed=self._seed,
                backend=self.backend.name),
            "backends": {}, "modes": {}}
        reqs = [Request(prompt=[1, 2 + i % 7, 3], max_new=max_new, rid=i)
                for i in range(requests)]
        # entries already in the process-global cache were tuned by earlier
        # sessions, not by this benchmark — attribute only new winners
        seen_tiles = set(tune.snapshot())
        for mode in modes:
            eng = Engine(self.cfg, params=self.params)
            if mode != "dense":
                eng.compress(CompressionSpec(mode=mode, density=density))
            sess = eng.session(batch_slots=batch_slots,
                               max_len=max_new + 8)
            sess.submit(Request(prompt=[1], max_new=1, rid=-1))
            sess.run()  # warm the compiled step
            sess.results.clear()
            # best-of-3 passes: a single load spike on a shared host can
            # halve one mode's tok/s and flake the CI gate.  (dt, n_tok)
            # travel as a pair — the fastest pass's own token count.
            dt, n_tok = float("inf"), 0
            for _ in range(3):
                for r in reqs:
                    sess.submit(r)
                t0 = time.perf_counter()
                res = sess.run()
                pass_dt = time.perf_counter() - t0
                pass_tok = sum(len(r.tokens) for r in res)
                sess.results.clear()
                if pass_tok / pass_dt > (n_tok / dt if n_tok else 0.0):
                    dt, n_tok = pass_dt, pass_tok
            # tiles the autotuner picked for this mode's layer shapes —
            # recorded so the perf trajectory is reproducible
            snap = tune.snapshot()
            tiles = {k: v for k, v in snap.items() if k not in seen_tiles}
            seen_tiles.update(snap)
            out["modes"][mode] = {
                "backend": eng.backend.name,
                "tokens": n_tok, "seconds": round(dt, 4),
                "tok_per_s": round(n_tok / dt, 2),
                "tiles": tiles,
                "compression_ratio": (round(eng.stats["ratio"], 2)
                                      if eng.stats else 1.0)}
        if kv_mode is not None and self.cfg.family != "rwkv6":
            # paged-vs-dense KV cache section (attention time share, KV
            # bytes/token, paged step-time parity) — gated by
            # benchmarks/check_regression.py alongside the FC modes;
            # attention-free archs have nothing to page
            out["kv"] = self.kv_benchmark(mode=kv_mode,
                                          batch_slots=batch_slots,
                                          density=density)
            # scheduler section: chunked-prefill TTFT, heterogeneous
            # continuous-batching throughput/latency, prefix-cache reuse,
            # preemption-instead-of-OutOfPages — also CI-gated
            out["serving"] = self.serving_benchmark(mode=kv_mode,
                                                    density=density)
            from repro import sched as schd
            if schd.supports_chunked_prefill(self.cfg):
                # disaggregated prefill/decode vs co-located on the burst
                # preset: token parity + handoff/migration accounting +
                # TTFT-p99 — also CI-gated
                out["disagg"] = self.disagg_benchmark(mode=kv_mode,
                                                      density=density)
                # resilience section: burst under every FaultPlan preset
                # — token parity vs clean, zero leaks, deterministic
                # counters — also CI-gated
                out["resil"] = self.resil_benchmark(mode=kv_mode,
                                                    density=density)
                # capacity section: the burst preset swept over
                # (slots, pool, chunk, policy), each run's trace fed
                # through obs.analyze, smallest SLO-meeting config named
                # — tick-denominated, fully deterministic, CI-gated.
                # Ticks depend only on scheduling, not kernels, so the
                # dense engine (self) is the cheap honest substrate.
                out["capacity"] = self.capacity_benchmark()
        if problem is None:
            rng = np.random.default_rng(0)
            w = rng.integers(-15, 16, size=(24, 32)) \
                * (rng.random((24, 32)) < 0.3)
            b = rng.integers(-15, 16, size=(32,)) * (rng.random(32) < 0.6)
            problem = FCProblem(w=w, b=b, m=4, n=4)
        emu = self.estimate(backend="ap-emulator", workload=problem)
        sim = self.estimate(backend="cycle-sim", workload=problem)
        alex = self.estimate(backend="cycle-sim", workload="alexnet-fc")
        eie = self.estimate(backend="cycle-sim", workload="alexnet-fc",
                            simulator="eie")
        out["backends"]["ap-emulator"] = {
            "fc_cycles": int(emu["cycles"]), "exact": emu["exact"]}
        out["backends"]["cycle-sim"] = {
            "fc_cycles": int(sim["cycles"]),
            "agrees_with_emulator": int(sim["cycles"]) == int(emu["cycles"]),
            "alexnet_fc_cycles": int(alex["cycles"]),
            "alexnet_fc_inf_per_s": round(alex["inf_per_s"], 1),
            "eie_alexnet_fc_cycles": int(eie["cycles"]),
            "eie_alexnet_fc_inf_per_s": round(eie["inf_per_s"], 1)}
        return out
