"""ACSR — Associative CSR, the paper's sparse format (§3, Fig. 2).

Classic CSR keeps a per-row pointer array. ACSR drops it: every nonzero
carries, alongside its value and column index, a 2-bit *row flag* marking the
first / last / only element of its matrix row. This makes each CAM row (PU)
self-describing, which is what lets AIDA run the soft reduction fully in
parallel.

Flags (paper Fig. 3):
    FLAG_FIRST = 0b01   first element of a matrix row
    FLAG_LAST  = 0b10   last element of a matrix row
    FLAG_ONLY  = 0b11   row has a single element
    FLAG_MID   = 0b00   interior element (and padding)

TPU adaptation: TPU kernels need static shapes, so the nnz stream is padded to
a block multiple and every entry additionally carries an explicit ``seg_id``
(its matrix-row index; padding uses ``n_rows`` as a sentinel).  ``seg_id`` is
derivable from the flags by a prefix count of FIRST|ONLY — the flags are kept
for faithfulness (the emulator uses them verbatim) and the seg_ids for the
array-level / Pallas paths.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

FLAG_MID = 0b00
FLAG_FIRST = 0b01
FLAG_LAST = 0b10
FLAG_ONLY = 0b11


@dataclasses.dataclass
class ACSR:
    """ACSR matrix: per-nnz (value, col_idx, row_flag, seg_id), padded."""

    values: jnp.ndarray    # [nnz_pad] float32 (or uint8 codebook codes)
    col_idx: jnp.ndarray   # [nnz_pad] int32
    row_flag: jnp.ndarray  # [nnz_pad] uint8 (FLAG_*)
    seg_id: jnp.ndarray    # [nnz_pad] int32; padding entries = n_rows
    shape: Tuple[int, int]  # (n_rows, n_cols) of the dense matrix
    nnz: int                # true (unpadded) number of nonzeros

    @property
    def nnz_pad(self) -> int:
        return int(self.values.shape[0])

    def density(self) -> float:
        return self.nnz / float(self.shape[0] * self.shape[1])

    def tree(self):
        return dict(values=self.values, col_idx=self.col_idx,
                    row_flag=self.row_flag, seg_id=self.seg_id)


def encode(dense: np.ndarray, block: int = 128) -> ACSR:
    """Encode a dense matrix into ACSR, padding nnz to a multiple of ``block``.

    Nonzeros are stored row-major (all elements of matrix row j are
    consecutive), exactly as the paper lays PUs out in the CAM.
    """
    dense = np.asarray(dense)
    assert dense.ndim == 2, "ACSR encodes 2-D matrices"
    n_rows, n_cols = dense.shape
    rows, cols = np.nonzero(dense)
    order = np.lexsort((cols, rows))  # row-major
    rows, cols = rows[order], cols[order]
    vals = dense[rows, cols]
    nnz = vals.shape[0]

    flags = np.full((nnz,), FLAG_MID, dtype=np.uint8)
    if nnz:
        first = np.ones((nnz,), dtype=bool)
        first[1:] = rows[1:] != rows[:-1]
        last = np.ones((nnz,), dtype=bool)
        last[:-1] = rows[:-1] != rows[1:]
        flags[first & ~last] = FLAG_FIRST
        flags[last & ~first] = FLAG_LAST
        flags[first & last] = FLAG_ONLY

    nnz_pad = max(block, ((nnz + block - 1) // block) * block)
    pad = nnz_pad - nnz
    values = np.concatenate([vals.astype(np.float32), np.zeros(pad, np.float32)])
    col_idx = np.concatenate([cols.astype(np.int32), np.zeros(pad, np.int32)])
    row_flag = np.concatenate([flags, np.full(pad, FLAG_MID, np.uint8)])
    seg_id = np.concatenate([rows.astype(np.int32),
                             np.full(pad, n_rows, np.int32)])
    return ACSR(values=jnp.asarray(values), col_idx=jnp.asarray(col_idx),
                row_flag=jnp.asarray(row_flag), seg_id=jnp.asarray(seg_id),
                shape=(n_rows, n_cols), nnz=int(nnz))


def decode(a: ACSR) -> np.ndarray:
    """Inverse of :func:`encode` (drops padding)."""
    out = np.zeros(a.shape, dtype=np.float32)
    vals = np.asarray(a.values)[: a.nnz]
    cols = np.asarray(a.col_idx)[: a.nnz]
    segs = np.asarray(a.seg_id)[: a.nnz]
    out[segs, cols] = vals
    return out


def seg_id_from_flags(row_flag: np.ndarray, nnz: int, n_rows: int) -> np.ndarray:
    """Recover seg_ids from row flags alone (prefix count of FIRST|ONLY).

    Demonstrates ACSR's self-describing property: the 2-bit flag stream fully
    determines row membership, which is all the soft reduction needs.
    """
    flags = np.asarray(row_flag)
    is_first = (flags & FLAG_FIRST).astype(np.int64) != 0
    seg = np.cumsum(is_first) - 1
    seg[nnz:] = n_rows
    # matrices with empty rows need the explicit ids; flags only count
    # populated rows — map back through the populated-row order.
    return seg.astype(np.int32)


def prune_topk(dense: np.ndarray, density: float) -> np.ndarray:
    """Magnitude pruning to a target density (Deep-Compression style)."""
    dense = np.asarray(dense)
    k = max(1, int(round(density * dense.size)))
    thresh = np.partition(np.abs(dense).ravel(), -k)[-k]
    mask = np.abs(dense) >= thresh
    return dense * mask


def spmv_ref(a: ACSR, b: jnp.ndarray) -> jnp.ndarray:
    """Array-level oracle for ACSR matvec: gather → multiply → segment-sum.

    This is stage-for-stage the paper's algorithm in array form:
    activation broadcast = gather b[col_idx]; multiplication = elementwise
    product in every PU; soft reduction = segment_sum over seg_id.
    """
    n_rows = a.shape[0]
    gathered = jnp.take(b, a.col_idx, axis=0)          # activation broadcast
    prod = a.values * gathered                          # parallel multiply
    return jax.ops.segment_sum(prod, a.seg_id, num_segments=n_rows + 1)[:n_rows]
