"""Production FC layer — the paper's technique as a first-class feature.

Every projection in the model zoo can run in one of five modes (per-layer,
config-selectable; ``aida`` = the paper's full configuration):

  dense      bf16/f32 matmul                                  (baseline)
  int8       symmetric per-channel int8                       (Fig. 5b axis)
  codebook4  16-entry shared-value weights, fused dequant     (perfect
             induction, weights-only)                          [Pallas]
  acsr       unstructured sparsity, blocked ACSR               [Pallas]
  aida       sparsity + 4-bit codebook (EIE/AIDA operating point) [Pallas]

`compress()` is the offline pipeline (magnitude prune → k-means share →
pack) that turns a trained dense checkpoint into AIDA serving format.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acsr as acsr_mod
from repro.core import codebook as cb
from repro.core import quant as q
from repro.kernels import acsr_spmv as sp
from repro.kernels import ops

MODES = ("dense", "int8", "codebook4", "acsr", "aida")


@dataclasses.dataclass
class CompressedFC:
    """One FC layer in a serving-compressed representation: y = x @ W.T.

    Registered as a pytree, so a CompressedFC can REPLACE a weight matrix
    inside model params and flow through jitted decode steps — the AIDA
    serving mode plugs into every architecture's projections transparently
    (see models.layers.dense)."""
    mode: str
    shape: tuple                      # (n_out, n_in)
    dense: Optional[jnp.ndarray] = None          # dense/fallback weights
    qt: Optional[q.QTensor] = None               # int8
    codes_packed: Optional[jnp.ndarray] = None   # codebook4 [N, K/2] uint8
    centroids: Optional[jnp.ndarray] = None
    blocked: Optional[sp.BlockedACSR] = None     # acsr / aida


def _cfc_flatten(c: CompressedFC):
    return ((c.dense, c.qt, c.codes_packed, c.centroids, c.blocked),
            (c.mode, c.shape))


def _cfc_unflatten(aux, children):
    dense, qt, codes_packed, centroids, blocked = children
    return CompressedFC(mode=aux[0], shape=aux[1], dense=dense, qt=qt,
                        codes_packed=codes_packed, centroids=centroids,
                        blocked=blocked)


jax.tree_util.register_pytree_node(CompressedFC, _cfc_flatten, _cfc_unflatten)


def _qt_flatten(t: q.QTensor):
    return ((t.q, t.scale), (t.bits,))


jax.tree_util.register_pytree_node(
    q.QTensor, _qt_flatten,
    lambda aux, ch: q.QTensor(q=ch[0], scale=ch[1], bits=aux[0]))


def compress(w: np.ndarray, mode: str = "aida", density: float = 0.10,
             k: int = 16, block_rows: int = 128,
             kmeans_iters: int = 25, dtype: str = "f32") -> CompressedFC:
    """Offline Deep-Compression-style pipeline (prune → share → pack).

    ``dtype="bf16"`` stores acsr nonzero values in bfloat16 (the ROADMAP
    bytes-win variant); other modes already store sub-f32 values and
    ignore it."""
    w = np.asarray(w, np.float32)
    n_out, n_in = w.shape
    if mode == "dense":
        return CompressedFC("dense", (n_out, n_in), dense=jnp.asarray(w))
    if mode == "int8":
        return CompressedFC("int8", (n_out, n_in),
                            qt=q.quantize_int(jnp.asarray(w), bits=8, axis=0))
    if mode == "codebook4":
        cbq = cb.quantize(jnp.asarray(w), k=k, iters=kmeans_iters, pack=True)
        return CompressedFC("codebook4", (n_out, n_in),
                            codes_packed=cbq.codes.reshape(n_out, n_in // 2),
                            centroids=cbq.centroids)
    if mode == "acsr":
        pruned = acsr_mod.prune_topk(w, density)
        return CompressedFC("acsr", (n_out, n_in),
                            blocked=sp.block_encode(pruned, block_rows,
                                                    value_dtype=dtype))
    if mode == "aida":
        pruned = acsr_mod.prune_topk(w, density)
        nz = pruned[pruned != 0]
        cents = np.asarray(cb.kmeans_1d(jnp.asarray(nz), k=k - 1,
                                        iters=kmeans_iters))
        cents = np.concatenate([[0.0], cents]).astype(np.float32)
        return CompressedFC("aida", (n_out, n_in),
                            blocked=sp.block_encode_coded(pruned, cents,
                                                          block_rows))
    raise ValueError(f"unknown mode {mode!r}; choose from {MODES}")


def _fit_bias(bias: Optional[jnp.ndarray], rows: int):
    """Row-padded containers (shard-aware stacking pads the output axis
    to a multiple of the shard count) need the bias padded to match."""
    if bias is not None and bias.shape[0] != rows:
        bias = jnp.pad(bias, (0, rows - bias.shape[0]))
    return bias


def apply_fc(layer: CompressedFC, x: jnp.ndarray,
             bias: Optional[jnp.ndarray] = None,
             activation: Optional[str] = None) -> jnp.ndarray:
    """y = act(x @ W.T + bias) for x [B, n_in] (or [n_in]) under any mode.

    ``bias`` ([n_out]) and ``activation`` are fused into the kernel
    epilogues on the Pallas paths (no extra HBM round-trip for y).
    Row-padded containers (see shard.partition / CompressionSpec.shards)
    are handled transparently: padded rows compute nothing real and are
    sliced off here, so ``y`` is always [B, layer.shape[0]].
    """
    squeeze = x.ndim == 1
    x2 = x[None, :] if squeeze else x
    if layer.mode == "dense":
        y = jnp.matmul(x2, layer.dense.T,
                       preferred_element_type=jnp.float32)
        y = ops.bias_act_epilogue(y, _fit_bias(bias, y.shape[-1]),
                                  activation)
    elif layer.mode == "int8":
        y = ops.int8_matmul(x2, layer.qt,
                            bias=_fit_bias(bias, layer.qt.q.shape[0]),
                            activation=activation)
    elif layer.mode == "codebook4":
        y = ops.lut_matmul(x2, layer.codes_packed, layer.centroids,
                           bias=_fit_bias(bias,
                                          layer.codes_packed.shape[0]),
                           activation=activation)
    elif layer.mode in ("acsr", "aida"):
        y = ops.acsr_spmv(layer.blocked, x2.T,
                          bias=_fit_bias(bias,
                                         layer.blocked.values.shape[-1]
                                         * layer.blocked.nblocks),
                          activation=activation).T
    else:
        raise ValueError(layer.mode)
    y = y[:, : layer.shape[0]]
    return y[0] if squeeze else y


def dense_equivalent(layer: CompressedFC) -> np.ndarray:
    """Materialize the effective dense weights (for error analysis)."""
    if layer.mode == "dense":
        return np.asarray(layer.dense)
    if layer.mode == "int8":
        return np.asarray(q.dequantize_int(layer.qt))
    if layer.mode == "codebook4":
        codes = np.asarray(cb.unpack4(layer.codes_packed))
        return np.asarray(layer.centroids)[codes.astype(np.int64)]
    if layer.mode in ("acsr", "aida"):
        b = layer.blocked
        vals = np.asarray(b.values, np.float32)
        if b.centroids is not None:
            vals = np.asarray(b.centroids)[np.asarray(b.values, np.int64)]
        out = np.zeros(layer.shape, np.float32)
        br, rmax = b.block_rows, b.rmax
        # vectorized inverse of the slot schedule: lane = row % block_rows,
        # live slots are those below the row's precomputed population
        live = (np.arange(rmax)[None, :, None]
                < np.asarray(b.row_nnz)[:, None, :])     # [nb, rmax, br]
        blk, slot, lane = np.nonzero(live)
        rows = blk * br + lane
        inb = rows < layer.shape[0]
        cols = np.asarray(b.col_idx, np.int64)[blk, slot, lane]
        out[rows[inb], cols[inb]] = vals[blk, slot, lane][inb]
        return out
    raise ValueError(layer.mode)
