"""Run the paper's Fig. 3 algorithm bit-for-bit on the CAM emulator, and
show the cycle model + Table-1-style projections for a real FC layer.

  PYTHONPATH=src python examples/aida_emulator_demo.py
"""
import numpy as np

from repro.core import aida_sim as S
from repro.core import eie_sim as E
from repro.core.aida_fc import (aida_fc_layer, aida_fc_layer_coded,
                                fc_reference, fc_reference_coded)


def main():
    rng = np.random.default_rng(0)

    print("== bit-serial mode (Fig. 3 verbatim) ==")
    W = rng.integers(-15, 16, size=(12, 16)) * (rng.random((12, 16)) < 0.4)
    b = rng.integers(-15, 16, size=(16,)) * (rng.random(16) < 0.6)
    res = aida_fc_layer(W, b, m=4, n=4)
    ref = fc_reference(W, b)
    print(f"  C = relu(W x B): emulator == oracle: "
          f"{np.array_equal(res.out, ref)}")
    print(f"  cycles={res.cycles} (broadcast {res.nnz_b} nnz acts, "
          f"{res.rounds} soft-reduction rounds)")
    print(f"  compare ops={res.counters['compare']} "
          f"writes={res.counters['write']} tag moves={res.counters['move']}")

    print("\n== coded mode (bit-parallel perfect induction, 4-bit) ==")
    cw = np.concatenate([[0], rng.integers(-99, 100, 15)])
    ca = np.concatenate([[0], rng.integers(-99, 100, 15)])
    Wc = rng.integers(0, 16, size=(12, 16)) * (rng.random((12, 16)) < 0.4)
    bc = rng.integers(0, 16, size=(16,)) * (rng.random(16) < 0.6)
    res = aida_fc_layer_coded(Wc, bc, cw, ca)
    print(f"  emulator == oracle: "
          f"{np.array_equal(res.out, fc_reference_coded(Wc, bc, cw, ca))}")
    print(f"  cycles={res.cycles} — the multiply stage is 225 cycles "
          f"for ANY layer size (perfect induction)")

    print("\n== projected to AlexNet-FC6 (closed-form model) ==")
    l = S.alexnet_fc()[0]
    ph = S.cycles_fc(l.n_in, l.nnz_b, l.max_row_nnz, S.PAPER)
    print(f"  broadcast={ph.broadcast} multiply={ph.multiply} "
          f"reduce={ph.reduce} cycles; total={ph.total(S.PAPER)} "
          f"@1GHz = {ph.total(S.PAPER)/1e3:.1f} us/layer")
    a, e = S.aida_table1(), E.eie_table1()
    print(f"  AIDA {a['pp_gops']:.0f} GOP/s vs EIE {e['pp_gops']:.0f} "
          f"-> {a['pp_gops']/e['pp_gops']:.1f}x (paper: 14.5x)")


if __name__ == "__main__":
    main()
