"""Deterministic, resumable, sharded data pipeline.

Synthetic token streams (zipfian unigram + short-range structure so tiny
models have learnable signal) keyed by (seed, step, shard) — any worker can
reproduce any batch, which is what checkpoint-restart and elastic rescaling
need: the pipeline state IS the step counter.  Audio/vision cells get
matching stand-in frontends (frames / patch embeddings).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass
class PipelineConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    n_shards: int = 1       # data-parallel shards
    shard_id: int = 0


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    """Zipf-ish unigram draw + markov-ish smoothing for learnable structure."""
    ranks = rng.zipf(1.3, size=shape).astype(np.int64)
    toks = np.minimum(ranks - 1, vocab - 1)
    # inject determinism: every 4th token repeats its predecessor's bucket
    toks[..., 3::4] = (toks[..., 2::4] * 31 + 7) % vocab
    return toks.astype(np.int32)


def make_batch(cfg: ArchConfig, pc: PipelineConfig, step: int) -> Dict:
    """The batch for (step, shard) — pure function of (seed, step, shard)."""
    assert pc.global_batch % pc.n_shards == 0
    local_b = pc.global_batch // pc.n_shards
    rng = np.random.default_rng(
        np.random.SeedSequence([pc.seed, step, pc.shard_id]))
    s = pc.seq_len
    if cfg.frontend == "audio":
        return {"frames": rng.standard_normal(
                    (local_b, s, cfg.audio_in_dim)).astype(np.float32),
                "labels": rng.integers(0, cfg.vocab,
                                       (local_b, s)).astype(np.int32)}
    if cfg.frontend == "vision":
        s_txt = s - cfg.n_img_tokens
        return {"tokens": _zipf_tokens(rng, (local_b, s_txt), cfg.vocab),
                "img_embeds": rng.standard_normal(
                    (local_b, cfg.n_img_tokens,
                     cfg.d_model)).astype(np.float32) * 0.02}
    return {"tokens": _zipf_tokens(rng, (local_b, s), cfg.vocab)}


class DataIterator:
    """Stateful wrapper with exact-resume semantics."""

    def __init__(self, cfg: ArchConfig, pc: PipelineConfig, start_step: int = 0):
        self.cfg, self.pc = cfg, pc
        self.step = start_step

    def __iter__(self) -> Iterator[Dict]:
        return self

    def __next__(self) -> Dict:
        b = make_batch(self.cfg, self.pc, self.step)
        self.step += 1
        return b

    def state(self) -> Dict:
        return {"step": self.step, "seed": self.pc.seed}

    @classmethod
    def restore(cls, cfg: ArchConfig, pc: PipelineConfig,
                state: Dict) -> "DataIterator":
        assert state["seed"] == pc.seed, "seed mismatch on resume"
        return cls(cfg, pc, start_step=state["step"])
