"""Public jit'd kernel API — dispatch between Pallas kernels and jnp refs.

On this (CPU) container Pallas runs in interpret mode; on TPU set
``REPRO_PALLAS_INTERPRET=0`` (or rely on the backend auto-detection) to lower
the kernels natively.  Training paths that need autodiff either use a
custom_vjp pairing the fwd/bwd kernels (attention) or a differentiable
lax.scan formulation (recurrences).
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import flash_attention as _fa
from repro.kernels import linear_scan as _ls
from repro.kernels import lut_matmul as _lm
from repro.kernels import acsr_spmv as _sp


def pallas_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------- attention
@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash(q, k, v, causal, window, softcap, scale, bq, bk, interp):
    o, _ = _fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale, bq=bq,
                                   bk=bk, interpret=interp)
    return o.astype(q.dtype)


def _flash_fwd(q, k, v, causal, window, softcap, scale, bq, bk, interp):
    o, lse = _fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                     softcap=softcap, scale=scale, bq=bq,
                                     bk=bk, interpret=interp)
    return o.astype(q.dtype), (q, k, v, o, lse)


def _flash_bwd(causal, window, softcap, scale, bq, bk, interp, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _fa.flash_attention_bwd(
        q, k, v, o, lse, do.astype(jnp.float32), causal=causal,
        window=window, softcap=softcap, scale=scale, bq=bq, bk=bk,
        interpret=interp)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              softcap: Optional[float] = None, scale: Optional[float] = None,
              impl: str = "flash", bq: int = 128, bk: int = 128):
    """Self-attention [B,H,T,D]×[B,Hkv,T,D] -> [B,H,T,D] (training/prefill).

    impl="flash": Pallas fwd/bwd kernels via custom_vjp.
    impl="ref":   pure-jnp oracle (XLA-fused; also the dry-run default, so
                  compiled HLO stays kernel-free and cost-analyzable).
    """
    if impl == "ref":
        return _ref.attention_ref(q, k, v, causal=causal, window=window,
                                  softcap=softcap, scale=scale).astype(q.dtype)
    t = q.shape[2]
    bq_, bk_ = min(bq, t), min(bk, t)
    return _flash(q, k, v, causal, window, softcap, scale, bq_, bk_,
                  pallas_interpret())


# ------------------------------------------------------------- recurrences
def rwkv6(r, k, v, w, u, *, impl: str = "scan", chunk: int = 64):
    """RWKV6 WKV. impl="scan" (differentiable, training/dry-run) or
    impl="kernel" (Pallas, serving)."""
    if impl == "kernel":
        return _ls.rwkv6_fwd(r, k, v, w, u, chunk=chunk,
                             interpret=pallas_interpret())
    return _ref.rwkv6_ref(r, k, v, w, u)


def rwkv6_decode_step(S, r, k, v, w, u):
    """Single-token WKV update. S [B,H,Dk,Dv]; r,k,w [B,H,Dk]; v [B,H,Dv]."""
    kv = k[..., :, None] * v[..., None, :]
    o = jnp.einsum("bhkv,bhk->bhv", S + u[None, :, :, None] * kv, r)
    S = w[..., :, None] * S + kv
    return S, o


def mamba(x, dt, A, B, C):
    """Selective SSM (differentiable lax.scan path)."""
    return _ref.mamba_ref(x, dt, A, B, C)


def mamba_decode_step(h, x, dt, A, B, C):
    """h [B,D,N]; x,dt [B,D]; B,C [B,N] -> (h', y [B,D])."""
    decay = jnp.exp(dt[..., None] * A[None])              # [B,D,N]
    h = decay * h + (dt * x)[..., None] * B[:, None, :]
    return h, jnp.einsum("bdn,bn->bd", h, C)


# --------------------------------------------------------------- quantized
def lut_matmul(x, codes_packed, centroids, **kw):
    kw.setdefault("interpret", pallas_interpret())
    return _lm.lut_matmul(x, codes_packed, centroids, **kw)


def lut_product_matmul(x_codes, codes_packed, lut, **kw):
    kw.setdefault("interpret", pallas_interpret())
    return _lm.lut_product_matmul(x_codes, codes_packed, lut, **kw)


def acsr_spmv(blocked, x, **kw):
    kw.setdefault("interpret", pallas_interpret())
    return _sp.acsr_spmv(blocked, x, **kw)
