"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun jsonl."""
from __future__ import annotations

import json
import sys
from collections import OrderedDict

ARCH_ORDER = ["h2o-danube-1.8b", "qwen1.5-0.5b", "gemma2-2b", "llama3-8b",
              "phi-3-vision-4.2b", "dbrx-132b", "mixtral-8x7b", "hymba-1.5b",
              "hubert-xlarge", "rwkv6-7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(path):
    recs = OrderedDict()
    for line in open(path):
        r = json.loads(line)
        recs[(r["arch"], r["shape"], r["mesh"])] = r  # later runs win
    return recs


def ms(x):
    return f"{x*1e3:.2f}" if x is not None else "—"


def gib(x):
    return f"{x/2**30:.2f}" if x is not None else "—"


def dryrun_table(recs, mesh="multi"):
    out = ["| arch | shape | status | compile s | args GiB/dev | "
           "temp GiB/dev | collective GB/dev |",
           "|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if r is None:
                out.append(f"| {a} | {s} | MISSING | | | | |")
            elif r["status"] == "skip":
                out.append(f"| {a} | {s} | skip — {r['reason']} | | | | |")
            elif r["status"] == "fail":
                out.append(f"| {a} | {s} | FAIL | | | | |")
            else:
                cb = sum(r["coll_bytes"].values()) / 1e9
                out.append(
                    f"| {a} | {s} | ok | {r['compile_s']} | "
                    f"{gib(r['arg_bytes'])} | {gib(r['temp_bytes'])} | "
                    f"{cb:.2f} |")
    return "\n".join(out)


def roofline_table(recs, mesh="single"):
    out = ["| arch | shape | compute ms | memory ms | collective ms | "
           "bottleneck | useful FLOPs | roofline frac | "
           "1-sentence lever |",
           "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if r is None or r["status"] != "ok":
                continue
            lever = LEVERS.get(r["bottleneck"], "")
            out.append(
                f"| {a} | {s} | {ms(r['t_compute'])} | {ms(r['t_memory'])} "
                f"| {ms(r['t_collective'])} | {r['bottleneck']} | "
                f"{r['useful_flops_frac']:.1%} | {r['roofline_frac']:.2%} | "
                f"{lever} |")
    return "\n".join(out)


LEVERS = {
    "memory": "fuse attention/softmax (flash kernel) + stream the vocab loss"
              " — O(T²)/O(V) tensors never touch HBM",
    "collective": "cast-before-gather (bf16 FSDP), overlap grads with bwd,"
                  " compress the cross-pod all-reduce",
    "compute": "remat policy down (less recompute), MXU-align tile shapes",
}


if __name__ == "__main__":
    recs = load(sys.argv[1] if len(sys.argv) > 1 else "dryrun_baseline.jsonl")
    print("## Dry-run (multi-pod 2x16x16 = 512 chips)\n")
    print(dryrun_table(recs, "multi"))
    print("\n## Roofline (single pod 16x16 = 256 chips)\n")
    print(roofline_table(recs, "single"))
