"""repro.obs: unified tracing, metrics registry, and flight recorder.

Covers: tracer determinism (two same-seed serves — co-located and
disaggregated — export byte-identical Chrome traces), the zero-cost-off
property (a traced run's scheduling decisions are identical to an
untraced run's), Chrome export schema (role pids, slot tids, tick-
derived timestamps), the flight-recorder ring dumping the last-N events
on a forced HealthError and on a structured RequestFailed, the typed
counter/gauge/histogram registry, provenance stamps, ``obs.timeit``,
wall-phase timers, and the serve CLI's ``--trace``/``--json`` flags.

Also the sched.metrics edge cases the registry rewrite is gated by:
percentile/_dist on empty and single-element inputs, an all-unserved
outcome fold, and the stable ``summarize()`` key schema.
"""
import dataclasses
import json
import os

import jax
import pytest

from repro import kvstore as kvs
from repro import obs
from repro import resil as rsl
from repro import sched as schd
from repro.api import Request
from repro.api.session import Session
from repro.configs import get, reduced
from repro.disagg import DisaggConfig, DisaggSession
from repro.models import model as M
from repro.sched import metrics

CFG = reduced(get("llama3-8b"), n_layers=2, d_model=64, d_ff=128,
              vocab=256)
PS = 4
ML = 48


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def burst_arrivals(n=5, seed=0):
    wl = schd.WorkloadSpec.preset("burst", n_requests=n, vocab=CFG.vocab,
                                  seed=seed)
    return schd.generate(wl)


def replay(arrivals):
    return [(t, dataclasses.replace(r)) for t, r in arrivals]


def mk_disagg(params, tracer, resil=None):
    return DisaggSession(CFG, params,
                         disagg=DisaggConfig(prefill_slots=2,
                                             decode_slots=3),
                         max_len=ML, page_size=PS,
                         scheduler={"chunk": 4}, resil=resil, obs=tracer)


# ------------------------------------------------------------- registry
def test_registry_counter_gauge_histogram():
    reg = obs.Registry()
    reg.counter("requests").inc()
    reg.counter("requests").inc(2)
    reg.gauge("level").set(3)
    h = reg.histogram("lat")
    h.observe_many([1.0, 2.0, 3.0, 4.0])
    assert reg.counter("requests").value == 3
    assert reg.gauge("level").value == 3
    s = h.summary()
    # nearest-rank p50 of 4 values rounds up (round-half-even on 1.5)
    assert s["mean"] == 2.5 and s["p50"] == 3.0 and s["p99"] == 4.0
    # scaled summary (seconds -> ms)
    assert h.summary(scale=1000.0)["mean"] == 2500.0
    snap = reg.snapshot()
    assert snap["counters"] == {"requests": 3}
    assert snap["gauges"] == {"level": 3}
    assert snap["histograms"]["lat"]["mean"] == 2.5


def test_histogram_empty_and_single():
    h = obs.Histogram("x")
    assert h.summary() is None
    h.observe(7.0)
    assert h.summary() == {"mean": 7.0, "p50": 7.0, "p99": 7.0}


def test_percentile_edges():
    assert obs.percentile([], 50) is None
    assert obs.percentile([5.0], 0) == 5.0
    assert obs.percentile([5.0], 100) == 5.0
    assert obs.percentile([1.0, 2.0, 3.0], 100) == 3.0
    assert obs.percentile([1.0, 2.0, 3.0], 0) == 1.0


def test_provenance_stamp():
    p = obs.provenance(config="llama3-8b", mode="aida", seed=3,
                       backend="pallas", extra_field=1)
    for k in ("config", "mode", "seed", "backend", "jax", "git_sha",
              "timestamp"):
        assert k in p
    assert p["seed"] == 3 and p["extra_field"] == 1
    assert p["jax"] == jax.__version__


# ------------------------------------------------------- sched.metrics
def test_metrics_dist_empty_and_single():
    assert metrics._dist([]) is None
    d = metrics._dist([2.0])
    assert d["mean"] == 2.0 and d["p50"] == 2.0 and d["p99"] == 2.0


def test_metrics_outcomes_only_unserved():
    recs = [{"state": "unserved"}, {"state": "unserved"}]
    out = metrics._outcomes(recs)
    assert out == {"unserved": 2}


def test_summarize_key_schema(params):
    """The registry rewrite must keep summarize()'s key set stable —
    benchmarks, the CLI, and check_regression.py all read it by name."""
    sess = Session(CFG, params, batch_slots=2, max_len=ML, page_size=PS,
                   scheduler={"chunk": 4})
    sess.run_workload(replay(burst_arrivals(3)))
    m = metrics.summarize(sess.records, 1.0, sess.stats["steps"])
    assert set(metrics.SUMMARY_KEYS) <= set(m)
    assert set(m) - set(metrics.SUMMARY_KEYS) <= \
        set(metrics.SUMMARY_KEYS_CONDITIONAL)
    assert m["outcomes"] == {"completed": m["completed"]}
    m2 = metrics.summarize(sess.records, 1.0, sess.stats["steps"],
                           roles={"prefill": {"steps": 1, "busy_ticks": 1},
                                  "decode": {"steps": 1, "busy_ticks": 1},
                                  "_ticks": 2},
                           resil={"shed": 0})
    assert set(m2) - set(metrics.SUMMARY_KEYS) <= \
        set(metrics.SUMMARY_KEYS_CONDITIONAL)
    assert "roles" in m2 and "resil" in m2
    # no-requests fold stays total-function
    empty = metrics.summarize([], 0.0, 0)
    assert empty["requests"] == 0 and empty["tok_per_s"] is None


# --------------------------------------------------------------- timeit
def test_timeit_returns_best_per_call():
    calls = []

    def fn(x):
        calls.append(x)
        return x

    dt = obs.timeit(fn, 1, reps=2, inner=3, warmup=1)
    assert dt >= 0.0
    assert len(calls) == 1 + 2 * 3   # warmup + reps x inner

    with pytest.raises(ZeroDivisionError):
        obs.timeit(lambda: 1 / 0, reps=1)


def test_wall_timers_phases():
    w = obs.WallTimers()
    with w.phase("decode"):
        pass
    with w.phase("decode"):
        pass
    with w.phase("prefill"):
        pass
    s = w.summary()
    assert s["decode"]["calls"] == 2 and s["prefill"]["calls"] == 1
    assert abs(sum(v["share"] for v in s.values()) - 1.0) < 1e-6


# --------------------------------------------------------------- tracer
def test_null_tracer_is_free_and_silent():
    t = obs.NULL
    assert not t.enabled
    t.instant("req.submit", tick=0)
    t.span("step.decode", tick=0)
    assert t.crash("whatever") is None


def test_tracer_chrome_export_schema(tmp_path):
    t = obs.Tracer()
    t.instant("req.submit", tick=0, role="prefill", rid=1)
    t.span("step.decode", tick=2, role="decode", slot=1, active=1)
    doc = t.to_chrome()
    evs = doc["traceEvents"]
    roles = {e["args"]["name"]: e["pid"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert roles == {"prefill": 1, "decode": 2}
    span = next(e for e in evs if e["name"] == "step.decode")
    assert span["ph"] == "X" and span["ts"] == 2 * obs.trace.TICK_US
    assert span["dur"] == obs.trace.TICK_US and span["tid"] == 2
    assert span["args"]["tick"] == 2
    inst = next(e for e in evs if e["name"] == "req.submit")
    assert inst["ph"] == "i" and inst["s"] == "t" and inst["tid"] == 0
    p = tmp_path / "t.json"
    t.export(str(p))
    assert json.loads(p.read_text()) == doc


def test_traced_serve_replay_identical(params, tmp_path):
    """Two same-seed co-located serves emit byte-identical traces."""
    paths = []
    for i in range(2):
        t = obs.Tracer()
        sess = Session(CFG, params, batch_slots=2, max_len=ML,
                       page_size=PS, scheduler={"chunk": 4,
                                                "prefix_cache": True},
                       obs=t)
        sess.run_workload(replay(burst_arrivals(4)))
        p = tmp_path / f"co_{i}.json"
        t.export(str(p))
        paths.append(p)
        assert any(e["name"] == "step.decode" for e in t.events)
        assert any(e["name"] == "prefix.pin" for e in t.events)
    assert paths[0].read_bytes() == paths[1].read_bytes()


def test_traced_serve_does_not_change_behavior(params):
    """Tracing must observe, never steer: token streams and scheduler
    stats are identical with and without a live tracer."""
    plain = Session(CFG, params, batch_slots=2, max_len=ML, page_size=PS,
                    scheduler={"chunk": 4})
    rp = plain.run_workload(replay(burst_arrivals(4)))
    traced = Session(CFG, params, batch_slots=2, max_len=ML, page_size=PS,
                     scheduler={"chunk": 4}, obs=obs.Tracer())
    rt = traced.run_workload(replay(burst_arrivals(4)))
    assert [r.tokens for r in rp] == [r.tokens for r in rt]
    assert plain.stats["steps"] == traced.stats["steps"]
    assert plain.sched.stats == traced.sched.stats


def test_disagg_trace_covers_handoff_seams(params, tmp_path):
    traces = []
    for i in range(2):
        t = obs.Tracer()
        d = mk_disagg(params, t,
                      resil={"fault_plan": "drop-handoff:1"})
        d.run_workload(replay(burst_arrivals(4)), on_incomplete="warn")
        p = tmp_path / f"dis_{i}.json"
        t.export(str(p))
        traces.append(p.read_bytes())
        names = {e["name"] for e in t.events}
        assert {"handoff.enqueue", "handoff.deliver", "handoff.migrate",
                "step.prefill", "step.decode"} <= names
        roles = {e["role"] for e in t.events}
        assert {"prefill", "decode"} <= roles
    assert traces[0] == traces[1]


# ------------------------------------------------------ flight recorder
def test_flight_recorder_ring_and_dump(tmp_path):
    r = obs.FlightRecorder(capacity=3, out_dir=str(tmp_path))
    for i in range(5):
        r.record({"name": "alloc.pages", "tick": i})
    assert r.total == 5 and len(r.ring) == 3
    path = r.dump(reason="OutOfPages", context={"tick": 4})
    doc = json.loads(open(path).read())
    assert doc["reason"] == "OutOfPages"
    assert [e["tick"] for e in doc["events"]] == [2, 3, 4]
    assert doc["events_total"] == 5
    # a second dump gets a fresh sequence number, not an overwrite
    p2 = r.dump(reason="OutOfPages", context={})
    assert p2 != path and os.path.exists(p2)


def test_health_error_dumps_flight_recorder(params, tmp_path, monkeypatch):
    """A watchdog HealthError must leave a post-mortem dump holding the
    failing session's last events."""
    monkeypatch.chdir(tmp_path)
    rec = obs.FlightRecorder(capacity=64, out_dir=str(tmp_path))
    t = obs.Tracer(recorder=rec)
    sess = Session(CFG, params, batch_slots=2, max_len=ML, page_size=PS,
                   scheduler={"chunk": 4},
                   resil={"watchdog_every": 2}, obs=t)
    orig = rsl.health.audit_session

    def corrupt(s, extra_refs=None):
        return orig(s, extra_refs) + ["manufactured leak (test)"]

    monkeypatch.setattr(rsl.health, "audit_session", corrupt)
    with pytest.raises(rsl.HealthError):
        sess.run_workload(replay(burst_arrivals(3)))
    dumps = sorted(tmp_path.glob("flight_*.json"))
    assert dumps, "HealthError did not dump the flight recorder"
    doc = json.loads(dumps[0].read_text())
    assert doc["reason"] == "HealthError"
    assert doc["events"], "dump carries no events"
    assert any(e["name"] == "req.submit" for e in doc["events"])
    assert doc["context"]["role"] == "engine"


def test_request_failed_dumps_flight_recorder(params, tmp_path):
    rec = obs.FlightRecorder(capacity=32, out_dir=str(tmp_path))
    t = obs.Tracer(recorder=rec)
    sess = Session(CFG, params, batch_slots=2, max_len=ML, page_size=PS,
                   scheduler={"chunk": 4},
                   resil={"deadline_ticks": 1}, obs=t)
    sess.run_workload(replay(burst_arrivals(3)), on_incomplete="warn")
    assert sess.failed, "deadline_ticks=1 should fail requests"
    dumps = sorted(tmp_path.glob("flight_*RequestFailed*.json"))
    assert dumps
    doc = json.loads(dumps[0].read_text())
    assert doc["context"]["why"] == "deadline"
    assert any(e["name"] == "resil.fail" for e in doc["events"])


# ------------------------------------------------------------------ CLI
def test_serve_cli_trace_and_json(tmp_path):
    import subprocess
    import sys
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    trace = tmp_path / "trace.json"
    mjson = tmp_path / "metrics.json"
    report = tmp_path / "report.json"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "llama3-8b", "--requests", "3", "--max-new", "4",
         "--trace", str(trace), "--trace-ring", "32",
         "--json", str(mjson), "--report", str(report),
         "--slo", "ttft_p99=40,goodput=1.0"],
        env=dict(os.environ, PYTHONPATH=src, REPRO_AUTOTUNE="0"),
        capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "trace:" in out.stdout and "json:" in out.stdout
    assert "critical path" in out.stdout and "slo PASS" in out.stdout
    rep = json.loads(report.read_text())
    assert rep["schema"] == "repro.obs.analyze/v1"
    assert rep["slo"]["pass"] is True
    assert all(sum(r["segments"].values()) == r["span"]
               for r in rep["requests"].values())
    doc = json.loads(trace.read_text())
    assert doc["traceEvents"]
    names = {e["name"] for e in doc["traceEvents"]}
    assert "step.decode" in names and "req.finish" in names
    m = json.loads(mjson.read_text())
    assert set(m) >= {"provenance", "metrics", "pages", "failed"}
    assert m["provenance"]["config"] == "llama3-8b-smoke"
    assert m["metrics"]["completed"] == 3
    assert m["pages"]["leaked"] == 0
    assert m["wall_phases"]["decode"]["calls"] >= 1
