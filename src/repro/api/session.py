"""Serving session: continuous batching over a fixed-slot decode batch,
driven by the `repro.sched` scheduler subsystem.

Requests occupy slots, finished slots are refilled from the scheduler's
queue without stopping the batch (continuous batching).  The scheduler
(`repro.sched.Scheduler`) decides admission order (FIFO or
shortest-prompt-first), applies page-pool admission control (a request is
admitted only when its worst-case page need fits), and picks preemption
victims under pool pressure (youngest first, recompute-style resume)
instead of letting `OutOfPages` crash the batch.

Prefill is chunked when the KV cache is paged and the arch supports it
(`scheduler=...` with ``chunk=C``): C prompt tokens per model call via
`sched.prefill`, written straight into pool pages — first-token latency
drops from prompt_len calls to ceil(prompt_len/C).  With ``chunk=1``
(default) prompts feed token-by-token through the decode step.

KV cache resolution: ``kv_cache=None`` resolves through REPRO_KV_CACHE
(default "auto"); "auto" picks the paged pool for every arch with
attention layers and falls back to the dense cache for attention-free
ones (rwkv6).  Paged pages are allocated host-side the step a sequence
crosses a page boundary, freed the moment its request completes, and —
on pure-SWA architectures — reclaimed as soon as they slide fully behind
the attention window.  With ``prefix_cache=True`` full prompt pages are
content-hashed and shared across requests (refcounted), so common
prompt heads are prefilled once.

Sessions are created by `repro.api.Engine.session()` (or directly); the
compiled decode step comes from the engine's backend, so dense and
compressed (Pallas) serving share one code path.

Mesh serving: a ``plan`` (repro.shard.ShardingPlan, built by
``Engine.session(mesh=...)``) makes the same session tensor-parallel —
params are shard-padded and placed per the plan, KV pools shard their
head axis, and the decode/prefill steps compile with explicit
input/output shardings.  All host-side bookkeeping (page allocator,
admission, preemption, prefix cache) is placement-agnostic and runs
unchanged; ``plan=None`` is the exact pre-mesh single-device path.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
import warnings
from typing import Deque, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import kvstore as kvs
from repro import obs as obs_mod
from repro import resil as rsl
from repro import sched as schd
from repro.api import env
from repro.api.registry import Executor, get_backend
from repro.configs.base import ArchConfig

# env knobs resolved ONCE at import via repro.api.env (traced code must
# not read os.environ); per-session override via the kv_cache= /
# kv_dtype= constructor args.  "auto" resolves per-arch in
# resolve_kv_cache: paged for attention archs (exact bf16 pages by
# default — int8 is the opt-in memory lever).
KV_CACHE_DEFAULT = env.KV_CACHE
KV_DTYPE_DEFAULT = env.KV_DTYPE


def resolve_kv_cache(kv_cache: Optional[str], cfg: ArchConfig) -> str:
    """None -> env default; "auto" -> paged wherever there is attention
    state to page (explicit "full" always available)."""
    kv = KV_CACHE_DEFAULT if kv_cache is None else kv_cache
    if kv == "auto":
        kv = "full" if cfg.family == "rwkv6" else "paged"
    return kv


# Compiled decode steps keyed by (backend, cfg): sessions on the same
# config reuse one jitted step (its trace cache handles dense vs
# compressed param structures), so spinning up a Session is cheap.
# The decode state (argnum 1) is DONATED: every step consumes the state
# it is handed and the caller keeps only the returned one — KV
# pool/cache buffers are updated in place, never silently copied.
# Mesh sessions compile per session instead (their in/out shardings
# depend on the session's concrete param/state trees).
_STEP_CACHE: dict = {}


def _jitted_step(backend: Executor, cfg: ArchConfig):
    key = (backend.name, cfg)
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = jax.jit(backend.make_decode_step(cfg),
                                   donate_argnums=(1,))
    return _STEP_CACHE[key]


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new: int = 16
    temperature: float = 0.0
    rid: int = 0
    # per-request completion budget in ticks from submit (overrides
    # ResilConfig.deadline_ticks; None = use the session default)
    deadline_ticks: Optional[int] = None


@dataclasses.dataclass
class Result:
    rid: int
    tokens: List[int]


def _unserved_record(req: "Request") -> dict:
    """Lifecycle record for a request that never reached submit() —
    same schema as Session.submit's records, terminal state 'unserved'."""
    return {"rid": req.rid, "prompt_len": len(req.prompt),
            "max_new": req.max_new, "submit_step": None,
            "submit_time": None, "admit_step": None, "admit_time": None,
            "first_token_step": None, "first_token_time": None,
            "finish_time": None, "n_generated": 0, "preemptions": 0,
            "prefix_pages": 0, "state": "unserved",
            "failed_reason": None, "retries": 0}


class Session:
    def __init__(self, cfg: ArchConfig, params, batch_slots: int = 4,
                 max_len: int = 256, seed: int = 0,
                 backend: Optional[Executor] = None,
                 kv_cache: Optional[str] = None, page_size: int = 16,
                 kv_pool_pages: Optional[int] = None,
                 kv_dtype: Optional[str] = None,
                 scheduler=None, plan=None, resil=None, obs=None):
        assert cfg.has_decode, "encoder archs don't serve autoregressively"
        from repro.models import model as M
        self.cfg, self.params = cfg, params
        self.plan = plan
        self._param_sh = None
        if plan is not None:
            # shard-aware stacking: compressed leaves are padded to the
            # tp degree and placed per the plan; raw leaves get their
            # Megatron TP shardings (replicated over data for serving)
            from repro import shard as shardmod
            self.params, self._param_sh = shardmod.prepare_params(
                plan, cfg, params)
        self.slots = batch_slots
        self.max_len = max_len
        kv_cache = resolve_kv_cache(kv_cache, cfg)
        if cfg.family == "rwkv6":
            kv_cache = "full"      # attention-free: nothing to page
        self.kv_cache = kv_cache
        self.page_size = page_size
        self.kv_dtype = kv_dtype or KV_DTYPE_DEFAULT
        self.sched = schd.Scheduler(schd.SchedConfig.coerce(scheduler))
        # chunked prefill needs pages to write into and attention-only
        # token mixing; elsewhere prompts feed token-by-token
        self.chunk = self.sched.cfg.chunk if (
            kv_cache == "paged"
            and schd.supports_chunked_prefill(cfg)) else 1
        if kv_cache == "paged":
            self.state = M.init_decode_state(
                cfg, batch_slots, max_len, kv_cache="paged",
                page_size=page_size, kv_pool_pages=kv_pool_pages,
                kv_dtype=self.kv_dtype)
            n_pages = jax.tree.leaves(
                self.state["layers"]["kv"])[0].shape[1]
            self.alloc = kvs.PageAllocator(n_pages)
            # host mirror of the device page table (allocation decisions
            # never read device memory back)
            self.host_table = np.full(
                (batch_slots, self.state["page_table"].shape[1]), -1,
                np.int64)
            self.slot_pos = [0] * batch_slots
            wins = cfg.layer_windows()
            # page reclamation is safe only when EVERY layer is windowed
            # (one global layer pins the whole history, like the dense
            # path's ring-vs-full split)
            self._swa_window = max(wins) if wins and all(
                w > 0 for w in wins) else None
            self.prefix = schd.PrefixCache() \
                if self.sched.cfg.prefix_cache else None
        else:
            self.state = M.init_decode_state(cfg, batch_slots, max_len)
            self.alloc = None
            self.prefix = None
        self.key = jax.random.PRNGKey(seed)
        if backend is None or isinstance(backend, str):
            backend = get_backend(backend or "jax-dense")
        self.backend = backend
        if plan is not None:
            # mesh session: KV heads shard over the model axis, page
            # table/pos replicate, and the step compiles with explicit
            # input/output shardings so the donated state buffers keep
            # their placement (no silent gathers/copies per step)
            self._state_sh = plan.state_shardings(self.state)
            self.state = jax.device_put(self.state, self._state_sh)
            rep = plan.replicated()
            step = backend.make_decode_step(cfg, plan=plan)
            self._step = jax.jit(
                step,
                in_shardings=(self._param_sh, self._state_sh, rep),
                out_shardings=(self._state_sh, rep),
                donate_argnums=(1,))
            self._prefill = schd.make_prefill_step(
                cfg, self.chunk, plan=plan,
                in_shardings=(self._param_sh, self._state_sh, rep, rep),
                out_shardings=(self._state_sh, rep)) \
                if self.chunk > 1 else None
        else:
            self._state_sh = None
            self._step = _jitted_step(backend, cfg)
            self._prefill = schd.make_prefill_step(cfg, self.chunk) \
                if self.chunk > 1 else None
        # per-slot bookkeeping (host side)
        self.slot_entry: List[Optional[schd.SchedEntry]] = \
            [None] * batch_slots
        self.slot_pending: List[List[int]] = [[] for _ in range(batch_slots)]
        self.slot_out: List[List[int]] = [[] for _ in range(batch_slots)]
        self.slot_cache_j: List[int] = [0] * batch_slots
        self.results: List[Result] = []
        self.failed: List[rsl.RequestFailed] = []
        self.records: List[dict] = []
        # resilience layer: None (default) is the exact pre-resil path;
        # a ResilState may be shared across roles (disagg) so counters
        # aggregate in one place
        if resil is None or isinstance(resil, rsl.ResilState):
            self.resil = resil
        else:
            self.resil = rsl.ResilState(rsl.ResilConfig.coerce(resil))
        self.role = "engine"       # disagg roles override ("prefill"/...)
        self.tick = 0              # scheduling-opportunity clock
        self.stats = {"steps": 0, "fills": 0, "preemptions": 0,
                      "chunk": self.chunk}
        if kv_cache == "paged":
            self.stats.update({"page_allocs": 0, "pages_in_use": 0,
                               "pages_peak": 0, "pages_reclaimed_swa": 0,
                               "prefix_hits": 0, "prefix_pages_reused": 0})
        # observability: obs.NULL keeps every seam on the exact pre-obs
        # path (hooks stay None, emits are no-ops); a live obs.Tracer
        # wires the allocator / prefix / scheduler / resil seams so the
        # tick-clock event stream covers the whole request lifecycle
        self.tracer = obs if obs is not None else obs_mod.NULL
        if self.tracer.enabled:
            self._wire_obs()

    def _wire_obs(self) -> None:
        """Attach this session's tracer to the host-side seams.  The
        hook reads ``self.role`` / ``self.tick`` at emit time, so disagg
        roles renamed after construction stamp correctly."""
        def hook(name, **args):
            self.tracer.instant(name, tick=self.tick, role=self.role,
                                **args)
        if self.alloc is not None:
            self.alloc.obs = hook
        if self.prefix is not None:
            self.prefix.obs = hook
        self.sched.obs = hook
        if self.resil is not None:
            if self.resil.degrade is not None:
                self.resil.degrade.obs = hook
            if self.resil.watchdog is not None:
                self.resil.watchdog.obs = hook

    def _step_ctx(self, phase: str):
        """Wall-clock phase accounting around the jitted step (tracing
        on only); wall times never enter the tick-clock event stream."""
        if not self.tracer.enabled:
            return contextlib.nullcontext()
        return self.tracer.wall.phase(phase)

    # ------------------------------------------------------------ public
    def submit(self, req: Request) -> None:
        entry = self.sched.submit(req, step=self.stats["steps"],
                                  now=time.perf_counter())
        if self.kv_cache == "paged":
            entry.hashes = schd.page_hashes(req.prompt, self.page_size)
        rec = {"rid": req.rid, "prompt_len": len(req.prompt),
               "max_new": req.max_new, "submit_step": entry.submit_step,
               "submit_time": entry.submit_time, "admit_step": None,
               "admit_time": None, "first_token_step": None,
               "first_token_time": None, "finish_time": None,
               "n_generated": 0, "preemptions": 0, "prefix_pages": 0,
               "state": "queued", "failed_reason": None, "retries": 0}
        entry.record = rec
        self.records.append(rec)
        if self.resil is not None:
            entry.deadline_tick = self.resil.deadline_for(req, self.tick)
            rec["deadline_tick"] = entry.deadline_tick
        self.tracer.instant("req.submit", tick=self.tick, role=self.role,
                            rid=req.rid, prompt_len=len(req.prompt),
                            max_new=req.max_new)

    def run(self, max_steps: int = 10_000,
            on_incomplete: str = "raise") -> List[Result]:
        """Drain the queue; returns all results in deterministic rid
        order.  ``on_incomplete``: what to do when ``max_steps`` is
        exhausted (or admission deadlocks) with requests still queued or
        in flight — "raise" (default), "warn" (report partial results),
        or "ignore"."""
        return self.run_workload([], max_steps=max_steps,
                                 on_incomplete=on_incomplete)

    def run_workload(self, arrivals: Sequence[Tuple[int, Request]],
                     max_steps: int = 10_000,
                     on_incomplete: str = "raise") -> List[Result]:
        """Serve timed traffic: ``arrivals`` is [(arrival_step, Request)]
        (see sched.workload); requests already submit()ed count as
        step-0 arrivals.  Idle gaps fast-forward the step clock.

        A ``HealthError`` or ``OutOfPages`` escaping the loop dumps the
        flight recorder (when one is attached) before re-raising, so
        chaos-sweep crashes leave a post-mortem on disk."""
        try:
            return self._run_loop(arrivals, max_steps, on_incomplete)
        except (rsl.HealthError, kvs.OutOfPages) as e:
            self.tracer.crash(type(e).__name__, role=self.role,
                              tick=self.tick, error=str(e))
            raise

    def _run_loop(self, arrivals: Sequence[Tuple[int, Request]],
                  max_steps: int,
                  on_incomplete: str) -> List[Result]:
        pending: Deque[Tuple[int, Request]] = collections.deque(
            sorted(arrivals, key=lambda a: a[0]))
        # the arrival clock mirrors the model-call count but can jump
        # forward over idle gaps; stats["steps"] stays honest (executed
        # model calls only)
        clock = self.stats["steps"]
        for _ in range(max_steps):
            self.tick = clock
            while pending and pending[0][0] <= clock:
                self.submit(pending.popleft()[1])
            if self.resil is not None:
                self._resil_tick(clock)
            self._fill_slots()
            if all(e is None for e in self.slot_entry):
                if self._fault_waiting():
                    # an injected page spike is holding the pool hostage;
                    # burn the tick so the window can pass instead of
                    # misreading it as an admission deadlock
                    self.resil.count("wait_ticks")
                    clock += 1
                    continue
                if len(self.sched):
                    self._incomplete(on_incomplete, blocked=True,
                                     pending=pending)
                    break
                if pending:        # idle until the next arrival
                    clock = pending[0][0]
                    continue
                break
            try:
                self._advance()
            except rsl.InjectedFault as f:
                # deliberately injected step failure (role-stall /
                # straggler): the tick is lost, the work is not
                self.resil.count("fault_steps")
                self.tracer.instant("fault.injected", tick=self.tick,
                                    role=self.role,
                                    fault=f.fault_class)
            except kvs.OutOfPages:
                if self.resil is not None and self.alloc is not None \
                        and self.alloc.holdback > 0:
                    # page-spike squeezed even the last runner; wait the
                    # window out (pages come back, recompute resumes)
                    self.resil.count("wait_ticks")
                else:
                    raise
            clock += 1
        else:
            self._incomplete(on_incomplete, blocked=False, pending=pending)
        return sorted(self.results, key=lambda r: r.rid)

    # ----------------------------------------------------------- internals
    def _incomplete(self, on_incomplete: str, blocked: bool,
                    pending: Sequence[Tuple[int, Request]] = ()) -> None:
        live = [e for e in self.slot_entry if e is not None]
        live += list(self.sched.queue)
        # terminal lifecycle state for everything that never finished —
        # including arrivals still pending at max_steps exhaustion, which
        # previously left no record at all (metrics denominators lied)
        for e in live:
            if e.record is not None and e.record.get("state") == "queued":
                e.record["state"] = "unserved"
        for _, req in pending:
            self.records.append(_unserved_record(req))
        unfinished = [e.req.rid for e in live]
        unfinished += [req.rid for _, req in pending]  # never submitted
        if not unfinished or on_incomplete == "ignore":
            return
        why = ("admission blocked (page pool too small for the "
               "head-of-line request's worst-case need)" if blocked
               else "max_steps exhausted")
        msg = (f"Session.run stopped with {len(unfinished)} unfinished "
               f"request(s) {sorted(unfinished)}: {why}; "
               f"{len(self.results)} completed")
        if on_incomplete == "warn":
            warnings.warn(msg, RuntimeWarning, stacklevel=3)
            return
        raise kvs.OutOfPages(msg) if blocked else RuntimeError(msg)

    # ------------------------------------------------------- resil layer
    def resil_summary(self) -> Optional[dict]:
        """Shed/retry/deadline-miss/fault counters, or None when the
        resilience layer is off."""
        return None if self.resil is None else self.resil.summary()

    def _fault_waiting(self) -> bool:
        """True when idleness is an injected condition (page spike), not
        an admission deadlock — the caller should burn the tick."""
        return (self.resil is not None and self.alloc is not None
                and self.alloc.holdback > 0)

    def _resil_tick(self, tick: int) -> None:
        """Per-tick policy: apply the fault plan's page holdback, expire
        deadlines, shed load past the watermark, walk the degradation
        ladder, run the watchdog audit."""
        r = self.resil
        if r.plan is not None and self.alloc is not None:
            self.alloc.holdback = r.plan.page_holdback(
                self.alloc.n_pages - 1, tick, role=self.role)
        self._expire_queue_deadlines(tick)
        self._expire_slot_deadlines(tick)
        if r.cfg.shed_watermark is not None and self.alloc is not None:
            self._shed_load()
        if r.degrade is not None and self.alloc is not None:
            usable = max(1, self.alloc.n_pages - 1)
            if r.degrade.update(self.alloc.available / usable) >= 1 \
                    and self.prefix is not None:
                self.prefix.release(self.alloc, 1)  # L1: drop LRU pins
        if r.watchdog is not None and r.watchdog.due(tick):
            r.count("watchdog_audits")
            r.watchdog.audit(self)

    def _expire_queue_deadlines(self, tick: int) -> None:
        for e in self.sched.pop_expired(tick):
            self.resil.count("deadline_miss")
            self._fail_entry(e, "deadline")

    def _expire_slot_deadlines(self, tick: int) -> None:
        for i, entry in enumerate(self.slot_entry):
            if entry is None or entry.deadline_tick is None \
                    or tick <= entry.deadline_tick:
                continue
            entry.out = list(self.slot_out[i])
            if self.kv_cache == "paged":
                self._release_slot_pages(i)
            self.slot_entry[i] = None
            self.slot_pending[i] = []
            self.slot_out[i] = []
            self.resil.count("deadline_miss")
            self._fail_entry(entry, "deadline")

    def _shed_load(self) -> None:
        """Reject never-admitted queued work, youngest first, while the
        queue's summed worst-case page need exceeds the watermark
        fraction of the usable pool."""
        r = self.resil
        limit = r.cfg.shed_watermark * max(1, self.alloc.n_pages - 1)
        total = sum(self._page_need(e) for e in self.sched.queue)
        while total > limit:
            e = self.sched.shed_youngest()
            if e is None:
                break
            total -= self._page_need(e)
            r.count("shed")
            self.tracer.instant("sched.shed", tick=self.tick,
                                role=self.role, rid=e.req.rid)
            self._fail_entry(e, "shed")

    def _fail_entry(self, entry: schd.SchedEntry, reason: str) -> None:
        """Terminal structured failure: the request leaves the system as
        a RequestFailed result, never an unhandled exception."""
        rec = entry.record
        if rec is not None:
            rec["state"] = "failed"
            rec["failed_reason"] = reason
            rec["retries"] = entry.retries
            rec["n_generated"] = len(entry.out)
        self.failed.append(rsl.RequestFailed(
            rid=entry.req.rid, reason=reason, tokens=list(entry.out),
            retries=entry.retries))
        if self.resil is not None:
            self.resil.count("failed")
        self.tracer.instant("resil.fail", tick=self.tick, role=self.role,
                            rid=entry.req.rid, reason=reason,
                            retries=entry.retries)
        # flight-recorder post-mortem: the ticks leading up to the failure
        self.tracer.crash(f"RequestFailed_{reason}",
                          rid=entry.req.rid, why=reason,
                          role=self.role, tick=self.tick)

    def _page_need(self, entry: schd.SchedEntry) -> int:
        req = entry.req
        return schd.scheduler.page_need(
            len(req.prompt) + len(entry.out), req.max_new - len(entry.out),
            self.max_len, self.page_size)

    def _prefix_hit_pids(self, entry: schd.SchedEntry) -> List[int]:
        """Page ids of the leading full prompt pages this entry could
        attach from the prefix cache right now (pure lookup, no refs)."""
        if self.prefix is None:
            return []
        n = schd.prefix.usable_prefix_pages(len(entry.req.prompt),
                                            self.page_size)
        pids: List[int] = []
        for j in range(min(n, self.host_table.shape[1])):
            pid = self.prefix.peek(entry.hashes[j])
            if pid is None:
                break
            pids.append(pid)
        return pids

    def _fits(self, entry: schd.SchedEntry) -> bool:
        if self.kv_cache != "paged":
            return True            # dense cache: slots are pre-allocated
        hits = self._prefix_hit_pids(entry)
        avail = self.alloc.available
        if self.prefix is not None:
            # cache pins can be released under pressure; count the pages
            # only the cache still holds as effectively available — but
            # NOT the pages this entry would itself attach (releasing
            # those frees nothing once the slot holds a ref)
            avail += self.prefix.releasable(self.alloc, exclude=hits)
        return self._page_need(entry) - len(hits) <= avail

    def _fill_slots(self):
        for i in range(self.slots):
            if self.slot_entry[i] is not None:
                continue
            entry = self.sched.next_entry(self._fits,
                                          step=self.stats["steps"])
            if entry is None:
                break
            self._admit(i, entry)

    def _admit(self, i: int, entry: schd.SchedEntry):
        req = entry.req
        now = time.perf_counter()
        rec = entry.record
        if rec["admit_step"] is None:
            rec["admit_step"] = self.stats["steps"]
            rec["admit_time"] = now
        if self.resil is not None and self.resil.degrade is not None \
                and self.resil.degrade.kv_demote and not rec.get("degraded"):
            # L2 degradation: this admission would get int8 KV in the next
            # session generation (pool dtype is fixed per live session)
            rec["degraded"] = True
            self.resil.count("degraded_admissions")
        self.tracer.instant("sched.admit", tick=self.tick, role=self.role,
                            slot=i, rid=req.rid,
                            resumed=len(entry.out))
        self.slot_entry[i] = entry
        # recompute resume: a preempted request re-prefills its prompt
        # PLUS its generated-so-far tokens, then continues sampling
        self.slot_pending[i] = list(req.prompt) + list(entry.out)
        self.slot_out[i] = list(entry.out)
        self._reset_slot_state(i)
        self.stats["fills"] += 1
        if self.kv_cache != "paged":
            return
        self.slot_cache_j[i] = 0
        if self.prefix is not None:
            self._attach_prefix(i, entry)

    def _attach_prefix(self, i: int, entry: schd.SchedEntry):
        """Reuse cached prefix pages: attach their ids into this slot's
        table rows and skip the covered prompt tokens."""
        n = schd.prefix.usable_prefix_pages(len(entry.req.prompt),
                                            self.page_size)
        attached: List[Tuple[int, int]] = []           # (table_j, pid)
        for j in range(min(n, self.host_table.shape[1])):
            pid = self.prefix.lookup(entry.hashes[j])
            if pid is None:
                break
            self.alloc.ref(pid)
            self.host_table[i, j] = pid
            attached.append((j, pid))
        if not attached:
            return
        pj = jnp.asarray([a[0] for a in attached], jnp.int32)
        pids = jnp.asarray([a[1] for a in attached], jnp.int32)
        self.state["page_table"] = \
            self.state["page_table"].at[i, pj].set(pids)
        skip = len(attached) * self.page_size
        self.slot_pending[i] = self.slot_pending[i][skip:]
        self.slot_pos[i] = skip
        self.state["pos"] = self.state["pos"].at[i].set(skip)
        self.slot_cache_j[i] = len(attached)
        entry.prefix_pages += len(attached)
        entry.record["prefix_pages"] += len(attached)
        self.stats["prefix_hits"] += 1
        self.stats["prefix_pages_reused"] += len(attached)
        self.stats["pages_in_use"] = self.alloc.in_use

    def _reset_slot_state(self, i: int):
        def zero_slot(x):
            if x.ndim >= 2 and x.shape[1] == self.slots:  # [L, B, ...]
                return x.at[:, i].set(jnp.zeros_like(x[:, i]))
            return x
        if self.kv_cache == "paged":
            # pool pages are shared, not slot-indexed: release the slot's
            # pages (idempotent — already freed at request completion) and
            # zero only the slot-shaped leaves (mamba conv/h etc.).  Stale
            # page contents are harmless: the position mask never reaches
            # unwritten slots and scales reset on re-allocation.
            self._release_slot_pages(i)
            layers = dict(self.state["layers"])
            kv = layers.pop("kv")
            layers = jax.tree.map(zero_slot, layers)
            layers["kv"] = kv
            self.state = {"layers": layers,
                          "pos": self.state["pos"].at[i].set(0),
                          "page_table": self.state["page_table"]}
            self.slot_pos[i] = 0
            return
        layers = jax.tree.map(zero_slot, self.state["layers"])
        pos = self.state["pos"].at[i].set(0)
        # empty cache slots must read as "never written": pos fields are -1
        if self.cfg.family not in ("rwkv6",):
            layers = dict(layers)
            kv = layers["kv"]
            layers["kv"] = kv._replace(
                pos=kv.pos.at[:, i].set(-jnp.ones_like(kv.pos[:, i])))
        self.state = {"layers": layers, "pos": pos}

    # ------------------------------------------------------ paged KV admin
    def _release_slot_pages(self, i: int) -> None:
        """Free every page owned by slot ``i`` (request done / slot reset /
        preemption).  Shared prefix pages just lose this slot's ref."""
        pages = [int(p) for p in self.host_table[i] if p >= 0]
        if not pages:
            return
        self.alloc.free(pages)
        self.host_table[i] = -1
        self.state["page_table"] = self.state["page_table"].at[i].set(
            jnp.int32(kvs.NO_PAGE))
        self.stats["pages_in_use"] = self.alloc.in_use

    def _preempt_slot(self, i: int) -> None:
        """Evict slot ``i`` back to the queue front: pages freed now,
        tokens regenerated on re-admission (recompute resume)."""
        entry = self.slot_entry[i]
        entry.out = list(self.slot_out[i])
        entry.record["preemptions"] += 1
        self.tracer.instant("sched.preempt", tick=self.tick,
                            role=self.role, slot=i, rid=entry.req.rid,
                            generated=len(entry.out))
        self._release_slot_pages(i)
        self.slot_entry[i] = None
        self.slot_pending[i] = []
        self.slot_out[i] = []
        self.sched.requeue(entry)
        self.stats["preemptions"] += 1

    def _ensure_pages(self, counts: List[int]) -> None:
        """Host-side page faults: before a step, make sure each active
        slot owns every page its next ``counts[i]`` tokens land in; fresh
        pages get their quantization scales cleared so stale maxima can't
        poison them."""
        npp = self.host_table.shape[1]
        events = []
        try:
            for i, entry in enumerate(self.slot_entry):
                if entry is None or counts[i] == 0:
                    continue
                lo = self.slot_pos[i] // self.page_size
                hi = (self.slot_pos[i] + counts[i] - 1) // self.page_size
                for pi in range(lo, min(hi, npp - 1) + 1):
                    if pi >= npp or self.host_table[i, pi] >= 0:
                        continue   # beyond max_len (clamped) / present
                    pid = self.alloc.alloc()
                    self.host_table[i, pi] = pid
                    events.append((i, pi, pid))
        except kvs.OutOfPages:
            # transactional: roll back this round's host-side grants so a
            # caller that drains requests and retries never sees a page
            # recorded host-side but absent from the device table
            for i, pi, pid in events:
                self.host_table[i, pi] = -1
            self.alloc.free(pid for _, _, pid in events)
            raise
        if not events:
            return
        si, pi, pids = (jnp.asarray([e[n] for e in events], jnp.int32)
                        for n in range(3))
        self.state["page_table"] = \
            self.state["page_table"].at[si, pi].set(pids)
        kv = self.state["layers"]["kv"]
        if kv.k_scale is not None:
            kv = kv._replace(k_scale=kv.k_scale.at[:, pids].set(0.0),
                             v_scale=kv.v_scale.at[:, pids].set(0.0))
            layers = dict(self.state["layers"])
            layers["kv"] = kv
            self.state["layers"] = layers
        self.stats["page_allocs"] = self.alloc.total_allocs
        self.stats["pages_in_use"] = self.alloc.in_use
        self.stats["pages_peak"] = self.alloc.peak

    def _ensure_pages_or_preempt(self, counts: List[int]) -> None:
        """Resolve page pressure: allocate; on OutOfPages release prefix
        pins LRU-first, then preempt the youngest slot, until the
        remaining batch fits.  The last runner is never preempted — a
        pool too small for a single request still raises."""
        while True:
            try:
                self._ensure_pages(counts)
                return
            except kvs.OutOfPages:
                if self.prefix is not None \
                        and self.prefix.release(self.alloc, 1):
                    continue
                victim = schd.Scheduler.choose_victim(self.slot_entry)
                if victim is None:
                    raise
                self._preempt_slot(victim)
                counts[victim] = 0

    def _reclaim_swa_pages(self) -> None:
        """On pure-SWA archs, free pages that slid fully behind the widest
        layer window — decode memory stays O(window), page-granular."""
        if self._swa_window is None:
            return
        events = []
        for i, entry in enumerate(self.slot_entry):
            if entry is None:
                continue
            dead = kvs.reclaimable_prefix(self.slot_pos[i],
                                          self._swa_window, self.page_size)
            for pi in range(min(dead, self.host_table.shape[1])):
                pid = int(self.host_table[i, pi])
                if pid >= 0:
                    self.alloc.free([pid])
                    self.host_table[i, pi] = -1
                    events.append((i, pi))
        if not events:
            return
        si = jnp.asarray([e[0] for e in events], jnp.int32)
        pi = jnp.asarray([e[1] for e in events], jnp.int32)
        self.state["page_table"] = self.state["page_table"].at[si, pi].set(
            jnp.int32(kvs.NO_PAGE))
        self.stats["pages_reclaimed_swa"] += len(events)
        self.stats["pages_in_use"] = self.alloc.in_use

    def _insert_slot_prefix(self, i: int, entry: schd.SchedEntry) -> None:
        """Pin slot ``i``'s freshly-completed full prompt pages into the
        prefix cache (first writer wins; generated-token pages are never
        cached).  Also called by the disagg prefill role right before a
        handoff, when the slot's entry reference is already detached."""
        n_full = len(entry.req.prompt) // self.page_size
        j = self.slot_cache_j[i]
        while j < min(n_full, self.host_table.shape[1]) \
                and self.slot_pos[i] >= (j + 1) * self.page_size:
            pid = int(self.host_table[i, j])
            if pid >= 0:           # may be gone (SWA reclamation)
                self.prefix.insert(entry.hashes[j], pid, self.alloc)
            j += 1
        self.slot_cache_j[i] = j

    def _insert_prefix_pages(self) -> None:
        if self.prefix is None:
            return
        for i, entry in enumerate(self.slot_entry):
            if entry is not None:
                self._insert_slot_prefix(i, entry)

    # ------------------------------------------------------------ stepping
    def _advance(self):
        if self.resil is not None and self.resil.plan is not None:
            # fault seam: a stalled/straggling role loses the whole tick
            # (raises InjectedFault before any state is touched)
            self.resil.plan.check_step(self.role, self.tick)
        if self.chunk > 1 and any(self.slot_pending[i]
                                  for i, e in enumerate(self.slot_entry)
                                  if e is not None):
            self._advance_chunked()
        else:
            self._advance_decode()
        if self.kv_cache == "paged":
            self._reclaim_swa_pages()
            self._insert_prefix_pages()

    def _active_counts(self, chunk: int) -> List[int]:
        counts = [0] * self.slots
        for i, entry in enumerate(self.slot_entry):
            if entry is None:
                continue
            counts[i] = min(chunk, len(self.slot_pending[i])) \
                if self.slot_pending[i] else 1
        return counts

    def _advance_decode(self):
        """One token per active slot through the backend's decode step."""
        counts = self._active_counts(1)
        if self.kv_cache == "paged":
            self._ensure_pages_or_preempt(counts)
        tokens = np.zeros((self.slots,), np.int32)
        for i, entry in enumerate(self.slot_entry):
            if entry is None:
                continue
            if self.slot_pending[i]:
                tokens[i] = self.slot_pending[i][0]
            elif self.slot_out[i]:
                tokens[i] = self.slot_out[i][-1]
            else:
                tokens[i] = entry.req.prompt[-1]
        with self._step_ctx("decode"):
            self.state, logits = self._step(self.params, self.state,
                                            jnp.asarray(tokens))
        self.stats["steps"] += 1
        self.tracer.span("step.decode", tick=self.tick, role=self.role,
                         active=sum(1 for c in counts if c),
                         step=self.stats["steps"])
        now = time.perf_counter()
        if self.kv_cache == "paged":
            for i, entry in enumerate(self.slot_entry):
                if entry is not None:
                    self.slot_pos[i] += 1
        logits = np.asarray(logits[:, : self.cfg.vocab])
        for i, entry in enumerate(self.slot_entry):
            if entry is None:
                continue
            if self.slot_pending[i]:
                self.slot_pending[i].pop(0)
                if self.slot_pending[i]:
                    continue  # still prefilling
            self._emit(i, logits[i], now)

    def _advance_chunked(self):
        """Mixed prefill+decode step: up to ``chunk`` prompt tokens per
        prefilling slot, 1 token per decoding slot, all in one call."""
        counts = self._active_counts(self.chunk)
        self._ensure_pages_or_preempt(counts)
        tokens = np.zeros((self.slots, self.chunk), np.int32)
        for i, entry in enumerate(self.slot_entry):
            if entry is None:
                continue
            if self.slot_pending[i]:
                k = counts[i]
                tokens[i, :k] = self.slot_pending[i][:k]
            elif self.slot_out[i]:
                tokens[i, 0] = self.slot_out[i][-1]
            else:
                tokens[i, 0] = entry.req.prompt[-1]
        with self._step_ctx("prefill"):
            self.state, logits = self._prefill(
                self.params, self.state, jnp.asarray(tokens),
                jnp.asarray(counts, jnp.int32))
        self.stats["steps"] += 1
        self.tracer.span("step.prefill", tick=self.tick, role=self.role,
                         active=sum(1 for c in counts if c),
                         tokens=sum(counts), step=self.stats["steps"])
        now = time.perf_counter()
        for i, entry in enumerate(self.slot_entry):
            if entry is not None:
                self.slot_pos[i] += counts[i]
        logits = np.asarray(logits[:, :, : self.cfg.vocab])
        for i, entry in enumerate(self.slot_entry):
            if entry is None:
                continue
            if self.slot_pending[i]:
                del self.slot_pending[i][:counts[i]]
                if self.slot_pending[i]:
                    continue  # still prefilling
            self._emit(i, logits[i, counts[i] - 1], now)

    def _emit(self, i: int, logits_i: np.ndarray, now: float):
        """Sample the next token for slot ``i`` from this step's logits;
        finish the request when max_new is reached."""
        entry = self.slot_entry[i]
        req = entry.req
        if req.temperature > 0:
            self.key, sub = jax.random.split(self.key)
            nxt = int(jax.random.categorical(
                sub, jnp.asarray(logits_i) / req.temperature))
        else:
            nxt = int(logits_i.argmax())
        self.slot_out[i].append(nxt)
        rec = entry.record
        if rec["first_token_time"] is None:
            rec["first_token_time"] = now
            rec["first_token_step"] = self.stats["steps"]
            self.tracer.instant("req.first_token", tick=self.tick,
                                role=self.role, slot=i, rid=req.rid)
        if len(self.slot_out[i]) >= req.max_new:
            self.results.append(Result(req.rid, self.slot_out[i]))
            rec["finish_time"] = now
            rec["n_generated"] = len(self.slot_out[i])
            rec["state"] = "completed"
            self.tracer.instant("req.finish", tick=self.tick,
                                role=self.role, slot=i, rid=req.rid,
                                tokens=len(self.slot_out[i]))
            self.slot_entry[i] = None
            if self.kv_cache == "paged":
                # return pages eagerly — don't wait for a refill
                self._release_slot_pages(i)
