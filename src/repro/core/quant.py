"""Scalar quantization utilities — the wordlength axis of Fig. 5(b).

AIDA's bit-serial arithmetic makes runtime quadratic in wordlength, so the
paper sweeps precision (binary/ternary → 16-bit). On TPU wordlength becomes a
storage/bandwidth axis: int8 (MXU-native), int4-codebook (see codebook.py) and
ternary are supported per layer; bf16 is the dense baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass
class QTensor:
    """Symmetric per-channel quantized tensor: w ≈ q * scale."""
    q: jnp.ndarray        # int8 (or int4 range stored in int8) [..., n]
    scale: jnp.ndarray    # f32, broadcastable to q
    bits: int

    @property
    def shape(self):
        return self.q.shape


def quantize_int(w: jnp.ndarray, bits: int = 8,
                 axis: Optional[int] = 0) -> QTensor:
    """Symmetric per-channel (along ``axis``) integer quantization."""
    qmax = 2 ** (bits - 1) - 1
    if axis is None:
        amax = jnp.max(jnp.abs(w))
    else:
        amax = jnp.max(jnp.abs(w), axis=tuple(i for i in range(w.ndim)
                                              if i != axis), keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax).astype(jnp.int8)
    return QTensor(q=q, scale=scale.astype(jnp.float32), bits=bits)


def dequantize_int(t: QTensor) -> jnp.ndarray:
    return t.q.astype(jnp.float32) * t.scale


def quantize_ternary(w: jnp.ndarray) -> QTensor:
    """Ternary {-1, 0, +1}·scale with 0.7·mean|w| threshold (TWN)."""
    thresh = 0.7 * jnp.mean(jnp.abs(w))
    mask = jnp.abs(w) > thresh
    scale = jnp.sum(jnp.abs(w) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    q = jnp.sign(w) * mask
    return QTensor(q=q.astype(jnp.int8), scale=scale[None], bits=2)


def int8_matmul_ref(x: jnp.ndarray, t: QTensor) -> jnp.ndarray:
    """x @ dequant(W)^T with the dequant folded after the int accumulate."""
    acc = jnp.matmul(x.astype(jnp.float32), t.q.astype(jnp.float32).T)
    return acc * t.scale.reshape(1, -1)
