"""H2O-Danube-1.8B — llama architecture + mistral sliding window.
[arXiv:2401.16818; hf:h2oai/h2o-danube-1.8b-base]"""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv=8, d_ff=6912,
    vocab=32000, d_head=80, window=4096, rope_theta=10000.0,
    tie_embeddings=False, source="arXiv:2401.16818"))
