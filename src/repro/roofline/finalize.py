"""Assemble the final EXPERIMENTS.md sections from all recorded jsonls."""
from __future__ import annotations

import glob
import json
import sys

from repro.roofline.report import (ARCH_ORDER, SHAPE_ORDER, dryrun_table,
                                   load, roofline_table)


def perf_section():
    out = ["\n## §Perf — measured iterations\n"]
    cells = [
        ("(a) qwen1.5-0.5b × train_4k (worst train roofline fraction)",
         ["hillclimb_qwen_train.jsonl", "hillclimb_qwen_train_bf16psum.jsonl"]),
        ("(c) llama3-8b × decode_32k (paper-representative serving)",
         ["hillclimb_llama3_decode.jsonl",
          "hillclimb_llama3_decode_bf16psum.jsonl"]),
        ("(b) h2o-danube-1.8b × decode_32k (most collective-bound)",
         ["hillclimb_danube_decode.jsonl",
          "hillclimb_danube_decode_bf16psum.jsonl"]),
    ]
    for title, files in cells:
        rows = []
        for f in files:
            for path in glob.glob(f):
                for line in open(path):
                    rows.append(json.loads(line))
        if not rows:
            continue
        out.append(f"### {title}\n")
        out.append("| variant | compute ms | memory ms | collective ms | "
                   "temp GiB/dev | roofline frac |")
        out.append("|---|---|---|---|---|---|")
        for r in rows:
            v = r.get("variant", "?")
            out.append(
                f"| {v} | {r['t_compute']*1e3:.2f} | {r['t_memory']*1e3:.2f}"
                f" | {r['t_collective']*1e3:.2f} | "
                f"{r['temp_bytes']/2**30:.2f} | "
                f"{r['roofline_frac']:.2%} |")
        out.append("")
    return "\n".join(out)


def main():
    recs = load("dryrun_baseline.jsonl")
    n_ok = sum(1 for r in recs.values() if r["status"] == "ok")
    n_skip = sum(1 for r in recs.values() if r["status"] == "skip")
    parts = []
    parts.append(f"\n\n## §Dry-run table — multi-pod (2,16,16)=512 chips "
                 f"[{n_ok} ok / {n_skip} documented skips of "
                 f"{len(recs)} recorded]\n")
    parts.append(dryrun_table(recs, "multi"))
    parts.append("\n\n## §Dry-run table — single-pod (16,16)=256 chips\n")
    parts.append(dryrun_table(recs, "single"))
    parts.append("\n\n## §Roofline table — single-pod, per-chip terms\n")
    parts.append(roofline_table(recs, "single"))
    parts.append(perf_section())
    text = "\n".join(parts)
    if len(sys.argv) > 1 and sys.argv[1] == "--append":
        with open("EXPERIMENTS.md", "a") as f:
            f.write(text)
        print("appended to EXPERIMENTS.md")
    else:
        print(text)


if __name__ == "__main__":
    main()
