"""repro.obs.analyze: trace analytics, SLO gating, capacity planning.

Hand-built synthetic traces with known answers pin the critical-path
state machine (single request, preempted+retried, disagg handoff with
an injected drop); real same-seed serves pin the golden byte-identity
property (two runs -> byte-identical TraceReport JSON, and a Chrome
export round-trips to the same report).  Also: SLOSpec parsing and
violator naming, ``WorkloadSpec.from_trace`` record/replay, the
flight-recorder dump-collision fix, ``benchmarks/validate_trace.py``
exit codes per failure class, and ``Engine.capacity_benchmark``
deterministically naming the smallest SLO-meeting config.
"""
import dataclasses
import json
import pathlib
import subprocess
import sys

import jax
import pytest

from repro import obs
from repro import sched as schd
from repro.api.session import Session
from repro.configs import get, reduced
from repro.models import model as M
from repro.obs.analyze import SLOSpec, TraceReport, analyze

CFG = reduced(get("llama3-8b"), n_layers=2, d_model=64, d_ff=128,
              vocab=256)
PS = 4
ML = 48
REPO = pathlib.Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def burst_arrivals(n=5, seed=0):
    wl = schd.WorkloadSpec.preset("burst", n_requests=n, vocab=CFG.vocab,
                                  seed=seed)
    return schd.generate(wl)


def replay(arrivals):
    return [(t, dataclasses.replace(r)) for t, r in arrivals]


def ev(name, tick, role="engine", slot=None, **args):
    """A tracer-internal event dict (what a live Tracer holds)."""
    return {"name": name, "ph": "i", "tick": tick, "role": role,
            "slot": slot, "args": args}


def traced_session(params, **kw):
    t = obs.Tracer()
    sess = Session(CFG, params, batch_slots=2, max_len=ML, page_size=PS,
                   scheduler={"chunk": 4}, obs=t, **kw)
    return sess, t


# ------------------------------------------ synthetic known-answer traces
def test_single_request_critical_path():
    events = [
        ev("req.submit", 0, rid=0, prompt_len=4, max_new=3),
        ev("sched.admit", 1, slot=0, rid=0, resumed=0),
        ev("req.first_token", 3, rid=0, slot=0),
        ev("req.finish", 6, rid=0, slot=0, tokens=3),
    ]
    rep = analyze(events)
    r = rep.requests["0"]
    assert r["segments"] == {"queue": 1, "prefill": 2, "handoff": 0,
                             "decode": 3}
    assert r["span"] == 6 and r["outcome"] == "completed"
    assert r["ttft_sched"] == 3
    assert r["tpot_ticks"] == 1.5            # (6-3)/(3-1)
    assert rep.segments_consistent()
    assert rep.critical_path["decode"]["ticks"] == 3
    assert rep.critical_path["decode"]["share"] == 0.5


def test_preempted_request_detours_attributed():
    events = [
        ev("req.submit", 0, rid=0, prompt_len=4, max_new=3),
        ev("sched.admit", 0, slot=0, rid=0, resumed=0),
        ev("req.first_token", 2, rid=0, slot=0),
        ev("sched.preempt", 3, slot=0, rid=0, generated=1),
        ev("sched.admit", 5, slot=1, rid=0, resumed=1),
        ev("req.finish", 8, rid=0, slot=1, tokens=3),
    ]
    rep = analyze(events)
    r = rep.requests["0"]
    # preempt sends it back to queue; re-admission restarts prefill
    assert r["segments"] == {"queue": 2, "prefill": 5, "handoff": 0,
                             "decode": 1}
    assert r["span"] == 8
    assert r["detours"] == {"preemptions": 1, "readmissions": 1}
    assert rep.segments_consistent()


def test_disagg_handoff_with_drop():
    events = [
        ev("req.submit", 0, role="prefill", rid=0, prompt_len=6,
           max_new=4),
        ev("sched.admit", 1, role="prefill", slot=0, rid=0, resumed=0),
        ev("req.first_token", 4, role="prefill", rid=0, slot=0),
        ev("handoff.enqueue", 4, role="prefill", rid=0, pages=2,
           drops=1, ready_tick=5, backlog=1),
        ev("handoff.deliver", 7, role="decode", slot=0, rid=0, waited=2,
           drops=1),
        ev("req.finish", 10, role="decode", rid=0, slot=0, tokens=4),
    ]
    rep = analyze(events)
    r = rep.requests["0"]
    assert r["segments"] == {"queue": 1, "prefill": 3, "handoff": 3,
                             "decode": 3}
    assert r["span"] == 10
    assert r["detours"] == {"handoff_drops": 1}
    assert rep.segments_consistent()
    assert rep.critical_path["handoff"]["ticks"] == 3


def test_unfinished_request_accumulates_to_trace_end():
    events = [
        ev("req.submit", 0, rid=0, prompt_len=4, max_new=8),
        ev("sched.admit", 2, slot=0, rid=0, resumed=0),
        ev("step.decode", 6, active=1, step=6),    # stretches trace end
    ]
    rep = analyze(events)
    r = rep.requests["0"]
    assert r["outcome"] == "unfinished"
    assert r["segments"]["queue"] == 2 and r["segments"]["prefill"] == 4
    assert r["span"] == 6
    assert rep.segments_consistent()


def test_failed_request_terminal():
    events = [
        ev("req.submit", 0, rid=0, prompt_len=4, max_new=8),
        ev("sched.admit", 1, slot=0, rid=0, resumed=0),
        ev("resil.fail", 5, rid=0, reason="retries_exhausted", retries=2),
    ]
    rep = analyze(events)
    r = rep.requests["0"]
    assert r["outcome"] == "failed"
    assert r["failed_reason"] == "retries_exhausted" and r["retries"] == 2
    assert r["span"] == 5 and rep.segments_consistent()
    assert rep.detours["failed"] == 1


def test_pages_timeline_change_compressed():
    events = [
        ev("req.submit", 0, rid=0, prompt_len=4, max_new=2),
        ev("sched.admit", 0, slot=0, rid=0, resumed=0),
        ev("alloc.pages", 0, n=2, in_use=2),
        ev("alloc.pages", 1, n=1, in_use=3),
        ev("alloc.free", 2, n=0, in_use=3),     # level unchanged: dropped
        ev("req.first_token", 2, rid=0, slot=0),
        ev("alloc.free", 3, n=3, in_use=0),
        ev("req.finish", 3, rid=0, slot=0, tokens=2),
    ]
    rep = analyze(events)
    p = rep.pages["engine"]
    assert p["timeline"] == [[0, 2], [1, 3], [3, 0]]
    assert p["peak"] == 3 and p["allocs"] == 3 and p["frees"] == 3


# -------------------------------------------------------------- SLOSpec
def test_slospec_parse_and_aliases():
    s = SLOSpec.parse("ttft_p99=40,tpot_p99=4,goodput=0.95")
    assert s == SLOSpec(ttft_p99=40.0, tpot_p99=4.0, goodput=0.95)
    assert SLOSpec.parse("ttft=10").ttft_p99 == 10.0
    assert SLOSpec.parse("tpot=2, goodput=1").goodput == 1.0
    with pytest.raises(ValueError):
        SLOSpec.parse("latency=4")
    with pytest.raises(ValueError):
        SLOSpec.parse("")
    with pytest.raises(ValueError):
        SLOSpec.parse("ttft_p99")


def test_slospec_names_violators():
    reqs = {
        "0": {"ttft_sched": 2, "tpot_ticks": 1.0, "outcome": "completed"},
        "1": {"ttft_sched": 50, "tpot_ticks": 1.0, "outcome": "completed"},
        "2": {"ttft_sched": 3, "tpot_ticks": None, "outcome": "failed"},
    }
    out = SLOSpec.parse("ttft_p99=10,goodput=1.0").evaluate(reqs)
    assert not out["pass"]
    assert out["metrics"]["ttft_p99"]["violators"] == [1]
    assert out["metrics"]["goodput"]["violators"] == [2]
    ok = SLOSpec.parse("ttft_p99=99,goodput=0.5").evaluate(reqs)
    assert ok["pass"] and ok["metrics"]["goodput"]["value"] == 0.6667


# ------------------------------------------------- golden byte-identity
def test_report_byte_identical_across_same_seed_serves(params):
    outs = []
    for _ in range(2):
        sess, t = traced_session(params)
        sess.run_workload(replay(burst_arrivals(4)))
        rep = analyze(t, slo="ttft_p99=40,goodput=1.0")
        assert rep.segments_consistent()
        assert rep.slo["pass"]
        outs.append(rep.to_json())
    assert outs[0] == outs[1]
    # every request completed and was analyzed
    rep = analyze(t)
    assert len(rep.requests) == 4
    assert all(r["outcome"] == "completed" for r in rep.requests.values())


def test_chrome_export_roundtrips_to_same_report(params, tmp_path):
    sess, t = traced_session(params)
    sess.run_workload(replay(burst_arrivals(4)))
    live = analyze(t)
    path = tmp_path / "trace.json"
    t.export(str(path))
    from_file = analyze(str(path))
    assert live.to_json() == from_file.to_json()
    # dict (parsed Chrome doc) input too
    from_doc = analyze(json.loads(path.read_text()))
    assert live.to_json() == from_doc.to_json()


# ------------------------------------------------- trace record/replay
def test_workload_from_trace_reconstructs_schedule(params):
    arrivals = burst_arrivals(5)
    sess, t = traced_session(params)
    sess.run_workload(replay(arrivals))
    spec = schd.WorkloadSpec.from_trace(t, vocab=CFG.vocab)
    assert spec.arrival == "trace" and spec.n_requests == 5
    want = [(step, len(r.prompt), r.max_new) for step, r in arrivals]
    assert list(spec.schedule) == want
    # generate() replays the schedule verbatim with fresh seeded tokens
    regen = schd.generate(spec)
    assert [(s, len(r.prompt), r.max_new) for s, r in regen] == want
    assert [r.rid for _, r in regen] == [0, 1, 2, 3, 4]
    # and a replayed serve reproduces the recorded scheduling exactly
    sess2, t2 = traced_session(params)
    sess2.run_workload(regen)
    assert analyze(t).to_json() == analyze(t2).to_json()


def test_workload_from_trace_empty_raises():
    with pytest.raises(ValueError):
        schd.WorkloadSpec.from_trace([ev("step.decode", 0, active=0,
                                         step=0)])


# ------------------------------------------- flight-recorder collisions
def test_recorder_dump_collision_two_recorders(tmp_path):
    a = obs.FlightRecorder(capacity=4, out_dir=str(tmp_path))
    b = obs.FlightRecorder(capacity=4, out_dir=str(tmp_path))
    a.record(ev("step.decode", 0, active=1, step=0))
    b.record(ev("step.decode", 0, active=1, step=0))
    pa = a.dump("OutOfPages")
    pb = b.dump("OutOfPages")          # same seq + same reason: collides
    assert pa != pb
    assert pathlib.Path(pa).exists() and pathlib.Path(pb).exists()
    assert json.loads(pathlib.Path(pb).read_text())["reason"] == \
        "OutOfPages"
    # and a recorder re-dumping advances past its own files
    pa2 = a.dump("OutOfPages")
    assert pa2 not in (pa, pb) and pathlib.Path(pa2).exists()


# ------------------------------------------- validate_trace exit codes
@pytest.fixture(scope="module")
def exported_trace(params, tmp_path_factory):
    sess, t = traced_session(params)
    sess.run_workload(replay(burst_arrivals(3)))
    path = tmp_path_factory.mktemp("vt") / "trace.json"
    t.export(str(path))
    return path


def run_validate(*paths):
    r = subprocess.run(
        [sys.executable, "benchmarks/validate_trace.py"]
        + [str(p) for p in paths],
        cwd=REPO, capture_output=True, text=True)
    return r.returncode, r.stdout


def test_validate_trace_ok_and_usage(exported_trace):
    code, _ = run_validate(exported_trace)
    assert code == 0
    code, _ = run_validate()
    assert code == 2


def test_validate_trace_schema_exit_code(exported_trace, tmp_path):
    doc = json.loads(exported_trace.read_text())
    for e in doc["traceEvents"]:
        if e.get("ph") != "M":
            e["name"] = "bogus.seam"
            break
    bad = tmp_path / "bad_schema.json"
    bad.write_text(json.dumps(doc))
    code, out = run_validate(bad)
    assert code == 3 and "unknown seam" in out


def test_validate_trace_tick_exit_code(exported_trace, tmp_path):
    doc = json.loads(exported_trace.read_text())
    for e in doc["traceEvents"]:
        if e.get("ph") != "M":
            e["ts"] += 7
            break
    bad = tmp_path / "bad_ticks.json"
    bad.write_text(json.dumps(doc))
    code, out = run_validate(bad)
    assert code == 4 and "TICK_US" in out


def test_validate_trace_replay_exit_code(exported_trace, tmp_path):
    doc = json.loads(exported_trace.read_text())
    for e in doc["traceEvents"]:
        if e.get("name") == "req.finish":
            e["args"]["tokens"] += 1
            break
    bad = tmp_path / "bad_replay.json"
    bad.write_text(json.dumps(doc))
    code, out = run_validate(exported_trace, bad)
    assert code == 5
    assert "first diverging event" in out and "req.finish" in out


# --------------------------------------------------- capacity planning
def test_capacity_benchmark_names_smallest_passing_config():
    from repro.api.engine import CAPACITY_SLO, Engine
    eng = Engine(CFG)
    section = eng.capacity_benchmark()      # burst n=8, 2-point smoke
    labels = [e["label"] for e in section["sweep"]]
    assert labels == ["slots=2,pages=16,chunk=4,policy=fifo",
                      "slots=4,pages=24,chunk=4,policy=fifo"]
    # calibrated: the 2-slot point misses the TTFT bound, 4 slots meets it
    assert [e["slo_pass"] for e in section["sweep"]] == [False, True]
    assert section["chosen"] == "slots=4,pages=24,chunk=4,policy=fifo"
    assert section["deterministic_replay"] is True
    assert all(e["segments_ok"] for e in section["sweep"])
    assert section["slo"] == SLOSpec.parse(CAPACITY_SLO).describe()
    json.dumps(section)                     # BENCH-section serializable
