"""Trace smoke gate: validate a repro.obs Chrome/Perfetto trace export.

  python benchmarks/validate_trace.py TRACE.json [TRACE2.json]

Checks (all deterministic — this is a CI gate, not a heuristic):

* the file is Chrome ``trace_event`` JSON object format
  (``{"traceEvents": [...]}``) that https://ui.perfetto.dev loads;
* every event row is schema-complete for its phase: ``X`` (complete)
  rows carry ``ts``/``dur``, ``i`` (instant) rows carry scope ``s``,
  ``M`` (metadata) rows name a process or thread;
* pids/tids are consistent: every event's pid has a ``process_name``
  metadata row, every nonzero tid a ``thread_name`` row;
* timestamps are tick-derived (non-negative multiples of the tracer's
  TICK_US) and every event row echoes its tick in ``args`` — the
  property that makes same-seed replays byte-comparable;
* the serving stack actually traced: at least one step span and one
  request-lifecycle event, and every event name is a known seam
  (``repro.obs.trace.EVENT_NAMES``).

With a second path, additionally require the two files byte-identical
(the same-seed replay gate — run both serves with REPRO_AUTOTUNE=0 so
per-process autotune timing cannot pick different kernels).
"""
from __future__ import annotations

import json
import sys

sys.path.insert(0, "src")

from repro.obs.trace import EVENT_NAMES, TICK_US  # noqa: E402

KNOWN = set(EVENT_NAMES)


def validate(path: str, log=print) -> bool:
    with open(path) as f:
        doc = json.load(f)
    errs = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        log(f"  {path}: not object-format trace_event JSON")
        return False
    evs = doc["traceEvents"]
    procs, threads = set(), set()
    names = set()
    n_spans = n_instants = 0
    for i, ev in enumerate(evs):
        ph = ev.get("ph")
        if ph == "M":
            if ev.get("name") == "process_name":
                procs.add(ev.get("pid"))
            elif ev.get("name") == "thread_name":
                threads.add((ev.get("pid"), ev.get("tid")))
            else:
                errs.append(f"event {i}: unknown metadata {ev.get('name')}")
            continue
        if ph not in ("X", "i"):
            errs.append(f"event {i}: unknown phase {ph!r}")
            continue
        for field in ("name", "pid", "tid", "ts", "args"):
            if field not in ev:
                errs.append(f"event {i} ({ev.get('name')}): missing "
                            f"{field}")
        if ev.get("name") not in KNOWN:
            errs.append(f"event {i}: unknown seam {ev.get('name')!r}")
        names.add(ev.get("name"))
        ts = ev.get("ts", -1)
        if ts < 0 or ts % TICK_US != 0:
            errs.append(f"event {i} ({ev.get('name')}): ts {ts} is not a "
                        f"non-negative multiple of TICK_US={TICK_US}")
        if ev.get("args", {}).get("tick") != ts // TICK_US:
            errs.append(f"event {i} ({ev.get('name')}): args.tick "
                        f"{ev.get('args', {}).get('tick')} != ts/TICK_US")
        if ph == "X":
            n_spans += 1
            if ev.get("dur", 0) <= 0:
                errs.append(f"event {i}: span without positive dur")
        else:
            n_instants += 1
            if ev.get("s") != "t":
                errs.append(f"event {i}: instant without thread scope")
        if ev.get("pid") not in procs:
            errs.append(f"event {i}: pid {ev.get('pid')} has no "
                        "process_name metadata")
        if ev.get("tid") and (ev.get("pid"), ev.get("tid")) not in threads:
            errs.append(f"event {i}: tid {ev.get('tid')} has no "
                        "thread_name metadata")
    if n_spans == 0:
        errs.append("no step spans — the serving loop did not trace")
    if not names & {"req.submit", "req.first_token", "req.finish"}:
        errs.append("no request-lifecycle events")
    for e in errs[:20]:
        log(f"  {path}: {e}")
    if not errs:
        log(f"  {path}: {len(evs)} events ({n_spans} spans, "
            f"{n_instants} instants, {len(procs)} roles, "
            f"{sorted(names)}) OK")
    return not errs


def main() -> int:
    if len(sys.argv) not in (2, 3):
        print(__doc__)
        return 2
    ok = validate(sys.argv[1])
    if len(sys.argv) == 3:
        ok &= validate(sys.argv[2])
        with open(sys.argv[1], "rb") as a, open(sys.argv[2], "rb") as b:
            if a.read() != b.read():
                print(f"  REPLAY DIVERGED: {sys.argv[1]} != {sys.argv[2]} "
                      "(same-seed traces must be byte-identical)")
                ok = False
            else:
                print("  replay byte-identical OK")
    print("PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
