"""ACSR format: round-trip, flags, self-description (hypothesis-based)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import acsr  # noqa: E402


def random_sparse(rng, n, k, density):
    m = rng.normal(size=(n, k))
    return m * (rng.random((n, k)) < density)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 24), k=st.integers(1, 24),
       density=st.floats(0.0, 1.0), seed=st.integers(0, 99))
def test_roundtrip(n, k, density, seed):
    rng = np.random.default_rng(seed)
    m = random_sparse(rng, n, k, density).astype(np.float32)
    a = acsr.encode(m, block=8)
    assert a.nnz == int((m != 0).sum())
    assert a.nnz_pad % 8 == 0
    np.testing.assert_array_equal(acsr.decode(a), m)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 16), k=st.integers(1, 16), seed=st.integers(0, 99))
def test_row_flags(n, k, seed):
    rng = np.random.default_rng(seed)
    m = random_sparse(rng, n, k, 0.4)
    a = acsr.encode(m)
    flags = np.asarray(a.row_flag)[: a.nnz]
    segs = np.asarray(a.seg_id)[: a.nnz]
    for row in np.unique(segs):
        idx = np.nonzero(segs == row)[0]
        if len(idx) == 1:
            assert flags[idx[0]] == acsr.FLAG_ONLY
        else:
            assert flags[idx[0]] == acsr.FLAG_FIRST
            assert flags[idx[-1]] == acsr.FLAG_LAST
            assert all(f == acsr.FLAG_MID for f in flags[idx[1:-1]])


def test_flags_self_describing(rng):
    """seg ids are recoverable from the 2-bit flag stream alone."""
    m = random_sparse(rng, 12, 20, 0.3)
    # ensure no empty rows for the pure-flag reconstruction property
    m[:, 0] = 1.0
    a = acsr.encode(m)
    rec = acsr.seg_id_from_flags(a.row_flag, a.nnz, 12)
    np.testing.assert_array_equal(rec[: a.nnz], np.asarray(a.seg_id)[: a.nnz])


def test_spmv_ref_matches_dense(rng):
    import jax.numpy as jnp
    m = random_sparse(rng, 40, 60, 0.15).astype(np.float32)
    b = rng.normal(size=(60,)).astype(np.float32)
    a = acsr.encode(m)
    out = np.asarray(acsr.spmv_ref(a, jnp.asarray(b)))
    np.testing.assert_allclose(out, m @ b, rtol=1e-5, atol=1e-5)


def test_prune_topk_density(rng):
    m = rng.normal(size=(64, 64))
    p = acsr.prune_topk(m, 0.1)
    got = (p != 0).mean()
    assert 0.05 <= got <= 0.15
    # surviving entries are the largest-magnitude ones
    assert np.abs(p[p != 0]).min() >= np.abs(m[p == 0]).max() - 1e-12
