"""Blocked-ACSR sparse matvec/matmul — fused multi-block decode pipeline.

The paper's per-nnz stream (value, col_idx, row flags) is re-scheduled at
``block_encode`` time into a *row-balanced slot layout*, EIE's PE schedule
mapped onto TPU lanes: each block owns ``block_rows`` consecutive matrix
rows (one row per lane), and slot step ``s`` consumes the ``s``-th nonzero
of every row in the block simultaneously —

    values:  [nblocks, rmax, block_rows]   (slot-major; lane = matrix row)
    col_idx: [nblocks, rmax, block_rows]
    row_nnz: [nblocks, block_rows]         per-row segment lengths

``row_nnz`` IS the precomputed segment structure: under this schedule the
segment one-hot of the paper's soft reduction becomes the *static* matrix
kron(I_block_rows, 1_rmax), so the segmented sum is a plain slot-axis
reduction and nothing is rebuilt per kernel invocation.  (The previous
kernel materialized a fresh [me, block_rows] one-hot and pushed it through
the MXU on every call — nnz x block_rows MACs per block, 30-80x the work
of the dense matmul it replaced.)

Each grid step of the fused kernel IS the paper's Fig. 3 pipeline for a
*batch* of ``mb`` row blocks:

  activation broadcast -> gather x_tile[col_idx]  (K-tiled: only a [bk, B]
                          slice of x is VMEM-resident; out-of-tile entries
                          are masked and accumulated on a later K step)
  multiplication       -> values * gathered       (VPU, 128 rows in flight)
  soft reduction       -> slot-axis sum           (static segment one-hot)
  epilogue             -> + bias, activation      (fused on the last K step)

Supports matvec (x: [K]) and multi-activation matmul (x: [K, B]), plus
codebook-coded values (uint8 codes dequantized against a 16-entry VMEM
table — combine with sparsity for the full AIDA mode).

Load imbalance caveat: ``rmax`` is the max row population, so a single
dense row pads every other row's slot stream (EIE has the same
pathology).  Magnitude-pruned layers are near-balanced in practice.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import apply_activation as _act
from repro.kernels.util import cdiv as _cdiv


# --------------------------------------------------------------- format
@dataclasses.dataclass
class BlockedACSR:
    """Row-blocked ACSR in the balanced slot schedule (TPU layout of the
    paper's Fig. 2, rescheduled for 128-lane execution).

    values:  [nblocks, rmax, block_rows] f32 (or uint8 codes if ``coded``)
    col_idx: [nblocks, rmax, block_rows] int32 (int16 when n_cols allows)
    row_nnz: [nblocks, block_rows] int32 — nonzeros per matrix row; the
             encode-time segment structure (slot >= row_nnz is padding)

    Registered as a pytree (arrays = leaves, geometry = static) so
    compressed weights can live INSIDE jitted model params.
    """
    values: jnp.ndarray
    col_idx: jnp.ndarray
    row_nnz: jnp.ndarray
    shape: Tuple[int, int]
    block_rows: int
    nnz: int
    centroids: Optional[jnp.ndarray] = None  # set when values are codes

    @property
    def nblocks(self) -> int:
        return int(self.values.shape[0])

    @property
    def rmax(self) -> int:
        """Padded slot count (max nonzeros of any row)."""
        return int(self.values.shape[1])


def _bacsr_flatten(b: "BlockedACSR"):
    return ((b.values, b.col_idx, b.row_nnz, b.centroids),
            (b.shape, b.block_rows, b.nnz))


def _bacsr_unflatten(aux, children):
    values, col_idx, row_nnz, centroids = children
    shape, block_rows, nnz = aux
    return BlockedACSR(values=values, col_idx=col_idx, row_nnz=row_nnz,
                       shape=shape, block_rows=block_rows, nnz=nnz,
                       centroids=centroids)


jax.tree_util.register_pytree_node(BlockedACSR, _bacsr_flatten,
                                   _bacsr_unflatten)


def block_encode(dense: np.ndarray, block_rows: int = 128,
                 slot_pad: int = 8,
                 value_dtype: str = "f32") -> BlockedACSR:
    """Pack a dense matrix's nonzeros into the balanced slot schedule.

    Fully vectorized (bincount + cumsum over the whole matrix — no
    per-block Python loops), so offline compression of real layer shapes
    is linear in nnz.  ``value_dtype="bf16"`` stores the nonzeros in
    bfloat16 (half the value bytes; the kernel upcasts in VMEM).
    """
    dense = np.asarray(dense)
    assert dense.ndim == 2, "BlockedACSR encodes 2-D matrices"
    n_rows, n_cols = dense.shape
    nblocks = max(1, _cdiv(n_rows, block_rows))
    rows, cols = np.nonzero(dense)              # row-major by construction
    nnz = len(rows)
    counts = np.bincount(rows, minlength=nblocks * block_rows)
    rmax = int(counts.max(initial=0))
    rmax = max(slot_pad, _cdiv(rmax, slot_pad) * slot_pad)
    # slot of each entry = its index within its row
    starts = np.concatenate([[0], np.cumsum(counts)])[:-1]
    slot = np.arange(nnz) - starts[rows]
    blk, lane = rows // block_rows, rows % block_rows
    # compact index types — the memory footprint IS the paper's argument
    col_t = np.int16 if n_cols < 2 ** 15 else np.int32
    vals = np.zeros((nblocks, rmax, block_rows), np.float32)
    cidx = np.zeros((nblocks, rmax, block_rows), col_t)
    vals[blk, slot, lane] = dense[rows, cols]
    cidx[blk, slot, lane] = cols
    row_nnz = counts.reshape(nblocks, block_rows).astype(np.int32)
    jvals = jnp.asarray(vals)
    if value_dtype == "bf16":
        jvals = jvals.astype(jnp.bfloat16)
    elif value_dtype != "f32":
        raise ValueError(f"unknown value_dtype {value_dtype!r}")
    return BlockedACSR(values=jvals, col_idx=jnp.asarray(cidx),
                       row_nnz=jnp.asarray(row_nnz),
                       shape=(n_rows, n_cols), block_rows=block_rows,
                       nnz=int(nnz))


def block_encode_coded(dense: np.ndarray, centroids: np.ndarray,
                       block_rows: int = 128,
                       slot_pad: int = 8) -> BlockedACSR:
    """Sparse + codebook: store the nonzeros' 4-bit codes, not values."""
    b = block_encode(dense, block_rows, slot_pad)
    cents = np.asarray(centroids, np.float32)
    vals = np.asarray(b.values)
    codes = np.abs(vals[..., None] - cents[None, None, None, :]).argmin(-1)
    codes[vals == 0.0] = 0  # padding slots (masked by row_nnz in-kernel)
    return dataclasses.replace(
        b, values=jnp.asarray(codes.astype(np.uint8)),
        centroids=jnp.asarray(cents))


# --------------------------------------------------------------- kernel
def _fused_spmv_kernel(vals_ref, cols_ref, nnz_ref, x_ref, *opt_refs,
                       block_rows: int, bk: int, n_k_blocks: int,
                       coded: bool, has_bias: bool,
                       activation: Optional[str]):
    """One grid step = the Fig. 3 pipeline for ``mb`` row blocks over one
    K tile.  opt_refs order: [cents], [bias], out, acc(scratch)."""
    refs = list(opt_refs)
    cents_ref = refs.pop(0) if coded else None
    bias_ref = refs.pop(0) if has_bias else None
    o_ref, acc_ref = refs
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    vals = vals_ref[...]                                # [mb, rmax, br]
    if coded:
        vals = jnp.take(cents_ref[0], vals.astype(jnp.int32), axis=0)
    mb, rmax, br = vals.shape
    cols = cols_ref[...].astype(jnp.int32)              # [mb, rmax, br]
    # precomputed segment structure: slot >= row_nnz is padding
    slot = jax.lax.broadcasted_iota(jnp.int32, (mb, rmax, br), 1)
    live = slot < nnz_ref[...][:, None, :]              # [mb, rmax, br]
    # K-tiled activation broadcast: gather from the resident [bk, B] slice,
    # masking entries whose column lives in another K tile
    local = cols - kb * bk
    in_tile = live & (local >= 0) & (local < bk)
    x = x_ref[...]                                      # [bk, B]
    gathered = jnp.take(x, jnp.clip(local, 0, bk - 1).reshape(-1),
                        axis=0).reshape(mb, rmax, br, -1)
    prod = jnp.where(in_tile, vals.astype(jnp.float32), 0.0)[..., None] \
        * gathered.astype(jnp.float32)
    # soft reduction: the segment one-hot is static under the slot
    # schedule (kron(I_br, 1_rmax)) -> plain slot-axis sum
    acc_ref[...] += prod.sum(axis=1)                    # [mb, br, B]

    @pl.when(kb == n_k_blocks - 1)
    def _done():
        y = acc_ref[...]
        if has_bias:
            y = y + bias_ref[...][..., None]            # [mb, br, 1]
        o_ref[...] = _act(activation, y)


@functools.partial(jax.jit, static_argnames=(
    "block_rows", "mb", "bk", "activation", "interpret"))
def _spmv_call(values, col_idx, row_nnz, x2d, centroids, bias, *,
               block_rows: int, mb: int, bk: int,
               activation: Optional[str], interpret: bool):
    nblocks, rmax, br = values.shape
    k, bsz = x2d.shape
    coded = centroids is not None
    has_bias = bias is not None
    # pad the block axis to a multiple of mb (padding blocks: row_nnz = 0)
    nsuper = _cdiv(nblocks, mb)
    pad_b = nsuper * mb - nblocks
    if pad_b:
        values = jnp.pad(values, ((0, pad_b), (0, 0), (0, 0)))
        col_idx = jnp.pad(col_idx, ((0, pad_b), (0, 0), (0, 0)))
        row_nnz = jnp.pad(row_nnz, ((0, pad_b), (0, 0)))
    # pad K to a multiple of bk (zero activations never contribute)
    n_k = _cdiv(k, bk)
    if n_k * bk != k:
        x2d = jnp.pad(x2d, ((0, n_k * bk - k), (0, 0)))
    grid = (nsuper, n_k)
    in_specs = [
        pl.BlockSpec((mb, rmax, br), lambda i, kb: (i, 0, 0)),
        pl.BlockSpec((mb, rmax, br), lambda i, kb: (i, 0, 0)),
        pl.BlockSpec((mb, br), lambda i, kb: (i, 0)),
        pl.BlockSpec((bk, bsz), lambda i, kb: (kb, 0)),
    ]
    args = [values, col_idx, row_nnz, x2d]
    if coded:
        cents2d = centroids.reshape(1, -1)
        in_specs.append(pl.BlockSpec((1, cents2d.shape[1]),
                                     lambda i, kb: (0, 0)))
        args.append(cents2d)
    if has_bias:
        bias2d = jnp.pad(bias.astype(jnp.float32),
                         (0, (nblocks + pad_b) * br - bias.shape[0])
                         ).reshape(-1, br)
        in_specs.append(pl.BlockSpec((mb, br), lambda i, kb: (i, 0)))
        args.append(bias2d)
    kern = functools.partial(
        _fused_spmv_kernel, block_rows=br, bk=bk, n_k_blocks=n_k,
        coded=coded, has_bias=has_bias, activation=activation)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((mb, br, bsz), lambda i, kb: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nsuper * mb, br, bsz), jnp.float32),
        scratch_shapes=[pltpu.VMEM((mb, br, bsz), jnp.float32)],
        interpret=interpret,
    )(*args)


def default_tiles(nblocks: int, k: int) -> Tuple[int, int]:
    """Heuristic (mb, bk) when no autotuned choice is cached: fuse up to 8
    row blocks per grid step; keep x resident unless K is large."""
    mb = min(8, max(1, nblocks))
    bk = k if k <= 2048 else 512
    return mb, bk


def acsr_spmv(b: BlockedACSR, x: jnp.ndarray, *,
              bias: Optional[jnp.ndarray] = None,
              activation: Optional[str] = None,
              mb: Optional[int] = None, bk: Optional[int] = None,
              interpret: bool = True) -> jnp.ndarray:
    """Sparse (optionally coded) fused pipeline: act(W @ x + bias).

    x: [K] or [K, B]; bias: [n_rows] broadcast over B.  Returns
    [n_rows] / [n_rows, B] f32.  ``mb``/``bk`` select the fused tile
    shape (see kernels.tune for the autotuner that picks them).
    """
    squeeze = x.ndim == 1
    x2d = x[:, None] if squeeze else x
    d_mb, d_bk = default_tiles(b.nblocks, x2d.shape[0])
    mb = d_mb if mb is None else min(mb, b.nblocks)
    bk = d_bk if bk is None else min(bk, x2d.shape[0])
    out = _spmv_call(b.values, b.col_idx, b.row_nnz, x2d, b.centroids,
                     bias, block_rows=b.block_rows, mb=mb, bk=bk,
                     activation=activation, interpret=interpret)
    out = out.reshape(-1, out.shape[-1])[: b.shape[0]]
    return out[:, 0] if squeeze else out
