"""Phi-3-vision-4.2B — phi3-mini backbone + CLIP frontend (STUB: the dry-run
feeds precomputed patch embeddings [B, 576, d_model]).
[hf:microsoft/Phi-3-vision-128k-instruct]"""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    name="phi-3-vision-4.2b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv=32, d_ff=8192,
    vocab=32064, d_head=96, rope_theta=10000.0, frontend="vision",
    n_img_tokens=576, tie_embeddings=False,
    source="hf:microsoft/Phi-3-vision-128k-instruct"))
