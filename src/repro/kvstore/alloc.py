"""Host-side page allocator — ties pool pages to the serving lifecycle.

Pure bookkeeping (no jax): the Session allocates a page when a sequence's
position crosses a page boundary, frees the sequence's pages when its
request completes or its slot is reset, and (on pure-SWA architectures)
reclaims pages that have slid entirely behind the attention window.
Page 0 is never handed out — it is the in-jit write sink for inactive
slots (see pool.GARBAGE_PAGE).

Pages are refcounted so the shared-prefix cache (sched.prefix) can hand
one physical page to several sequences at once: ``alloc()`` returns a
page at refcount 1, ``ref()`` adds an owner, and ``free()`` drops one —
the page returns to the free list only when its last owner lets go.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Optional

from repro.kvstore.pool import GARBAGE_PAGE


class OutOfPages(RuntimeError):
    """The pool is exhausted — raise rather than corrupt a live page."""


class PageAllocator:
    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the garbage sink)")
        self.n_pages = n_pages
        # LIFO free list, ascending hand-out order (nice for debugging)
        self._free: List[int] = list(range(n_pages - 1, GARBAGE_PAGE, -1))
        self._used: set = set()
        self._ref: Dict[int, int] = {}
        self.peak = 0
        self.total_allocs = 0
        # fault-injection seam (repro.resil page-spike): pages temporarily
        # treated as unavailable.  Affects available/alloc/alloc_many only
        # — pages already granted are never clawed back.
        self._holdback = 0
        # observability seam: a ``(name, **args)`` emitter (obs.Tracer
        # .hook) attached by the owning Session when tracing is on; None
        # keeps every alloc/free on the exact pre-obs path.
        self.obs: Optional[Callable] = None

    @property
    def holdback(self) -> int:
        return self._holdback

    @holdback.setter
    def holdback(self, n: int) -> None:
        # the resil layer re-derives the holdback every tick; only a
        # CHANGE is a spike edge worth an event
        if self.obs is not None and n != self._holdback:
            self.obs("alloc.holdback", pages=int(n),
                     prev=int(self._holdback))
        self._holdback = n

    # ------------------------------------------------------------- queries
    @property
    def in_use(self) -> int:
        """Distinct pages with at least one owner (sharing counts once)."""
        return len(self._used)

    @property
    def available(self) -> int:
        return max(0, len(self._free) - self.holdback)

    def refcount(self, pid: int) -> int:
        return self._ref.get(pid, 0)

    # --------------------------------------------------------------- ops
    def alloc(self) -> int:
        if self.available <= 0:
            held = f", {self.holdback} held back" if self.holdback else ""
            raise OutOfPages(
                f"page pool exhausted ({self.n_pages} pages, "
                f"{self.in_use} in use{held}) — grow kv_pool_pages or "
                "finish requests faster")
        pid = self._free.pop()
        self._used.add(pid)
        self._ref[pid] = 1
        self.total_allocs += 1
        self.peak = max(self.peak, self.in_use)
        if self.obs is not None:
            self.obs("alloc.pages", n=1, in_use=self.in_use)
        return pid

    def alloc_many(self, n: int) -> List[int]:
        """Allocate ``n`` pages atomically: either all of them (refcount 1
        each) or none (`OutOfPages`).  The disagg migration channel uses
        this so a half-admitted handoff can never strand pages in the
        decode pool."""
        if n < 0:
            raise ValueError(f"alloc_many wants n >= 0, got {n}")
        if self.available < n:
            held = f", {self.holdback} held back" if self.holdback else ""
            raise OutOfPages(
                f"page pool exhausted ({self.n_pages} pages, "
                f"{self.in_use} in use{held}, {n} requested) — grow "
                "kv_pool_pages or finish requests faster")
        return [self.alloc() for _ in range(n)]

    def ref(self, pid: int) -> int:
        """Add an owner to a live page (prefix sharing). Returns the new
        refcount; refusing to resurrect a freed page keeps double-free
        bugs loud instead of silently aliasing."""
        if pid not in self._used:
            raise ValueError(f"ref() on page {pid} which is not allocated")
        self._ref[pid] += 1
        return self._ref[pid]

    def free(self, pages: Iterable[int]) -> None:
        """Drop one owner per listed page; a page with remaining owners
        stays resident.  Unallocated ids are skipped (idempotent — a slot
        reset may race a request-completion free)."""
        freed = 0
        for pid in pages:
            if pid == GARBAGE_PAGE or pid < 0:
                continue
            if pid not in self._used:     # idempotent (reset after finish)
                continue
            self._ref[pid] -= 1
            if self._ref[pid] > 0:
                continue                  # another owner (shared prefix)
            del self._ref[pid]
            self._used.remove(pid)
            self._free.append(pid)
            freed += 1
        if self.obs is not None and freed:
            self.obs("alloc.free", n=freed, in_use=self.in_use)


def reclaimable_prefix(cur_pos: int, window: int, page_size: int) -> int:
    """How many leading table entries of a sequence at ``cur_pos`` are
    fully behind a ``window``-wide SWA mask (mask keeps pos > cur-window,
    so a page is dead once its last slot <= cur_pos - window).  Safe to
    free: future steps only grow cur_pos."""
    if window <= 0:
        return 0
    dead_below = cur_pos - window + 1     # positions < this are masked out
    return max(0, dead_below // page_size)
