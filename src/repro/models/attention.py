"""GQA attention with every variant the assigned archs need.

Per-layer attention windows are passed as *traced* scalars (-1 = full
causal), so a scanned layer stack can alternate local/global (gemma2,
hymba) without breaking layer-structure homogeneity.  The training/prefill
path defaults to the fused-mask jnp formulation (GSPMD-shardable, used by
the dry-run); `impl="flash"` switches to the Pallas kernels when the window
is static.  Decode attends against a KVCache (full or ring).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import kvstore as kvs
from repro.kernels import ops
from repro.models import kvcache as kvc
from repro.models.layers import COMPUTE_DTYPE, dense, dense_init, rope, softcap

NEG_INF = -1e30


def attn_init(key, d: int, n_heads: int, n_kv: int, d_head: int,
              qkv_bias: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, n_heads * d_head),
        "wk": dense_init(ks[1], d, n_kv * d_head),
        "wv": dense_init(ks[2], d, n_kv * d_head),
        "wo": dense_init(ks[3], n_heads * d_head, d),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * d_head,), jnp.float32)
        p["bk"] = jnp.zeros((n_kv * d_head,), jnp.float32)
        p["bv"] = jnp.zeros((n_kv * d_head,), jnp.float32)
    return p


def _split_heads(x, n, d_head):
    b, t, _ = x.shape
    return x.reshape(b, t, n, d_head).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, t, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * d)


def _qkv(p, x, n_heads, n_kv, d_head, positions, theta, plan=None):
    q = dense(x, p["wq"], p.get("bq"), plan=plan)
    k = dense(x, p["wk"], p.get("bk"), plan=plan)
    v = dense(x, p["wv"], p.get("bv"), plan=plan)
    q = _split_heads(q, n_heads, d_head)
    k = _split_heads(k, n_kv, d_head)
    v = _split_heads(v, n_kv, d_head)
    if theta is not None:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    return q, k, v


def _core(q, k, v, mask, cap: Optional[float], scale: float):
    """Masked softmax attention (GSPMD-friendly einsum form).

    GQA is expressed by GROUPING query heads [B, Hkv, G, T, D] instead of
    jnp.repeat-ing k/v — the repeated [B,H,T,D] tensors never exist
    (§Perf: decode HBM bytes / flops ↓ for every GQA arch)."""
    b, h, tq, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    qg = q.reshape(b, hkv, g, tq, d)
    s = jnp.einsum("bkgqd,bkcd->bkgqc", qg.astype(COMPUTE_DTYPE),
                   k.astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, cap)
    if mask.ndim == 4:  # [B,1,Tq,Tk] or [1,1,Tq,Tk] -> group broadcast
        mask = mask[:, :, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bkcd->bkgqd", p.astype(COMPUTE_DTYPE),
                   v.astype(COMPUTE_DTYPE))
    return o.reshape(b, h, tq, d)


def _chunked_core(q, k, v, window, causal, cap, scale, chunk: int,
                  unroll: bool = False):
    """Blockwise attention: scan over QUERY chunks — O(T·chunk) residency
    instead of O(T²), so long-sequence training fits HBM (flash-attention
    schedule expressed in XLA ops; the Pallas kernel is the TPU-fused
    version of the same schedule)."""
    b, h, t, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    chunk = min(chunk, t)
    assert t % chunk == 0
    qg = q.reshape(b, hkv, g, t, d).astype(COMPUTE_DTYPE)
    kc = k.astype(COMPUTE_DTYPE)
    vc = v.astype(COMPUTE_DTYPE)
    ki = jnp.arange(t)

    def one_chunk(ci):
        qs = jax.lax.dynamic_slice_in_dim(qg, ci * chunk, chunk, axis=3)
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qs, kc,
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, cap)
        qi = ci * chunk + jnp.arange(chunk)
        m = jnp.ones((chunk, t), jnp.bool_)
        if causal:
            m &= ki[None, :] <= qi[:, None]
        m &= (window < 0) | (ki[None, :] > qi[:, None] - window)
        s = jnp.where(m[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bkgqc,bkcd->bkgqd", p.astype(COMPUTE_DTYPE), vc)

    n = t // chunk
    _, out = jax.lax.scan(lambda c, ci: (c, one_chunk(ci)), (),
                          jnp.arange(n), unroll=n if unroll else 1)
    out = jnp.moveaxis(out, 0, 3).reshape(b, hkv, g, t, d)
    return out.reshape(b, h, t, d)


def attn_apply(p, x, positions, *, n_heads: int, n_kv: int, d_head: int,
               window, causal: bool = True, cap: Optional[float] = None,
               theta: Optional[float] = 10000.0,
               scale: Optional[float] = None, impl: str = "einsum",
               chunk: int = 512, unroll: bool = False):
    """Training / prefill self-attention.  window: traced scalar, -1=full."""
    scale = (d_head ** -0.5) if scale is None else scale
    q, k, v = _qkv(p, x, n_heads, n_kv, d_head, positions, theta)
    t = x.shape[1]
    if impl == "flash":
        win = None if (isinstance(window, int) and window < 0) else int(window)
        o = ops.attention(q, k, v, causal=causal, window=win, softcap=cap,
                          scale=scale, impl="flash")
    elif impl == "chunked":
        o = _chunked_core(q, k, v, window, causal, cap, scale, chunk,
                          unroll=unroll)
    else:
        qi = jnp.arange(t)[:, None]
        ki = jnp.arange(t)[None, :]
        mask = jnp.ones((t, t), jnp.bool_)
        if causal:
            mask &= ki <= qi
        wmask = (window < 0) | (ki > qi - window)
        mask = mask & wmask
        o = _core(q, k, v, mask[None, None], cap, scale)
    return dense(_merge_heads(o.astype(COMPUTE_DTYPE)), p["wo"])


def decode_attend(cache: kvc.KVCache, q, k, v, cur_pos, *, window,
                  ring: bool = False, cap: Optional[float] = None,
                  scale: float = 1.0):
    """Post-projection decode attention against a dense cache: cache
    update + masked softmax over the slots.  Split out from attn_decode
    so benchmarks can time the attention/KV term separately from the
    (compressible) FC projections."""
    cache = kvc.update(cache, k, v, cur_pos, ring=ring)
    mask = kvc.attention_mask(cache, cur_pos,
                              jnp.asarray(window, jnp.int32))  # [B, S]
    o = _core(q, cache.k, cache.v, mask[:, None, None, :], cap, scale)
    return cache, o


def decode_attend_paged(pool: kvs.PagedKV, table, q, k, v, cur_pos, *,
                        window, cap: Optional[float] = None,
                        scale: float = 1.0, impl: Optional[str] = None,
                        plan=None):
    """Paged counterpart of decode_attend: quantize-into-page update +
    page-gather attention (q/k/v are [B, H(kv), 1, Dh] as from _qkv).
    ``impl`` overrides the tuner's kernel choice; with a ``plan`` the
    tuned kernel runs shard-local over the head axis via shard_map."""
    pool = kvs.update(pool, table, k[:, :, 0].astype(jnp.float32),
                      v[:, :, 0].astype(jnp.float32), cur_pos)
    if impl is None and plan is not None and plan.tp > 1:
        from repro.shard import paged_attention_sharded
        o = paged_attention_sharded(plan, q[:, :, 0], pool, table, cur_pos,
                                    jnp.asarray(window, jnp.int32),
                                    scale=scale, cap=cap)
    else:
        o = kvs.paged_attention(q[:, :, 0], pool, table, cur_pos,
                                jnp.asarray(window, jnp.int32),
                                scale=scale, cap=cap, impl=impl)
    return pool, o[:, :, None, :]


def attn_decode(p, cache: kvc.KVCache, x, cur_pos, *, n_heads: int,
                n_kv: int, d_head: int, window, ring: bool = False,
                cap: Optional[float] = None,
                theta: Optional[float] = 10000.0,
                scale: Optional[float] = None, plan=None):
    """One-token decode. x [B,1,D], cur_pos [B] absolute position."""
    scale = (d_head ** -0.5) if scale is None else scale
    q, k, v = _qkv(p, x, n_heads, n_kv, d_head, cur_pos[:, None], theta,
                   plan=plan)
    cache, o = decode_attend(cache, q, k, v, cur_pos, window=window,
                             ring=ring, cap=cap, scale=scale)
    return cache, dense(_merge_heads(o.astype(COMPUTE_DTYPE)), p["wo"],
                        plan=plan)


def attn_decode_paged(p, pool: kvs.PagedKV, table, x, cur_pos, *,
                      n_heads: int, n_kv: int, d_head: int, window,
                      cap: Optional[float] = None,
                      theta: Optional[float] = 10000.0,
                      scale: Optional[float] = None, plan=None):
    """One-token decode against the paged KV pool (cache="paged" route).

    The current token's k/v are quantized into their page first, then the
    paged-attention kernel attends over the sequence's page table — same
    write-then-attend semantics as attn_decode, O(used pages) memory.
    Windowing is mask-only here; page reclamation behind an SWA window is
    the Session's host-side job (kvstore.reclaimable_prefix).  Under a
    sharding plan the tuned kernel — Pallas included — runs shard-local
    over the head axis via `shard.paged_attention_sharded` (heads are
    independent, so mesh output is bit-identical to single-device)."""
    scale = (d_head ** -0.5) if scale is None else scale
    q, k, v = _qkv(p, x, n_heads, n_kv, d_head, cur_pos[:, None], theta,
                   plan=plan)
    pool, o = decode_attend_paged(pool, table, q, k, v, cur_pos,
                                  window=window, cap=cap, scale=scale,
                                  plan=plan)
    return pool, dense(_merge_heads(o.astype(COMPUTE_DTYPE)), p["wo"],
                       plan=plan)
