"""Perf regression gate: compare a fresh BENCH_api.json against the
committed baseline and fail if any compressed mode lost >tol throughput.

  python benchmarks/check_regression.py NEW.json BASELINE.json [--tol 0.2]

A mode passes if EITHER its absolute tok/s OR its dense-normalized
throughput (mode tok/s / same-run dense tok/s) is within tol of the
baseline.  Rationale: the two views fail together only for genuine
kernel regressions — a faster host inflates absolute numbers (normalized
may dip because XLA dense scales with cores while interpret-mode kernels
are overhead-bound), a slower host deflates absolute numbers roughly
uniformly (normalized holds), but a change that actually slows a kernel
loses on the same machine in both units.  Also re-asserts the cost-model
invariants recorded in the file (emulator exactness + emulator/cycle-sim
agreement).

The paged-KV section ("kv") is gated too:
  * KV bytes/token ratio must stay <= KV_BYTES_CEIL (deterministic
    accounting — any regression here is a real layout change);
  * paged serving must hold parity with the dense cache: the
    paged/full tok/s ratio (same run, same host, so host speed cancels)
    passes within tol of 1.0 or of the baseline's ratio;
  * the attention/FC time-share fields must be present and sane —
    they are the trajectory signal the next attention PR builds on.

And the sharding section ("sharding"):
  * mesh decode must stay token-identical to the single-device path
    (deterministic — any loss is a real sharding bug);
  * sharded throughput gates dual-unit: absolute tok/s OR the same-run
    mesh/single ratio within tol of the baseline's.

And the scheduler section ("serving"):
  * chunked prefill must reach the first token within its call bound
    (ceil(prompt/chunk)+1 — deterministic step counts, no wall clock);
  * the shared-prefix workload must actually hit the prefix cache, and
    every drain must end with zero leaked pages (deterministic);
  * the pressure workload must complete through preemption, not crash;
  * heterogeneous-workload throughput gates like the FC modes: within
    tol of the baseline in absolute tok/s OR normalized by the same
    run's dense-mode tok/s (host speed cancels in the second unit).

And the disaggregated-serving section ("disagg"):
  * token parity with the co-located engine, handoffs actually moving
    pages, and zero leaked pages on both pools are deterministic and
    gate hard;
  * scheduling-clock TTFT-p99 (ticks) must be no worse disaggregated
    than co-located — the deterministic form of the latency win;
  * wall throughput gates dual-unit (absolute OR disagg/co-located
    ratio vs baseline).

And the resilience section ("resil"):
  * under every built-in fault preset the burst workload must complete
    all requests with token streams identical to the fault-free run,
    zero leaked pages, and counters identical across a same-seed
    replay — all deterministic, all gate hard (goodput_vs_clean is
    trajectory only).

And the capacity section ("capacity"):
  * every number is tick-denominated, so everything gates hard: the
    burst sweep must name a smallest SLO-meeting config ("chosen"
    non-null), the chosen config's trace re-analysis must be
    byte-deterministic, and every swept point's per-request
    critical-path segments must sum to its submit->finish span
    (``segments_ok`` — the obs.analyze attribution invariant).
"""
from __future__ import annotations

import argparse
import json
import sys

GATED_MODES = ("int8", "codebook4", "acsr", "aida")
#: paged int8 KV must keep at least this bytes/token win vs dense bf16
KV_BYTES_CEIL = 0.55


def _rel(run: dict, mode: str):
    modes = run.get("modes", {})
    dense = modes.get("dense", {}).get("tok_per_s")
    tok = modes.get(mode, {}).get("tok_per_s")
    if not dense or tok is None:
        return None
    return tok / dense


def _provenance_line(run: dict, label: str) -> str:
    p = run.get("provenance")
    if not p:
        return f"  {label}: no provenance recorded"
    fields = ("config", "mode", "seed", "backend", "jax", "git_sha",
              "timestamp")
    return f"  {label}: " + " ".join(
        f"{k}={p[k]}" for k in fields if p.get(k) is not None)


def check(new: dict, base: dict, tol: float, log=print) -> bool:
    ok = True
    for mode in GATED_MODES:
        b = base.get("modes", {}).get(mode)
        n = new.get("modes", {}).get(mode)
        if b is None or n is None:
            log(f"  {mode:10s} missing from "
                f"{'baseline' if b is None else 'new run'} — skipped")
            continue
        abs_ok = n["tok_per_s"] >= b["tok_per_s"] * (1.0 - tol)
        rb, rn = _rel(base, mode), _rel(new, mode)
        rel_ok = rb is not None and rn is not None and rn >= rb * (1.0 - tol)
        status = "OK" if (abs_ok or rel_ok) else "REGRESSION"
        if status != "OK":
            ok = False
        log(f"  {mode:10s} {b['tok_per_s']:8.1f} -> {n['tok_per_s']:8.1f} "
            f"tok/s [{'ok' if abs_ok else 'lo'}]  "
            f"{rb or 0:6.3f} -> {rn or 0:6.3f} x dense "
            f"[{'ok' if rel_ok else 'lo'}]  {status}")
    inv = new.get("backends", {})
    if not inv.get("ap-emulator", {}).get("exact", False):
        log("  ap-emulator exactness LOST")
        ok = False
    if not inv.get("cycle-sim", {}).get("agrees_with_emulator", False):
        log("  emulator/cycle-sim agreement LOST")
        ok = False
    ok &= check_kv(new, base, tol, log=log)
    ok &= check_serving(new, base, tol, log=log)
    ok &= check_sharding(new, base, tol, log=log)
    ok &= check_disagg(new, base, tol, log=log)
    ok &= check_resil(new, base, tol, log=log)
    ok &= check_capacity(new, base, tol, log=log)
    return ok


def check_capacity(new: dict, base: dict, tol: float, log=print) -> bool:
    """Capacity-planning gate — tick-denominated, so every fact gates
    hard: the sweep must be non-empty, name a smallest SLO-meeting
    config, hold the critical-path attribution invariant on every swept
    point, and re-analyze byte-identically on replay.  The baseline is
    not consulted (there are no wall-clock numbers to compare)."""
    cap = new.get("capacity")
    if cap is None:
        log("  capacity section MISSING from new run")
        return False
    ok = True
    sweep = cap.get("sweep") or []
    if not sweep:
        log("  capacity sweep is empty")
        ok = False
    if cap.get("chosen") is None:
        log("  capacity sweep found NO config meeting the SLO "
            f"{cap.get('slo')} — the planner cannot answer the sizing "
            "question")
        ok = False
    if not cap.get("deterministic_replay"):
        log("  capacity chosen-config re-analysis diverged — the trace "
            "report is not a pure function of the trace")
        ok = False
    bad_seg = [e.get("label") for e in sweep if not e.get("segments_ok")]
    if bad_seg:
        log(f"  capacity critical-path segments do not sum to request "
            f"spans on: {bad_seg}")
        ok = False
    if ok:
        n_pass = sum(1 for e in sweep if e.get("slo_pass"))
        log(f"  capacity   {len(sweep)} configs swept, {n_pass} meet "
            f"SLO, chosen {cap.get('chosen')}  "
            "replay-deterministic  OK")
    return ok


def check_resil(new: dict, base: dict, tol: float, log=print) -> bool:
    """Resilience gate — every fact here is deterministic and gates
    hard.  Under each built-in fault preset the burst workload must:
    complete every request (faults delay, they must not lose work),
    keep every completed token stream identical to the fault-free run
    (greedy decode + recompute-retry means faults can reorder, never
    rewrite), leak zero pages on either role's pool, and produce
    identical counters on a same-(seed,preset) replay.  No wall-clock
    fields gate — goodput_vs_clean is trajectory only."""
    rs = new.get("resil")
    if rs is None:
        log("  resil section MISSING from new run")
        return False
    ok = True
    if rs.get("clean", {}).get("pages_leaked") != 0:
        log(f"  resil clean run leaked "
            f"{rs.get('clean', {}).get('pages_leaked')} pages")
        ok = False
    n_req = rs.get("clean", {}).get("completed")
    for preset, rec in sorted(rs.get("presets", {}).items()):
        bad = []
        if not rec.get("token_parity"):
            bad.append("token parity LOST")
        if rec.get("pages_leaked") != 0:
            bad.append(f"{rec.get('pages_leaked')} pages leaked")
        if not rec.get("deterministic"):
            bad.append("replay diverged (counters/tokens)")
        if rec.get("completed") != n_req or rec.get("failed"):
            bad.append(f"completed {rec.get('completed')}/{n_req}, "
                       f"failed {rec.get('failed')}")
        if bad:
            log(f"  resil[{preset}] " + "; ".join(bad))
            ok = False
    if ok:
        n_faults = sum(sum((rec.get("counters") or {})
                           .get("faults", {}).values())
                       for rec in rs.get("presets", {}).values())
        log(f"  resil      {len(rs.get('presets', {}))} presets x "
            f"{n_req} requests: parity OK, 0 leaks, replay-deterministic "
            f"({n_faults} faults injected)  OK")
    return ok


def check_disagg(new: dict, base: dict, tol: float, log=print) -> bool:
    """Disaggregated-serving gate.  Deterministic facts gate hard: token
    parity with the co-located engine, every decode-bound request handed
    off exactly once, zero pages leaked on either pool, and the
    scheduling-clock TTFT-p99 (ticks — the signal that survives a noisy
    host; on one emulated device the two roles serialize, so wall TTFT
    is NOT comparable) no worse than the same run's co-located baseline.
    Wall-clock throughput gates dual-unit like the FC modes."""
    dg = new.get("disagg")
    if dg is None:
        log("  disagg section MISSING from new run")
        return False
    ok = True
    if not dg.get("token_parity"):
        log("  disagg token parity LOST — disaggregated decode diverged "
            "from the co-located engine")
        ok = False
    co, di = dg.get("colocated", {}), dg.get("disagg", {})
    for label, side in (("colocated", co), ("disagg", di)):
        if side.get("pages_leaked") != 0:
            log(f"  disagg {label} leaked "
                f"{side.get('pages_leaked')} pages at drain")
            ok = False
    hand = di.get("handoff", {})
    if not hand.get("count") or not hand.get("migrated_bytes"):
        log(f"  disagg handoffs {hand.get('count')} / migrated bytes "
            f"{hand.get('migrated_bytes')} — the migration channel did "
            "not move any pages")
        ok = False
    cop99 = (co.get("ttft_sched") or {}).get("p99")
    dip99 = (di.get("ttft_sched") or {}).get("p99")
    if cop99 is None or dip99 is None or dip99 > cop99:
        log(f"  disagg scheduling-clock TTFT p99 {dip99} worse than "
            f"co-located {cop99} — role separation lost its latency win")
        ok = False
    # wall throughput: dual-unit (absolute OR same-run disagg/co-located
    # ratio vs baseline's)
    tok, ctok = di.get("tok_per_s"), co.get("tok_per_s")
    bdg = base.get("disagg", {})
    btok = bdg.get("disagg", {}).get("tok_per_s")
    bctok = bdg.get("colocated", {}).get("tok_per_s")
    if tok is None:
        log("  disagg throughput missing")
        ok = False
    elif btok:
        abs_ok = tok >= btok * (1.0 - tol)
        rel_ok = (ctok and bctok
                  and tok / ctok >= (btok / bctok) * (1.0 - tol))
        if not (abs_ok or rel_ok):
            log(f"  disagg throughput REGRESSION {btok:.1f} -> "
                f"{tok:.1f} tok/s (vs co-located "
                f"{btok / bctok if bctok else 0:.3f} -> "
                f"{tok / ctok if ctok else 0:.3f})")
            ok = False
    if ok:
        log(f"  disagg     parity OK  TTFT-p99 {dip99} vs {cop99} ticks  "
            f"{hand.get('count')} handoffs "
            f"({hand.get('migrated_bytes')} B)  {tok:.1f} tok/s  OK")
    return ok


def check_sharding(new: dict, base: dict, tol: float, log=print) -> bool:
    """Mesh-aware serving gate: token parity with the single-device path
    is deterministic and must hold exactly; the sharded decode step time
    gates dual-unit like the FC modes (absolute tok/s OR the same-run
    mesh/single ratio — host speed cancels in the second unit)."""
    sh = new.get("sharding")
    if sh is None:
        log("  sharding section MISSING from new run")
        return False
    ok = True
    if not sh.get("token_parity"):
        log("  sharding token parity LOST — mesh decode diverged from "
            "the single-device path")
        ok = False
    tok, ratio = sh.get("tok_per_s_mesh"), sh.get("mesh_over_single")
    bsh = base.get("sharding", {})
    btok, bratio = bsh.get("tok_per_s_mesh"), bsh.get("mesh_over_single")
    if tok is None or ratio is None:
        log("  sharding throughput fields missing")
        ok = False
    elif btok:
        abs_ok = tok >= btok * (1.0 - tol)
        rel_ok = bratio and ratio >= bratio * (1.0 - tol)
        if not (abs_ok or rel_ok):
            log(f"  sharding mesh throughput REGRESSION {btok:.1f} -> "
                f"{tok:.1f} tok/s (mesh/single {bratio or 0:.3f} -> "
                f"{ratio:.3f})")
            ok = False
    if ok:
        step_us = sh.get("decode_step_us_per_shard") or 0
        log(f"  sharding   parity OK  {tok:.1f} tok/s on "
            f"{sh.get('n_model')}x{sh.get('n_data')} mesh "
            f"(x{ratio:.2f} of single, {step_us:.0f} us/shard)  OK")
    return ok


def check_kv(new: dict, base: dict, tol: float, log=print) -> bool:
    kv = new.get("kv")
    if kv is None:
        log("  kv section MISSING from new run")
        return False
    ok = True
    bytes_ratio = kv.get("kv_bytes_per_token", {}).get("ratio")
    if bytes_ratio is None or bytes_ratio > KV_BYTES_CEIL:
        log(f"  kv bytes/token ratio {bytes_ratio} exceeds "
            f"{KV_BYTES_CEIL} — paged int8 lost its memory win")
        ok = False
    ratio = kv.get("paged_over_full")
    base_ratio = base.get("kv", {}).get("paged_over_full")
    par_ok = ratio is not None and ratio >= 1.0 - tol
    hist_ok = (ratio is not None and base_ratio is not None
               and ratio >= base_ratio * (1.0 - tol))
    if not (par_ok or hist_ok):
        log(f"  paged/full step-time parity LOST "
            f"(ratio {ratio}, baseline {base_ratio}, tol {tol:.0%})")
        ok = False
    share = kv.get("attn_time_share", {})
    for kind in ("full", "paged"):
        s = share.get(kind)
        if s is None or not (0.0 < s < 1.0):
            log(f"  attn_time_share[{kind}] missing or insane: {s}")
            ok = False
    if ok:
        log(f"  kv         paged/full x{ratio:.2f}  "
            f"bytes/token x{bytes_ratio:.2f}  attn share "
            f"{share.get('full'):.0%} -> {share.get('paged'):.0%}  OK")
    return ok


def check_serving(new: dict, base: dict, tol: float, log=print) -> bool:
    sv = new.get("serving")
    if sv is None:
        log("  serving section MISSING from new run")
        return False
    ok = True
    # chunked prefill: deterministic call counts
    pf = sv.get("prefill", {})
    calls = pf.get("chunked", {}).get("first_token_calls")
    one = pf.get("one_token", {}).get("first_token_calls")
    bound = pf.get("bound_calls")
    if calls is None or bound is None or calls > bound:
        log(f"  serving prefill first-token calls {calls} exceed bound "
            f"{bound} — chunked prefill lost its latency win")
        ok = False
    if one is not None and calls is not None and calls >= one:
        log(f"  serving chunked prefill ({calls} calls) no better than "
            f"one-token ({one})")
        ok = False
    # prefill latency: dual-unit gate like the throughput one — absolute
    # chunked TTFT within tol of baseline, OR the chunked/one-token TTFT
    # ratio no worse.  The ratio is the host-speed-invariant unit (both
    # sides ran in the same process); the absolute arm catches a fast
    # host masking a kernel regression behind a good ratio.
    ttft = pf.get("chunked", {}).get("ttft_s")
    one_ttft = pf.get("one_token", {}).get("ttft_s")
    bpf = base.get("serving", {}).get("prefill", {})
    bttft = bpf.get("chunked", {}).get("ttft_s")
    bone = bpf.get("one_token", {}).get("ttft_s")
    if ttft is None:
        log("  serving chunked prefill ttft_s missing")
        ok = False
    elif bttft:
        abs_ok = ttft <= bttft * (1.0 + tol)
        rel_ok = (one_ttft and bone
                  and ttft / one_ttft <= (bttft / bone) * (1.0 + tol))
        if not (abs_ok or rel_ok):
            log(f"  serving chunked-prefill TTFT REGRESSION "
                f"{bttft:.4f}s -> {ttft:.4f}s (normalized vs one-token "
                f"{bttft / bone if bone else 0:.3f} -> "
                f"{ttft / one_ttft if one_ttft else 0:.3f})")
            ok = False
    # prefix cache: must hit, must not leak (deterministic)
    px = sv.get("prefix", {})
    if not px.get("page_hits"):
        log(f"  serving prefix-cache hits {px.get('page_hits')} — shared "
            "prefixes are being re-prefilled")
        ok = False
    leaks = (px.get("pages_leaked"), px.get("pages_leaked_after_clear"),
             sv.get("preemption", {}).get("pages_leaked"))
    if any(lk is None or lk != 0 for lk in leaks):
        log(f"  serving leaked pages at drain: {leaks} (prefix, "
            "prefix-after-clear, preemption) — refcount bug")
        ok = False
    # preemption: the over-committed workload completes
    pre = sv.get("preemption", {})
    if pre.get("completed") != pre.get("requests") \
            or not pre.get("preemptions"):
        log(f"  serving preemption: {pre.get('completed')}/"
            f"{pre.get('requests')} completed with "
            f"{pre.get('preemptions')} preemptions — pressure workload "
            "must finish via eviction, not crash")
        ok = False
    # throughput: dual-unit gate vs baseline (like the FC modes)
    tok = sv.get("throughput", {}).get("tok_per_s")
    btok = base.get("serving", {}).get("throughput", {}).get("tok_per_s")
    dense = new.get("modes", {}).get("dense", {}).get("tok_per_s")
    bdense = base.get("modes", {}).get("dense", {}).get("tok_per_s")
    if tok is None:
        log("  serving throughput missing")
        ok = False
    elif btok:
        abs_ok = tok >= btok * (1.0 - tol)
        rel_ok = (dense and bdense
                  and tok / dense >= (btok / bdense) * (1.0 - tol))
        if not (abs_ok or rel_ok):
            log(f"  serving throughput REGRESSION {btok:.1f} -> "
                f"{tok:.1f} tok/s (normalized "
                f"{btok / bdense if bdense else 0:.3f} -> "
                f"{tok / dense if dense else 0:.3f} x dense)")
            ok = False
    if ok:
        log(f"  serving    prefill {calls}<={bound} calls  "
            f"prefix hits {px.get('page_hits')}  "
            f"preemptions {pre.get('preemptions')}  "
            f"{tok:.1f} tok/s  OK")
    return ok


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new")
    ap.add_argument("baseline")
    ap.add_argument("--tol", type=float, default=0.2,
                    help="allowed fractional tok/s loss (default 0.2)")
    args = ap.parse_args()
    with open(args.new) as f:
        new = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)
    print(f"perf gate (tol {args.tol:.0%}) — {args.new} vs {args.baseline}")
    ok = check(new, base, args.tol)
    if not ok:
        # name the exact setups being compared so a failing gate is
        # diagnosable from the CI log alone
        print(_provenance_line(new, "new run "))
        print(_provenance_line(base, "baseline"))
    print("PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
