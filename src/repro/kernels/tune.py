"""Per-layer kernel autotuner — tile search + winner cache for the FC paths.

Decode FC shapes are few and static, so the right tile parameters can be
searched *once per (shape, mode, backend)* on real timings and then read
back at trace time by the `ops` dispatchers:

  acsr / aida   (mb, bk)       — fused row blocks per grid step, K tile
  int8 / lut    impl + (bm, bn, bk) — Pallas tiles, or the XLA reference
                                 (the MXU tiling that wins on TPU loses to
                                 a fused XLA matmul on interpret-mode hosts;
                                 the tuner measures instead of guessing)
  block_rows    — encode-time row-block height (searched at compress time
                  when REPRO_TUNE_BLOCK_ROWS=1; re-encodes per candidate)

`Engine.session()` calls :func:`tune_params` before compiling the decode
step, so every unique CompressedFC geometry is tuned eagerly (outside any
jit trace) and the jitted step picks the winners up at trace time.
`Engine.benchmark` embeds :func:`snapshot` into BENCH_api.json so the
chosen tiles ship with every recorded perf number.

The cache is process-global and keyed on everything that changes the
winner: kind, geometry, batch width, and interpret vs native lowering.
Tiles are read at trace time — re-tuning after a step has been compiled
does not retroactively change that step.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api import env

Key = Tuple


@dataclasses.dataclass(frozen=True)
class KernelChoice:
    """One point in a kernel's implementation/tile space."""
    impl: str = "pallas"
    tiles: Tuple[Tuple[str, int], ...] = ()
    us: float = float("nan")          # measured microseconds (best run)

    def tile(self, name: str, default: Optional[int] = None) -> Optional[int]:
        return dict(self.tiles).get(name, default)

    def to_json(self) -> dict:
        d = {"impl": self.impl, **dict(self.tiles)}
        if np.isfinite(self.us):
            d["us"] = round(self.us, 1)
        return d


_CACHE: Dict[Key, KernelChoice] = {}


def get(key: Key) -> Optional[KernelChoice]:
    return _CACHE.get(key)


def record(key: Key, choice: KernelChoice) -> None:
    _CACHE[key] = choice


def clear() -> None:
    _CACHE.clear()


def snapshot() -> dict:
    """JSON-ready view of every tuned winner (key -> impl/tiles/us)."""
    return {"/".join(str(p) for p in key): choice.to_json()
            for key, choice in sorted(_CACHE.items(), key=lambda kv: kv[0])}


def enabled() -> bool:
    return env.AUTOTUNE


# ------------------------------------------------------------------- keys
def acsr_key(nblocks: int, rmax: int, block_rows: int, k: int, batch: int,
             coded: bool, interpret: bool) -> Key:
    return ("aida" if coded else "acsr", nblocks, rmax, block_rows, k,
            batch, "interp" if interpret else "tpu")


def int8_key(n: int, k: int, batch: int, interpret: bool) -> Key:
    return ("int8", n, k, batch, "interp" if interpret else "tpu")


def lut_key(n: int, k: int, batch: int, interpret: bool) -> Key:
    return ("codebook4", n, k, batch, "interp" if interpret else "tpu")


def paged_key(hkv: int, group: int, d_head: int, page_size: int, npp: int,
              batch: int, quantized: bool, interpret: bool) -> Key:
    # npp is bucketed to the padded table width the kernel actually runs,
    # so a growing page table hits one cache entry instead of re-tuning
    # (and recompiling) at every width
    from repro.kvstore.paged_attention import npp_bucket
    return ("paged-attn", hkv, group, d_head, page_size, npp_bucket(npp),
            batch, "q8" if quantized else "bf16",
            "interp" if interpret else "tpu")


def paged_chunk_key(hkv: int, group: int, d_head: int, page_size: int,
                    npp: int, batch: int, chunk: int, quantized: bool,
                    interpret: bool) -> Key:
    from repro.kvstore.paged_attention import npp_bucket
    return ("paged-attn-chunk", hkv, group, d_head, page_size,
            npp_bucket(npp), batch, chunk,
            "q8" if quantized else "bf16",
            "interp" if interpret else "tpu")


# ------------------------------------------------------------- candidates
def acsr_candidates(nblocks: int, k: int) -> List[KernelChoice]:
    mbs = sorted({m for m in (1, 2, 4, 8) if m <= max(1, nblocks)})
    bks = sorted({min(k, b) for b in (256, 512, k)}) if k > 256 else [k]
    return [KernelChoice("pallas", (("mb", mb), ("bk", bk)))
            for mb in mbs for bk in bks]


def int8_candidates(n: int, k: int) -> List[KernelChoice]:
    tiles = [(8, 128, 512), (8, 256, 256), (16, 128, 128), (8, 512, 512)]
    cands = [KernelChoice("xla")]
    for bm, bn, bk in tiles:
        cands.append(KernelChoice("pallas", (
            ("bm", bm), ("bn", min(bn, n)), ("bk", min(bk, k)))))
    return cands


def lut_candidates(n: int, k: int) -> List[KernelChoice]:
    tiles = [(8, 128, 512), (8, 128, 256), (8, 256, 512)]
    cands = [KernelChoice("xla")]
    for bm, bn, bk in tiles:
        cands.append(KernelChoice("pallas", (
            ("bm", bm), ("bn", min(bn, n)), ("bk", min(bk, k)))))
    return cands


def paged_candidates(npp: int) -> List[KernelChoice]:
    """XLA gather reference vs the Pallas kernel at a few page-block
    widths (pb = table slots folded per grid step)."""
    from repro.kvstore.paged_attention import npp_bucket
    cands = [KernelChoice("xla")]
    for pb in sorted({min(p, npp_bucket(npp)) for p in (1, 2, 4)}):
        cands.append(KernelChoice("pallas", (("pb", pb),)))
    return cands


def paged_chunk_candidates(npp: int, chunk: int) -> List[KernelChoice]:
    """Chunked-prefill space: XLA gather reference vs the Pallas chunk
    kernel over (pb page blocks) x (qt query tiles dividing the chunk)."""
    from repro.kvstore.paged_attention import npp_bucket
    cands = [KernelChoice("xla")]
    pbs = sorted({min(p, npp_bucket(npp)) for p in (1, 2, 4)})
    qts = sorted({q for q in (1, 2, 4, chunk) if chunk % q == 0})
    for pb in pbs:
        for qt in qts:
            cands.append(KernelChoice("pallas", (("pb", pb), ("qt", qt))))
    return cands


# ---------------------------------------------------------------- search
def autotune(key: Key, candidates: Sequence[KernelChoice],
             runner: Callable[[KernelChoice], object], *,
             reps: int = 3, inner: int = 3) -> KernelChoice:
    """Time each candidate (1 warmup, then ``reps`` samples of ``inner``
    back-to-back calls, best sample) and cache the winner under ``key``.
    Sub-ms kernels need the inner loop — single-call samples are noise on
    a busy host and a wrong pick taxes every decode step afterwards.
    Candidates that fail to compile or run are skipped; an already-cached
    key returns immediately."""
    from repro.obs import timeit
    cached = get(key)
    if cached is not None:
        return cached
    best: Optional[KernelChoice] = None
    for cand in candidates:
        try:
            t_best = timeit(runner, cand, reps=reps, inner=inner)
        except Exception:
            continue
        timed = dataclasses.replace(cand, us=t_best * 1e6)
        if best is None or timed.us < best.us:
            best = timed
    if best is None:  # nothing ran — record a no-op marker so we don't loop
        best = KernelChoice("pallas")
    record(key, best)
    return best


# ------------------------------------------------------- layer-level entry
def _layer0_view(layer):
    """A single-layer view of a (possibly [L, ...]-stacked) CompressedFC."""
    import jax
    import jax.numpy as jnp
    from repro.core import sparse_fc as sfc

    def unstack(x):
        return x[0] if isinstance(x, jnp.ndarray) else x

    leaves, treedef = jax.tree_util.tree_flatten(layer)
    ndims = {"dense": 2, "int8": 2, "codebook4": 2, "acsr": 3, "aida": 3}
    # stacked leaves carry one extra leading dim vs the single-layer layout
    want = ndims[layer.mode]
    probe = leaves[0]
    if probe.ndim > want:
        leaves = [unstack(x) for x in leaves]
    lay = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(lay, sfc.CompressedFC)
    return lay


def tune_layer(layer, batch: int, interpret: bool) -> Optional[KernelChoice]:
    """Search tiles for one CompressedFC (stacked or single-layer) at the
    given decode batch width.  Returns the winner (or None for modes with
    nothing to tune)."""
    import jax
    import jax.numpy as jnp
    from repro.core import sparse_fc as sfc
    from repro.kernels import int8_matmul as i8
    from repro.kernels import lut_matmul as lm
    from repro.kernels import acsr_spmv as sp
    from repro.kernels import ref

    lay = _layer0_view(layer)
    n_out, n_in = lay.shape
    rng = np.random.default_rng(0)
    if lay.mode in ("acsr", "aida"):
        b = lay.blocked
        key = acsr_key(b.nblocks, b.rmax, b.block_rows, n_in, batch,
                       b.centroids is not None, interpret)
        if get(key) is not None:
            return get(key)
        x = jnp.asarray(rng.normal(size=(n_in, batch)).astype(np.float32))

        def run(c):
            return sp.acsr_spmv(b, x, mb=c.tile("mb"), bk=c.tile("bk"),
                                interpret=interpret)
        return autotune(key, acsr_candidates(b.nblocks, n_in), run)
    if lay.mode == "int8":
        key = int8_key(n_out, n_in, batch, interpret)
        if get(key) is not None:
            return get(key)
        x = jnp.asarray(rng.normal(size=(batch, n_in)).astype(np.float32))
        from repro.core import quant as q
        # jit the XLA candidate — inside a decode step it runs XLA-fused
        xla_run = jax.jit(lambda xx: q.int8_matmul_ref(xx, lay.qt))

        def run(c):
            if c.impl == "xla":
                return xla_run(x)
            return i8.int8_matmul(x, lay.qt.q, lay.qt.scale,
                                  bm=c.tile("bm"), bn=c.tile("bn"),
                                  bk=c.tile("bk"), interpret=interpret)
        return autotune(key, int8_candidates(n_out, n_in), run)
    if lay.mode == "codebook4":
        key = lut_key(n_out, n_in, batch, interpret)
        if get(key) is not None:
            return get(key)
        x = jnp.asarray(rng.normal(size=(batch, n_in)).astype(np.float32))
        xla_run = jax.jit(lambda xx: ref.lut_matmul_ref(
            xx, lay.codes_packed, lay.centroids))

        def run(c):
            if c.impl == "xla":
                return xla_run(x)
            return lm.lut_matmul(x, lay.codes_packed, lay.centroids,
                                 bm=c.tile("bm"), bn=c.tile("bn"),
                                 bk=c.tile("bk"), interpret=interpret)
        return autotune(key, lut_candidates(n_out, n_in), run)
    return None


def tune_paged(cfg, batch: int, max_len: int, page_size: int,
               kv_dtype: str, interpret: bool) -> Optional[KernelChoice]:
    """Search the paged-attention impl/tile space for one serving
    geometry (cfg attention shape x batch x table width) on a synthetic
    fully-populated pool — the worst-case gather the decode step runs."""
    import jax
    import jax.numpy as jnp
    from repro import kvstore as kvsto

    hkv, dh = cfg.n_kv, cfg.head_dim
    group = cfg.n_heads // hkv
    npp = -(-max_len // page_size)
    quantized = kv_dtype == "int8"
    key = paged_key(hkv, group, dh, page_size, npp, batch, quantized,
                    interpret)
    if get(key) is not None:
        return get(key)
    rng = np.random.default_rng(0)
    pool = kvsto.init_pool(1 + batch * npp, hkv, page_size, dh,
                           kv_dtype=kv_dtype)
    # every table slot owns a page and every slot is written: tune on the
    # full-occupancy gather, the steady-state cost of a long sequence
    table = jnp.asarray(
        1 + np.arange(batch * npp).reshape(batch, npp), jnp.int32)
    for t in range(max_len):
        pool = kvsto.update(
            pool, table,
            jnp.asarray(rng.normal(size=(batch, hkv, dh)), jnp.float32),
            jnp.asarray(rng.normal(size=(batch, hkv, dh)), jnp.float32),
            jnp.full((batch,), t, jnp.int32))
    q = jnp.asarray(rng.normal(size=(batch, cfg.n_heads, dh)), jnp.float32)
    cur = jnp.full((batch,), max_len - 1, jnp.int32)
    win = jnp.int32(-1)
    # jit the XLA candidate — inside a decode step it runs XLA-fused
    xla_run = jax.jit(lambda qq, cc, ww: kvsto.paged_attention_xla(
        qq, pool, table, cc, ww, scale=cfg.attn_scale,
        cap=cfg.attn_softcap))

    def run(c):
        if c.impl == "xla":
            return xla_run(q, cur, win)
        return kvsto.paged_attention_pallas(
            q, pool, table, cur, win, scale=cfg.attn_scale,
            cap=cfg.attn_softcap, pb=c.tile("pb", 2), interpret=interpret)
    return autotune(key, paged_candidates(npp), run)


def tune_paged_chunk(cfg, batch: int, max_len: int, page_size: int,
                     chunk: int, kv_dtype: str,
                     interpret: bool) -> Optional[KernelChoice]:
    """Search the chunked-prefill paged-attention space for one serving
    geometry: a [batch, H, chunk, Dh] query block over a fully-populated
    synthetic pool — the steady-state cost of the last prefill chunk of a
    long prompt."""
    import jax
    import jax.numpy as jnp
    from repro import kvstore as kvsto

    if chunk <= 1:
        return None
    hkv, dh = cfg.n_kv, cfg.head_dim
    group = cfg.n_heads // hkv
    npp = -(-max_len // page_size)
    quantized = kv_dtype == "int8"
    key = paged_chunk_key(hkv, group, dh, page_size, npp, batch, chunk,
                          interpret=interpret, quantized=quantized)
    if get(key) is not None:
        return get(key)
    rng = np.random.default_rng(0)
    pool = kvsto.init_pool(1 + batch * npp, hkv, page_size, dh,
                           kv_dtype=kv_dtype)
    table = jnp.asarray(
        1 + np.arange(batch * npp).reshape(batch, npp), jnp.int32)
    for t in range(max_len):
        pool = kvsto.update(
            pool, table,
            jnp.asarray(rng.normal(size=(batch, hkv, dh)), jnp.float32),
            jnp.asarray(rng.normal(size=(batch, hkv, dh)), jnp.float32),
            jnp.full((batch,), t, jnp.int32))
    q = jnp.asarray(rng.normal(size=(batch, cfg.n_heads, chunk, dh)),
                    jnp.float32)
    # query the trailing chunk of the sequence (the worst-case mask span)
    q_pos = jnp.broadcast_to(
        jnp.arange(max_len - chunk, max_len, dtype=jnp.int32)[None, :],
        (batch, chunk))
    win = jnp.int32(-1)
    xla_run = jax.jit(lambda qq, pp, ww: kvsto.paged_attention_xla_chunk(
        qq, pool, table, pp, ww, scale=cfg.attn_scale,
        cap=cfg.attn_softcap))

    def run(c):
        if c.impl == "xla":
            return xla_run(q, q_pos, win)
        return kvsto.paged_attention_pallas_chunk(
            q, pool, table, q_pos, win, scale=cfg.attn_scale,
            cap=cfg.attn_softcap, pb=c.tile("pb", 2),
            qt=c.tile("qt", chunk), interpret=interpret)
    return autotune(key, paged_chunk_candidates(npp, chunk), run)


def tune_params(params, batch: int, interpret: bool) -> int:
    """Tune every unique CompressedFC geometry found in a param pytree.
    Returns the number of newly tuned cache entries."""
    import jax
    from repro.core import sparse_fc as sfc

    before = len(_CACHE)

    def visit(leaf):
        # no (mode, shape)-level dedupe: same-shape projections can still
        # differ in geometry (rmax varies per weight matrix), and the
        # cache key is the real dedupe — tune_layer returns immediately
        # on a key hit
        if isinstance(leaf, sfc.CompressedFC) and leaf.mode != "dense":
            tune_layer(leaf, batch, interpret)
        return leaf

    jax.tree_util.tree_map(
        visit, params,
        is_leaf=lambda x: isinstance(x, sfc.CompressedFC))
    return len(_CACHE) - before


# --------------------------------------------------- encode-time block_rows
_BLOCK_ROWS_CACHE: Dict[Tuple, int] = {}


def choose_block_rows(w: np.ndarray, mode: str, density: float,
                      default: int = 128, batch: int = 2,
                      candidates: Sequence[int] = (64, 128, 256),
                      interpret: bool = True) -> int:
    """Encode-time tile search over the row-block height (re-encodes the
    pruned matrix per candidate and times the fused kernel).  Cached by
    (shape, mode); only consulted when REPRO_TUNE_BLOCK_ROWS=1 since
    re-encoding per candidate is much slower than the (mb, bk) search."""
    import jax.numpy as jnp
    from repro.kernels import acsr_spmv as sp
    from repro.obs import timeit

    key = (w.shape, mode, density)
    if key in _BLOCK_ROWS_CACHE:
        return _BLOCK_ROWS_CACHE[key]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(w.shape[1], batch)).astype(np.float32))
    best, best_t = default, float("inf")
    for br in candidates:
        try:
            if mode == "aida":
                # time the coded kernel the real decode will run
                nz = w[w != 0]
                cents = np.concatenate(
                    [[0.0], np.quantile(nz, np.linspace(0.02, 0.98, 15))]
                ).astype(np.float32) if nz.size else np.zeros(16, np.float32)
                blocked = sp.block_encode_coded(w, cents, block_rows=br)
            else:
                blocked = sp.block_encode(w, block_rows=br)
            # best-of-3 samples of 3 calls (noise floor on a busy host)
            dt = timeit(sp.acsr_spmv, blocked, x, interpret=interpret,
                        reps=3, inner=3)
        except Exception:
            continue
        if dt < best_t:
            best, best_t = br, dt
    _BLOCK_ROWS_CACHE[key] = best
    return best
