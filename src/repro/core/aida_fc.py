"""Paper Fig. 3 — the AIDA FC-layer algorithm, executed on the AP emulator.

Stages (all massively parallel across PUs = CAM rows):
  1. activation broadcast — per nonzero activation: one fused compare+write
     (paper lines 2–5, "lines 3 and 4 are executed in parallel"),
  2. multiplication — bit-serial schoolbook multiply of every (W, B) pair at
     once, each single-bit op realized by perfect induction (lines 7–12),
  3. soft reduction — binary-tree segmented accumulation steered by the ACSR
     row flags; odd partials are tag-Moved onto even ones and added
     bit-serially until every '10' (last) flag merges into its '01' (first),
     turning it '11' (lines 14–26),
  4. activation function — ReLU: match the sign bit, write zeros (lines 28–29).

Implementation elaborations beyond the paper's pseudocode (documented in
DESIGN.md §7): two's-complement product/accumulator with explicit sign fix
(the paper leaves signed arithmetic unspecified), a per-PU local-position
field POS used to key the tree senders (the paper steers with the moved
row-flag MSB; POS is precomputable at ACSR-encode time and keeps every
controller step data-independent), and a dedicated move-receive field MV
(the paper reuses the B field).

Every step is issued through AP primitives, so `ap.counters` afterwards holds
the exact cycle count; `aida_sim.cycles_fc` reproduces it in closed form and
tests assert equality.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import numpy as np

from repro.core import acsr as acsr_mod
from repro.core.associative import AP, Field, move_cycles  # noqa: F401


@dataclasses.dataclass
class Layout:
    """CAM bit-column layout for one FC layer instance."""
    flag: Field      # 2 bits: bit0 = FIRST, bit1 = LAST  ('01','10','11')
    alive: Field     # 1 bit
    pos: Field       # local index within the matrix-row segment
    col_idx: Field   # column index of the nonzero weight
    w: Field         # weight magnitude, m bits
    w_sign: Field    # 1 bit
    b: Field         # activation magnitude, n bits
    b_sign: Field    # 1 bit
    c: Field         # accumulator, two's complement, kc bits
    mv: Field        # move-receive buffer, kc bits
    mv_last: Field   # 1 bit: moved LAST flag
    t: Field         # 1 bit: AND result
    carry: Field     # 1 bit
    scr: Field       # 1 bit: carry/bit snapshot
    scr_a: Field     # 1 bit: addend snapshot
    psign: Field     # 1 bit: product sign
    total_bits: int


def make_layout(m: int, n: int, ci_bits: int, pos_bits: int,
                kc: int) -> Layout:
    base = 0
    fields = {}
    for name, width in [("flag", 2), ("alive", 1), ("pos", pos_bits),
                        ("col_idx", ci_bits), ("w", m), ("w_sign", 1),
                        ("b", n), ("b_sign", 1), ("c", kc), ("mv", kc),
                        ("mv_last", 1), ("t", 1), ("carry", 1), ("scr", 1),
                        ("scr_a", 1), ("psign", 1)]:
        fields[name] = Field(base, width)
        base += width
    return Layout(total_bits=base, **fields)


# --------------------------------------------------------- micro-operations
def clear_bits(ap: AP, cols) -> None:
    """Tag all rows (empty compare mask) + parallel write zeros: 1 cycle."""
    cols = np.atleast_1d(np.asarray(cols))
    ap.compare_write([], [], cols, np.zeros(cols.size, np.uint8))


def bit_and(ap: AP, dst: int, a: int, b: int) -> None:
    """dst = a & b by perfect induction: clear + match the single 1-entry."""
    clear_bits(ap, [dst])
    ap.compare_write([a, b], [1, 1], [dst], [1])


def snapshot(ap: AP, src: int, dst: int) -> None:
    """dst = src (2 cycles: clear, conditional set)."""
    clear_bits(ap, [dst])
    ap.compare_write([src], [1], [dst], [1])


def full_add(ap: AP, a: int, b: int, carry: int, scr: int, scr_a: int) -> None:
    """(a, carry) = a + b + carry, in-place, by perfect induction.

    Keys match snapshots (scr_a, b, scr) — none written — so truth-table
    order is irrelevant.  Entries 000 and 111 are fixed points (no write).
    10 cycles, data-independent.
    """
    snapshot(ap, carry, scr)
    snapshot(ap, a, scr_a)
    for av, bv, cv in [(0, 0, 1), (0, 1, 0), (0, 1, 1),
                       (1, 0, 0), (1, 0, 1), (1, 1, 0)]:
        s = av ^ bv ^ cv
        cout = (av & bv) | (cv & (av | bv))
        ap.compare_write([scr_a, b, scr], [av, bv, cv],
                         [a, carry], [s, cout])


def half_add(ap: AP, a: int, carry: int, scr: int, scr_a: int) -> None:
    """(a, carry) = a + carry (carry ripple step). 6 cycles."""
    snapshot(ap, carry, scr)
    snapshot(ap, a, scr_a)
    ap.compare_write([scr_a, scr], [0, 1], [a, carry], [1, 0])
    ap.compare_write([scr_a, scr], [1, 1], [a, carry], [0, 1])


# ------------------------------------------------------------- the FC layer
def load_cam(ap: AP, lay: Layout, a: acsr_mod.ACSR,
             w_int: np.ndarray) -> np.ndarray:
    """DMA the ACSR image into the CAM (host-side, not cycle-counted).

    Returns the per-PU local positions (for assertions only).
    """
    seg = np.asarray(a.seg_id)
    flags = np.asarray(a.row_flag)
    cols = np.asarray(a.col_idx)
    nnz = a.nnz
    pos = np.zeros(ap.rows, np.int64)
    run = 0
    for r in range(nnz):
        if flags[r] & acsr_mod.FLAG_FIRST:
            run = 0
        pos[r] = run
        run += 1
        ap.load_field(r, lay.flag, int(flags[r]))
        ap.load_field(r, lay.alive, 1)
        ap.load_field(r, lay.pos, int(pos[r]))
        ap.load_field(r, lay.col_idx, int(cols[r]))
        wv = int(w_int[r])
        ap.load_field(r, lay.w, abs(wv))
        ap.load_field(r, lay.w_sign, 1 if wv < 0 else 0)
    del seg
    return pos


def broadcast(ap: AP, lay: Layout, b_int: np.ndarray) -> int:
    """Stage 1 (lines 2–5): one fused compare+write per nonzero activation."""
    n_bits = lay.b.width
    ci = lay.col_idx.width
    nnz_b = 0
    for idx in range(b_int.shape[0]):
        val = int(b_int[idx])
        if val == 0:
            continue  # sparsity: zero activations are never broadcast
        nnz_b += 1
        key = [(idx >> k) & 1 for k in range(ci)]
        bits = [(abs(val) >> k) & 1 for k in range(n_bits)]
        bits.append(1 if val < 0 else 0)
        ap.compare_write(lay.col_idx.cols(), key,
                         np.concatenate([lay.b.cols(), lay.b_sign.cols()]),
                         bits)
    return nnz_b


def multiply(ap: AP, lay: Layout) -> None:
    """Stage 2 (lines 7–12): bit-serial W×B into C, all PUs in parallel."""
    m, n, kc = lay.w.width, lay.b.width, lay.c.width
    t, carry = lay.t.col(0), lay.carry.col(0)
    scr, scr_a = lay.scr.col(0), lay.scr_a.col(0)
    for j in range(n):
        for i in range(m):
            bit_and(ap, t, lay.w.col(i), lay.b.col(j))
            full_add(ap, lay.c.col(i + j), t, carry, scr, scr_a)
        # worst-case (data-independent) carry ripple to the product top bit
        for p in range(j + m, m + n):
            half_add(ap, lay.c.col(p), carry, scr, scr_a)
    # sign fix: psign = w_sign XOR b_sign; negate C on negative products
    ps = lay.psign.col(0)
    clear_bits(ap, [ps])
    ap.compare_write([lay.w_sign.col(0), lay.b_sign.col(0)], [1, 0], [ps], [1])
    ap.compare_write([lay.w_sign.col(0), lay.b_sign.col(0)], [0, 1], [ps], [1])
    for bpos in range(kc):  # bitwise NOT on tagged rows (4 cycles/bit)
        cb = lay.c.col(bpos)
        snapshot(ap, cb, scr)
        ap.compare_write([ps, scr], [1, 0], [cb], [1])
        ap.compare_write([ps, scr], [1, 1], [cb], [0])
    clear_bits(ap, [t])                       # +1 via T column
    ap.compare_write([ps], [1], [t], [1])
    full_add(ap, lay.c.col(0), t, carry, scr, scr_a)
    for p in range(1, kc):
        half_add(ap, lay.c.col(p), carry, scr, scr_a)
    clear_bits(ap, [carry])


def soft_reduction(ap: AP, lay: Layout) -> int:
    """Stage 3 (lines 14–26): segmented binary-tree accumulation.

    Returns the number of rounds executed (paper: do-while any '10' alive).
    """
    kc = lay.c.width
    t_col, carry = lay.t.col(0), lay.carry.col(0)
    scr, scr_a = lay.scr.col(0), lay.scr_a.col(0)
    del t_col
    rounds = 0
    while True:
        d = 1 << rounds
        # sender key: POS ≡ 2^t (mod 2^{t+1}) and ALIVE
        pos_cols = lay.pos.cols(0, min(rounds + 1, lay.pos.width))
        pos_key = [0] * (len(pos_cols) - 1) + [1] if len(pos_cols) > rounds \
            else [0] * len(pos_cols)
        sender_cols = np.concatenate([pos_cols, lay.alive.cols()])
        sender_key = np.array(pos_key + [1], np.uint8)

        clear_bits(ap, np.concatenate([lay.mv.cols(), lay.mv_last.cols()]))
        # per-bit: tag sender bits, shift tags up by d, deposit into MV
        move_srcs = [(lay.c.col(bpos), lay.mv.col(bpos)) for bpos in range(kc)]
        move_srcs.append((lay.flag.col(1), lay.mv_last.col(0)))  # LAST flag
        for src, dst in move_srcs:
            ap.compare(np.concatenate([sender_cols, [src]]),
                       np.concatenate([sender_key, [1]]))
            ap.move_by("up", d)
            ap.write([dst], [1])
        # receivers accumulate: C += MV  (runs on all PUs; MV=0 elsewhere)
        for bpos in range(kc):
            full_add(ap, lay.c.col(bpos), lay.mv.col(bpos), carry, scr, scr_a)
        clear_bits(ap, [carry])
        # fold the moved LAST flag: '01' head that received it becomes '11'
        ap.compare_write(lay.mv_last.cols(), [1], [lay.flag.col(1)], [1])
        # senders die
        ap.compare_write(sender_cols, sender_key, lay.alive.cols(), [0])
        rounds += 1
        # completion check (lines 25–26): any ALIVE row still flagged '10'?
        ap.compare([lay.flag.col(0), lay.flag.col(1), lay.alive.col(0)],
                   [0, 1, 1])
        if not ap.if_match():
            return rounds


def relu(ap: AP, lay: Layout) -> None:
    """Stage 4 (lines 28–29): match sign bit, write zeros. One fused cycle."""
    kc = lay.c.width
    ap.compare_write([lay.c.col(kc - 1)], [1],
                     lay.c.cols(), np.zeros(kc, np.uint8))


# ---------------------------------------------------- coded (bit-parallel)
def multiply_coded(ap: AP, lay: Layout, cents_w: np.ndarray,
                   cents_a: np.ndarray) -> int:
    """Bit-parallel perfect induction (§3): traverse all multiplier×
    multiplicand code combinations, substitute precomputed products.

    One fused compare+write per (w_code, a_code) pair — for 4-bit codebooks
    that is 15×15 = 225 cycles for the ENTIRE multiplication stage,
    independent of nnz. Code 0 is the structural zero (product 0 = the
    preloaded C), so zero combos are skipped. Returns cycles spent.
    """
    cw_bits, ca_bits = lay.w.width, lay.b.width
    kc = lay.c.width
    cycles = 0
    for wc in range(1, 1 << cw_bits):
        for ac in range(1, 1 << ca_bits):
            prod = int(cents_w[wc]) * int(cents_a[ac])
            bits = [(prod >> k) & 1 for k in range(kc)]  # 2's complement
            key_w = [(wc >> k) & 1 for k in range(cw_bits)]
            key_a = [(ac >> k) & 1 for k in range(ca_bits)]
            ap.compare_write(
                np.concatenate([lay.w.cols(), lay.b.cols()]),
                key_w + key_a, lay.c.cols(), bits)
            cycles += 1
    return cycles


def aida_fc_layer_coded(w_codes: np.ndarray, b_codes: np.ndarray,
                        cents_w: np.ndarray, cents_a: np.ndarray,
                        activation: Optional[str] = "relu",
                        block: int = 1) -> "FCResult":
    """Coded-mode FC layer: 4-bit weight/activation codes, product LUT.

    w_codes: [N, K] uint (0 = structural zero), cents_w/cents_a: integer
    codebooks with cents[0] == 0.  This is AIDA's compressed-network
    configuration (the one benchmarked in Table 1).
    """
    w_codes = np.asarray(w_codes, dtype=np.int64)
    b_codes = np.asarray(b_codes, dtype=np.int64)
    cents_w = np.asarray(cents_w, dtype=np.int64)
    cents_a = np.asarray(cents_a, dtype=np.int64)
    assert cents_w[0] == 0 and cents_a[0] == 0, "code 0 is the structural zero"
    n_rows, n_cols = w_codes.shape
    cw_bits = max(1, math.ceil(math.log2(len(cents_w))))
    ca_bits = max(1, math.ceil(math.log2(len(cents_a))))

    a = acsr_mod.encode(w_codes.astype(np.float64), block=block)
    seg = np.asarray(a.seg_id)[: a.nnz]
    row_nnz = np.bincount(seg, minlength=n_rows) if a.nnz else np.zeros(n_rows)
    max_row_nnz = int(row_nnz.max(initial=1)) or 1
    pmax = int(np.abs(np.outer(cents_w, cents_a)).max())
    prod_bits = max(1, math.ceil(math.log2(pmax + 1)))
    acc_bits = max(0, math.ceil(math.log2(max_row_nnz))) if max_row_nnz > 1 else 0
    kc = prod_bits + acc_bits + 1
    pos_bits = max(1, math.ceil(math.log2(max_row_nnz))) if max_row_nnz > 1 else 1
    ci_bits = max(1, math.ceil(math.log2(max(n_cols, 2))))

    lay = make_layout(cw_bits, ca_bits, ci_bits, pos_bits, kc)
    ap = AP(rows=a.nnz_pad, bits=lay.total_bits)
    codes = np.zeros(ap.rows, np.int64)
    codes[: a.nnz] = np.asarray(a.values)[: a.nnz].astype(np.int64)
    load_cam(ap, lay, a, codes)  # loads |code| into W field (codes ≥ 0)

    nnz_b = broadcast(ap, lay, b_codes)  # writes b codes into the B field
    multiply_coded(ap, lay, cents_w, cents_a)
    rounds = soft_reduction(ap, lay)
    if activation == "relu":
        relu(ap, lay)

    out = np.zeros(n_rows, np.int64)
    flags = np.asarray(a.row_flag)[: a.nnz]
    segs = np.asarray(a.seg_id)[: a.nnz]
    for r in range(a.nnz):
        if flags[r] & acsr_mod.FLAG_FIRST:
            out[segs[r]] = ap.read_field(r, lay.c, signed=True)
    return FCResult(out=out, cycles=ap.counters["cycles"], rounds=rounds,
                    nnz_b=nnz_b, counters=dict(ap.counters), layout=lay,
                    max_row_nnz=max_row_nnz)


def fc_reference_coded(w_codes, b_codes, cents_w, cents_a,
                       activation: Optional[str] = "relu") -> np.ndarray:
    w = np.asarray(cents_w)[np.asarray(w_codes, np.int64)]
    b = np.asarray(cents_a)[np.asarray(b_codes, np.int64)]
    out = w.astype(np.int64) @ b.astype(np.int64)
    if activation == "relu":
        out = np.maximum(out, 0)
    return out


# ------------------------------------------------------------------ driver
@dataclasses.dataclass
class FCResult:
    out: np.ndarray            # [n_rows] int64 output activations
    cycles: int
    rounds: int
    nnz_b: int
    counters: dict
    layout: Layout
    max_row_nnz: int


def aida_fc_layer(w_int: np.ndarray, b_int: np.ndarray, m: int, n: int,
                  activation: Optional[str] = "relu",
                  block: int = 1) -> FCResult:
    """Run one FC layer C = f(W×B) through the emulator.

    w_int: [N, K] integer weight matrix (|w| < 2^m), b_int: [K] (|b| < 2^n).
    """
    w_int = np.asarray(w_int, dtype=np.int64)
    b_int = np.asarray(b_int, dtype=np.int64)
    n_rows, n_cols = w_int.shape
    assert np.abs(w_int).max(initial=0) < (1 << m)
    assert np.abs(b_int).max(initial=0) < (1 << n)

    a = acsr_mod.encode(w_int.astype(np.float64), block=block)
    # per-row nnz → accumulator width and POS width
    seg = np.asarray(a.seg_id)[: a.nnz]
    row_nnz = np.bincount(seg, minlength=n_rows) if a.nnz else np.zeros(n_rows)
    max_row_nnz = int(row_nnz.max(initial=1)) or 1
    acc_bits = max(1, math.ceil(math.log2(max(max_row_nnz, 1)))) \
        if max_row_nnz > 1 else 0
    kc = m + n + acc_bits + 1
    pos_bits = max(1, math.ceil(math.log2(max_row_nnz))) \
        if max_row_nnz > 1 else 1
    ci_bits = max(1, math.ceil(math.log2(max(n_cols, 2))))

    lay = make_layout(m, n, ci_bits, pos_bits, kc)
    ap = AP(rows=a.nnz_pad, bits=lay.total_bits)
    w_vals = np.asarray(a.values)[: a.nnz].astype(np.int64)
    w_stream = np.zeros(ap.rows, np.int64)
    w_stream[: a.nnz] = w_vals
    load_cam(ap, lay, a, w_stream)

    nnz_b = broadcast(ap, lay, b_int)
    multiply(ap, lay)
    rounds = soft_reduction(ap, lay)
    if activation == "relu":
        relu(ap, lay)

    # read out: head PUs (FIRST or ONLY flag) hold the row results
    out = np.zeros(n_rows, np.int64)
    flags = np.asarray(a.row_flag)[: a.nnz]
    segs = np.asarray(a.seg_id)[: a.nnz]
    for r in range(a.nnz):
        if flags[r] & acsr_mod.FLAG_FIRST:
            out[segs[r]] = ap.read_field(r, lay.c, signed=True)
    return FCResult(out=out, cycles=ap.counters["cycles"], rounds=rounds,
                    nnz_b=nnz_b, counters=dict(ap.counters), layout=lay,
                    max_row_nnz=max_row_nnz)


def fc_reference(w_int: np.ndarray, b_int: np.ndarray,
                 activation: Optional[str] = "relu") -> np.ndarray:
    """Plain integer matvec oracle."""
    out = np.asarray(w_int, np.int64) @ np.asarray(b_int, np.int64)
    if activation == "relu":
        out = np.maximum(out, 0)
    return out
