"""Trace analytics: turn the tick-clock event stream into attribution.

PR 8 made the serving stack *emit* a deterministic trace; this module
*consumes* it.  :func:`analyze` takes a live :class:`~repro.obs.Tracer`
(or an exported Chrome/Perfetto JSON path, or a raw event list) and
folds it into a :class:`TraceReport`:

* **per-request critical path** — every tick between ``req.submit`` and
  the terminal event is attributed to exactly one phase (``queue`` /
  ``prefill`` / ``handoff`` / ``decode``) by replaying the request's
  lifecycle events as a state machine, so the segments *sum to the
  submit->finish span by construction*.  Fault/retry/degrade activity
  shows up as detour counters (preemptions, re-admissions, handoff
  drops, fallbacks), never as unattributed time.
* **queueing split** — queue-wait ticks (time not occupying a slot)
  separated from service ticks, each as mean/p50/p99.
* **per-role / per-seam attribution** — step counts, busy-step
  utilization, and event counts per seam name for every role.
* **page-pool pressure timeline** — the allocator's ``in_use`` level
  per role over ticks (change-compressed), plus peak/alloc/free/
  holdback totals.
* **SLO evaluation** — a declarative :class:`SLOSpec` (scheduling-clock
  TTFT p99, TPOT p99, goodput floor) scored against the report, with
  the violating requests *named*.

The analysis is a pure function of the trace: no wall clock, no
environment, no provenance timestamps enter the report, so two
same-seed serves produce **byte-identical** ``TraceReport`` JSON —
the same CI property the trace export itself has.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Union

from repro.obs.registry import Histogram, percentile
from repro.obs.trace import TICK_US

#: report schema version (bump on any key change — CI diffs report bytes)
SCHEMA = "repro.obs.analyze/v1"

#: critical-path phases a request can occupy, in lifecycle order
PHASES = ("queue", "prefill", "handoff", "decode")

#: lifecycle event -> the phase the request is in AFTER seeing it
_PHASE_AFTER = {
    "req.submit": "queue",
    "sched.admit": "prefill",       # re-admission after preempt too
    "req.first_token": "decode",
    "handoff.enqueue": "handoff",   # waiting for the decode role
    "handoff.deliver": "decode",
    "handoff.fallback": "queue",    # back to the decode role's queue
    "sched.preempt": "queue",
}
#: terminal lifecycle events -> request outcome
_TERMINAL = {"req.finish": "completed", "resil.fail": "failed"}


# ----------------------------------------------------------- trace input
def events_from_chrome(doc: dict) -> List[dict]:
    """Invert ``Tracer.to_chrome()``: Chrome ``trace_event`` rows back to
    the tracer's internal event dicts (name/ph/tick/role/slot/args), in
    file order.  Roles come from the ``process_name`` metadata rows;
    ticks from the ``args.tick`` echo every exported event carries."""
    roles: Dict[int, str] = {}
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") == "M" and ev.get("name") == "process_name":
            roles[ev.get("pid")] = ev.get("args", {}).get("name")
    out: List[dict] = []
    for ev in doc.get("traceEvents", []):
        ph = ev.get("ph")
        if ph not in ("X", "i"):
            continue
        args = dict(ev.get("args", {}))
        tick = args.pop("tick", ev.get("ts", 0) // TICK_US)
        tid = ev.get("tid", 0)
        rec = {"name": ev.get("name"), "ph": ph, "tick": int(tick),
               "role": roles.get(ev.get("pid"), str(ev.get("pid"))),
               "slot": (int(tid) - 1) if tid else None, "args": args}
        if ph == "X":
            rec["dur"] = int(ev.get("dur", TICK_US)) // TICK_US
        out.append(rec)
    return out


def load_trace(path: str) -> List[dict]:
    """Load an exported Chrome trace file back into event-dict form."""
    with open(path) as f:
        return events_from_chrome(json.load(f))


def coerce_events(trace) -> List[dict]:
    """Accept a live Tracer, an exported-trace path, a Chrome JSON doc,
    or a raw event list — return the event list."""
    if hasattr(trace, "events"):                 # live Tracer
        return list(trace.events)
    if isinstance(trace, str):
        return load_trace(trace)
    if isinstance(trace, dict):
        return events_from_chrome(trace)
    return list(trace)


# ------------------------------------------------------------------- SLO
@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Declarative serving SLO, all in deterministic scheduler-tick /
    fraction units (wall clock never gates):

    * ``ttft_p99`` — p99 of scheduling-clock TTFT (submit -> first
      token, ticks) must be <= this;
    * ``tpot_p99`` — p99 of per-request ticks-per-output-token (after
      the first token) must be <= this;
    * ``goodput`` — completed/submitted fraction must be >= this.

    Unset fields don't gate.  ``evaluate`` names every violating rid.
    """

    ttft_p99: Optional[float] = None
    tpot_p99: Optional[float] = None
    goodput: Optional[float] = None

    @classmethod
    def parse(cls, spec: str) -> "SLOSpec":
        """``"ttft_p99=40,tpot_p99=4,goodput=0.95"`` (``ttft``/``tpot``
        accepted as aliases)."""
        alias = {"ttft": "ttft_p99", "ttft_p99": "ttft_p99",
                 "tpot": "tpot_p99", "tpot_p99": "tpot_p99",
                 "goodput": "goodput"}
        kw: Dict[str, float] = {}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            key, sep, val = part.partition("=")
            key = key.strip().lower()
            if not sep or key not in alias:
                raise ValueError(
                    f"bad SLO term {part!r}; want "
                    "ttft_p99=N,tpot_p99=N,goodput=F")
            kw[alias[key]] = float(val)
        if not kw:
            raise ValueError(f"empty SLO spec {spec!r}")
        return cls(**kw)

    def describe(self) -> dict:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}

    def evaluate(self, requests: Dict[str, dict]) -> dict:
        """Score per-request report records (``TraceReport.requests``
        values) against the declared bounds.  Returns ``{"spec", "pass",
        "metrics": {name: {bound, value, pass, violators}}}``."""
        metrics: Dict[str, dict] = {}
        if self.ttft_p99 is not None:
            vals = {rid: r["ttft_sched"] for rid, r in requests.items()
                    if r.get("ttft_sched") is not None}
            p99 = percentile(list(vals.values()), 99)
            metrics["ttft_p99"] = {
                "bound": self.ttft_p99,
                "value": p99,
                "pass": p99 is not None and p99 <= self.ttft_p99,
                "violators": sorted(
                    (int(rid) for rid, v in vals.items()
                     if v > self.ttft_p99), key=int),
            }
        if self.tpot_p99 is not None:
            vals = {rid: r["tpot_ticks"] for rid, r in requests.items()
                    if r.get("tpot_ticks") is not None}
            p99 = percentile(list(vals.values()), 99)
            metrics["tpot_p99"] = {
                "bound": self.tpot_p99,
                "value": p99,
                "pass": p99 is not None and p99 <= self.tpot_p99,
                "violators": sorted(
                    (int(rid) for rid, v in vals.items()
                     if v > self.tpot_p99), key=int),
            }
        if self.goodput is not None:
            done = [rid for rid, r in requests.items()
                    if r["outcome"] == "completed"]
            frac = round(len(done) / len(requests), 4) if requests \
                else None
            metrics["goodput"] = {
                "bound": self.goodput,
                "value": frac,
                "pass": frac is not None and frac >= self.goodput,
                "violators": sorted(
                    (int(rid) for rid, r in requests.items()
                     if r["outcome"] != "completed")),
            }
        return {"spec": self.describe(),
                "pass": all(m["pass"] for m in metrics.values()),
                "metrics": metrics}


# ------------------------------------------------------------ the report
@dataclasses.dataclass
class TraceReport:
    """Structured, JSON-ready trace analysis.  Every field is a pure
    function of the trace events (plus the optional SLOSpec) — no wall
    clock, no provenance — so ``to_json()`` is byte-identical across
    same-seed replays."""

    schema: str
    ticks: dict                  # {"begin", "end", "span"}
    requests: Dict[str, dict]    # str(rid) -> lifecycle record
    critical_path: dict          # phase -> {"ticks", "share"}
    queueing: dict               # queue_wait/service/ttft_sched/tpot dists
    roles: dict                  # role -> steps/busy/utilization
    seams: dict                  # role -> {event name: count}
    pages: dict                  # role -> pressure timeline + totals
    detours: dict                # fault/degrade/audit/shed totals
    slo: Optional[dict]          # SLOSpec.evaluate() output, if given

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        """Canonical serialization (sorted keys, trailing newline) —
        the byte form CI diffs across same-seed replays."""
        return json.dumps(self.to_dict(), indent=1, sort_keys=True) + "\n"

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    def segments_consistent(self) -> bool:
        """The acceptance invariant: each request's critical-path
        segments sum exactly to its submit->end tick span."""
        return all(sum(r["segments"].values()) == r["span"]
                   for r in self.requests.values())


def _dist(values: Sequence[float]) -> Optional[dict]:
    h = Histogram("_dist")
    h.observe_many(values)
    return h.summary()


def _request_paths(events: Sequence[dict], end_tick: int) -> Dict[str, dict]:
    """Replay each rid's lifecycle events as a phase state machine.
    Every tick between submit and the terminal event lands in exactly
    one phase bucket; unfinished requests accumulate to the trace end."""
    reqs: Dict[int, dict] = {}
    for ev in events:
        rid = ev["args"].get("rid")
        if rid is None:
            continue
        name, t = ev["name"], ev["tick"]
        if name == "req.submit":
            reqs[rid] = {
                "submit_tick": t, "finish_tick": None,
                "first_token_tick": None,
                "prompt_len": ev["args"].get("prompt_len"),
                "max_new": ev["args"].get("max_new"),
                "outcome": "unfinished", "tokens": 0,
                "segments": {p: 0 for p in PHASES},
                "detours": {},
                "_phase": "queue", "_t": t,
            }
            continue
        st = reqs.get(rid)
        if st is None or st["finish_tick"] is not None:
            continue
        det = st["detours"]
        if name == "sched.block":
            det["blocked"] = det.get("blocked", 0) + 1
            continue
        if name == "sched.shed":
            det["shed"] = det.get("shed", 0) + 1
            continue
        if name == "handoff.oversized":
            det["oversized"] = det.get("oversized", 0) + 1
            continue
        if name == "handoff.migrate":
            continue                         # deliver did the transition
        if name in _TERMINAL:
            st["segments"][st["_phase"]] += t - st["_t"]
            st["_t"] = t
            st["finish_tick"] = t
            st["outcome"] = _TERMINAL[name]
            if name == "req.finish":
                st["tokens"] = ev["args"].get("tokens", 0)
            else:
                st["failed_reason"] = ev["args"].get("reason")
                st["retries"] = ev["args"].get("retries", 0)
            continue
        nxt = _PHASE_AFTER.get(name)
        if nxt is None:
            continue
        st["segments"][st["_phase"]] += t - st["_t"]
        st["_phase"], st["_t"] = nxt, t
        if name == "req.first_token":
            st["first_token_tick"] = t
        elif name == "sched.preempt":
            det["preemptions"] = det.get("preemptions", 0) + 1
        elif name == "sched.admit" and ev["args"].get("resumed"):
            det["readmissions"] = det.get("readmissions", 0) + 1
        elif name == "handoff.fallback":
            det["handoff_fallbacks"] = det.get("handoff_fallbacks", 0) + 1
        elif name == "handoff.enqueue" and ev["args"].get("drops"):
            det["handoff_drops"] = (det.get("handoff_drops", 0)
                                    + ev["args"]["drops"])
    out: Dict[str, dict] = {}
    for rid, st in reqs.items():
        if st["finish_tick"] is None:        # still in flight at trace end
            st["segments"][st["_phase"]] += end_tick - st["_t"]
        end = st["finish_tick"] if st["finish_tick"] is not None \
            else end_tick
        st["span"] = end - st["submit_tick"]
        st["ttft_sched"] = (st["first_token_tick"] - st["submit_tick"]
                            if st["first_token_tick"] is not None else None)
        st["tpot_ticks"] = None
        if (st["outcome"] == "completed" and st["tokens"] > 1
                and st["first_token_tick"] is not None):
            st["tpot_ticks"] = round(
                (st["finish_tick"] - st["first_token_tick"])
                / (st["tokens"] - 1), 4)
        del st["_phase"], st["_t"]
        out[str(rid)] = st
    return out


def _roles(events: Sequence[dict], span: int) -> dict:
    out: dict = {}
    for ev in events:
        if not ev["name"].startswith("step."):
            continue
        r = out.setdefault(ev["role"], {
            "steps": 0, "busy_steps": 0, "decode_steps": 0,
            "prefill_steps": 0, "prefill_tokens": 0})
        r["steps"] += 1
        if ev["args"].get("active"):
            r["busy_steps"] += 1
        if ev["name"] == "step.decode":
            r["decode_steps"] += 1
        else:
            r["prefill_steps"] += 1
            r["prefill_tokens"] += ev["args"].get("tokens", 0)
    for r in out.values():
        r["utilization"] = round(r["busy_steps"] / span, 4) \
            if span > 0 else None
    return out


def _seams(events: Sequence[dict]) -> dict:
    out: Dict[str, Dict[str, int]] = {}
    for ev in events:
        role = out.setdefault(ev["role"], {})
        role[ev["name"]] = role.get(ev["name"], 0) + 1
    return out


def _pages(events: Sequence[dict]) -> dict:
    """Per-role page-pool pressure: the allocator's post-op ``in_use``
    level over ticks (one point per tick where the level changed),
    plus alloc/free/holdback totals and the peak level."""
    out: dict = {}
    for ev in events:
        name = ev["name"]
        if not name.startswith("alloc."):
            continue
        p = out.setdefault(ev["role"], {
            "timeline": [], "peak": 0, "allocs": 0, "frees": 0,
            "holdbacks": 0})
        if name == "alloc.holdback":
            p["holdbacks"] += 1
            continue
        in_use = ev["args"].get("in_use", 0)
        if name == "alloc.pages":
            p["allocs"] += ev["args"].get("n", 0)
        else:
            p["frees"] += ev["args"].get("n", 0)
        p["peak"] = max(p["peak"], in_use)
        tl = p["timeline"]
        if tl and tl[-1][0] == ev["tick"]:
            tl[-1][1] = in_use                  # last level on this tick
        else:
            tl.append([ev["tick"], in_use])
    for p in out.values():
        # change-compress: drop points that repeat the previous level
        tl, kept = p["timeline"], []
        for pt in tl:
            if not kept or kept[-1][1] != pt[1]:
                kept.append(pt)
        p["timeline"] = kept
    return out


def _detours(events: Sequence[dict]) -> dict:
    faults: Dict[str, int] = {}
    degrades = audits = sheds = fails = 0
    for ev in events:
        name = ev["name"]
        if name == "fault.injected":
            cls = ev["args"].get("fault", "?")
            faults[cls] = faults.get(cls, 0) + 1
        elif name == "resil.degrade":
            degrades += 1
        elif name == "health.audit":
            audits += 1
        elif name == "sched.shed":
            sheds += 1
        elif name == "resil.fail":
            fails += 1
    return {"faults": faults, "degrades": degrades, "audits": audits,
            "shed": sheds, "failed": fails}


def analyze(trace, slo: Optional[Union[SLOSpec, str]] = None) -> TraceReport:
    """Fold a trace (live Tracer / exported path / Chrome doc / event
    list) into a :class:`TraceReport`; optionally score an SLO."""
    if isinstance(slo, str):
        slo = SLOSpec.parse(slo)
    events = coerce_events(trace)
    begin = min((ev["tick"] for ev in events), default=0)
    end = max((ev["tick"] + ev.get("dur", 0) for ev in events), default=0)
    span = end - begin
    requests = _request_paths(events, end)
    totals = {p: sum(r["segments"][p] for r in requests.values())
              for p in PHASES}
    denom = sum(totals.values())
    critical_path = {
        p: {"ticks": totals[p],
            "share": round(totals[p] / denom, 4) if denom else None}
        for p in PHASES}
    queue_waits = [r["segments"]["queue"] for r in requests.values()]
    services = [r["span"] - r["segments"]["queue"]
                for r in requests.values()]
    queueing = {
        "queue_wait": _dist(queue_waits),
        "service": _dist(services),
        "ttft_sched": _dist([r["ttft_sched"] for r in requests.values()
                             if r["ttft_sched"] is not None]),
        "tpot_ticks": _dist([r["tpot_ticks"] for r in requests.values()
                             if r["tpot_ticks"] is not None]),
    }
    return TraceReport(
        schema=SCHEMA,
        ticks={"begin": begin, "end": end, "span": span},
        requests=requests,
        critical_path=critical_path,
        queueing=queueing,
        roles=_roles(events, span),
        seams=_seams(events),
        pages=_pages(events),
        detours=_detours(events),
        slo=slo.evaluate(requests) if slo is not None else None,
    )
