"""HuBERT-XLarge — encoder-only audio transformer (w2v2 arch); the conv
feature extractor is a STUB (precomputed frame embeddings [B, S, 512]).
No decode step (encoder). [arXiv:2106.07447]"""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv=16, d_ff=5120,
    vocab=504, d_head=80, causal=False, gated_mlp=False, act="gelu",
    norm="layer", frontend="audio", audio_in_dim=512,
    tie_embeddings=False, rope_theta=10000.0,
    source="arXiv:2106.07447"))
