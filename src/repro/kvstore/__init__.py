"""Paged, quantized KV-cache subsystem.

A shared page pool (int8 values + per-page scales) with per-sequence page
tables replaces the dense O(B·S_max) decode cache with an O(used pages)
one — the AIDA thesis (keep data resident, exploit lower precision)
applied to attention state.  See pool.py for the memory layout,
paged_attention.py for the decode kernel, alloc.py for the host-side
lifecycle, and api/session.py for the continuous-batching integration.
"""
from repro.kvstore.alloc import OutOfPages, PageAllocator, reclaimable_prefix
from repro.kvstore.paged_attention import (npp_bucket, paged_attention,
                                           paged_attention_chunk,
                                           paged_attention_pallas,
                                           paged_attention_pallas_chunk,
                                           paged_attention_xla,
                                           paged_attention_xla_chunk,
                                           resolve_paged,
                                           resolve_paged_chunk)
from repro.kvstore.pool import (GARBAGE_PAGE, NO_PAGE, PagedKV,
                                attention_mask, chunk_attention_mask,
                                copy_pages, dense_kv_bytes_per_token,
                                gather_kv, init_pool, init_table,
                                kv_bytes_per_token, update, update_chunk)

__all__ = [
    "GARBAGE_PAGE", "NO_PAGE", "OutOfPages", "PageAllocator", "PagedKV",
    "attention_mask", "chunk_attention_mask", "copy_pages",
    "dense_kv_bytes_per_token",
    "gather_kv", "init_pool", "init_table", "kv_bytes_per_token",
    "npp_bucket", "paged_attention", "paged_attention_chunk",
    "paged_attention_pallas", "paged_attention_pallas_chunk",
    "paged_attention_xla", "paged_attention_xla_chunk",
    "reclaimable_prefix", "resolve_paged", "resolve_paged_chunk",
    "update", "update_chunk",
]
