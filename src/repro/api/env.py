"""Central `REPRO_*` environment configuration — resolved ONCE at import.

Every runtime knob the serving stack reads from the environment lives
here, so mesh/backend/cache configuration has a single source of truth
(and a single place to audit).  Traced code must never read
``os.environ`` per call: the trace bakes in whatever the first call saw
and the host-side dict lookup is pure overhead — resolving at import
makes that contract structural.

Import-light on purpose (stdlib only): kernels, kvstore, models and the
session all import this at module scope.

Knobs:

``REPRO_KV_CACHE``      serving KV cache default ("auto" -> paged for
                        attention archs; "full"/"paged" force it)
``REPRO_KV_DTYPE``      paged-pool value dtype ("bf16" exact / "int8")
``REPRO_KV_UPDATE``     dense-cache update strategy ("scatter"/"dynamic")
``REPRO_AUTOTUNE``      "0"/"false" disables the kernel autotuner
``REPRO_TUNE_BLOCK_ROWS``  "1" enables encode-time block_rows search
``REPRO_BF16_PSUM``     "1" narrows TP matmul partial sums to bf16
``REPRO_PALLAS_INTERPRET``  force Pallas interpret ("1") or native ("0");
                        unset -> auto-detect (interpret off-TPU), which
                        must stay lazy because the jax backend is not
                        known at import time
"""
from __future__ import annotations

import os
from typing import Optional

KV_CACHE: str = os.environ.get("REPRO_KV_CACHE", "auto")
KV_DTYPE: str = os.environ.get("REPRO_KV_DTYPE", "bf16")
KV_UPDATE: str = os.environ.get("REPRO_KV_UPDATE", "scatter")
AUTOTUNE: bool = os.environ.get("REPRO_AUTOTUNE", "1") not in ("0", "false")
TUNE_BLOCK_ROWS: bool = os.environ.get("REPRO_TUNE_BLOCK_ROWS") == "1"
BF16_PSUM: bool = os.environ.get("REPRO_BF16_PSUM") == "1"
#: raw override for Pallas interpret mode; None = auto-detect per backend
PALLAS_INTERPRET: Optional[bool] = (
    None if (_pi := os.environ.get("REPRO_PALLAS_INTERPRET")) is None
    else _pi not in ("0", "false", "False"))
