"""Benchmark runner — one section per paper table/figure + kernel accounting.

  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
import time


def main() -> int:
    t0 = time.time()
    from benchmarks import fig5, kernels_bench, table1

    print("=" * 72)
    print("TABLE 1 — AIDA vs EIE (calibrated analytical simulators)")
    print("=" * 72)
    table1.run()
    ok = table1.validate()
    print(f"\n  -> paper-claim validation (PP 14.5x, thrpt 2.5x, EE, power): "
          f"{'PASS' if ok else 'FAIL'}")

    print()
    print("=" * 72)
    print("FIG 5(a) — area / energy efficiency vs weight sparsity")
    print("=" * 72)
    rows = fig5.sparsity_sweep()
    lin = all(r2["rel_area"] > r1["rel_area"]
              for r1, r2 in zip(rows, rows[1:]))
    print(f"  -> area grows monotonically with density (linear-in-sparsity "
          f"claim): {'PASS' if lin else 'FAIL'}")

    print()
    print("=" * 72)
    print("FIG 5(b) — area / energy efficiency vs wordlength")
    print("=" * 72)
    rows = fig5.precision_sweep()
    mono = all(r1["rel_ee"] >= r2["rel_ee"] for r1, r2
               in zip(rows, rows[1:]))
    quad = rows[-1]["mult_cycles"] / rows[2]["mult_cycles"] > 8  # 16b vs 4b
    print(f"  -> EE best at binary/ternary and monotone in wordlength: "
          f"{'PASS' if mono else 'FAIL'}; multiply-stage cycles quadratic "
          f"(16b/4b > 8x): {'PASS' if quad else 'FAIL'}\n"
          f"     (note: END-TO-END EE gain is sub-quadratic because the "
          f"soft reduction, not the multiply, dominates at short "
          f"wordlengths — see EXPERIMENTS.md)")

    print()
    print("=" * 72)
    print("§4.3 — broadcast/M×V overlap scalability")
    print("=" * 72)
    ov = fig5.overlap_scalability()
    ov_ok = 1.3 < ov["best_speedup"] <= 2.0 and 0.2 < ov["area_overhead"] < 0.6
    print(f"  -> 'up to 1.86x at +28% area': "
          f"{'PASS' if ov_ok else 'FAIL'} "
          f"(model: {ov['best_speedup']:.2f}x, +{ov['area_overhead']:.0%})")

    print()
    print("=" * 72)
    print("KERNELS — compression dividend (HBM bytes) + host wall-clock")
    print("=" * 72)
    kernels_bench.bytes_model()
    print("\nwall-clock (host CPU, interpret-mode kernels — correctness "
          "path, not TPU perf):")
    kernels_bench.wallclock()
    kernels_bench.attention_bench()

    print(f"\n[benchmarks] done in {time.time()-t0:.0f}s")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
