"""Roofline machinery: HLO collective parsing + model-flops accounting."""
import numpy as np
import pytest

from repro.configs import SHAPES, get
from repro.roofline import analysis as RA

HLO = """
HloModule jit_step
  %all-reduce = f32[512,4096]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
  %ag = bf16[1024,128]{1,0} all-gather(%p0), channel_id=2, replica_groups=[4,2]<=[8], dimensions={0}
  %rs = f32[64,64]{1,0} reduce-scatter(%x), channel_id=3, replica_groups=[1,16]<=[16], to_apply=%add
  %cp = bf16[32,32]{1,0} collective-permute(%y), channel_id=4, source_target_pairs={{0,1},{1,0}}
  %aa = f32[128]{0} all-to-all(%z), channel_id=5, replica_groups=[2,4]<=[8]
  %ar-start = f32[16]{0} all-reduce-start(%w), channel_id=6, replica_groups=[2,4]<=[8], to_apply=%add
  %ar-done = f32[16]{0} all-reduce-done(%ar-start)
  %dot2 = f32[10,10]{1,0} dot(%a, %b)
"""


def test_parse_collective_bytes():
    out = RA.parse_collective_bytes(HLO)
    # all-reduce: 512*4096*4 B * 2*(3/4) ring + start op 16*4*1.5
    ar = 512 * 4096 * 4
    assert out["all-reduce"] == int(2 * ar * 3 / 4) + int(2 * 16 * 4 * 3 / 4)
    ag = 1024 * 128 * 2
    assert out["all-gather"] == int(ag * 1 / 2)  # group size 2
    rs = 64 * 64 * 4
    assert out["reduce-scatter"] == int(rs * 16 * 15 / 16)
    assert out["collective-permute"] == 32 * 32 * 2
    assert out["all-to-all"] == int(128 * 4 * 3 / 4)


def test_parse_ignores_non_collectives():
    out = RA.parse_collective_bytes("%d = f32[8,8]{1,0} dot(%a, %b)")
    assert sum(out.values()) == 0


def test_model_flops_conventions():
    cfg = get("llama3-8b")
    n = cfg.active_params_count()
    tr = RA.model_flops(cfg, SHAPES["train_4k"])
    pf = RA.model_flops(cfg, SHAPES["prefill_32k"])
    de = RA.model_flops(cfg, SHAPES["decode_32k"])
    assert tr == 6.0 * n * 256 * 4096
    assert pf == 2.0 * n * 32 * 32768
    assert de == 2.0 * n * 128
    # MoE: active < total
    moe = get("mixtral-8x7b")
    assert moe.active_params_count() < moe.params_count() * 0.45


def test_roofline_terms_and_bottleneck():
    r = RA.Roofline(arch="x", shape="train_4k", mesh="single", chips=256,
                    hlo_flops=197e12 * 0.05,          # 50 ms compute
                    hlo_bytes=819e9 * 0.1,            # 100 ms memory
                    coll_bytes={"all-reduce": int(50e9 * 0.02)},  # 20 ms
                    model_flops=197e12 * 0.04 * 256)
    assert abs(r.t_compute - 0.05) < 1e-9
    assert abs(r.t_memory - 0.1) < 1e-9
    assert abs(r.t_collective - 0.02) < 1e-9
    assert r.bottleneck == "memory"
    assert abs(r.useful_flops_frac - 0.8) < 1e-9
    assert abs(r.roofline_frac - 0.4) < 1e-9


def test_params_count_sanity():
    """Config param counts within 15% of the published model sizes."""
    approx = {
        "llama3-8b": 8.0e9, "qwen1.5-0.5b": 0.46e9, "gemma2-2b": 2.6e9,
        "mixtral-8x7b": 46.7e9, "dbrx-132b": 132e9, "rwkv6-7b": 7.6e9,
        "h2o-danube-1.8b": 1.8e9,
    }
    for name, want in approx.items():
        got = get(name).params_count()
        assert 0.7 < got / want < 1.35, (name, got, want)
