"""Pallas kernels (interpret=True) vs pure-jnp oracles: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.acsr_spmv import acsr_spmv, block_encode, block_encode_coded
from repro.kernels.flash_attention import (flash_attention_bwd,
                                           flash_attention_fwd)
from repro.kernels.linear_scan import rwkv6_fwd
from repro.kernels.lut_matmul import lut_matmul, lut_product_matmul


# ------------------------------------------------------------- lut_matmul
@pytest.mark.parametrize("b,n,k,dtype", [
    (8, 128, 256, jnp.float32),
    (128, 256, 1024, jnp.float32),
    (16, 128, 512, jnp.bfloat16),
])
def test_lut_matmul(rng, b, n, k, dtype):
    cents = jnp.asarray(np.sort(rng.normal(size=16)).astype(np.float32))
    codes = rng.integers(0, 16, size=(n, k)).astype(np.uint8)
    packed = jnp.asarray(codes[:, 0::2] | (codes[:, 1::2] << 4))
    x = jnp.asarray(rng.normal(size=(b, k))).astype(dtype)
    out = lut_matmul(x, packed, cents, bm=8, bn=128, bk=256)
    want = ref.lut_matmul_ref(x, packed, cents)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol * 10)


def test_lut_product_matmul(rng):
    n, k = 128, 256
    cents = jnp.asarray(np.sort(rng.normal(size=16)).astype(np.float32))
    codes = rng.integers(0, 16, size=(n, k)).astype(np.uint8)
    packed = jnp.asarray(codes[:, 0::2] | (codes[:, 1::2] << 4))
    xc = jnp.asarray(rng.integers(0, 16, size=(8, k)).astype(np.uint8))
    lut = jnp.outer(cents, cents)
    out = lut_product_matmul(xc, packed, lut, bm=8, bn=128, bk=128)
    want = ref.lut_product_matmul_ref(xc, packed, lut, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # non-multiplicative induction table (perfect induction generality)
    lut2 = jnp.tanh(lut) + 0.1 * jnp.sign(lut)
    out2 = lut_product_matmul(xc, packed, lut2, bm=8, bn=128, bk=128)
    want2 = ref.lut_product_matmul_ref(xc, packed, lut2, n)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(want2),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- acsr_spmv
@pytest.mark.parametrize("n,k,density,nb", [
    (300, 512, 0.1, 0), (128, 256, 0.5, 0), (257, 128, 0.05, 0),
    (300, 512, 0.1, 4),
])
def test_acsr_spmv(rng, n, k, density, nb):
    w = (rng.normal(size=(n, k)) * (rng.random((n, k)) < density)
         ).astype(np.float32)
    x = rng.normal(size=(k,) if nb == 0 else (k, nb)).astype(np.float32)
    blocked = block_encode(w, block_rows=128)
    out = np.asarray(acsr_spmv(blocked, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(out, w @ x, rtol=2e-4, atol=2e-4)


def test_acsr_spmv_coded(rng):
    n, k = 256, 384
    w = (rng.normal(size=(n, k)) * (rng.random((n, k)) < 0.1)
         ).astype(np.float32)
    nz = w[w != 0]
    cents = np.concatenate(
        [[0.0], np.quantile(nz, np.linspace(0.02, 0.98, 15))]
    ).astype(np.float32)
    blocked = block_encode_coded(w, cents, block_rows=128)
    x = rng.normal(size=(k,)).astype(np.float32)
    out = np.asarray(acsr_spmv(blocked, jnp.asarray(x), interpret=True))
    wq = cents[np.abs(w[..., None] - cents).argmin(-1)] * (w != 0)
    np.testing.assert_allclose(out, wq @ x, rtol=2e-4, atol=2e-4)


# -------------------------------------------------------- flash attention
@pytest.mark.parametrize("causal,window,softcap,hkv", [
    (True, None, None, 4), (True, 64, None, 2), (True, None, 30.0, 4),
    (False, None, None, 1), (True, 128, 50.0, 2),
])
def test_flash_attention_fwd_bwd(rng, causal, window, softcap, hkv):
    B, H, T, D = 2, 4, 128, 32
    q = jnp.asarray(rng.normal(size=(B, H, T, D)).astype(np.float32)) * 0.3
    k = jnp.asarray(rng.normal(size=(B, hkv, T, D)).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.normal(size=(B, hkv, T, D)).astype(np.float32))
    o, lse = flash_attention_fwd(q, k, v, causal=causal, window=window,
                                 softcap=softcap, bq=64, bk=64)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    do = jnp.asarray(rng.normal(size=o.shape).astype(np.float32))
    gq, gk, gv = jax.grad(
        lambda q_, k_, v_: (ref.attention_ref(
            q_, k_, v_, causal=causal, window=window,
            softcap=softcap) * do).sum(), argnums=(0, 1, 2))(q, k, v)
    dq, dk, dv = flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                                     window=window, softcap=softcap,
                                     bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(dq), np.asarray(gq),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(gk),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(gv),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_attention_dtypes(rng, dtype):
    B, H, T, D = 1, 2, 64, 64
    q = (jnp.asarray(rng.normal(size=(B, H, T, D))) * 0.3).astype(dtype)
    k = (jnp.asarray(rng.normal(size=(B, H, T, D))) * 0.3).astype(dtype)
    v = jnp.asarray(rng.normal(size=(B, H, T, D))).astype(dtype)
    o, _ = flash_attention_fwd(q, k, v, bq=32, bk=32)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


# ------------------------------------------------------------ linear scan
@pytest.mark.parametrize("t,chunk,dk,dv", [(128, 32, 16, 16),
                                           (64, 64, 32, 64),
                                           (96, 16, 8, 8)])
def test_rwkv6_kernel(rng, t, chunk, dk, dv):
    B, H = 2, 2
    r = jnp.asarray(rng.normal(size=(B, H, t, dk)).astype(np.float32)) * .5
    k = jnp.asarray(rng.normal(size=(B, H, t, dk)).astype(np.float32)) * .5
    v = jnp.asarray(rng.normal(size=(B, H, t, dv)).astype(np.float32))
    w = jnp.asarray(np.exp(-np.exp(rng.normal(size=(B, H, t, dk))))
                    .astype(np.float32))
    u = jnp.asarray(rng.normal(size=(H, dk)).astype(np.float32))
    o = rwkv6_fwd(r, k, v, w, u, chunk=chunk)
    want = ref.rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_rwkv6_kernel_tiny_decay(rng):
    """Extreme decays (w→0) stay numerically exact (the sequential-in-chunk
    design choice vs cumprod factorization — DESIGN.md)."""
    B, H, T, D = 1, 1, 64, 8
    r = jnp.ones((B, H, T, D)) * 0.1
    k = jnp.ones((B, H, T, D)) * 0.1
    v = jnp.ones((B, H, T, D))
    w = jnp.full((B, H, T, D), 1e-9)
    u = jnp.zeros((H, D))
    o = rwkv6_fwd(r, k, v, w, u, chunk=16)
    want = ref.rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
