"""DBRX-132B — fine-grained MoE: 16 experts, top-4. [hf:databricks/dbrx-base]"""
from repro.configs.base import ArchConfig, MoECfg, register

CFG = register(ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv=8, d_ff=10752,
    vocab=100352, d_head=128, rope_theta=500_000.0,
    moe=MoECfg(n_experts=16, top_k=4), tie_embeddings=False,
    source="hf:databricks/dbrx-base"))
