"""Projection-leaf dispatch — the facade's FC mode dispatch point.

`models.layers.dense` consults this table instead of hard-coding leaf
types: a param leaf whose type name is registered here is applied through
its registered function (e.g. a core.sparse_fc.CompressedFC routes to
`apply_fc`, which picks the dense/int8/codebook4/acsr/aida path).  New
compressed representations plug in with `register_applier` — no model
code changes.

Import-light on purpose: models.layers imports this at module scope, so
nothing here may import the model zoo (appliers lazy-import their kernels).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

_APPLIERS: Dict[str, Callable] = {}


def register_applier(type_name: str, fn: Callable) -> None:
    """Register `fn(leaf, x2d, bias=None, activation=None) -> y2d` for
    param leaves of `type_name`.  ``bias``/``activation`` let the leaf's
    kernel fuse the FC epilogue (appliers may ignore them only by applying
    the same semantics some other way)."""
    _APPLIERS[type_name] = fn


def applier_for(leaf) -> Optional[Callable]:
    """The registered applier for this leaf, or None for raw matrices."""
    return _APPLIERS.get(type(leaf).__name__)


def _apply_compressed_fc(leaf, x, bias=None, activation=None):
    from repro.core.sparse_fc import apply_fc
    return apply_fc(leaf, x, bias=bias, activation=activation)


register_applier("CompressedFC", _apply_compressed_fc)
