"""Production meshes.  Defined as FUNCTIONS so importing never touches jax
device state (jax locks the device count on first backend init)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16 data × 16 model).  Multi-pod: 2 × 256."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_model: Optional[int] = None,
                   n_data: Optional[int] = None):
    """A (data, model) mesh sized from the devices actually present —
    the mesh you can exercise on a laptop/CI host via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the
    production mesh hard-assumes 256 chips and cannot).

    With both degrees given they must multiply to ``jax.device_count()``;
    with one given the other is inferred; with neither, every device
    goes on the model axis (serving TP, the axis this repo shards
    today).
    """
    n = jax.device_count()
    for name, deg in (("n_model", n_model), ("n_data", n_data)):
        if deg is not None and deg < 1:
            raise ValueError(f"mesh degrees must be >= 1; got {name}={deg}")
    if n_model is None and n_data is None:
        n_model, n_data = n, 1
    elif n_model is None:
        if n % n_data:
            raise ValueError(
                f"n_data={n_data} does not divide device_count={n}")
        n_model = n // n_data
    elif n_data is None:
        if n % n_model:
            raise ValueError(
                f"n_model={n_model} does not divide device_count={n}")
        n_data = n // n_model
    if n_model * n_data != n:
        raise ValueError(
            f"mesh {n_data}x{n_model} (data x model) needs "
            f"{n_data * n_model} devices but jax.device_count()={n}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count "
            "accordingly BEFORE importing jax")
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_role_meshes(n_prefill: int, n_decode: int):
    """Disjoint (data, model) meshes for disaggregated serving roles:
    the first ``n_prefill`` devices become the prefill role's mesh, the
    next ``n_decode`` the decode role's.  Every device serves tensor-
    parallel on the model axis (the axis this repo shards today); the
    page-migration channel (repro.disagg.migrate) carries KV across the
    two device sets.  Each role needs at least one device and the split
    must fit the devices present."""
    for name, deg in (("n_prefill", n_prefill), ("n_decode", n_decode)):
        if deg < 1:
            raise ValueError(
                f"disaggregated roles need >= 1 device each; "
                f"got {name}={deg}")
    n = jax.device_count()
    if n_prefill + n_decode > n:
        raise ValueError(
            f"role split {n_prefill}+{n_decode} needs "
            f"{n_prefill + n_decode} devices but jax.device_count()={n}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count "
            "accordingly BEFORE importing jax")
    devs = jax.devices()
    import numpy as np
    pre = np.asarray(devs[:n_prefill]).reshape(1, n_prefill)
    dec = np.asarray(devs[n_prefill:n_prefill + n_decode]) \
        .reshape(1, n_decode)
    axes = ("data", "model")
    return jax.sharding.Mesh(pre, axes), jax.sharding.Mesh(dec, axes)


def make_pp_mesh():
    """Optional pipeline-parallel mesh (4 stages × 8 data × 8 model)."""
    return jax.make_mesh((4, 8, 8), ("pipe", "data", "model"))


def mesh_shape_dict(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh) -> Tuple[str, ...]:
    """The batch-sharding axes for this mesh (pod folds into data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    d = mesh_shape_dict(mesh)
    out = 1
    for a in dp_axes(mesh):
        out *= d[a]
    return out
