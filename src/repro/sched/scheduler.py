"""Continuous-batching scheduling policy: admission order, page-pool
admission control, preemption victim choice.

The Scheduler owns the *waiting* side of serving — requests that have
been submitted but hold no batch slot — while `api.Session` owns slots
and device state.  Separation of concerns:

* **policy** — who goes next.  ``fifo`` is strict head-of-line (a
  request that does not fit blocks the ones behind it: deterministic,
  starvation-free); ``sjf`` (shortest-prompt-first) picks the smallest
  admissible prompt, which maximizes slot turnover under heterogeneous
  workloads.  Starvation of long prompts is bounded by an *aging*
  knob: a request waiting more than ``sjf_age_limit`` steps is
  promoted to head-of-line (oldest first) and, like a fifo head,
  blocks everything behind it until it fits — so no prompt waits
  forever behind a stream of shorter ones.
* **admission control** — a request is admitted only when its
  *worst-case* page need (every token it could ever hold live,
  ``ceil(min(prompt+max_new, max_len)/page_size)`` minus pages it will
  reuse from the prefix cache) fits the allocator's free list right now.
  Concurrent requests may still out-grow the pool together; that is what
  preemption is for.
* **preemption** — under page pressure the *youngest* admitted request
  (highest admission sequence number) is evicted back to the queue
  front: its pages are freed, its generated-so-far tokens ride along in
  the entry, and on re-admission the Session re-prefills
  prompt+generated (vLLM-style recompute — with greedy sampling the
  resumed stream is token-identical to an uninterrupted run).  The
  oldest request is never preempted, so the system always makes
  progress; a pool too small for even one request still raises
  `OutOfPages`.

Everything here is host-side bookkeeping — no jax.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Deque, List, Optional

import collections

POLICIES = ("fifo", "sjf")


@dataclasses.dataclass
class SchedConfig:
    """Serving scheduler knobs (see module docstring for semantics)."""
    policy: str = "fifo"          # "fifo" | "sjf"
    chunk: int = 1                # prefill tokens per model call (1 = off)
    admission: bool = True        # page-pool admission control
    prefix_cache: bool = False    # shared-prefix page reuse (paged only)
    sjf_age_limit: Optional[int] = 256  # steps before an sjf entry is
    #                             # promoted head-of-line (None = starve)

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}; "
                             f"choose one of {POLICIES}")
        if self.chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk}")
        if self.sjf_age_limit is not None and self.sjf_age_limit < 1:
            raise ValueError(
                f"sjf_age_limit must be >= 1 (or None), "
                f"got {self.sjf_age_limit}")

    @classmethod
    def coerce(cls, val) -> "SchedConfig":
        if val is None:
            return cls()
        if isinstance(val, cls):
            return val
        if isinstance(val, str):
            return cls(policy=val)
        if isinstance(val, dict):
            return cls(**val)
        raise TypeError(f"cannot make a SchedConfig from {val!r}")


@dataclasses.dataclass
class SchedEntry:
    """One queued (or preempted-back-to-queue) request plus its serving
    lifecycle state.  ``out`` carries generated tokens across a
    preemption (recompute resume); ``seq`` is the admission age —
    -1 until first admitted, then monotone (youngest = max)."""
    req: object                   # api.session.Request
    submit_step: int = 0
    submit_time: float = 0.0
    out: List[int] = dataclasses.field(default_factory=list)
    seq: int = -1
    preemptions: int = 0
    prefix_pages: int = 0         # pages attached from the prefix cache
    record: Optional[dict] = None  # lifecycle metrics (api.Session owns)
    hashes: Optional[list] = None  # prompt page hashes, computed once
    # repro.resil lifecycle state (None/0 when the layer is off):
    deadline_tick: Optional[int] = None  # absolute tick it must finish by
    retries: int = 0              # re-admissions after faults/recovery


class Scheduler:
    def __init__(self, cfg: Optional[SchedConfig] = None):
        self.cfg = SchedConfig.coerce(cfg)
        self.queue: Deque[SchedEntry] = collections.deque()
        self._seq = 0
        self.stats = {"preemptions": 0, "admission_blocks": 0}
        # observability seam: a ``(name, **args)`` emitter (obs.Tracer
        # .hook) attached by the owning Session; None = no tracing.
        self.obs = None

    # ------------------------------------------------------------ queue
    def __len__(self) -> int:
        return len(self.queue)

    def submit(self, req, step: int = 0, now: float = 0.0) -> SchedEntry:
        e = SchedEntry(req=req, submit_step=step, submit_time=now)
        self.queue.append(e)
        return e

    def requeue(self, entry: SchedEntry) -> None:
        """A preempted entry resumes at the queue FRONT — it was admitted
        once, so anything behind it has strictly lower priority under
        both policies (fifo: older; sjf: it will be shortest-or-equal
        among equally-old when it was first picked)."""
        entry.preemptions += 1
        self.stats["preemptions"] += 1
        self.queue.appendleft(entry)

    # -------------------------------------------------------- admission
    def _aged(self, step: Optional[int]) -> List[int]:
        """Queue indices whose wait exceeds the sjf aging bound, oldest
        first (submit_step, then queue position — deterministic)."""
        k = self.cfg.sjf_age_limit
        if k is None or step is None:
            return []
        aged = [i for i in range(len(self.queue))
                if step - self.queue[i].submit_step > k]
        return sorted(aged, key=lambda i: (self.queue[i].submit_step, i))

    def next_entry(self, fits: Callable[[SchedEntry], bool],
                   step: Optional[int] = None) -> Optional[SchedEntry]:
        """Pop the next admissible entry per policy, or None.  ``fits``
        is the Session's page-need predicate (always-True when admission
        control is off or the cache is dense); ``step`` is the caller's
        model-call clock, used only for the sjf aging bound."""
        if not self.queue:
            return None
        aged: List[int] = []
        if self.cfg.policy == "sjf":
            aged = self._aged(step)
            if aged:
                # an over-age entry behaves like a fifo head: it goes
                # next and, if it does not fit, blocks — otherwise a
                # stream of short prompts starves it forever
                order = aged[:1]
            else:
                order = sorted(range(len(self.queue)),
                               key=lambda i: (len(self.queue[i].req.prompt)
                                              + len(self.queue[i].out),
                                              i))
        else:                      # fifo: strict head-of-line
            order = [0]
        for i in order:
            e = self.queue[i]
            if not self.cfg.admission or fits(e):
                del self.queue[i]
                # (re-)admission stamps a fresh age: a resumed request is
                # youngest again until something is admitted after it
                e.seq = self._seq
                self._seq += 1
                return e
            self.stats["admission_blocks"] += 1
            if self.obs is not None:
                self.obs("sched.block", rid=e.req.rid,
                         queued=len(self.queue))
            if self.cfg.policy == "fifo" or aged:
                return None        # head-of-line blocks
        return None

    # ----------------------------------------------- resil queue surgery
    def pop_expired(self, tick: int) -> List[SchedEntry]:
        """Remove and return queued entries whose deadline has passed
        (``tick > deadline_tick``).  Queue order is preserved for the
        survivors; the Session turns the expired ones into structured
        RequestFailed results."""
        expired = [e for e in self.queue
                   if e.deadline_tick is not None and tick > e.deadline_tick]
        if expired:
            gone = set(id(e) for e in expired)
            self.queue = collections.deque(
                e for e in self.queue if id(e) not in gone)
        return expired

    def shed_youngest(self) -> Optional[SchedEntry]:
        """Remove and return the lowest-priority queued entry for load
        shedding: the most recently submitted one that has never been
        admitted (preempted entries sit at the front with work already
        invested — shedding them would waste it).  None if every queued
        entry has run before."""
        best = None
        for i in range(len(self.queue) - 1, -1, -1):
            e = self.queue[i]
            if e.seq == -1 and not e.out:
                best = i
                break
        if best is None:
            return None
        e = self.queue[best]
        del self.queue[best]
        return e

    # ------------------------------------------------------- preemption
    @staticmethod
    def choose_victim(active: List[Optional[SchedEntry]]) -> Optional[int]:
        """Slot index of the youngest admitted entry, or None if <= 1
        active (never preempt the last runner — no progress otherwise)."""
        live = [(e.seq, i) for i, e in enumerate(active) if e is not None]
        if len(live) <= 1:
            return None
        return max(live)[1]


def page_need(prompt_len: int, max_new: int, max_len: int,
              page_size: int) -> int:
    """Worst-case pages a request holds simultaneously: every position it
    can ever write, clipped at the table width (positions beyond max_len
    are clamped, like the dense cache)."""
    total = min(prompt_len + max_new, max_len)
    return -(-total // page_size)
