"""Gradient compression for the cross-pod all-reduce.

At 512+ chips the data-parallel gradient all-reduce crosses the pod axis
(DCN, ~10× slower than ICI), so the bytes on the wire dominate.  Two
schemes:

  bf16 — cast f32 grads to bf16 for the reduce (2× traffic cut, lossless in
         practice because Adam renormalizes),
  int8 — per-chunk symmetric int8 with f32 scales (≈4× cut) plus error
         feedback: the quantization residual is added back into the next
         step's gradient, keeping the optimizer unbiased over time.

The compress/decompress pair brackets the point where GSPMD inserts the
all-reduce, so the collective moves the compressed payload.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

CHUNK = 2048


def _int8_enc(g: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % CHUNK
    flat = jnp.pad(flat, (0, pad))
    ch = flat.reshape(-1, CHUNK)
    scale = jnp.max(jnp.abs(ch), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(ch / jnp.maximum(scale, 1e-12)),
                 -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale}


def _int8_dec(enc: Dict[str, jnp.ndarray], shape) -> jnp.ndarray:
    flat = (enc["q"].astype(jnp.float32) * enc["scale"]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_grads(grads: Any, scheme: str) -> Any:
    if scheme == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    if scheme == "int8":
        return jax.tree.map(_int8_enc, grads)
    raise ValueError(scheme)


def roundtrip(grads: Any, scheme: str) -> Any:
    """compress → (all-reduce happens here under GSPMD) → decompress."""
    if scheme == "bf16":
        return jax.tree.map(
            lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
    if scheme == "int8":
        return jax.tree.map(lambda g: _int8_dec(_int8_enc(g), g.shape),
                            grads)
    raise ValueError(scheme)


def decompress_grads(payload: Any, scheme: str, shapes=None) -> Any:
    if scheme == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.float32), payload)
    if scheme == "int8":
        is_enc = lambda x: isinstance(x, dict) and "q" in x
        def dec(enc):
            n = enc["q"].size
            return (enc["q"].astype(jnp.float32)
                    * enc["scale"]).reshape(-1)[:n]
        # shape restoration handled by caller keeping the original tree
        return jax.tree.map(
            lambda e: _int8_dec(e, e["__shape__"]) if "__shape__" in e
            else (e["q"].astype(jnp.float32) * e["scale"]).reshape(-1),
            payload, is_leaf=is_enc)
    raise ValueError(scheme)


class ErrorFeedback:
    """Residual accumulator for biased compressors (int8)."""

    def __init__(self, params_template):
        self.residual = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params_template)

    def apply(self, grads):
        """g' = compress(g + r); r = (g + r) - decompress(g')."""
        gplus = jax.tree.map(jnp.add, grads, self.residual)
        dec = jax.tree.map(lambda g: _int8_dec(_int8_enc(g), g.shape), gplus)
        self.residual = jax.tree.map(jnp.subtract, gplus, dec)
        return dec
