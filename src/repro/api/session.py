"""Serving session: continuous batching over a fixed-slot decode batch.

Requests occupy slots, finished slots are refilled from the queue without
stopping the batch (continuous batching).  Prefill is chunk-free
(token-by-token through the decode path) to keep one compiled step;
prompts for a slot are fed before its generation starts.  Greedy or
temperature sampling.

Sessions are created by `repro.api.Engine.session()` (or directly); the
compiled decode step comes from the engine's backend, so dense and
compressed (Pallas) serving share one code path.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registry import Executor, get_backend
from repro.configs.base import ArchConfig

# Compiled decode steps keyed by (backend, cfg): sessions on the same
# config reuse one jitted step (its trace cache handles dense vs
# compressed param structures), so spinning up a Session is cheap.
_STEP_CACHE: dict = {}


def _jitted_step(backend: Executor, cfg: ArchConfig):
    key = (backend.name, cfg)
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = jax.jit(backend.make_decode_step(cfg))
    return _STEP_CACHE[key]


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new: int = 16
    temperature: float = 0.0
    rid: int = 0


@dataclasses.dataclass
class Result:
    rid: int
    tokens: List[int]


class Session:
    def __init__(self, cfg: ArchConfig, params, batch_slots: int = 4,
                 max_len: int = 256, seed: int = 0,
                 backend: Optional[Executor] = None):
        assert cfg.has_decode, "encoder archs don't serve autoregressively"
        from repro.models import model as M
        self.cfg, self.params = cfg, params
        self.slots = batch_slots
        self.max_len = max_len
        self.state = M.init_decode_state(cfg, batch_slots, max_len)
        self.key = jax.random.PRNGKey(seed)
        if backend is None or isinstance(backend, str):
            backend = get_backend(backend or "jax-dense")
        self.backend = backend
        self._step = _jitted_step(backend, cfg)
        # per-slot bookkeeping (host side)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pending: List[List[int]] = [[] for _ in range(batch_slots)]
        self.slot_out: List[List[int]] = [[] for _ in range(batch_slots)]
        self.queue: Deque[Request] = collections.deque()
        self.results: List[Result] = []
        self.stats = {"steps": 0, "fills": 0}

    # ------------------------------------------------------------ public
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Result]:
        """Drain the queue; returns all results in deterministic rid order."""
        for _ in range(max_steps):
            self._fill_slots()
            if all(r is None for r in self.slot_req):
                break
            self._advance()
        return sorted(self.results, key=lambda r: r.rid)

    # ----------------------------------------------------------- internals
    def _fill_slots(self):
        for i in range(self.slots):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.popleft()
                self.slot_req[i] = req
                self.slot_pending[i] = list(req.prompt)
                self.slot_out[i] = []
                self._reset_slot_state(i)
                self.stats["fills"] += 1

    def _reset_slot_state(self, i: int):
        def zero_slot(x):
            if x.ndim >= 2 and x.shape[1] == self.slots:  # [L, B, ...]
                return x.at[:, i].set(jnp.zeros_like(x[:, i]))
            return x
        layers = jax.tree.map(zero_slot, self.state["layers"])
        pos = self.state["pos"].at[i].set(0)
        # empty cache slots must read as "never written": pos fields are -1
        if self.cfg.family not in ("rwkv6",):
            layers = dict(layers)
            kv = layers["kv"]
            layers["kv"] = kv._replace(
                pos=kv.pos.at[:, i].set(-jnp.ones_like(kv.pos[:, i])))
        self.state = {"layers": layers, "pos": pos}

    def _advance(self):
        tokens = np.zeros((self.slots,), np.int32)
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.slot_pending[i]:
                tokens[i] = self.slot_pending[i][0]
            elif self.slot_out[i]:
                tokens[i] = self.slot_out[i][-1]
            else:
                tokens[i] = req.prompt[-1]
        self.state, logits = self._step(self.params, self.state,
                                        jnp.asarray(tokens))
        self.stats["steps"] += 1
        logits = np.asarray(logits[:, : self.cfg.vocab])
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.slot_pending[i]:
                self.slot_pending[i].pop(0)
                if self.slot_pending[i]:
                    continue  # still prefilling
            # sample the next token from this step's logits
            if req.temperature > 0:
                self.key, sub = jax.random.split(self.key)
                nxt = int(jax.random.categorical(
                    sub, jnp.asarray(logits[i]) / req.temperature))
            else:
                nxt = int(logits[i].argmax())
            self.slot_out[i].append(nxt)
            if len(self.slot_out[i]) >= req.max_new:
                self.results.append(Result(req.rid, self.slot_out[i]))
                self.slot_req[i] = None
