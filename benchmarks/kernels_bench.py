"""Kernel microbenchmarks: HBM-byte and FLOP accounting for the AIDA
kernels vs their dense equivalents (the in-memory-compression dividend),
plus wall-clock on this host (interpret mode — correctness path, NOT TPU
performance; the byte model is the TPU-relevant number).

`paged_attention_bench` sweeps the paged-attention space the serving hot
path dispatches over — (page_size, npp, pb, C) x pallas-vs-xla x
bf16/int8, decode and chunked-prefill shapes — and `--json` dumps every
row for the CI artifact, so impl-choice trajectories are inspectable
per commit alongside the BENCH numbers."""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparse_fc as sfc
from repro.kernels import ops, ref


def bytes_model(n=4096, k=4096, density=0.1, log=print):
    """Weights-at-rest and weights-moved-per-matvec for each FC mode."""
    dense_bf16 = n * k * 2
    rows = [
        ("dense bf16", dense_bf16),
        ("int8", n * k * 1),
        ("codebook4 (packed)", n * k // 2 + 64),
        ("acsr f32 (val+idx)", int(n * k * density) * 8),
        ("aida (4b codes + idx)", int(n * k * density) * 5),  # 4b+32b idx
    ]
    log(f"FC {n}x{k}, density {density:.0%} — HBM bytes per matvec:")
    out = {}
    for name, b in rows:
        log(f"  {name:24s} {b/1e6:10.2f} MB   ({dense_bf16/b:5.1f}x less"
            f" than dense bf16)" if b else "")
        out[name] = b
    return out


def wallclock(log=print):
    rng = np.random.default_rng(0)
    n, k, b = 1024, 2048, 8
    w = rng.normal(size=(n, k)).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(b, k)).astype(np.float32))
    rows = []
    for mode in sfc.MODES:
        layer = sfc.compress(w, mode=mode, density=0.1)
        f = jax.jit(lambda xx, l=layer: sfc.apply_fc(l, xx))
        f(x).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            f(x).block_until_ready()
        us = (time.perf_counter() - t0) / 5 * 1e6
        rows.append((f"fc_{mode}", us))
        log(f"  fc_{mode:10s} {us:12.0f} us/call")
    return rows


def attention_bench(log=print):
    rng = np.random.default_rng(1)
    B, H, T, D = 1, 8, 1024, 64
    q = jnp.asarray(rng.normal(size=(B, H, T, D)).astype(np.float32)) * 0.3
    k = jnp.asarray(rng.normal(size=(B, H, T, D)).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.normal(size=(B, H, T, D)).astype(np.float32))
    rows = []
    for impl in ("ref",):
        f = jax.jit(lambda a, b_, c: ops.attention(a, b_, c, impl=impl))
        f(q, k, v).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            f(q, k, v).block_until_ready()
        us = (time.perf_counter() - t0) / 3 * 1e6
        rows.append((f"attention_{impl}", us))
        log(f"  attention_{impl:6s} {us:12.0f} us/call  "
            f"({4*B*H*T*T*D/ (us*1e-6) /1e9:.1f} GFLOP/s host)")
    return rows


def _filled_paged_pool(rng, B, Hkv, Dh, ps, npp, kv_dtype):
    from repro import kvstore as kvs
    pool = kvs.init_pool(1 + B * npp, Hkv, ps, Dh, kv_dtype=kv_dtype)
    table = jnp.asarray(1 + np.arange(B * npp).reshape(B, npp), jnp.int32)
    S = ps * npp
    for t in range(S):
        pool = kvs.update(
            pool, table,
            jnp.asarray(rng.normal(size=(B, Hkv, Dh)), jnp.float32),
            jnp.asarray(rng.normal(size=(B, Hkv, Dh)), jnp.float32),
            jnp.full((B,), t, jnp.int32))
    return pool, table, S


def paged_attention_bench(log=print, geometries=((8, 4), (16, 8)),
                          pbs=(1, 2, 4), chunks=(1, 4)):
    """Paged-attention sweep: (page_size, npp, pb, C) x pallas-vs-xla x
    bf16/int8 over a fully-populated pool — the serving steady state.
    C=1 rows are the decode kernel; C>1 rows the chunked-prefill kernel
    (qt = C query tile).  Interpret-mode wall-clock: trajectory signal
    for the tuner's impl choice, not TPU performance."""
    from repro import kvstore as kvs
    from repro.obs import timeit
    B, Hkv, G, Dh = 2, 2, 2, 16
    rows = []
    for ps, npp in geometries:
        for kv_dtype in ("bf16", "int8"):
            rng = np.random.default_rng(0)
            pool, table, S = _filled_paged_pool(rng, B, Hkv, Dh, ps, npp,
                                                kv_dtype)
            win = jnp.int32(-1)
            for c in chunks:
                if c == 1:
                    q = jnp.asarray(
                        rng.normal(size=(B, Hkv * G, Dh)), jnp.float32)
                    cur = jnp.full((B,), S - 1, jnp.int32)
                    runs = [("xla", None, jax.jit(
                        lambda: kvs.paged_attention_xla(
                            q, pool, table, cur, win)))]
                    for pb in pbs:
                        runs.append(("pallas", pb, (
                            lambda pb=pb: kvs.paged_attention_pallas(
                                q, pool, table, cur, win, pb=pb,
                                interpret=True))))
                else:
                    qc = jnp.asarray(
                        rng.normal(size=(B, Hkv * G, c, Dh)), jnp.float32)
                    q_pos = jnp.broadcast_to(
                        jnp.arange(S - c, S, dtype=jnp.int32)[None],
                        (B, c))
                    runs = [("xla", None, jax.jit(
                        lambda: kvs.paged_attention_xla_chunk(
                            qc, pool, table, q_pos, win)))]
                    for pb in pbs:
                        runs.append(("pallas", pb, (
                            lambda pb=pb: kvs.paged_attention_pallas_chunk(
                                qc, pool, table, q_pos, win, pb=pb, qt=c,
                                interpret=True))))
                for impl, pb, fn in runs:
                    us = timeit(fn, reps=3, inner=3) * 1e6
                    row = {"page_size": ps, "npp": npp, "kv_dtype": kv_dtype,
                           "C": c, "impl": impl, "pb": pb,
                           "us": round(us, 1)}
                    rows.append(row)
                    tag = impl if pb is None else f"{impl}/pb{pb}"
                    log(f"  paged ps={ps:2d} npp={npp} {kv_dtype:4s} "
                        f"C={c} {tag:10s} {us:10.0f} us/call")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write all rows (bytes/wallclock/attention/paged)"
                         " to this path for the CI artifact")
    args = ap.parse_args()
    bm = bytes_model()
    print("\nwall-clock (host CPU, interpret-mode kernels):")
    wc = wallclock()
    at = attention_bench()
    print("\npaged attention (decode + chunked prefill):")
    pg = paged_attention_bench()
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bytes_model": bm,
                       "wallclock_us": dict(wc),
                       "attention_us": dict(at),
                       "paged_attention": pg}, f, indent=1)
        print(f"\nwrote {args.json}")
