"""Paged, quantized KV-cache subsystem: pool/allocator semantics, paged
attention (Pallas vs XLA vs dense reference), decode equivalence against
the full cache, session lifecycle (alloc on boundary, free on completion,
SWA reclamation), and int8 error bounds.

Key invariants:
  * bf16 pages reproduce the full bf16 cache BIT-EXACTLY through the
    decode step (same mixed-precision semantics, page-gathered);
  * int8 pages stay inside the quantization floor (~1 LSB of the
    per-page scale after online requantization) and well under 0.55x
    the dense cache's bytes per token;
  * pages never leak: every alloc is matched by a free at request
    completion / slot reset / SWA reclamation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kvstore as kvs
from repro.api import Engine, Request
from repro.configs import get, reduced
from repro.models import model as M

CFG = reduced(get("llama3-8b"), n_layers=2, d_model=64, d_ff=128, vocab=256)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def dense_attention_ref(q, k, v, scale, window=-1):
    """numpy oracle: full-precision masked GQA attention over history."""
    b, h, dh = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, dh)
    sc = np.einsum("bkgd,bkcd->bkgc", qg, k) * scale
    if window >= 0:
        pos = np.arange(s)
        sc = np.where(pos[None, None, None] > s - 1 - window, sc, -1e30)
    p = np.exp(sc - sc.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    return np.einsum("bkgc,bkcd->bkgd", p, v).reshape(b, h, dh)


def fill_pool(rng, B, Hkv, Dh, ps, npp, S, kv_dtype="int8", scramble=None):
    """Write S tokens through update(); page ids optionally scrambled."""
    pool = kvs.init_pool(1 + B * npp, Hkv, ps, Dh, kv_dtype=kv_dtype)
    table = np.full((B, npp), -1, np.int32)
    alloc = kvs.PageAllocator(pool.n_pages)
    order = list(range(1, pool.n_pages))
    if scramble is not None:
        scramble.shuffle(order)
    nxt = iter(order)
    ks = rng.normal(size=(S, B, Hkv, Dh)).astype(np.float32)
    vs = rng.normal(size=(S, B, Hkv, Dh)).astype(np.float32)
    for t in range(S):
        for b in range(B):
            if table[b, t // ps] < 0:
                pid = next(nxt)
                alloc._free.remove(pid)
                alloc._used.add(pid)
                table[b, t // ps] = pid
        pool = kvs.update(pool, jnp.asarray(table), jnp.asarray(ks[t]),
                          jnp.asarray(vs[t]), jnp.full((B,), t, jnp.int32))
    return pool, jnp.asarray(table), ks, vs


# ------------------------------------------------------------- allocator
def test_allocator_randomized_orderings():
    rng = np.random.default_rng(0)
    a = kvs.PageAllocator(32)
    held = []
    for _ in range(2000):
        if held and rng.random() < 0.45:
            k = rng.integers(1, len(held) + 1)
            batch = [held.pop(rng.integers(len(held))) for _ in range(k)]
            a.free(batch)
        elif a.available:
            pid = a.alloc()
            assert pid != kvs.GARBAGE_PAGE
            assert pid not in held          # never handed out twice
            held.append(pid)
        assert a.in_use == len(held)
    a.free(held)
    assert a.in_use == 0 and a.available == 31
    a.free(held)                            # double-free is a no-op
    assert a.available == 31


def test_allocator_exhaustion_raises():
    a = kvs.PageAllocator(4)
    got = [a.alloc() for _ in range(3)]
    assert sorted(got) == [1, 2, 3]
    with pytest.raises(kvs.OutOfPages):
        a.alloc()
    assert a.peak == 3


def test_reclaimable_prefix():
    # window 5, ps 4: positions < cur-window+1 are dead
    assert kvs.reclaimable_prefix(3, 5, 4) == 0
    assert kvs.reclaimable_prefix(8, 5, 4) == 1      # pos 0..3 dead at cur=8
    assert kvs.reclaimable_prefix(12, 5, 4) == 2
    assert kvs.reclaimable_prefix(100, -1, 4) == 0   # global: never
    assert kvs.reclaimable_prefix(100, 0, 4) == 0


# ----------------------------------------------------- pool + attention
@pytest.mark.parametrize("kv_dtype,tol", [("bf16", 1.2e-2), ("int8", 6e-2)])
def test_paged_attention_vs_dense_reference(kv_dtype, tol):
    B, Hkv, G, Dh, ps, npp, S = 2, 2, 2, 16, 4, 3, 9
    rng = np.random.default_rng(0)
    pool, table, ks, vs = fill_pool(rng, B, Hkv, Dh, ps, npp, S, kv_dtype,
                                    scramble=np.random.default_rng(7))
    q = rng.normal(size=(B, Hkv * G, Dh)).astype(np.float32)
    cur = jnp.full((B,), S - 1, jnp.int32)
    o = np.asarray(kvs.paged_attention_xla(jnp.asarray(q), pool, table,
                                           cur, -1))
    ref = dense_attention_ref(q, ks.transpose(1, 2, 0, 3),
                              vs.transpose(1, 2, 0, 3), Dh ** -0.5)
    np.testing.assert_allclose(o, ref, atol=tol)


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
@pytest.mark.parametrize("pb", [1, 2, 3])
@pytest.mark.parametrize("window", [-1, 5])
def test_pallas_kernel_matches_xla(kv_dtype, pb, window):
    B, Hkv, G, Dh, ps, npp, S = 2, 2, 2, 16, 4, 3, 10
    rng = np.random.default_rng(1)
    pool, table, _, _ = fill_pool(rng, B, Hkv, Dh, ps, npp, S, kv_dtype)
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, Dh)), jnp.float32)
    cur = jnp.full((B,), S - 1, jnp.int32)
    o_x = kvs.paged_attention_xla(q, pool, table, cur, window)
    o_p = kvs.paged_attention_pallas(q, pool, table, cur, window, pb=pb,
                                     interpret=True)
    np.testing.assert_allclose(np.asarray(o_x), np.asarray(o_p),
                               atol=2e-2 if kv_dtype == "bf16" else 1e-5,
                               rtol=2e-2 if kv_dtype == "bf16" else 1e-5)


def test_pallas_softcap_and_unallocated_pages():
    B, Hkv, G, Dh, ps, npp, S = 1, 2, 1, 16, 4, 4, 6   # 2 pages unused
    rng = np.random.default_rng(2)
    pool, table, _, _ = fill_pool(rng, B, Hkv, Dh, ps, npp, S, "int8")
    assert int((np.asarray(table) >= 0).sum()) == 2    # -1 tail masked
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, Dh)), jnp.float32)
    cur = jnp.full((B,), S - 1, jnp.int32)
    o_x = kvs.paged_attention_xla(q, pool, table, cur, -1, cap=20.0)
    o_p = kvs.paged_attention_pallas(q, pool, table, cur, -1, cap=20.0,
                                     pb=2, interpret=True)
    np.testing.assert_allclose(np.asarray(o_x), np.asarray(o_p), atol=1e-5)


# -------------------------------------------------- chunked prefill path
@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_update_chunk_matches_scan_of_updates(kv_dtype):
    """update_chunk (one scatter per chunk) == a scan of per-token
    update() calls: bf16 bit-identical; int8 lands the same dequantized
    values within the documented ~1 LSB bound (the chunk write quantizes
    against the final page scale instead of walking per-token rescales,
    so codes may differ by a rounding step but content may not)."""
    B, Hkv, Dh, ps, npp, S, C = 2, 2, 8, 4, 5, 9, 4
    rng = np.random.default_rng(5)
    pool, table, _, _ = fill_pool(rng, B, Hkv, Dh, ps, npp, S, kv_dtype)
    kc = jnp.asarray(rng.normal(size=(B, Hkv, C, Dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, Hkv, C, Dh)), jnp.float32)
    positions = jnp.broadcast_to(
        jnp.arange(S, S + C, dtype=jnp.int32)[None], (B, C))
    valid = jnp.asarray([[True] * C, [True, True, False, False]])
    scanned = pool
    for j in range(C):
        scanned = kvs.update(scanned, table, kc[:, :, j], vc[:, :, j],
                             positions[:, j], valid=valid[:, j])
    vec = kvs.update_chunk(pool, table, kc, vc, positions, valid=valid)
    # page 0 is the garbage sink: scatter collisions land there by design
    # (invalid/overflow tokens), its content is documented don't-care —
    # compare real pages only
    if kv_dtype == "bf16":
        for a, b in ((scanned.k_pages, vec.k_pages),
                     (scanned.v_pages, vec.v_pages)):
            np.testing.assert_array_equal(np.asarray(a)[1:],
                                          np.asarray(b)[1:])
    else:
        for pages_a, pages_b, sc_a, sc_b in (
                (scanned.k_pages, vec.k_pages, scanned.k_scale,
                 vec.k_scale),
                (scanned.v_pages, vec.v_pages, scanned.v_scale,
                 vec.v_scale)):
            da = np.asarray(pages_a, np.float32) * \
                np.asarray(sc_a)[:, :, None, None]
            db = np.asarray(pages_b, np.float32) * \
                np.asarray(sc_b)[:, :, None, None]
            bound = 2.0 * np.maximum(np.asarray(sc_a),
                                     np.asarray(sc_b))[:, :, None, None]
            assert (np.abs(da - db) <= bound + 1e-7)[1:].all()


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
@pytest.mark.parametrize("pb,qt", [(1, 1), (2, 2), (3, 4), (2, None)])
@pytest.mark.parametrize("window", [-1, 5])
def test_chunk_pallas_matches_xla(kv_dtype, pb, qt, window):
    """Pallas chunk kernel == XLA chunk reference across page-block and
    query-tile candidates (same tolerances as the decode kernel test:
    online softmax vs. one-shot softmax rounding in bf16)."""
    B, Hkv, G, Dh, ps, npp, S, C = 2, 2, 2, 16, 4, 3, 10, 4
    rng = np.random.default_rng(6)
    pool, table, _, _ = fill_pool(rng, B, Hkv, Dh, ps, npp, S, kv_dtype)
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, C, Dh)), jnp.float32)
    q_pos = jnp.broadcast_to(
        jnp.arange(S - C, S, dtype=jnp.int32)[None], (B, C))
    o_x = kvs.paged_attention_xla_chunk(q, pool, table, q_pos, window,
                                        cap=20.0)
    o_p = kvs.paged_attention_pallas_chunk(q, pool, table, q_pos, window,
                                           cap=20.0, pb=pb, qt=qt,
                                           interpret=True)
    np.testing.assert_allclose(np.asarray(o_x), np.asarray(o_p),
                               atol=2e-2 if kv_dtype == "bf16" else 1e-5,
                               rtol=2e-2 if kv_dtype == "bf16" else 1e-5)


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_chunk_c1_bit_identical_to_decode_kernel(kv_dtype):
    """A C=1 chunk through the Pallas chunk kernel IS the decode kernel:
    same grid arithmetic, bit-identical output."""
    B, Hkv, G, Dh, ps, npp, S = 2, 2, 3, 16, 4, 3, 10
    rng = np.random.default_rng(7)
    pool, table, _, _ = fill_pool(rng, B, Hkv, Dh, ps, npp, S, kv_dtype)
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, Dh)), jnp.float32)
    cur = jnp.full((B,), S - 1, jnp.int32)
    for pb, window in ((1, -1), (2, 5), (4, -1)):
        o_d = kvs.paged_attention_pallas(q, pool, table, cur, window,
                                         pb=pb, interpret=True)
        o_c = kvs.paged_attention_pallas_chunk(
            q[:, :, None], pool, table, cur[:, None], window, pb=pb,
            qt=1, interpret=True)
        np.testing.assert_array_equal(np.asarray(o_d),
                                      np.asarray(o_c[:, :, 0]))


def test_chunk_dispatch_and_bucketed_key():
    """paged_attention_chunk honors a pinned impl, and the tune key
    buckets npp so a growing table maps to one cache entry."""
    from repro.kernels import tune
    B, Hkv, G, Dh, ps, npp, S, C = 1, 2, 2, 8, 4, 3, 8, 2
    rng = np.random.default_rng(8)
    pool, table, _, _ = fill_pool(rng, B, Hkv, Dh, ps, npp, S, "bf16")
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, C, Dh)), jnp.float32)
    q_pos = jnp.broadcast_to(
        jnp.arange(S - C, S, dtype=jnp.int32)[None], (B, C))
    o_auto = kvs.paged_attention_chunk(q, pool, table, q_pos, -1,
                                       interpret=True)
    o_xla = kvs.paged_attention_chunk(q, pool, table, q_pos, -1,
                                      impl="xla", interpret=True)
    np.testing.assert_allclose(np.asarray(o_auto), np.asarray(o_xla),
                               atol=2e-2, rtol=2e-2)
    # npp 5..8 bucket to one key; 9 starts the next bucket
    keys = {tune.paged_key(2, 2, 8, 4, n, 1, False, True)
            for n in (5, 6, 7, 8)}
    assert len(keys) == 1
    assert tune.paged_key(2, 2, 8, 4, 9, 1, False, True) not in keys
    ckeys = {tune.paged_chunk_key(2, 2, 8, 4, n, 1, C, False, True)
             for n in (5, 6, 7, 8)}
    assert len(ckeys) == 1


def test_int8_error_bound():
    """Online requantization stays inside ~1 LSB of the final per-page
    scale (0.5 LSB base + the rescale random walk).  Dequantizes through
    gather_kv — the naive per-sequence materialization oracle — so the
    table-order position convention is asserted along the way."""
    B, Hkv, Dh, ps, npp, S = 1, 4, 32, 8, 5, 40
    rng = np.random.default_rng(0)
    pool, table, ks, vs = fill_pool(rng, B, Hkv, Dh, ps, npp, S, "int8")
    deq_k, deq_v = (np.asarray(x) for x in kvs.gather_kv(pool, table))
    sc = np.asarray(pool.k_scale)
    tbl = np.asarray(table)
    for t in range(S):
        pid = tbl[0, t // ps]
        err = np.abs(deq_k[0, :, t] - ks[t, 0])          # [Hkv, Dh]
        assert (err <= 2.0 * sc[pid][:, None] + 1e-7).all()
        errv = np.abs(deq_v[0, :, t] - vs[t, 0])
        assert (errv <= 2.0 * np.asarray(
            pool.v_scale)[pid][:, None] + 1e-7).all()


def test_bytes_per_token_budget():
    pbt = kvs.kv_bytes_per_token(CFG.n_kv, CFG.head_dim, 16, "int8")
    dbt = kvs.dense_kv_bytes_per_token(CFG.n_kv, CFG.head_dim)
    assert pbt / dbt <= 0.55


# --------------------------------------------------- decode equivalence
def test_paged_bf16_decode_is_bit_exact(params):
    """bf16 pages through the real decode step == the full bf16 cache,
    bit for bit (same mixed-precision semantics, page-gathered)."""
    toks = [1, 7, 3, 9, 2, 8, 4, 6, 5] * 3
    step = jax.jit(lambda p, s, t: M.decode_step(CFG, p, s, t))

    def logits_for(state):
        out = []
        for t in toks:
            state, lg = step(params, state, jnp.asarray([t]))
            out.append(np.asarray(lg[0, :CFG.vocab]))
        return np.stack(out)

    full = logits_for(M.init_decode_state(CFG, 1, 32))
    st = M.init_decode_state(CFG, 1, 32, kv_cache="paged", page_size=8,
                             kv_dtype="bf16")
    npp = st["page_table"].shape[1]
    st["page_table"] = jnp.asarray(np.arange(1, npp + 1)[None], jnp.int32)
    paged = logits_for(st)
    np.testing.assert_array_equal(full, paged)


def test_paged_int8_decode_logits_close(params):
    """int8 pages track the full bf16 cache within the quantization
    floor (~1 LSB of the KV scales, measured ~0.11 peak on random-init
    logits of scale ~4; the bound is the regression tripwire — bf16
    pages cover exactness above).  Random-init logits are near-uniform,
    so a few greedy flips at ~zero margin are expected and benign."""
    toks = [1, 7, 3, 9, 2, 8, 4, 6, 5] * 3
    step = jax.jit(lambda p, s, t: M.decode_step(CFG, p, s, t))

    def logits_for(state):
        out = []
        for t in toks:
            state, lg = step(params, state, jnp.asarray([t]))
            out.append(np.asarray(lg[0, :CFG.vocab]))
        return np.stack(out)

    full = logits_for(M.init_decode_state(CFG, 1, 32))
    st = M.init_decode_state(CFG, 1, 32, kv_cache="paged", page_size=8,
                             kv_dtype="int8")
    npp = st["page_table"].shape[1]
    st["page_table"] = jnp.asarray(np.arange(1, npp + 1)[None], jnp.int32)
    paged = logits_for(st)
    assert np.abs(full - paged).max() <= 0.2
    assert (full.argmax(-1) == paged.argmax(-1)).mean() >= 0.8


# -------------------------------------------------------------- session
def test_session_paged_matches_full_serving(params):
    """Refill-heavy continuous batch: identical greedy tokens through
    both cache kinds (bf16 pages — bit-exact attention), and no leaked
    pages afterwards."""
    reqs = lambda: [Request(prompt=[1, 2 + r], max_new=3 + 2 * r, rid=r)  # noqa: E731
                    for r in range(5)]
    eng = Engine(CFG, params=params)
    full = eng.serve(reqs(), batch_slots=2, max_len=32)
    sess = eng.session(batch_slots=2, max_len=32, kv_cache="paged",
                       page_size=8, kv_dtype="bf16")
    for r in reqs():
        sess.submit(r)
    paged = sess.run()
    assert [r.tokens for r in full] == [r.tokens for r in paged]
    assert sess.alloc.in_use == 0
    assert sess.stats["fills"] == 5
    assert sess.stats["page_allocs"] >= 5    # one page minimum per request


def test_session_randomized_alloc_free(params):
    """Random request lengths/order: every request completes, pages are
    recycled (peak stays below the worst case), nothing leaks."""
    rng = np.random.default_rng(3)
    reqs = [Request(prompt=[1 + int(rng.integers(0, 40))] *
                    int(rng.integers(1, 6)),
                    max_new=int(rng.integers(1, 12)), rid=i)
            for i in range(9)]
    eng = Engine(CFG, params=params)
    sess = eng.session(batch_slots=3, max_len=32, kv_cache="paged",
                       page_size=4)
    for r in reqs:
        sess.submit(r)
    res = sess.run()
    assert [r.rid for r in res] == list(range(9))
    assert [len(r.tokens) for r in res] == [r.max_new for r in reqs]
    assert sess.alloc.in_use == 0
    assert sess.stats["pages_peak"] <= 3 * (32 // 4)


def test_session_out_of_pages_raises(params):
    eng = Engine(CFG, params=params)
    sess = eng.session(batch_slots=2, max_len=32, kv_cache="paged",
                       page_size=4, kv_pool_pages=3)   # 2 usable pages
    sess.submit(Request(prompt=[1, 2, 3, 4, 5], max_new=8, rid=0))
    with pytest.raises(kvs.OutOfPages):
        sess.run()
    # the failed allocation round rolled back: every allocator-held page
    # is visible in the host table (no orphaned grants)
    assert sess.alloc.in_use == int((sess.host_table >= 0).sum())


def test_swa_reclamation_over_page_boundaries():
    """Pure-SWA arch (danube): paged serving matches the dense ring cache
    token-for-token while pages behind the window are recycled, keeping
    residency O(window) — page-granular, across page boundaries."""
    cfg = reduced(get("h2o-danube-1.8b"))       # window 32, all layers
    eng = Engine(cfg)
    req = lambda: [Request(prompt=[1, 2, 3], max_new=56, rid=0)]  # noqa: E731
    full = eng.serve(req(), batch_slots=1, max_len=80)
    sess = eng.session(batch_slots=1, max_len=80, kv_cache="paged",
                       page_size=8, kv_dtype="bf16")
    for r in req():
        sess.submit(r)
    paged = sess.run()
    assert full[0].tokens == paged[0].tokens
    assert sess.stats["pages_reclaimed_swa"] > 0
    # live pages never exceed window/page_size + 2 boundary pages
    assert sess.stats["pages_peak"] <= 32 // 8 + 2


def test_paged_state_specs_match_state(params):
    """Sharding specs tree mirrors the paged decode state structure —
    for both pool dtypes (bf16 pools have None scale leaves)."""
    for dt in ("int8", "bf16"):
        st = M.init_decode_state(CFG, 2, 32, kv_cache="paged",
                                 page_size=8, kv_dtype=dt)
        sp = M.state_specs(CFG, 2, dp_ok=True, kv_cache="paged",
                           kv_dtype=dt)
        jax.tree.map(lambda a, b: None, st, sp)  # same treedef or raises


# ----------------------------------------------------- property sweeps
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYP = False

if HAVE_HYP:
    @settings(max_examples=15, deadline=None)
    @given(ps=st.sampled_from([2, 4, 8, 16]), S=st.integers(1, 24),
           B=st.integers(1, 3), window=st.sampled_from([-1, 3, 7]),
           seed=st.integers(0, 99))
    def test_prop_paged_attention(ps, S, B, window, seed):
        """(page_size, S, B) sweep: bf16 paged attention == windowed
        dense reference for any geometry, including part-filled pages."""
        Hkv, G, Dh = 2, 2, 8
        npp = max(1, -(-S // ps))
        rng = np.random.default_rng(seed)
        pool, table, ks, vs = fill_pool(
            rng, B, Hkv, Dh, ps, npp, S, "bf16",
            scramble=np.random.default_rng(seed + 1))
        q = rng.normal(size=(B, Hkv * G, Dh)).astype(np.float32)
        cur = jnp.full((B,), S - 1, jnp.int32)
        o = np.asarray(kvs.paged_attention_xla(jnp.asarray(q), pool,
                                               table, cur, window))
        ref = dense_attention_ref(q, ks.transpose(1, 2, 0, 3),
                                  vs.transpose(1, 2, 0, 3), Dh ** -0.5,
                                  window=window)
        np.testing.assert_allclose(o, ref, atol=2e-2)

    @settings(max_examples=12, deadline=None)
    @given(ps=st.sampled_from([2, 4, 8]), S=st.integers(2, 20),
           c=st.integers(1, 6), g=st.sampled_from([1, 2, 4]),
           window=st.sampled_from([-1, 3, 7]),
           cap=st.sampled_from([None, 15.0]),
           kv_dtype=st.sampled_from(["bf16", "int8"]),
           pb=st.sampled_from([1, 2, 4]), seed=st.integers(0, 99))
    def test_prop_chunk_pallas_matches_xla(ps, S, c, g, window, cap,
                                           kv_dtype, pb, seed):
        """(C, page_size, npp, GQA group, window, softcap) sweep: the
        Pallas chunk kernel tracks the XLA chunk reference for any
        geometry — part-filled pages, bucket-padded tables, in-chunk
        causality — at the decode-kernel tolerances (bf16 rounding from
        online vs. one-shot softmax; int8 contracts in f32 either way)."""
        c = min(c, S)
        Hkv, Dh = 2, 8
        npp = max(1, -(-S // ps))
        B = 2
        rng = np.random.default_rng(seed)
        pool, table, _, _ = fill_pool(
            rng, B, Hkv, Dh, ps, npp, S, kv_dtype,
            scramble=np.random.default_rng(seed + 1))
        q = jnp.asarray(rng.normal(size=(B, Hkv * g, c, Dh)), jnp.float32)
        q_pos = jnp.broadcast_to(
            jnp.arange(S - c, S, dtype=jnp.int32)[None], (B, c))
        o_x = kvs.paged_attention_xla_chunk(q, pool, table, q_pos, window,
                                            cap=cap)
        o_p = kvs.paged_attention_pallas_chunk(
            q, pool, table, q_pos, window, cap=cap, pb=pb,
            qt=2 if c % 2 == 0 else None, interpret=True)
        np.testing.assert_allclose(
            np.asarray(o_x), np.asarray(o_p),
            atol=2e-2 if kv_dtype == "bf16" else 1e-5,
            rtol=2e-2 if kv_dtype == "bf16" else 1e-5)
