"""Fused codebook-dequant matmul — AIDA's perfect induction on the MXU.

Weights live in HBM as packed 4-bit codebook indices (2 codes/byte, 4× less
HBM traffic than bf16, 8× less than f32).  Each kernel instance expands its
[bn × bk] code tile against the 16-entry centroid table *inside VMEM* and
feeds the MXU — the dense weight matrix never exists in HBM.  This is the
TPU realization of "the bulk of data never leaves the confines of the memory
arrays": compressed weights are only expanded next to the compute unit,
multiplying effective memory bandwidth (decode is memory-bound, so the
roofline's memory term drops ≈4×).

Two modes:
* ``lut_matmul``         — codes × real activations (weights-only coding):
  VMEM dequant-gather then MXU matmul.
* ``lut_product_matmul`` — codes × coded activations through an arbitrary
  16×16 LUT (bit-parallel perfect induction verbatim).  Supports
  non-multiplicative induction tables; gather-based (VPU), sized for decode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _unpack4(packed: jnp.ndarray) -> jnp.ndarray:
    lo = packed & 0xF
    hi = packed >> 4
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


# ------------------------------------------------------- weights-coded
def _lut_matmul_kernel(x_ref, codes_ref, cents_ref, o_ref, acc_ref, *,
                       n_k_blocks: int):
    """Grid (m, n, k): acc[bm,bn] += x[bm,bk] @ dequant(codes[bn,bk/2]).T."""
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack4(codes_ref[...]).astype(jnp.int32)       # [bn, bk]
    w = jnp.take(cents_ref[0], codes, axis=0)                # VMEM dequant
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kb == n_k_blocks - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def lut_matmul(x: jnp.ndarray, codes_packed: jnp.ndarray,
               centroids: jnp.ndarray, *, bm: int = 128, bn: int = 128,
               bk: int = 512, interpret: bool = True) -> jnp.ndarray:
    """x [B,K] @ dequant(codes [N,K/2], centroids [16]).T -> [B,N] f32.

    BlockSpecs: x tiles [bm,bk], code tiles [bn,bk/2] (uint8 — ½ byte/weight
    of VMEM), centroid table replicated (64 B).  MXU dims are 128-aligned.
    VMEM/instance ≈ bm·bk·4 + bn·bk/2 + 2·bm·bn·4 ≈ 0.5 MB at defaults.
    """
    b, k = x.shape
    n, k2 = codes_packed.shape
    assert k2 * 2 == k, "packed codes must cover K"
    bm, bn, bk = min(bm, b), min(bn, n), min(bk, k)
    assert b % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (b // bm, n // bn, k // bk)
    cents2d = centroids.reshape(1, -1).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(_lut_matmul_kernel, n_k_blocks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((bn, bk // 2), lambda i, j, kb: (j, kb)),
            pl.BlockSpec((1, cents2d.shape[1]), lambda i, j, kb: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, codes_packed, cents2d)


# ---------------------------------------------------------- fully-coded
def _lut_product_kernel(xc_ref, codes_ref, lut_ref, o_ref, acc_ref, *,
                        n_k_blocks: int, n_codes: int):
    """Grid (m, n, k): every multiply is LUT[w_code, x_code] (VPU gather)."""
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    wc = _unpack4(codes_ref[...]).astype(jnp.int32)          # [bn, bk]
    xc = xc_ref[...].astype(jnp.int32)                       # [bm, bk]
    flat_idx = wc[None, :, :] * n_codes + xc[:, None, :]     # [bm, bn, bk]
    prods = jnp.take(lut_ref[0], flat_idx.reshape(-1), axis=0)
    acc_ref[...] += prods.reshape(flat_idx.shape).sum(axis=-1)

    @pl.when(kb == n_k_blocks - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def lut_product_matmul(x_codes: jnp.ndarray, codes_packed: jnp.ndarray,
                       lut: jnp.ndarray, *, bm: int = 8, bn: int = 128,
                       bk: int = 128, interpret: bool = True) -> jnp.ndarray:
    """Fully-coded matmul via an arbitrary product LUT (perfect induction).

    x_codes [B,K] uint8, codes_packed [N,K/2] uint8, lut [nc,nc] f32 ->
    [B,N] f32.  Small bm (decode batches): the [bm,bn,bk] index tensor must
    fit VMEM (defaults → 8·128·128·4 B = 512 KiB).
    """
    b, k = x_codes.shape
    n, k2 = codes_packed.shape
    assert k2 * 2 == k
    nc = lut.shape[0]
    bm, bn, bk = min(bm, b), min(bn, n), min(bk, k)
    assert b % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (b // bm, n // bn, k // bk)
    lut_flat = lut.reshape(1, -1).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(_lut_product_kernel, n_k_blocks=grid[2],
                          n_codes=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((bn, bk // 2), lambda i, j, kb: (j, kb)),
            pl.BlockSpec((1, nc * nc), lambda i, j, kb: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x_codes, codes_packed, lut_flat)
