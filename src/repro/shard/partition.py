"""Shard-aware re-stacking of compressed containers + param placement.

Row-partitioning a compressed FC over ``tp`` shards needs the row axis to
divide evenly: BlockedACSR splits on its row-*block* axis (each shard
gets a contiguous band of blocks = a band of output rows, the per-IC
matrix partitioning of the paper), int8/codebook4/dense split on their
output-channel axis.  `pad_params_for_plan` appends empty row
blocks / zero rows so every compressed leaf divides — "per-shard
padding": padded rows have ``row_nnz == 0`` (acsr/aida) or zero
codes/scales, compute nothing real, and are sliced off after the shard
outputs are gathered (``CompressedFC.shape`` keeps the true row count).

`prepare_params` = pad + `jax.device_put` onto the plan's NamedShardings;
`local_view` builds the single-shard view of a stacked leaf so the
kernel autotuner can tune the geometry the shard-local SpMV will
actually run (`tune_local_views`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant as q
from repro.core import sparse_fc as sfc
from repro.kernels import acsr_spmv as sp


def _ceil_to(n: int, m: int) -> int:
    return -(-n // m) * m


def row_axis_len(leaf: sfc.CompressedFC) -> int:
    """Length of the axis the plan partitions (row blocks for acsr/aida,
    output channels otherwise) on a stacked or single-layer leaf."""
    if leaf.mode in ("acsr", "aida"):
        return leaf.blocked.values.shape[-3]      # nblocks
    if leaf.mode == "int8":
        return leaf.qt.q.shape[-2]
    if leaf.mode == "codebook4":
        return leaf.codes_packed.shape[-2]
    return leaf.dense.shape[-2]


def shardable(leaf: sfc.CompressedFC, tp: int) -> bool:
    return tp > 1 and row_axis_len(leaf) % tp == 0


def pad_leaf(leaf: sfc.CompressedFC, tp: int) -> sfc.CompressedFC:
    """Pad the partition axis of one compressed leaf to a multiple of
    ``tp`` (no-op when it already divides).  Works on stacked ([L, ...])
    and single-layer leaves; the aux ``shape`` keeps the true row count,
    so downstream slicing stays correct."""
    n = row_axis_len(leaf)
    pad = _ceil_to(n, tp) - n
    if pad == 0:
        return leaf

    def pad_rows(x, axis):
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        return jnp.pad(x, widths)

    if leaf.mode in ("acsr", "aida"):
        b = leaf.blocked
        blocked = dataclasses.replace(
            b, values=pad_rows(b.values, b.values.ndim - 3),
            col_idx=pad_rows(b.col_idx, b.col_idx.ndim - 3),
            row_nnz=pad_rows(b.row_nnz, b.row_nnz.ndim - 2))
        return dataclasses.replace(leaf, blocked=blocked)
    if leaf.mode == "int8":
        qt = q.QTensor(q=pad_rows(leaf.qt.q, leaf.qt.q.ndim - 2),
                       scale=pad_rows(leaf.qt.scale,
                                      leaf.qt.scale.ndim - 2),
                       bits=leaf.qt.bits)
        return dataclasses.replace(leaf, qt=qt)
    if leaf.mode == "codebook4":
        return dataclasses.replace(
            leaf, codes_packed=pad_rows(leaf.codes_packed,
                                        leaf.codes_packed.ndim - 2))
    return dataclasses.replace(
        leaf, dense=pad_rows(leaf.dense, leaf.dense.ndim - 2))


def pad_params_for_plan(plan, params: Dict) -> Dict:
    """Pad every compressed leaf's partition axis to a multiple of the
    plan's tp degree.  Raw arrays pass through untouched (GSPMD handles
    or replicates them per the plan's fit rule)."""
    def visit(leaf):
        if isinstance(leaf, sfc.CompressedFC) and plan.tp > 1:
            return pad_leaf(leaf, plan.tp)
        return leaf
    return jax.tree.map(visit, params,
                        is_leaf=lambda x: isinstance(x, sfc.CompressedFC))


def prepare_params(plan, cfg, params: Dict) -> Tuple[Dict, object]:
    """(padded, device_put) params for a plan.  Returns (params,
    shardings) — the shardings tree doubles as the step's in_shardings."""
    padded = pad_params_for_plan(plan, params)
    shardings = plan.param_shardings(cfg, padded)
    return jax.device_put(padded, shardings), shardings


# --------------------------------------------------------------- tuning
def local_view(leaf: sfc.CompressedFC, tp: int,
               shard: int = 0) -> sfc.CompressedFC:
    """The single-layer, single-shard view of a (stacked) compressed
    leaf — the exact geometry `shard.apply` runs inside shard_map, so
    tuning this view caches winners under the keys the sharded step
    will look up at trace time."""
    from repro.kernels import tune
    lay = tune._layer0_view(pad_leaf(leaf, tp))
    n = row_axis_len(lay) // tp
    lo = shard * n

    def rows(x, axis):
        return jax.lax.slice_in_dim(x, lo, lo + n, axis=axis)

    if lay.mode in ("acsr", "aida"):
        b = lay.blocked
        blocked = dataclasses.replace(
            b, values=rows(b.values, 0), col_idx=rows(b.col_idx, 0),
            row_nnz=rows(b.row_nnz, 0),
            shape=(n * b.block_rows, b.shape[1]))
        return dataclasses.replace(lay, blocked=blocked,
                                   shape=(n * b.block_rows, lay.shape[1]))
    if lay.mode == "int8":
        qt = q.QTensor(q=rows(lay.qt.q, 0), scale=rows(lay.qt.scale, 0),
                       bits=lay.qt.bits)
        return dataclasses.replace(lay, qt=qt, shape=(n, lay.shape[1]))
    if lay.mode == "codebook4":
        return dataclasses.replace(lay, codes_packed=rows(
            lay.codes_packed, 0), shape=(n, lay.shape[1]))
    return dataclasses.replace(lay, dense=rows(lay.dense, 0),
                               shape=(n, lay.shape[1]))


def tune_local_views(params: Dict, plan, batch: int,
                     interpret: bool) -> int:
    """Autotune every unique *shard-local* compressed geometry, so the
    dispatch inside the sharded decode step finds winners at trace time
    (the global-geometry cache entries do not match local shapes)."""
    from repro.kernels import tune
    tuned = 0

    def visit(leaf):
        nonlocal tuned
        if isinstance(leaf, sfc.CompressedFC) and leaf.mode != "dense" \
                and plan.tp > 1:
            tune.tune_layer(local_view(leaf, plan.tp), batch, interpret)
            tuned += 1
        return leaf

    jax.tree.map(visit, params,
                 is_leaf=lambda x: isinstance(x, sfc.CompressedFC))
    return tuned
