"""Deterministic tick-clock structured event tracer for the serving stack.

One :class:`Tracer` collects *spans* (work that occupies ticks — decode
steps, chunked-prefill steps) and *instant events* (admissions,
preemptions, handoffs, page allocations, fault injections) from every
seam the stack already has.  Two properties make it useful as a CI
artifact and not just a debugging aid:

* **tick clock, not wall clock** — every event is stamped with the
  scheduler tick it happened on (plus role / slot / rid coordinates).
  For a fixed seed the serving stack's decisions are deterministic, so
  the exported event stream is *byte-identical across replays* and CI
  can diff two same-seed runs (wall-time phase timers live separately,
  see :class:`WallTimers`, and never enter the event stream).
* **zero cost when disabled** — sessions hold :data:`NULL` (a no-op
  tracer with ``enabled = False``) unless the caller passes a live one;
  hot-path seams (allocator, prefix cache, scheduler) are wired only
  when a live tracer is attached, so the off path adds nothing.

The Chrome/Perfetto ``trace_event`` exporter maps roles to processes
and slots to threads: load the exported JSON in https://ui.perfetto.dev
and a serve run renders as a per-role, per-slot timeline (one tick =
:data:`TICK_US` microseconds on the rendered axis).
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Callable, Dict, List, Optional

#: microseconds one scheduler tick occupies on the exported timeline
#: (purely presentational: ticks are the real clock)
TICK_US = 1000

#: stable process ids for the known roles; unknown roles are assigned
#: deterministically (sorted by name) after these
ROLE_PIDS = {"engine": 1, "prefill": 1, "decode": 2}

#: event names emitted by the serving stack (reference, not enforced —
#: the schema check in benchmarks/validate_trace.py validates shape)
EVENT_NAMES = (
    "req.submit", "req.first_token", "req.finish",
    "sched.admit", "sched.preempt", "sched.block", "sched.shed",
    "step.decode", "step.prefill",
    "handoff.enqueue", "handoff.deliver", "handoff.migrate",
    "handoff.fallback", "handoff.oversized",
    "alloc.pages", "alloc.free", "alloc.holdback",
    "prefix.hit", "prefix.pin", "prefix.release",
    "fault.injected", "resil.fail", "resil.degrade",
    "health.audit",
)


class NullTracer:
    """The disabled tracer: every emit is a no-op, ``enabled`` is False
    so seams that need to build expensive args can skip entirely."""

    enabled = False
    recorder = None

    def instant(self, name, **kw):
        pass

    def span(self, name, **kw):
        pass

    def crash(self, reason, **context):
        pass

    def hook(self, role="engine", clock=None):
        return None


#: the shared disabled tracer — sessions default to this
NULL = NullTracer()


class Tracer:
    """Collects structured events on the scheduler tick clock.

    ``capture=False`` keeps no full event list (useful when only the
    flight-recorder ring matters); a ``recorder`` (obs.FlightRecorder)
    receives every event regardless and is dumped by :meth:`crash`.
    """

    enabled = True

    def __init__(self, capture: bool = True, recorder=None):
        self.capture = capture
        self.recorder = recorder
        self.events: List[dict] = []
        self.wall = WallTimers()

    # ------------------------------------------------------------ emit
    def _emit(self, ev: dict) -> None:
        if self.capture:
            self.events.append(ev)
        if self.recorder is not None:
            self.recorder.record(ev)

    def instant(self, name: str, *, tick: int, role: str = "engine",
                slot: Optional[int] = None, **args) -> None:
        """A point event at ``tick`` (admission, handoff, fault, ...)."""
        self._emit({"name": name, "ph": "i", "tick": int(tick),
                    "role": role, "slot": slot, "args": args})

    def span(self, name: str, *, tick: int, dur: int = 1,
             role: str = "engine", slot: Optional[int] = None,
             **args) -> None:
        """Work occupying ``dur`` ticks starting at ``tick`` (a decode
        or prefill step)."""
        self._emit({"name": name, "ph": "X", "tick": int(tick),
                    "dur": int(dur), "role": role, "slot": slot,
                    "args": args})

    def crash(self, reason: str, **context) -> Optional[str]:
        """Flush the flight recorder to disk (HealthError / OutOfPages /
        RequestFailed post-mortems).  Returns the dump path, if any."""
        if self.recorder is None:
            return None
        return self.recorder.dump(reason=reason, context=context)

    def hook(self, role: str = "engine",
             clock: Optional[Callable[[], int]] = None) -> Callable:
        """A ``(name, **args) -> None`` emitter bound to a role and a
        tick-clock callable — the shape the allocator / prefix-cache /
        scheduler seams accept so they stay import-light."""
        if clock is None:
            return lambda name, **a: self.instant(name, tick=0,
                                                  role=role, **a)
        return lambda name, **a: self.instant(name, tick=clock(),
                                              role=role, **a)

    # ---------------------------------------------------------- export
    def to_chrome(self) -> dict:
        """Chrome/Perfetto ``trace_event`` JSON: roles become processes,
        slots become threads (tid 0 = role-level events)."""
        pids: Dict[str, int] = dict(ROLE_PIDS)
        for ev in self.events:
            if ev["role"] not in pids:
                pids[ev["role"]] = 0   # placeholder, assigned below
        nxt = max(pids.values(), default=0) + 1
        for role in sorted(r for r, p in pids.items() if p == 0):
            pids[role] = nxt
            nxt += 1
        out: List[dict] = []
        seen_threads = set()
        for role in sorted({ev["role"] for ev in self.events},
                           key=lambda r: (pids[r], r)):
            out.append({"name": "process_name", "ph": "M", "pid": pids[role],
                        "tid": 0, "args": {"name": role}})
        for ev in self.events:
            pid = pids[ev["role"]]
            tid = 0 if ev["slot"] is None else int(ev["slot"]) + 1
            if tid and (pid, tid) not in seen_threads:
                seen_threads.add((pid, tid))
                out.append({"name": "thread_name", "ph": "M", "pid": pid,
                            "tid": tid,
                            "args": {"name": f"slot {tid - 1}"}})
            rec = {"name": ev["name"], "ph": ev["ph"], "pid": pid,
                   "tid": tid, "ts": ev["tick"] * TICK_US,
                   "args": dict(ev["args"], tick=ev["tick"])}
            if ev["ph"] == "X":
                rec["dur"] = ev["dur"] * TICK_US
            elif ev["ph"] == "i":
                rec["s"] = "t"
            out.append(rec)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write the Perfetto-loadable trace; deterministic serialization
        (sorted keys) so same-seed replays are byte-identical."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1, sort_keys=True)
            f.write("\n")
        return path


class WallTimers:
    """Wall-clock phase accumulators (decode / prefill / migrate ...).

    Deliberately separate from the event stream: wall time is host noise
    and would break replay-identical traces, but the per-phase split is
    exactly the EIE-style accounting the BENCH trajectory needs."""

    def __init__(self):
        self.seconds: Dict[str, float] = {}
        self.calls: Dict[str, int] = {}

    @contextlib.contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self.seconds[name] = self.seconds.get(name, 0.0) + dt
            self.calls[name] = self.calls.get(name, 0) + 1

    def summary(self) -> dict:
        total = sum(self.seconds.values())
        return {name: {"seconds": round(self.seconds[name], 4),
                       "calls": self.calls[name],
                       "share": round(self.seconds[name] / total, 4)
                       if total > 0 else None}
                for name in sorted(self.seconds)}


@contextlib.contextmanager
def profile_trace(log_dir: Optional[str]):
    """Optional ``jax.profiler`` trace around the compiled steps: a
    no-op when ``log_dir`` is falsy, so callers can thread the flag
    through unconditionally (serve.py ``--profile-dir``)."""
    if not log_dir:
        yield
        return
    import jax
    jax.profiler.start_trace(log_dir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()
