"""Serving metrics: TTFT / TPOT / latency percentiles / goodput.

The Session records one lifecycle dict per request (submit/admit/first
token/finish, in both wall seconds and model-call steps); `summarize`
folds them into the JSON-ready `"serving"` record that
`Engine.benchmark` writes to BENCH_api.json and
`benchmarks/check_regression.py` gates.

Step-denominated numbers (`first_token_calls`, preemptions, prefix
pages) are deterministic for a given workload — those carry the hard CI
assertions; wall-clock numbers (TTFT seconds, tok/s, goodput) are the
host-noisy trajectory signal and get the usual dual-unit tolerance.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence


def percentile(values: Sequence[float], q: float) -> Optional[float]:
    """Nearest-rank percentile (q in [0, 100]); None on empty input."""
    if not values:
        return None
    xs = sorted(values)
    k = max(0, min(len(xs) - 1, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[k]


def _dist(values: Sequence[float], scale: float = 1.0) -> Optional[dict]:
    if not values:
        return None
    vs = [v * scale for v in values]
    return {"mean": round(sum(vs) / len(vs), 4),
            "p50": round(percentile(vs, 50), 4),
            "p99": round(percentile(vs, 99), 4)}


def summarize(records: Sequence[Dict], span_seconds: float,
              steps: int) -> dict:
    """Fold per-request lifecycle records into the serving summary.

    records: dicts with prompt_len, max_new, n_generated, submit_time,
    first_token_time, finish_time, submit_step, admit_step,
    first_token_step, preemptions, prefix_pages (absent fields skipped).
    """
    done = [r for r in records if r.get("finish_time") is not None]
    ttft = [r["first_token_time"] - r["submit_time"] for r in records
            if r.get("first_token_time") is not None]
    tpot: List[float] = []
    for r in done:
        if r["n_generated"] > 1 and r.get("first_token_time") is not None:
            tpot.append((r["finish_time"] - r["first_token_time"])
                        / (r["n_generated"] - 1))
    first_calls = [r["first_token_step"] - r["admit_step"] for r in records
                   if r.get("first_token_step") is not None
                   and r.get("admit_step") is not None]
    n_tok = sum(r["n_generated"] for r in done)
    span = max(span_seconds, 1e-9)
    return {
        "requests": len(records),
        "completed": len(done),
        "tokens": n_tok,
        "seconds": round(span_seconds, 4),
        "steps": steps,
        "tok_per_s": round(n_tok / span, 2),
        "goodput_req_per_s": round(len(done) / span, 3),
        "ttft_s": _dist(ttft),
        "tpot_s": _dist(tpot),
        "first_token_calls": _dist(first_calls) if first_calls else None,
        "preemptions": sum(r.get("preemptions", 0) for r in records),
        "prefix_pages_reused": sum(r.get("prefix_pages", 0)
                                   for r in records),
    }
