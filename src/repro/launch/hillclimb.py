import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ must precede jax import (same rule as dryrun).

"""§Perf hillclimb driver: lower one cell under a sequence of optimization
variants and report the three roofline terms for each (hypothesis →
change → before/after lives in EXPERIMENTS.md §Perf).

  PYTHONPATH=src python -m repro.launch.hillclimb --arch llama3-8b \
      --shape train_4k [--mesh single] [--json out.jsonl]
"""
import argparse
import json
import sys

from repro.launch.dryrun import lower_cell

VARIANTS = {
    "train": [
        ("baseline (einsum attn, dense loss, remat=dots)", {}),
        ("+chunked attention", {"attn_impl": "chunked"}),
        ("+streamed vocab loss", {"attn_impl": "chunked",
                                  "streamed_loss": True}),
        ("+bf16 cast-before-gather",
         {"attn_impl": "chunked", "streamed_loss": True,
          "cast_params": True}),
        ("+microbatch=4", {"attn_impl": "chunked", "streamed_loss": True,
                           "cast_params": True, "microbatches": 4}),
        ("full remat variant",
         {"attn_impl": "chunked", "streamed_loss": True,
          "cast_params": True, "remat": "full"}),
    ],
    "prefill": [
        ("baseline (einsum attn)", {}),
        ("+chunked attention", {"attn_impl": "chunked"}),
    ],
    "decode": [
        ("baseline (f32 params)", {}),
        ("+bf16 serving params", {"serve_bf16": True}),
    ],
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json", default=None)
    ap.add_argument("--variants", default=None,
                    help="comma list of variant indices to run")
    args = ap.parse_args(argv)
    kind = ("train" if args.shape.startswith("train") else
            "prefill" if args.shape.startswith("prefill") else "decode")
    variants = VARIANTS[kind]
    if args.variants:
        idx = [int(i) for i in args.variants.split(",")]
        variants = [variants[i] for i in idx]
    for name, kw in variants:
        try:
            rec = lower_cell(args.arch, args.shape, args.mesh == "multi",
                             cost_unroll=True, verbose=False, **kw)
        except Exception as e:  # noqa: BLE001
            print(f"[hillclimb] {name}: FAILED {e!r}")
            continue
        rec["variant"] = name
        print(f"[hillclimb] {name}:")
        print(f"    compute={rec['t_compute']*1e3:9.3f}ms "
              f"memory={rec['t_memory']*1e3:9.3f}ms "
              f"coll={rec['t_collective']*1e3:9.3f}ms "
              f"[{rec['bottleneck']}] temp={rec['temp_bytes']/2**30:6.2f}GiB "
              f"useful={rec['useful_flops_frac']:.1%} "
              f"roofline={rec['roofline_frac']:.2%}")
        if args.json:
            with open(args.json, "a") as f:
                f.write(json.dumps(rec) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
