"""Serving metrics: TTFT / TPOT / latency percentiles / goodput.

The Session records one lifecycle dict per request (submit/admit/first
token/finish, in both wall seconds and model-call steps); `summarize`
folds them into the JSON-ready `"serving"` record that
`Engine.benchmark` writes to BENCH_api.json and
`benchmarks/check_regression.py` gates.

Since PR 8 the folding runs on the typed `repro.obs.registry`
primitives — counters for totals, histograms for distributions — so the
serving summary, the ``--json`` dump, and every BENCH section share one
aggregation layer.  The *output shape is unchanged*: `summarize()`
returns the exact pre-registry key set (tests pin it), the registry is
an implementation substrate, not a new schema.

Step-denominated numbers (`first_token_calls`, preemptions, prefix
pages) are deterministic for a given workload — those carry the hard CI
assertions; wall-clock numbers (TTFT seconds, tok/s, goodput) are the
host-noisy trajectory signal and get the usual dual-unit tolerance.

Rate fields guard their denominators: a zero-span or zero-step run (a
tiny CI workload that completes inside one clock quantum, or an empty
request list) reports ``None`` for tok/s / goodput / utilization instead
of raising or fabricating an absurd rate.

Disaggregated serving adds two record families: per-request *handoff*
fields (``handoff_latency_s``, ``migrated_pages``, ``migrated_bytes``,
stamped by the decode role when it admits a migrated prompt) are folded
into a ``"handoff"`` sub-record, and a ``roles=`` dict of per-role step
counters becomes ``"roles"`` with per-role utilization (busy ticks over
total ticks).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

from repro.obs.registry import Histogram, Registry
from repro.obs.registry import percentile as percentile  # re-export


#: the stable top-level key set of `summarize()` — the schema contract
#: BENCH sections and downstream tooling rely on.  Conditional keys
#: appear only when their record family is present.
SUMMARY_KEYS = (
    "requests", "completed", "tokens", "seconds", "steps", "tok_per_s",
    "goodput_req_per_s", "ttft_s", "ttft_sched", "queue_wait_sched",
    "tpot_s", "first_token_calls", "preemptions", "prefix_pages_reused",
)
SUMMARY_KEYS_CONDITIONAL = ("outcomes", "resil", "handoff", "roles")


def _dist(values: Sequence[float], scale: float = 1.0) -> Optional[dict]:
    """mean/p50/p99 of a value list via a throwaway Histogram — the
    canonical distribution record; None on empty input."""
    h = Histogram("_dist")
    h.observe_many(values)
    return h.summary(scale=scale)


def _rate(num: float, denom: float, digits: int = 2) -> Optional[float]:
    """num/denom, or None when the denominator is degenerate (zero-span
    runs must not crash or report infinite rates)."""
    if denom is None or denom <= 0:
        return None
    return round(num / denom, digits)


def _handoff(records: Sequence[Dict]) -> Optional[dict]:
    """Fold the disagg handoff fields (absent on co-located runs)."""
    hs = [r for r in records if r.get("handoff_latency_s") is not None]
    if not hs:
        return None
    n = len(hs)
    return {
        "count": n,
        "latency_s": _dist([r["handoff_latency_s"] for r in hs]),
        "latency_ticks": _dist([r["handoff_ticks"] for r in hs
                                if r.get("handoff_ticks") is not None]),
        "migrated_pages": sum(r.get("migrated_pages", 0) for r in hs),
        "migrated_bytes": sum(r.get("migrated_bytes", 0) for r in hs),
        "bytes_per_request": _rate(
            sum(r.get("migrated_bytes", 0) for r in hs), n, 1),
    }


def _outcomes(records: Sequence[Dict]) -> Optional[dict]:
    """Terminal-state census (absent when no record carries a state —
    pre-resil callers).  ``failed_by_reason`` attributes every
    structured failure (deadline / shed / retries_exhausted /
    oversized) so denominators stay honest under faults."""
    reg = Registry()
    for r in records:
        s = r.get("state")
        if not s:
            continue
        reg.counter(s).inc()
        if s == "failed" and r.get("failed_reason"):
            reg.counter(f"failed/{r['failed_reason']}").inc()
    counts = {k: c.value for k, c in reg.counters.items()}
    states = {k: v for k, v in counts.items() if not k.startswith("failed/")}
    if not states:
        return None
    out: Dict[str, int] = dict(states)
    reasons = {k.split("/", 1)[1]: v for k, v in counts.items()
               if k.startswith("failed/")}
    if reasons:
        out["failed_by_reason"] = reasons
    return out


def summarize(records: Sequence[Dict], span_seconds: float,
              steps: int, roles: Optional[Dict[str, Dict]] = None,
              resil: Optional[Dict] = None) -> dict:
    """Fold per-request lifecycle records into the serving summary.

    records: dicts with prompt_len, max_new, n_generated, submit_time,
    first_token_time, finish_time, submit_step, admit_step,
    first_token_step, preemptions, prefix_pages (absent fields skipped).

    roles: optional per-role counters for disaggregated serving —
    ``{"prefill": {"steps": n, "busy_ticks": b}, "decode": {...}}`` plus
    a ``"ticks"`` total under the key ``"_ticks"``; folded into a
    ``"roles"`` record with per-role utilization.

    resil: optional resilience-layer counters (``Session.resil_summary``)
    — shed/retry/deadline-miss/degraded plus per-fault-class injection
    counts; folded through as a ``"resil"`` record.
    """
    reg = Registry()
    requests = reg.counter("requests")
    completed = reg.counter("completed")
    tokens = reg.counter("tokens")
    preempts = reg.counter("preemptions")
    prefix_pages = reg.counter("prefix_pages_reused")
    ttft = reg.histogram("ttft_s")
    tpot = reg.histogram("tpot_s")
    first_calls = reg.histogram("first_token_calls")
    ttft_tick = reg.histogram("ttft_ticks")
    ttft_step = reg.histogram("ttft_steps")
    queue_wait = reg.histogram("queue_wait_sched")
    for r in records:
        requests.inc()
        preempts.inc(r.get("preemptions", 0))
        prefix_pages.inc(r.get("prefix_pages", 0))
        if r.get("first_token_time") is not None:
            ttft.observe(r["first_token_time"] - r["submit_time"])
        if r.get("first_token_step") is not None:
            if r.get("admit_step") is not None:
                first_calls.observe(r["first_token_step"]
                                    - r["admit_step"])
            if r.get("submit_step") is not None:
                ttft_step.observe(r["first_token_step"]
                                  - r["submit_step"])
        if r.get("first_token_tick") is not None \
                and r.get("submit_tick") is not None:
            ttft_tick.observe(r["first_token_tick"] - r["submit_tick"])
        # queueing delay split from service time, in the scheduling
        # clock: the wait between submit and first slot admission is
        # pure queueing (admission back-pressure), deterministic for a
        # given workload — obs.analyze derives the full split (incl.
        # preemption re-queueing) from the trace; this is the cheap
        # always-on record-level view
        if r.get("admit_step") is not None \
                and r.get("submit_step") is not None:
            queue_wait.observe(r["admit_step"] - r["submit_step"])
        if r.get("finish_time") is None:
            continue
        completed.inc()
        tokens.inc(r["n_generated"])
        if r["n_generated"] > 1 and r.get("first_token_time") is not None:
            tpot.observe((r["finish_time"] - r["first_token_time"])
                         / (r["n_generated"] - 1))
    # scheduling-clock TTFT, comparable across engine shapes: a
    # disaggregated run stamps submit/first-token in orchestrator ticks
    # (one tick = one scheduling opportunity per role); a co-located run
    # falls back to the model-call step clock, which is its tick
    ttft_sched = ttft_tick if ttft_tick.values else ttft_step
    out = {
        "requests": requests.value,
        "completed": completed.value,
        "tokens": tokens.value,
        "seconds": round(span_seconds, 4),
        "steps": steps,
        "tok_per_s": _rate(tokens.value, span_seconds),
        "goodput_req_per_s": _rate(completed.value, span_seconds, 3),
        "ttft_s": ttft.summary(),
        "ttft_sched": ttft_sched.summary(),
        "queue_wait_sched": queue_wait.summary(),
        "tpot_s": tpot.summary(),
        "first_token_calls": first_calls.summary(),
        "preemptions": preempts.value,
        "prefix_pages_reused": prefix_pages.value,
    }
    outcomes = _outcomes(records)
    if outcomes is not None:
        out["outcomes"] = outcomes
    if resil is not None:
        out["resil"] = dict(resil)
    hand = _handoff(records)
    if hand is not None:
        out["handoff"] = hand
    if roles:
        ticks = roles.get("_ticks")
        out["roles"] = {
            name: {"steps": rec.get("steps"),
                   "utilization": _rate(rec.get("busy_ticks", 0),
                                        ticks, 3)}
            for name, rec in roles.items() if name != "_ticks"}
    return out
