"""Continuous-batching serving semantics, driven through repro.api.Engine:
slot refill after a request finishes mid-batch, the prefill-then-generate
boundary, and the greedy-vs-temperature sampling paths."""
import jax
import numpy as np
import pytest

from repro.api import Engine, Request
from repro.configs import get, reduced
from repro.models import model as M

CFG = reduced(get("llama3-8b"), n_layers=2, d_model=64, d_ff=128, vocab=256)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def test_slot_refill_mid_batch(params):
    """5 requests over 2 slots: finished slots refill without stopping the
    batch; every request completes; results come back in rid order."""
    eng = Engine(CFG, params=params)
    sess = eng.session(batch_slots=2, max_len=32)
    lens = [3, 6, 3, 6, 3]
    for rid, mn in enumerate(lens):
        sess.submit(Request(prompt=[1, 2 + rid], max_new=mn, rid=rid))
    res = sess.run()
    assert [r.rid for r in res] == [0, 1, 2, 3, 4]
    assert [len(r.tokens) for r in res] == lens
    assert sess.stats["fills"] == 5
    # batching overlap: far fewer batch steps than serial execution
    serial_steps = sum(2 + mn for mn in lens)
    assert sess.stats["steps"] < serial_steps


def test_prefill_then_generate_boundary(params):
    """The first generated token must be sampled from the logits of the
    LAST prompt token — verified against a manual decode loop."""
    prompt, max_new = [1, 2, 3, 4], 3
    eng = Engine(CFG, params=params)
    got = eng.serve([Request(prompt=prompt, max_new=max_new, rid=0)],
                    batch_slots=1, max_len=16)[0].tokens

    state = M.init_decode_state(CFG, 1, 16)
    toks = []
    nxt = None
    feed = list(prompt)
    for _ in range(len(prompt) + max_new - 1):
        tok = feed.pop(0) if feed else nxt
        state, logits = M.decode_step(CFG, params, state,
                                      jax.numpy.asarray([tok]))
        nxt = int(np.asarray(logits[0, :CFG.vocab]).argmax())
        if not feed:
            toks.append(nxt)
    assert got == toks


def test_greedy_is_deterministic(params):
    eng = Engine(CFG, params=params)
    reqs = lambda: [Request(prompt=[1, 2, 3], max_new=6, rid=0)]  # noqa: E731
    a = eng.serve(reqs(), batch_slots=1, max_len=16)[0].tokens
    b = eng.serve(reqs(), batch_slots=1, max_len=16)[0].tokens
    assert a == b


def test_temperature_sampling_paths(params):
    """Same seed -> reproducible samples; hot sampling diverges from the
    greedy path (near-uniform random-init logits over 256 tokens)."""
    eng = Engine(CFG, params=params)

    def serve(temp, seed):
        return eng.serve(
            [Request(prompt=[1, 2, 3], max_new=8, temperature=temp, rid=0)],
            batch_slots=1, max_len=16, seed=seed)[0].tokens

    greedy = serve(0.0, 0)
    hot1 = serve(5.0, 0)
    hot2 = serve(5.0, 0)
    hot3 = serve(5.0, 1)
    assert hot1 == hot2           # seeded sampling is reproducible
    assert hot1 != greedy         # sampling path actually samples
    assert hot3 != hot1           # different seed, different draw
    assert all(0 <= t < CFG.vocab for t in hot1)


def test_mixed_greedy_and_sampled_batch(params):
    """Greedy and temperature requests coexist in one continuous batch;
    the greedy slot is unaffected by its sampled neighbour."""
    eng = Engine(CFG, params=params)
    solo = eng.serve([Request(prompt=[1, 2], max_new=4, rid=0)],
                     batch_slots=2, max_len=16)[0].tokens
    mixed = eng.serve(
        [Request(prompt=[1, 2], max_new=4, rid=0),
         Request(prompt=[5, 6], max_new=4, temperature=2.0, rid=1)],
        batch_slots=2, max_len=16)
    assert mixed[0].tokens == solo
    assert len(mixed[1].tokens) == 4
