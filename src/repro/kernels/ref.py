"""Pure-jnp oracles for every Pallas kernel (the ref side of kernel tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


# ----------------------------------------------------------- lut_matmul
def unpack4(packed: jnp.ndarray) -> jnp.ndarray:
    lo = packed & 0xF
    hi = packed >> 4
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


def lut_matmul_ref(x: jnp.ndarray, codes_packed: jnp.ndarray,
                   centroids: jnp.ndarray) -> jnp.ndarray:
    """x [B, K] @ dequant(codes [N, K/2] packed, centroids [16]).T -> [B, N].

    The oracle materializes the full dense weight matrix; the kernel never
    does (codes expand tile-by-tile inside VMEM — AIDA's in-memory dividend).
    """
    codes = unpack4(codes_packed).astype(jnp.int32)       # [N, K]
    w = jnp.take(centroids, codes, axis=0)                # [N, K]
    return jnp.matmul(x, w.T.astype(x.dtype),
                      preferred_element_type=jnp.float32)


def lut_product_matmul_ref(x_codes: jnp.ndarray, codes_packed: jnp.ndarray,
                           lut: jnp.ndarray, n_rows: int) -> jnp.ndarray:
    """Fully-coded mode: BOTH operands are 4-bit codes, every multiply is a
    16×16 product-LUT lookup (bit-parallel perfect induction, paper §3).

    x_codes [B, K] uint8, codes_packed [N, K/2], lut [16,16] f32 -> [B, N].
    """
    del n_rows
    w_codes = unpack4(codes_packed).astype(jnp.int32)     # [N, K]
    prods = lut[w_codes[None, :, :], x_codes[:, None, :].astype(jnp.int32)]
    return prods.sum(axis=-1)                             # [B, N]


# ----------------------------------------------------------- acsr_spmv
def acsr_spmv_ref(values: jnp.ndarray, col_idx: jnp.ndarray,
                  seg_id: jnp.ndarray, x: jnp.ndarray,
                  n_rows: int) -> jnp.ndarray:
    """Per-nnz stream oracle. x: [K] or [K, B] -> [n_rows] or [n_rows, B]."""
    gathered = jnp.take(x, col_idx, axis=0)               # activation bcast
    prod = (values[:, None] if x.ndim == 2 else values) * gathered
    return jax.ops.segment_sum(prod, seg_id, num_segments=n_rows + 1)[:n_rows]


def blocked_acsr_spmv_ref(values: jnp.ndarray, col_idx: jnp.ndarray,
                          row_nnz: jnp.ndarray, x: jnp.ndarray,
                          block_rows: int) -> jnp.ndarray:
    """Row-blocked slot-schedule oracle.

    values/col_idx: [nblocks, rmax, block_rows]; row_nnz: [nblocks,
    block_rows]; x [K] or [K,B].  Lane = matrix row, slots past a row's
    population are padding (masked by row_nnz).
    Returns [nblocks*block_rows] or [nblocks*block_rows, B].
    """
    nblocks, rmax, br = values.shape
    out_rows = nblocks * br
    live = (jnp.arange(rmax)[None, :, None]
            < row_nnz[:, None, :])                       # [nb, rmax, br]
    g = jnp.take(x, col_idx.astype(jnp.int32).reshape(-1), axis=0)
    g = g.reshape(nblocks, rmax, br, -1) if x.ndim == 2 \
        else g.reshape(nblocks, rmax, br)
    vals = jnp.where(live, values.astype(jnp.float32), 0.0)
    prod = (vals[..., None] * g) if x.ndim == 2 else vals * g
    out = prod.sum(axis=1)                               # slot-axis reduce
    return out.reshape(out_rows, -1) if x.ndim == 2 else out.reshape(out_rows)


# ------------------------------------------------------ flash attention
def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, window: Optional[int] = None,
                  softcap: Optional[float] = None,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """q [B,H,Tq,D], k/v [B,Hkv,Tk,D] (GQA broadcast) -> [B,H,Tq,D]."""
    b, h, tq, d = q.shape
    hkv = k.shape[1]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = (d ** -0.5) if scale is None else scale
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    tk = k.shape[2]
    qi = jnp.arange(tq)[:, None] + (tk - tq)   # align ends (decode-friendly)
    ki = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


# ------------------------------------------------------ linear scan (ssm)
def rwkv6_ref(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              w: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """RWKV6 (Finch) WKV recurrence, sequential oracle.

    r,k,w: [B,H,T,Dk], v: [B,H,T,Dv], u: [H,Dk] (bonus).
    S_t = diag(w_t)·S_{t-1} + k_t v_tᵀ ;  o_t = (S_{t-1} + diag(u)·k_t v_tᵀ)ᵀ r_t
    Returns o: [B,H,T,Dv].  (w already exp(-exp(...)) ∈ (0,1).)
    """
    b, h, t, dk = r.shape
    dv = v.shape[-1]

    def head(rh, kh, vh, wh, uh):
        def step(S, inp):
            rt, kt, vt, wt = inp
            kv = jnp.outer(kt, vt)
            out = ((S + uh[:, None] * kv).T @ rt)
            S = wt[:, None] * S + kv
            return S, out
        S0 = jnp.zeros((dk, dv), jnp.float32)
        _, out = jax.lax.scan(step, S0, (rh, kh, vh, wh))
        return out

    return jax.vmap(jax.vmap(head, in_axes=(0, 0, 0, 0, 0)),
                    in_axes=(0, 0, 0, 0, None))(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        w.astype(jnp.float32), u.astype(jnp.float32))


def mamba_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
              Bm: jnp.ndarray, Cm: jnp.ndarray) -> jnp.ndarray:
    """Selective-SSM (Mamba) oracle.

    x,dt: [B,T,D], A: [D,N] (negative), Bm,Cm: [B,T,N] -> y [B,T,D].
    h_t[d,n] = exp(dt_t[d] A[d,n]) h_{t-1}[d,n] + dt_t[d] x_t[d] B_t[n]
    y_t[d]   = Σ_n h_t[d,n] C_t[n]
    """
    def seq(xb, dtb, Bb, Cb):
        def step(h, inp):
            xt, dtt, Bt, Ct = inp
            decay = jnp.exp(dtt[:, None] * A)          # [D,N]
            h = decay * h + (dtt * xt)[:, None] * Bt[None, :]
            return h, h @ Ct
        h0 = jnp.zeros((A.shape[0], A.shape[1]), jnp.float32)
        _, y = jax.lax.scan(step, h0, (xb, dtb, Bb, Cb))
        return y

    return jax.vmap(seq)(x.astype(jnp.float32), dt.astype(jnp.float32),
                         Bm.astype(jnp.float32), Cm.astype(jnp.float32))
