"""repro.resil: deterministic fault injection, deadlines/retry, and
graceful degradation across the serving stack.

Covers: FaultPlan purity (same (seed, preset) -> identical decisions
regardless of call order or instance), the bounded-drop redelivery
guarantee, config validation/coercion, watchdog audits (clean pass and
manufactured-leak detection), request deadlines becoming structured
RequestFailed results everywhere a request can wait, load shedding,
wedged-role drain-and-recover with bounded retries, handoff-timeout
fallback to co-located prefill on the decode role, the degradation
ladder demoting new sessions' KV to int8, never-fitting requests
failing structurally under ``on_incomplete="warn"``, and "unserved"
terminal records at max_steps exhaustion.

The ``test_chaos_*`` sweep is the CI chaos gate (multidevice workflow):
every built-in fault preset x 3 seeds on the burst workload through the
disaggregated engine must complete every request token-identical to the
fault-free run, leak zero pages on both pools, and replay with
identical counters.
"""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro import kvstore as kvs
from repro import resil as rsl
from repro import sched as schd
from repro.api import Engine, Request
from repro.api.session import Session
from repro.configs import get, reduced
from repro.disagg import DisaggConfig, DisaggSession
from repro.models import model as M

CFG = reduced(get("llama3-8b"), n_layers=2, d_model=64, d_ff=128,
              vocab=256)
PS = 4
ML = 48


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def burst_arrivals(n=6, seed=0):
    wl = schd.WorkloadSpec.preset("burst", n_requests=n, vocab=CFG.vocab,
                                  seed=seed)
    return schd.generate(wl)


def replay(arrivals):
    return [(t, dataclasses.replace(r)) for t, r in arrivals]


def mk_disagg(params, resil, **kw):
    d = dict(prefill_slots=2, decode_slots=3)
    d.update(kw)
    return DisaggSession(CFG, params, disagg=DisaggConfig(**d),
                         max_len=ML, page_size=PS,
                         scheduler={"chunk": 4}, resil=resil)


@pytest.fixture(scope="module")
def clean_tokens(params):
    """Fault-free disagg tokens for the module's burst workload."""
    d = mk_disagg(params, None)
    return {r.rid: r.tokens
            for r in d.run_workload(replay(burst_arrivals()))}


def leaked(d: DisaggSession) -> int:
    return d.pre.alloc.in_use + d.dec.alloc.in_use


# ------------------------------------------------------------ FaultPlan
def test_fault_plan_parse_and_validation():
    p = rsl.FaultPlan.parse("drop-handoff:3")
    assert (p.preset, p.seed) == ("drop-handoff", 3)
    assert rsl.FaultPlan.parse("straggler").seed == 0
    with pytest.raises(ValueError, match="unknown fault preset"):
        rsl.FaultPlan.parse("gremlins:1")
    with pytest.raises(ValueError, match="PRESET:SEED"):
        rsl.FaultPlan.parse("straggler:x")


def test_fault_plan_decisions_are_pure():
    """Decisions are a pure function of (seed, preset, coordinates):
    two independently built plans agree on everything, call order is
    irrelevant, and a different seed disagrees somewhere."""
    a = rsl.FaultPlan.make("drop-handoff", seed=7)
    b = rsl.FaultPlan.make("drop-handoff", seed=7)
    coords = [(rid, att) for rid in range(20) for att in range(3)]
    # query b in reverse order — must not matter
    got_a = [a.drop_handoff(r, t) for r, t in coords]
    got_b = list(reversed([b.drop_handoff(r, t)
                           for r, t in reversed(coords)]))
    assert got_a == got_b
    assert [a.handoff_delay(r) for r in range(20)] == \
           [b.handoff_delay(r) for r in range(20)]
    c = rsl.FaultPlan.make("drop-handoff", seed=8)
    assert got_a != [c.drop_handoff(r, t) for r, t in coords]

    s1 = rsl.FaultPlan.make("straggler", seed=1)
    s2 = rsl.FaultPlan.make("straggler", seed=1)
    ticks = [(role, t) for role in ("prefill", "decode")
             for t in range(40)]
    assert [s1.step_fault(r, t) for r, t in ticks] == \
           [s2.step_fault(r, t) for r, t in ticks]


def test_drop_handoff_bounded_redelivery():
    """Delivery is guaranteed: past max_drops the plan must say no."""
    p = rsl.FaultPlan.make("drop-handoff", seed=0, drop_p=1.0)
    for rid in range(10):
        assert p.drop_handoff(rid, 0)
        assert not p.drop_handoff(rid, p.params["max_drops"])


def test_page_holdback_only_inside_window():
    p = rsl.FaultPlan.make("page-spike", seed=0, start=5, span=3,
                           jitter=0, frac=0.5)
    assert p.page_holdback(20, 4, role="decode") == 0
    assert p.page_holdback(20, 5, role="decode") == 10
    assert p.page_holdback(20, 8, role="decode") == 0
    assert p.page_holdback(20, 5, role="prefill") == 0
    assert p.page_holdback(20, 5, role="engine") == 10   # co-located


def test_resil_config_validation_and_coercion():
    with pytest.raises(ValueError, match="deadline_ticks"):
        rsl.ResilConfig(deadline_ticks=0)
    with pytest.raises(ValueError, match="max_retries"):
        rsl.ResilConfig(max_retries=-1)
    with pytest.raises(ValueError, match="wedge_ticks"):
        rsl.ResilConfig(wedge_ticks=0)
    with pytest.raises(ValueError, match="shed_watermark"):
        rsl.ResilConfig(shed_watermark=0.0)
    assert rsl.ResilConfig.coerce("role-stall:2").fault_plan.seed == 2
    assert rsl.ResilConfig.coerce(True).fault_plan is None
    cfg = rsl.ResilConfig.coerce(
        {"fault_plan": {"preset": "page-spike", "seed": 1,
                        "params": {"frac": 0.9}}})
    assert cfg.fault_plan.params["frac"] == 0.9
    assert rsl.ResilConfig.coerce(cfg) is cfg


# --------------------------------------------------------------- health
def test_watchdog_audit_passes_and_catches_leak(params):
    sess = Session(CFG, params, batch_slots=2, max_len=ML, page_size=PS)
    sess.submit(Request(prompt=[2, 3, 4, 5, 6], max_new=3, rid=0))
    sess.run()
    assert rsl.audit_allocator(sess.alloc) == []
    assert rsl.audit_session(sess) == []   # drained: clean
    pid = sess.alloc.alloc()           # manufactured leak: no slot ref
    issues = rsl.audit_session(sess)
    assert issues and "refcount" in issues[0]
    with pytest.raises(rsl.HealthError, match="watchdog audit failed"):
        rsl.Watchdog(1).audit(sess)
    sess.alloc.free([pid])
    assert rsl.audit_session(sess) == []


def test_watchdog_audits_during_run(params):
    arrivals = burst_arrivals()
    d = mk_disagg(params, {"watchdog_every": 2})
    toks = {r.rid: r.tokens for r in d.run_workload(replay(arrivals))}
    base = mk_disagg(params, None)
    ref = {r.rid: r.tokens for r in base.run_workload(replay(arrivals))}
    assert toks == ref                 # auditing changes nothing
    assert d.resil.stats["watchdog_audits"] > 0
    assert leaked(d) == 0


# ------------------------------------------------- deadlines / shedding
def test_deadline_expiry_structured_failures(params):
    d = mk_disagg(params, {"deadline_ticks": 5})
    res = d.run_workload(replay(burst_arrivals()), on_incomplete="warn")
    assert len(res) + len(d.failed) == 6
    assert d.failed and all(f.reason == "deadline" for f in d.failed)
    assert d.resil.stats["deadline_miss"] == len(d.failed)
    assert leaked(d) == 0
    fr = [r for r in d.records if r["state"] == "failed"]
    assert {r["failed_reason"] for r in fr} == {"deadline"}
    m = schd.summarize(d.records, 1.0, 1, resil=d.resil_summary())
    assert m["outcomes"]["failed_by_reason"]["deadline"] == len(d.failed)
    assert m["resil"]["deadline_miss"] == len(d.failed)


def test_per_request_deadline_overrides_config(params):
    sess = Session(CFG, params, batch_slots=1, max_len=ML, page_size=PS,
                   resil={"deadline_ticks": 500})
    sess.submit(Request(prompt=[2] * 8, max_new=8, rid=0,
                        deadline_ticks=1))
    sess.submit(Request(prompt=[3] * 4, max_new=2, rid=1))
    res = sess.run(on_incomplete="warn")
    assert [f.rid for f in sess.failed] == [0]
    assert sess.failed[0].reason == "deadline"
    assert [r.rid for r in res] == [1]
    assert sess.alloc.in_use == 0


def test_shed_load_youngest_never_admitted(params, clean_tokens):
    d = mk_disagg(params, {"shed_watermark": 0.25})
    res = d.run_workload(replay(burst_arrivals()), on_incomplete="warn")
    assert d.resil.stats["shed"] > 0
    assert all(f.reason == "shed" and not f.tokens for f in d.failed)
    # survivors are token-identical: shedding rejects, never corrupts
    assert all(clean_tokens[r.rid] == r.tokens for r in res)
    assert leaked(d) == 0


# ------------------------------------------------------ chaos (CI gate)
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("preset", ["drop-handoff", "role-stall",
                                    "page-spike", "straggler"])
def test_chaos_preset_parity_and_replay(params, clean_tokens, preset,
                                        seed):
    """The hard resilience contract, per (preset, seed): every request
    completes, completed streams are token-identical to the fault-free
    run, zero pages leak on either pool, and a same-seed replay produces
    identical counters and tokens."""
    runs = []
    for _ in range(2):
        d = mk_disagg(params, {"fault_plan": f"{preset}:{seed}",
                               "max_retries": 2, "watchdog_every": 4})
        res = d.run_workload(replay(burst_arrivals()),
                             on_incomplete="warn")
        s = d.resil_summary()
        runs.append(({r.rid: r.tokens for r in res}, leaked(d),
                     {k: s[k] for k in rsl.ResilState.COUNTERS},
                     s.get("faults", {})))
        assert not d.failed
    toks, leak, counters, faults = runs[0]
    assert toks == clean_tokens, f"{preset}:{seed} diverged"
    assert leak == 0
    assert runs[0] == runs[1], f"{preset}:{seed} replay diverged"


# ---------------------------------------------- recovery / degradation
def test_handoff_timeout_falls_back_to_decode_role(params, clean_tokens):
    d = mk_disagg(params, {"fault_plan": "drop-handoff:0",
                           "handoff_timeout": 2, "max_retries": 2})
    res = d.run_workload(replay(burst_arrivals()), on_incomplete="warn")
    assert d.resil.stats["handoff_fallbacks"] > 0
    assert {r.rid: r.tokens for r in res} == clean_tokens
    assert any(r.get("degraded") == "colocated-prefill"
               for r in d.records)
    assert d.dec.stats["preemptions"] == 0   # reservation discipline held
    assert leaked(d) == 0


def test_wedged_role_drain_and_recover(params, clean_tokens):
    """A prefill role stalled far past wedge_ticks gets drained: its
    slots requeue through the retry path and either complete with
    oracle tokens or fail structurally once retries exhaust."""
    plan = {"preset": "role-stall", "seed": 0,
            "params": {"role": "prefill", "start": 2, "span": 12,
                       "jitter": 0}}
    d = mk_disagg(params, {"fault_plan": plan, "max_retries": 3,
                           "watchdog_every": 2, "wedge_ticks": 3})
    res = d.run_workload(replay(burst_arrivals()), on_incomplete="warn")
    r = d.resil.stats
    assert r["watchdog_recoveries"] > 0 and r["retries"] > 0
    assert all(clean_tokens[x.rid] == x.tokens for x in res)
    assert all(f.reason == "retries_exhausted" for f in d.failed)
    assert len(res) + len(d.failed) == 6
    assert leaked(d) == 0


def test_degrade_ladder_demotes_next_session_kv(params):
    plan = {"preset": "page-spike", "seed": 0,
            "params": {"frac": 0.8, "span": 500, "start": 2,
                       "jitter": 0}}
    d = mk_disagg(params, {"fault_plan": plan, "degrade_kv": True,
                           "degrade_sustain_ticks": 3})
    d.run_workload(replay(burst_arrivals()), on_incomplete="warn",
                   max_steps=400)
    assert d.resil.degrade.level == 2
    assert d.resil.next_kv_dtype("bf16") == "int8"
    assert leaked(d) == 0
    # next-session boundary: Engine.session consults the live state
    eng = Engine(CFG, params=params)
    s2 = eng.session(max_len=ML, kv_cache="paged", page_size=PS,
                     resil=d.resil)
    assert s2.kv_dtype == "int8"
    s2.submit(Request(prompt=[2, 3, 4], max_new=2, rid=0))
    s2.run()
    assert d.resil.stats["degraded_admissions"] > 0


# -------------------------------------------- structured terminal states
def test_oversized_request_warns_and_fails_structurally(params):
    """Satellite: a handoff that can NEVER fit the decode pool names the
    request, its page need, and the pool size — and with
    ``on_incomplete="warn"`` becomes a RequestFailed, not a hang."""
    d = DisaggSession(CFG, params,
                      disagg=DisaggConfig(decode_pool_pages=4),
                      max_len=ML, page_size=PS, scheduler={"chunk": 4},
                      resil=True)
    d.submit(Request(prompt=list(range(1, 21)), max_new=8, rid=7))
    with pytest.warns(RuntimeWarning, match=r"request 7 needs \d+ pages"):
        res = d.run(on_incomplete="warn")
    assert res == []
    assert [f.rid for f in d.failed] == [7]
    assert d.failed[0].reason == "oversized"
    assert leaked(d) == 0
    # without the resil layer the same situation still raises loudly
    d2 = DisaggSession(CFG, params,
                       disagg=DisaggConfig(decode_pool_pages=4),
                       max_len=ML, page_size=PS, scheduler={"chunk": 4})
    d2.submit(Request(prompt=list(range(1, 21)), max_new=8, rid=0))
    with pytest.raises(kvs.OutOfPages, match="decode page pool"):
        d2.run()


def test_unserved_records_at_max_steps(params):
    """Satellite: requests still queued/pending when max_steps runs out
    get a terminal "unserved" state instead of vanishing."""
    arrivals = [(0, Request(prompt=[2] * 8, max_new=6, rid=0)),
                (1, Request(prompt=[3] * 8, max_new=6, rid=1)),
                (500, Request(prompt=[4] * 4, max_new=2, rid=2))]
    sess = Session(CFG, params, batch_slots=1, max_len=ML, page_size=PS,
                   scheduler={"chunk": 4}, resil=True)
    with pytest.warns(RuntimeWarning, match="max_steps"):
        sess.run_workload(arrivals, max_steps=3, on_incomplete="warn")
    by_rid = {r["rid"]: r for r in sess.records}
    assert len(by_rid) == 3
    assert by_rid[2]["state"] == "unserved"      # never arrived
    assert by_rid[2]["n_generated"] == 0
    states = {r["state"] for r in sess.records}
    assert states <= {"completed", "unserved"} and "unserved" in states
    m = schd.summarize(sess.records, 1.0, 3)
    assert m["outcomes"]["unserved"] >= 2

    d = mk_disagg(params, True)
    with pytest.warns(RuntimeWarning, match="max_steps"):
        d.run_workload([(0, Request(prompt=[2] * 8, max_new=6, rid=0)),
                        (900, Request(prompt=[3] * 4, max_new=2, rid=1))],
                       max_steps=2, on_incomplete="warn")
    st = {r["rid"]: r["state"] for r in d.records}
    assert st[0] == "unserved" and st[1] == "unserved"


def test_resil_none_is_exact_noop(params):
    """resil=None must be byte-identical to the pre-resil path: no
    record fields change meaning, no counters appear."""
    arrivals = burst_arrivals()
    a = Session(CFG, params, batch_slots=2, max_len=ML, page_size=PS,
                scheduler={"chunk": 4})
    b = Session(CFG, params, batch_slots=2, max_len=ML, page_size=PS,
                scheduler={"chunk": 4}, resil=None)
    ra = a.run_workload(replay(arrivals))
    rb = b.run_workload(replay(arrivals))
    assert [r.tokens for r in ra] == [r.tokens for r in rb]
    assert a.resil is None and a.resil_summary() is None
    assert all(r["state"] == "completed" for r in a.records)


# ----------------------------------------------------------- CLI / bench
def test_serve_cli_accepts_resil_flags():
    import subprocess
    import sys
    import os
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "llama3-8b", "--requests", "3", "--max-new", "4",
         "--fault-plan", "straggler:1", "--deadline-ticks", "64",
         "--max-retries", "1"],
        env=dict(os.environ, PYTHONPATH=src), capture_output=True,
        text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "resil:" in out.stdout
    bad = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "llama3-8b", "--fault-plan", "nope:1"],
        env=dict(os.environ, PYTHONPATH=src), capture_output=True,
        text=True, timeout=600)
    assert bad.returncode != 0
    assert "unknown fault preset" in bad.stderr
