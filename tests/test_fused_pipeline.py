"""Fused multi-block decode pipeline: kernels vs dense references.

Covers the rearchitected compressed hot path end to end:
  * fused ACSR / AIDA kernel vs ``dense_equivalent`` across shapes, batch
    widths, densities and (mb, bk) tilings — including rows that are not a
    multiple of the 128-lane block and K-tiles smaller than K
  * the Pallas int8 kernel vs the XLA reference, odd shapes included
  * lut_matmul shape padding (no more divisibility asserts)
  * bias + activation epilogue fusion on every mode
  * the per-layer autotuner: cache behavior, snapshot, ops dispatch

Property-based sweeps additionally run when `hypothesis` is installed.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparse_fc as sfc
from repro.core.quant import int8_matmul_ref, quantize_int
from repro.kernels import ops, ref, tune
from repro.kernels.acsr_spmv import (BlockedACSR, acsr_spmv, block_encode,
                                     block_encode_coded)
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.lut_matmul import lut_matmul


def sparse(rng, n, k, density):
    return (rng.normal(size=(n, k)) * (rng.random((n, k)) < density)
            ).astype(np.float32)


# -------------------------------------------------- fused ACSR pipeline
@pytest.mark.parametrize("n,k,density,bsz,mb,bk", [
    (300, 512, 0.10, 0, 1, 512),     # matvec, 3 blocks (300 = 2*128+44)
    (300, 512, 0.10, 4, 2, 128),     # K-tiled, fused pairs of blocks
    (257, 128, 0.05, 2, 4, 128),     # mb > nblocks clamps
    (128, 256, 0.50, 3, 1, 96),      # bk not a divisor of K
    (64, 48, 0.30, 2, 1, 48),        # sub-block matrix
    (1, 1, 1.00, 0, 8, 512),         # degenerate
])
def test_fused_acsr_matches_dense(rng, n, k, density, bsz, mb, bk):
    w = sparse(rng, n, k, density)
    x = rng.normal(size=(k,) if bsz == 0 else (k, bsz)).astype(np.float32)
    b = block_encode(w, block_rows=128)
    out = np.asarray(acsr_spmv(b, jnp.asarray(x), mb=mb, bk=min(bk, k),
                               interpret=True))
    np.testing.assert_allclose(out, w @ x, rtol=2e-4, atol=2e-4)


def test_fused_acsr_matches_blocked_ref(rng):
    """The Pallas kernel agrees with the slot-schedule jnp oracle."""
    w = sparse(rng, 200, 160, 0.2)
    x = jnp.asarray(rng.normal(size=(160, 3)).astype(np.float32))
    b = block_encode(w, block_rows=128)
    got = np.asarray(acsr_spmv(b, x, interpret=True))
    want = np.asarray(ref.blocked_acsr_spmv_ref(
        b.values, b.col_idx, b.row_nnz, x, b.block_rows))[:200]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fused_acsr_coded_nonzero_centroid0(rng):
    """Padding slots are masked by row_nnz, so correctness cannot depend
    on the codebook containing an exact zero."""
    w = sparse(rng, 140, 96, 0.15)
    nz = w[w != 0]
    cents = np.quantile(nz, np.linspace(0.02, 0.98, 16)).astype(np.float32)
    assert not (cents == 0).any()
    b = block_encode_coded(w, cents, block_rows=128)
    x = rng.normal(size=(96, 2)).astype(np.float32)
    wq = cents[np.abs(w[..., None] - cents).argmin(-1)] * (w != 0)
    out = np.asarray(acsr_spmv(b, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(out, wq @ x, rtol=2e-4, atol=2e-4)


def test_fused_epilogue_bias_activation(rng):
    w = sparse(rng, 130, 64, 0.3)
    x = rng.normal(size=(64, 2)).astype(np.float32)
    bias = rng.normal(size=(130,)).astype(np.float32)
    b = block_encode(w, block_rows=128)
    for act, f in [("relu", lambda y: np.maximum(y, 0.0)),
                   ("silu", lambda y: y / (1 + np.exp(-y))),
                   ("gelu", None), (None, lambda y: y)]:
        out = np.asarray(acsr_spmv(b, jnp.asarray(x),
                                   bias=jnp.asarray(bias), activation=act,
                                   bk=32, interpret=True))
        want = w @ x + bias[:, None]
        if act == "gelu":
            want = np.asarray(jax.nn.gelu(want, approximate=True))
        else:
            want = f(want)
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)


def test_block_encode_vectorized_layout(rng):
    """Slot schedule invariants: lane = row % block_rows, slots dense from
    0, row_nnz = true per-row population."""
    w = sparse(rng, 70, 40, 0.25)
    b = block_encode(w, block_rows=32)
    assert b.nblocks == 3 and b.values.shape[2] == 32
    counts = (w != 0).sum(axis=1)
    got = np.asarray(b.row_nnz).reshape(-1)[:70]
    np.testing.assert_array_equal(got, counts)
    assert np.asarray(b.row_nnz).reshape(-1)[70:].sum() == 0
    # decode via dense_equivalent round-trips exactly
    layer = sfc.CompressedFC("acsr", (70, 40), blocked=b)
    np.testing.assert_array_equal(sfc.dense_equivalent(layer), w)


def test_block_encode_imbalanced_rows(rng):
    """A single dense row sets rmax but stays correct (EIE pathology)."""
    w = sparse(rng, 90, 64, 0.05)
    w[17] = rng.normal(size=64).astype(np.float32)  # fully dense row
    b = block_encode(w, block_rows=128)
    assert b.rmax >= 64
    x = rng.normal(size=(64,)).astype(np.float32)
    out = np.asarray(acsr_spmv(b, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(out, w @ x, rtol=2e-4, atol=2e-4)


# --------------------------------------------------------- int8 kernel
@pytest.mark.parametrize("b,n,k", [(8, 128, 256), (3, 130, 100),
                                   (1, 64, 512), (5, 257, 33)])
def test_int8_kernel_matches_ref(rng, b, n, k):
    w = rng.normal(size=(n, k)).astype(np.float32)
    qt = quantize_int(jnp.asarray(w), bits=8, axis=0)
    x = jnp.asarray(rng.normal(size=(b, k)).astype(np.float32))
    out = int8_matmul(x, qt.q, qt.scale, bm=8, bn=128, bk=64,
                      interpret=True)
    want = int8_matmul_ref(x, qt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_int8_kernel_fused_epilogue(rng):
    w = rng.normal(size=(96, 64)).astype(np.float32)
    qt = quantize_int(jnp.asarray(w), bits=8, axis=0)
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    bias = jnp.asarray(rng.normal(size=(96,)).astype(np.float32))
    out = int8_matmul(x, qt.q, qt.scale, bias=bias, activation="relu",
                      interpret=True)
    want = np.maximum(np.asarray(int8_matmul_ref(x, qt))
                      + np.asarray(bias)[None, :], 0.0)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


# ------------------------------------------------------ lut padding
@pytest.mark.parametrize("b,n,k", [(3, 100, 130), (1, 128, 256),
                                   (9, 65, 514)])
def test_lut_matmul_odd_shapes(rng, b, n, k):
    k += k % 2  # packed codes need even K
    cents = jnp.asarray(np.sort(rng.normal(size=16)).astype(np.float32))
    codes = rng.integers(0, 16, size=(n, k)).astype(np.uint8)
    packed = jnp.asarray(codes[:, 0::2] | (codes[:, 1::2] << 4))
    x = jnp.asarray(rng.normal(size=(b, k)).astype(np.float32))
    out = lut_matmul(x, packed, cents, bm=8, bn=128, bk=256,
                     interpret=True)
    want = ref.lut_matmul_ref(x, packed, cents)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-3)


# ------------------------------------------------------------ autotuner
def test_tuner_cache_and_dispatch(rng):
    tune.clear()
    w = sparse(rng, 96, 80, 0.2)
    layer = sfc.compress(w, mode="acsr", density=0.2)
    c1 = tune.tune_layer(layer, batch=2, interpret=True)
    assert c1.impl == "pallas" and np.isfinite(c1.us)
    assert c1.tile("mb") is not None and c1.tile("bk") is not None
    # second call is a cache hit (same object, no re-timing)
    assert tune.tune_layer(layer, batch=2, interpret=True) is c1
    # snapshot is JSON-able and keyed by geometry
    snap = tune.snapshot()
    import json
    json.dumps(snap)
    assert any(key.startswith("acsr/") for key in snap)
    # ops dispatch picks the tuned tiles up and still matches dense
    x = jnp.asarray(rng.normal(size=(80, 2)).astype(np.float32))
    got = np.asarray(ops.acsr_spmv(layer.blocked, x, interpret=True))
    np.testing.assert_allclose(
        got, sfc.dense_equivalent(layer) @ np.asarray(x),
        rtol=2e-4, atol=2e-4)
    tune.clear()
    assert tune.snapshot() == {}


def test_tuner_stacked_params(rng):
    """tune_params finds stacked CompressedFC leaves inside model params."""
    tune.clear()
    per = [sfc.compress(sparse(rng, 64, 48, 0.3), mode="aida", density=0.3)
           for _ in range(2)]
    from repro.api.compress import _stack_compressed
    stacked = _stack_compressed(per)
    n_new = tune.tune_params({"layers": {"blk": {"wq": stacked}}},
                             batch=2, interpret=True)
    assert n_new == 1
    assert any(key.startswith("aida/") for key in tune.snapshot())
    tune.clear()


# ---------------------------------------------- mode x dense_equivalent
@pytest.mark.parametrize("mode", ["int8", "codebook4", "acsr", "aida"])
def test_apply_fc_fused_epilogue_all_modes(rng, mode):
    n, k = (128, 256) if mode == "codebook4" else (130, 100)
    w = rng.normal(size=(n, k)).astype(np.float32)
    layer = sfc.compress(w, mode=mode, density=0.2)
    x = jnp.asarray(rng.normal(size=(3, k)).astype(np.float32))
    bias = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    got = np.asarray(sfc.apply_fc(layer, x, bias=bias, activation="silu"))
    pre = np.asarray(x) @ sfc.dense_equivalent(layer).T \
        + np.asarray(bias)[None, :]
    want = pre / (1 + np.exp(-pre))
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


# ------------------------------------------------- bf16 values variant
def test_bf16_acsr_values_variant(rng):
    """CompressionSpec(dtype='bf16'): bf16-stored nonzeros keep the fused
    kernel within bf16 tolerance of the ORIGINAL pruned weights and beat
    the f32 variant on bytes (the ROADMAP 'win on bytes' item)."""
    w = sparse(rng, 300, 256, 0.25)
    f32 = sfc.compress(w, mode="acsr", density=1.0)     # keep all nnz
    b16 = sfc.compress(w, mode="acsr", density=1.0, dtype="bf16")
    assert b16.blocked.values.dtype == jnp.bfloat16
    x = rng.normal(size=(256, 3)).astype(np.float32)
    y16 = np.asarray(sfc.apply_fc(b16, jnp.asarray(x).T)).T
    # matches its own dense_equivalent tightly ...
    np.testing.assert_allclose(y16, sfc.dense_equivalent(b16) @ x,
                               rtol=2e-4, atol=2e-4)
    # ... and the f32 kernel within accumulated bf16 weight rounding
    # (~0.4% per nonzero, K=256 random-sign accumulation)
    y32 = np.asarray(sfc.apply_fc(f32, jnp.asarray(x).T)).T
    np.testing.assert_allclose(y16, y32, rtol=2e-2, atol=1e-1)

    def nbytes(c):
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(c))
    assert nbytes(b16) < nbytes(f32)


def test_bf16_acsr_through_engine(rng):
    """Engine-level: dtype='bf16' halves acsr value bytes (ratio now
    beats the bf16-serving baseline at 25% density) and still serves."""
    from repro.api import CompressionSpec, Engine, Request
    from repro.configs import get, reduced
    cfg = reduced(get("llama3-8b"), n_layers=2, d_model=64, d_ff=128,
                  vocab=256)
    eng = Engine(cfg)
    e32 = Engine(cfg, params=eng.params).compress(
        CompressionSpec(mode="acsr", density=0.25, block_rows=64),
        verbose=None)
    e16 = Engine(cfg, params=eng.params).compress(
        CompressionSpec(mode="acsr", density=0.25, dtype="bf16",
                        block_rows=64), verbose=None)
    assert e16.stats["ratio"] > e32.stats["ratio"]
    assert e16.stats["ratio"] > 1.0      # finally beats the bf16 baseline
    res = e16.serve([Request(prompt=[1, 2, 3], max_new=6, rid=0)],
                    batch_slots=1, max_len=16)
    assert len(res[0].tokens) == 6


def test_compression_spec_rejects_bad_dtype():
    from repro.api import CompressionSpec
    with pytest.raises(ValueError):
        CompressionSpec(dtype="fp4")


# ----------------------------------------------------- property sweeps
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYP = False

if HAVE_HYP:
    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(1, 200), k=st.integers(1, 160),
           density=st.floats(0.0, 1.0), bsz=st.integers(0, 3),
           mb=st.sampled_from([1, 2, 4]),
           seed=st.integers(0, 99))
    def test_prop_fused_acsr(n, k, density, bsz, mb, seed):
        rng = np.random.default_rng(seed)
        w = sparse(rng, n, k, density)
        x = rng.normal(size=(k,) if bsz == 0 else (k, bsz)
                       ).astype(np.float32)
        layer = sfc.CompressedFC("acsr", (n, k),
                                 blocked=block_encode(w, block_rows=64))
        out = np.asarray(acsr_spmv(layer.blocked, jnp.asarray(x), mb=mb,
                                   bk=min(64, k), interpret=True))
        np.testing.assert_allclose(out, sfc.dense_equivalent(layer) @ x,
                                   rtol=2e-4, atol=2e-4)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(2, 120), k=st.integers(2, 120),
           density=st.floats(0.05, 0.8), seed=st.integers(0, 99))
    def test_prop_fused_aida(n, k, density, seed):
        rng = np.random.default_rng(seed)
        w = sparse(rng, n, k, density)
        if not (w != 0).any():
            w[0, 0] = 1.0
        layer = sfc.compress(w, mode="aida", density=min(0.9, density),
                             kmeans_iters=4)
        x = rng.normal(size=(k, 2)).astype(np.float32)
        out = np.asarray(sfc.apply_fc(layer, jnp.asarray(x).T)).T
        np.testing.assert_allclose(out, sfc.dense_equivalent(layer) @ x,
                                   rtol=3e-4, atol=3e-4)

    @settings(max_examples=10, deadline=None)
    @given(b=st.integers(1, 9), n=st.integers(1, 140),
           k=st.integers(1, 140), seed=st.integers(0, 99))
    def test_prop_int8_kernel(b, n, k, seed):
        rng = np.random.default_rng(seed)
        w = rng.normal(size=(n, k)).astype(np.float32)
        qt = quantize_int(jnp.asarray(w), bits=8, axis=0)
        x = jnp.asarray(rng.normal(size=(b, k)).astype(np.float32))
        out = int8_matmul(x, qt.q, qt.scale, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(int8_matmul_ref(x, qt)),
                                   rtol=2e-4, atol=2e-4)
