"""Shard-local compressed FC: the paper's multi-IC partitioning, executed.

`apply_fc_sharded` runs one compressed projection tensor-parallel over
the plan's model axis via `shard_map`: every shard holds a band of the
compressed matrix (a contiguous run of ACSR row blocks, or of
int8/codebook output channels) and runs the *existing* kernel —
Pallas fused SpMV, int8, LUT — on its local band only.  Combine policy:

* ``"gather"`` (default, every mode): row partitioning.  Each output
  element is produced entirely on one shard (identical arithmetic to
  the single-device kernel, so results are bit-identical), and the
  shard outputs concatenate along the feature axis — the all-gather is
  materialized lazily by GSPMD only where a consumer needs the full
  vector.
* ``"psum"`` (int8 only): input partitioning.  Shards hold a band of
  *columns*, contract against their slice of the activation, and
  all-reduce partial sums; the per-channel dequant scale + bias/act
  epilogue runs once on the reduced result.  ACSR modes cannot split
  columns (col_idx addresses the full input vector), which is why
  gather is the default policy everywhere.

Leaves whose partition axis does not divide the tp degree fall back to
the plain (replicated) apply — `partition.pad_params_for_plan` exists
so that fallback never triggers for plan-prepared params.

`paged_attention_sharded` / `paged_attention_chunk_sharded` do the same
for the paged-attention kernels: the head-sharded KV pool (plan
state_specs put Hkv over the model axis) runs the *existing* decode or
chunk kernel shard-local — Pallas scalar-prefetch included — instead of
forcing the XLA gather fallback.  Heads are fully independent in paged
attention (GQA groups ride with their kv head), so with the (impl, pb,
qt) choice resolved from the tune cache at the *global* geometry before
entering shard_map, the mesh output is bit-identical to single-device.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import sparse_fc as sfc
from repro.kernels import ops
from repro.shard import partition


def _local_layer(leaf: sfc.CompressedFC) -> sfc.CompressedFC:
    """Rebuild a CompressedFC whose static ``shape`` matches the local
    array shards shard_map handed us (the pytree aux still carries the
    global shape)."""
    n_in = leaf.shape[1]
    if leaf.mode in ("acsr", "aida"):
        b = leaf.blocked
        rows = b.values.shape[0] * b.block_rows
        blocked = dataclasses.replace(b, shape=(rows, n_in))
        return dataclasses.replace(leaf, blocked=blocked,
                                   shape=(rows, n_in))
    rows = partition.row_axis_len(leaf)
    return dataclasses.replace(leaf, shape=(rows, n_in))


def _row_specs(leaf: sfc.CompressedFC, tp_axis: str) -> sfc.CompressedFC:
    """shard_map in_specs for a single-layer leaf, row-partitioned."""
    from repro.core import quant as q
    from repro.kernels import acsr_spmv as sp
    if leaf.mode in ("acsr", "aida"):
        b = leaf.blocked
        blocked = sp.BlockedACSR(
            values=P(tp_axis, None, None), col_idx=P(tp_axis, None, None),
            row_nnz=P(tp_axis, None), shape=b.shape,
            block_rows=b.block_rows, nnz=b.nnz,
            centroids=None if b.centroids is None else P())
        return sfc.CompressedFC(leaf.mode, leaf.shape, blocked=blocked)
    if leaf.mode == "int8":
        qt = q.QTensor(q=P(tp_axis, None), scale=P(tp_axis, None),
                       bits=leaf.qt.bits)
        return sfc.CompressedFC(leaf.mode, leaf.shape, qt=qt)
    if leaf.mode == "codebook4":
        return sfc.CompressedFC(leaf.mode, leaf.shape,
                                codes_packed=P(tp_axis, None),
                                centroids=P())
    return sfc.CompressedFC(leaf.mode, leaf.shape, dense=P(tp_axis, None))


def _padded_rows(leaf: sfc.CompressedFC) -> int:
    if leaf.mode in ("acsr", "aida"):
        return leaf.blocked.values.shape[-3] * leaf.blocked.block_rows
    return partition.row_axis_len(leaf)


def apply_fc_sharded(plan, layer: sfc.CompressedFC, x: jnp.ndarray,
                     bias: Optional[jnp.ndarray] = None,
                     activation: Optional[str] = None) -> jnp.ndarray:
    """y = act(x @ W.T + bias) for a single-layer compressed leaf,
    computed shard-locally over ``plan``'s model axis.  x: [B, n_in]."""
    tp, ax = plan.tp, plan.tp_axis
    n_out = layer.shape[0]
    if tp == 1 or not partition.shardable(layer, tp):
        return sfc.apply_fc(layer, x, bias=bias, activation=activation)
    policy = plan.policy_for(layer.mode)

    if policy == "psum" and layer.mode == "int8" \
            and layer.shape[1] % tp == 0:
        def local_psum(q_band, x_band):
            acc = jnp.matmul(x_band, q_band.astype(jnp.float32).T,
                             preferred_element_type=jnp.float32)
            return jax.lax.psum(acc, ax)

        acc = shard_map(local_psum, mesh=plan.mesh,
                        in_specs=(P(None, ax), P(None, ax)),
                        out_specs=P(None, None),
                        check_rep=False)(layer.qt.q, x)
        # slice padded rows off BEFORE the epilogue: bias carries the
        # true n_out, the padded q/scale rows are inert
        y = acc[:, :n_out] * layer.qt.scale.reshape(1, -1)[:, :n_out]
        return ops.bias_act_epilogue(y, bias, activation)

    # ------------------------------------------------ gather (default)
    rows_pad = _padded_rows(layer)
    bias_p = None
    if bias is not None:
        bias_p = jnp.pad(bias.astype(jnp.float32),
                         (0, rows_pad - bias.shape[0]))

    if bias_p is None:
        def local(lay, xx):
            return sfc.apply_fc(_local_layer(lay), xx,
                                activation=activation)
        y = shard_map(local, mesh=plan.mesh,
                      in_specs=(_row_specs(layer, ax), P(None, None)),
                      out_specs=P(None, ax), check_rep=False)(layer, x)
    else:
        def local(lay, xx, bb):
            return sfc.apply_fc(_local_layer(lay), xx, bias=bb,
                                activation=activation)
        y = shard_map(local, mesh=plan.mesh,
                      in_specs=(_row_specs(layer, ax), P(None, None),
                                P(ax)),
                      out_specs=P(None, ax),
                      check_rep=False)(layer, x, bias_p)
    return y[:, :n_out]


# ------------------------------------------------- paged attention (kv)
def _pool_specs(pool, ax: str):
    """PagedKV-shaped shard_map spec tree: pages + scales over heads."""
    from repro.kvstore.pool import PagedKV
    return PagedKV(
        k_pages=P(None, ax, None, None), v_pages=P(None, ax, None, None),
        k_scale=None if pool.k_scale is None else P(None, ax),
        v_scale=None if pool.v_scale is None else P(None, ax))


def _paged_shardable(plan, hkv: int) -> bool:
    # h % tp == 0 follows from hkv % tp == 0 (GQA groups are contiguous
    # per kv head in the [Hkv, G] head layout every kernel uses)
    return plan is not None and plan.tp > 1 and hkv % plan.tp == 0


def paged_attention_sharded(plan, q: jnp.ndarray, pool, table: jnp.ndarray,
                            cur_pos: jnp.ndarray, window, *,
                            scale: Optional[float] = None,
                            cap: Optional[float] = None,
                            interpret: Optional[bool] = None) -> jnp.ndarray:
    """Decode paged attention (q [B, H, Dh]) with the KV pool head-sharded
    over ``plan``'s model axis: each shard runs the tuned kernel on its
    own Hkv/tp heads and local page arrays; outputs concatenate along the
    head axis (gather combine — every head computed entirely on one
    shard, bit-identical to single-device).  Falls back to the plain
    dispatcher when no plan is active or heads do not divide."""
    from repro import kvstore as kv
    b, h, dh = q.shape
    hkv = pool.k_pages.shape[1]
    if not _paged_shardable(plan, hkv):
        return kv.paged_attention(q, pool, table, cur_pos, window,
                                  scale=scale, cap=cap, interpret=interpret)
    # resolve with the GLOBAL geometry so every shard (and the
    # single-device reference) executes the identical kernel
    impl, pb, interp = kv.resolve_paged(b, h, dh, pool, table.shape[1],
                                        interpret)
    ax = plan.tp_axis

    def local(qq, pp, tbl, pos, win):
        return kv.paged_attention(qq, pp, tbl, pos, win, scale=scale,
                                  cap=cap, impl=impl, pb=pb,
                                  interpret=interp)

    return shard_map(
        local, mesh=plan.mesh,
        in_specs=(P(None, ax, None), _pool_specs(pool, ax),
                  P(None, None), P(None), P()),
        out_specs=P(None, ax, None), check_rep=False)(
            q, pool, table, cur_pos, jnp.asarray(window, jnp.int32))


def paged_attention_chunk_sharded(plan, q: jnp.ndarray, pool,
                                  table: jnp.ndarray, q_pos: jnp.ndarray,
                                  window, *,
                                  scale: Optional[float] = None,
                                  cap: Optional[float] = None,
                                  interpret: Optional[bool] = None
                                  ) -> jnp.ndarray:
    """Chunked-prefill paged attention (q [B, H, C, Dh] at positions
    ``q_pos`` [B, C]) run shard-local over the plan's model axis — the
    prefill-side twin of :func:`paged_attention_sharded`."""
    from repro import kvstore as kv
    b, h, c, dh = q.shape
    hkv = pool.k_pages.shape[1]
    if not _paged_shardable(plan, hkv):
        return kv.paged_attention_chunk(q, pool, table, q_pos, window,
                                        scale=scale, cap=cap,
                                        interpret=interpret)
    impl, pb, qt, interp = kv.resolve_paged_chunk(b, h, c, dh, pool,
                                                  table.shape[1], interpret)
    ax = plan.tp_axis

    def local(qq, pp, tbl, pos, win):
        return kv.paged_attention_chunk(qq, pp, tbl, pos, win, scale=scale,
                                        cap=cap, impl=impl, pb=pb, qt=qt,
                                        interpret=interp)

    return shard_map(
        local, mesh=plan.mesh,
        in_specs=(P(None, ax, None, None), _pool_specs(pool, ax),
                  P(None, None), P(None, None), P()),
        out_specs=P(None, ax, None, None), check_rep=False)(
            q, pool, table, q_pos, jnp.asarray(window, jnp.int32))
