"""Batched serving engine with continuous batching.

Fixed-slot decode batch: requests occupy slots, finished slots are refilled
from the queue without stopping the batch (continuous batching).  Prefill
is chunk-free (token-by-token through the decode path) to keep one compiled
step; prompts for a slot are fed before its generation starts.  Greedy or
temperature sampling.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new: int = 16
    temperature: float = 0.0
    rid: int = 0


@dataclasses.dataclass
class Result:
    rid: int
    tokens: List[int]


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, batch_slots: int = 4,
                 max_len: int = 256, seed: int = 0):
        assert cfg.has_decode, "encoder archs don't serve autoregressively"
        self.cfg, self.params = cfg, params
        self.slots = batch_slots
        self.max_len = max_len
        self.state = M.init_decode_state(cfg, batch_slots, max_len)
        self.key = jax.random.PRNGKey(seed)
        self._step = jax.jit(
            lambda p, s, t: M.decode_step(cfg, p, s, t))
        # per-slot bookkeeping (host side)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pending: List[List[int]] = [[] for _ in range(batch_slots)]
        self.slot_out: List[List[int]] = [[] for _ in range(batch_slots)]
        self.queue: List[Request] = []
        self.results: List[Result] = []

    # ------------------------------------------------------------ public
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Result]:
        for _ in range(max_steps):
            self._fill_slots()
            if all(r is None for r in self.slot_req):
                break
            self._advance()
        return self.results

    # ----------------------------------------------------------- internals
    def _fill_slots(self):
        for i in range(self.slots):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[i] = req
                self.slot_pending[i] = list(req.prompt)
                self.slot_out[i] = []
                self._reset_slot_state(i)

    def _reset_slot_state(self, i: int):
        def zero_slot(x):
            if x.ndim >= 2 and x.shape[1] == self.slots:  # [L, B, ...]
                return x.at[:, i].set(jnp.zeros_like(x[:, i]))
            return x
        layers = jax.tree.map(zero_slot, self.state["layers"])
        pos = self.state["pos"].at[i].set(0)
        # empty cache slots must read as "never written": pos fields are -1
        if self.cfg.family not in ("rwkv6",):
            layers = dict(layers)
            kv = layers["kv"]
            layers["kv"] = kv._replace(
                pos=kv.pos.at[:, i].set(-jnp.ones_like(kv.pos[:, i])))
        self.state = {"layers": layers, "pos": pos}

    def _advance(self):
        tokens = np.zeros((self.slots,), np.int32)
        active = np.zeros((self.slots,), bool)
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            active[i] = True
            if self.slot_pending[i]:
                tokens[i] = self.slot_pending[i][0]
            elif self.slot_out[i]:
                tokens[i] = self.slot_out[i][-1]
            else:
                tokens[i] = req.prompt[-1]
        self.state, logits = self._step(self.params, self.state,
                                        jnp.asarray(tokens))
        logits = np.asarray(logits[:, : self.cfg.vocab])
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.slot_pending[i]:
                self.slot_pending[i].pop(0)
                if self.slot_pending[i]:
                    continue  # still prefilling
            # sample the next token from this step's logits
            if req.temperature > 0:
                self.key, sub = jax.random.split(self.key)
                nxt = int(jax.random.categorical(
                    sub, jnp.asarray(logits[i]) / req.temperature))
            else:
                nxt = int(logits[i].argmax())
            self.slot_out[i].append(nxt)
            if len(self.slot_out[i]) >= req.max_new:
                self.results.append(Result(req.rid, self.slot_out[i]))
                self.slot_req[i] = None
