"""Disaggregated prefill/decode serving.

Splits the serving engine into a prefill role and a decode role — each
with its own batch slots, page pool, and allocator, optionally on
disjoint device meshes — connected by a page-migration channel and a
role-aware router with decode→prefill back-pressure.  See session.py
for the roles and the orchestrator, migrate.py for the page channel,
router.py for admission routing.  Entry points:
``Engine.session(disagg=...)`` / ``Engine.serve(disagg=...)`` /
``python -m repro.launch.serve --disagg``.
"""
from repro.disagg.migrate import Handoff, migrate_kv
from repro.disagg.router import DisaggRouter
from repro.disagg.session import (DecodeSession, DisaggConfig,
                                  DisaggSession, PrefillSession)

__all__ = [
    "DecodeSession", "DisaggConfig", "DisaggRouter", "DisaggSession",
    "Handoff", "PrefillSession", "migrate_kv",
]
