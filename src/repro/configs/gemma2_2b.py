"""Gemma2-2B — alternating local/global attention, logit softcaps,
pre+post norms, GeGLU, 256k vocab. [arXiv:2408.00118]"""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv=4, d_ff=9216,
    vocab=256000, d_head=256, window=4096, local_global_period=2,
    attn_softcap=50.0, final_softcap=30.0, attn_scale=256.0 ** -0.5,
    post_norms=True, act="gelu", embed_scale=True, tie_embeddings=True,
    rope_theta=10000.0, source="arXiv:2408.00118"))
