"""Hymba-1.5B — hybrid head: parallel attention + mamba in every block;
SWA everywhere except 3 full-attention layers. [arXiv:2411.13676]
(Meta tokens omitted — shape-neutral, noted in DESIGN.md.)"""
from repro.configs.base import ArchConfig, register

CFG = register(ArchConfig(
    name="hymba-1.5b", family="hymba",
    n_layers=32, d_model=1600, n_heads=25, n_kv=5, d_ff=5504,
    vocab=32001, d_head=64, window=1024, full_attn_layers=(0, 15, 31),
    ssm_state=16, rope_theta=10000.0, tie_embeddings=True,
    source="arXiv:2411.13676"))
