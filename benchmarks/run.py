"""Benchmark runner — one section per paper table/figure + kernel accounting,
plus the unified-API backend benchmark (machine-readable BENCH_api.json).

  PYTHONPATH=src python -m benchmarks.run [--api-only] [--out PATH]
"""
from __future__ import annotations

import json
import sys
import time


def _out_path(default: str = "BENCH_api.json") -> str:
    if "--out" in sys.argv:
        i = sys.argv.index("--out") + 1
        if i >= len(sys.argv) or sys.argv[i].startswith("--"):
            sys.exit("usage: benchmarks.run [--api-only] [--out PATH]")
        return sys.argv[i]
    return default


# The sharding section runs in a SUBPROCESS: the bench process must keep
# 1 device (dry-run isolation rule), and jax locks the device count on
# first backend init.  Parity is the deterministic CI assertion; the
# per-shard step time is the (host-noisy) trajectory, gated dual-unit
# like the FC modes (absolute OR mesh/single ratio, host speed cancels).
_SHARD_BENCH = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
from repro.api import CompressionSpec, Engine, Request
from repro.configs import get, reduced
from repro.launch.mesh import make_host_mesh

cfg = reduced(get("llama3-8b"), n_layers=2, d_model=128, d_ff=256,
              vocab=512)
eng = Engine(cfg).compress(CompressionSpec(mode="aida", density=0.25,
                                           block_rows=32), verbose=None)
reqs = [Request(prompt=[1, 2 + i % 7, 3], max_new=8, rid=i)
        for i in range(4)]

def serve(mesh=None):
    sess = eng.session(batch_slots=2, max_len=32, mesh=mesh,
                       scheduler={"chunk": 4})
    sess.submit(Request(prompt=[1], max_new=1, rid=-1))
    sess.run()
    sess.results.clear()
    best_tps, best_step, toks = 0.0, float("inf"), None
    for _ in range(3):
        s0 = sess.stats["steps"]
        for r in reqs:
            sess.submit(r)
        t0 = time.perf_counter()
        res = sess.run()
        dt = time.perf_counter() - t0
        n = sum(len(r.tokens) for r in res)
        steps = sess.stats["steps"] - s0
        best_tps = max(best_tps, n / dt)
        best_step = min(best_step, dt / steps)
        toks = [r.tokens for r in res]
        sess.results.clear()
    return best_tps, best_step, toks

tps1, step1, ref = serve()
tpsN, stepN, got = serve(make_host_mesh(n_model=4, n_data=2))
from repro.kernels import tune
print(json.dumps({
    "mode": "aida", "n_model": 4, "n_data": 2,
    "token_parity": got == ref,
    "tok_per_s_single": round(tps1, 2),
    "tok_per_s_mesh": round(tpsN, 2),
    "mesh_over_single": round(tpsN / tps1, 4),
    "decode_step_us": round(stepN * 1e6, 1),
    "decode_step_us_per_shard": round(stepN * 1e6 / 4, 1),
    # paged decode/chunk winners the mesh session resolved at its GLOBAL
    # geometry keys (shard_map wrappers pass them into every shard)
    "paged_tiles": {k: v for k, v in tune.snapshot().items()
                    if k.startswith("paged-attn")},
}))
"""


def bench_sharding() -> dict:
    """Mesh-aware serving section: (model=4, data=2) host mesh vs single
    device on the aida mode — token parity (deterministic gate) +
    per-shard decode step time (trajectory)."""
    import json as _json
    import os
    import subprocess

    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SHARD_BENCH], env=env,
                         capture_output=True, text=True, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"sharding bench failed:\n{out.stderr[-2000:]}")
    return _json.loads(out.stdout.strip().splitlines()[-1])


def bench_api(out_path: str = "BENCH_api.json") -> dict:
    """Serve + cost-model every backend through `repro.api.Engine` and
    write tokens/s + cycle counts to `out_path` so future PRs have a perf
    trajectory to compare against."""
    from repro.api import Engine
    from repro.configs import get, reduced

    cfg = reduced(get("llama3-8b"), n_layers=2, d_model=128, d_ff=256,
                  vocab=512)
    eng = Engine(cfg)
    # 8 requests x 16 tokens per mode: ~0.5s+ measured per mode, enough to
    # keep host scheduling noise inside the CI gate's 20% tolerance
    data = eng.benchmark(modes=("dense", "int8", "codebook4", "acsr",
                                "aida"),
                         requests=8, max_new=16, batch_slots=2)
    data["sharding"] = bench_sharding()
    data["meta"] = {"arch": cfg.name, "host": "cpu-interpret",
                    "note": "tok/s on host CPU interpret-mode kernels — "
                            "trajectory signal, not TPU perf"}
    with open(out_path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    for mode, rec in data["modes"].items():
        print(f"  {mode:10s} [{rec['backend']:9s}] {rec['tok_per_s']:8.1f} "
              f"tok/s  ratio {rec['compression_ratio']:.2f}x")
    kv = data.get("kv")
    if kv:
        share = kv["attn_time_share"]
        bpt = kv["kv_bytes_per_token"]
        print(f"  kv[{kv['mode']}]    full {kv['full']['tok_per_s']:.1f} "
              f"tok/s vs paged {kv['paged']['tok_per_s']:.1f} "
              f"(x{kv['paged_over_full']:.2f}); attn share "
              f"full {share['full']:.0%} / paged {share['paged']:.0%}; "
              f"KV {bpt['paged_int8']:.0f} vs {bpt['dense_bf16']:.0f} "
              f"B/token ({bpt['ratio']:.2f}x)")
    sv = data.get("serving")
    if sv:
        pf, th = sv["prefill"], sv["throughput"]
        print(f"  serving[{sv['mode']}] prefill {pf['prompt_len']} toks: "
              f"{pf['chunked']['first_token_calls']} calls (chunk "
              f"{sv['chunk']}) vs {pf['one_token']['first_token_calls']} "
              f"one-token (bound {pf['bound_calls']}); "
              f"hetero {th['tok_per_s']:.1f} tok/s, "
              f"goodput {th['goodput_req_per_s']:.2f} req/s, "
              f"TTFT p50 {th['ttft_s']['p50']*1e3:.0f} ms")
        print(f"  serving prefix-cache: {sv['prefix']['page_hits']} page "
              f"hits / {sv['prefix']['cache']['inserted']} cached; "
              f"preemption: {sv['preemption']['preemptions']} evictions, "
              f"{sv['preemption']['completed']}/"
              f"{sv['preemption']['requests']} completed, "
              f"{sv['preemption']['pages_leaked']} pages leaked")
    rs = data.get("resil")
    if rs:
        worst = None
        for preset, rec in sorted(rs["presets"].items()):
            g = rec.get("goodput_vs_clean")
            if g is not None and (worst is None or g < worst[1]):
                worst = (preset, g)
        all_ok = all(rec["token_parity"] and rec["pages_leaked"] == 0
                     and rec["deterministic"]
                     for rec in rs["presets"].values())
        print(f"  resil[{rs['mode']}]   {len(rs['presets'])} fault presets"
              f" x {rs['clean']['completed']} requests: "
              f"{'parity OK, 0 leaks, deterministic' if all_ok else 'FAIL'}"
              + (f"; worst goodput {worst[1]:.2f}x clean ({worst[0]})"
                 if worst else ""))
    cap = data.get("capacity")
    if cap:
        n_pass = sum(1 for e in cap["sweep"] if e["slo_pass"])
        print(f"  capacity[{cap['workload']}] {len(cap['sweep'])} configs"
              f" x {cap['requests']} requests: {n_pass} meet SLO "
              f"{cap['slo']}; chosen {cap['chosen']}; "
              f"replay deterministic {cap['deterministic_replay']}")
    sh = data.get("sharding")
    if sh:
        print(f"  sharding[{sh['mode']}] mesh {sh['n_model']}x"
              f"{sh['n_data']} (model x data): parity "
              f"{'OK' if sh['token_parity'] else 'LOST'}; "
              f"{sh['tok_per_s_mesh']:.1f} tok/s sharded vs "
              f"{sh['tok_per_s_single']:.1f} single "
              f"(x{sh['mesh_over_single']:.2f}); decode step "
              f"{sh['decode_step_us_per_shard']:.0f} us/shard")
        for key, ch in sorted(sh.get("paged_tiles", {}).items()):
            tiles = {k: v for k, v in ch.items() if k not in ("impl", "us")}
            print(f"    paged tile {key}: {ch['impl']} {tiles} "
                  f"({ch.get('us', float('nan')):.0f} us)")
    sim = data["backends"]["cycle-sim"]
    print(f"  ap-emulator FC cycles: "
          f"{data['backends']['ap-emulator']['fc_cycles']}  "
          f"cycle-sim: {sim['fc_cycles']} "
          f"(agree: {sim['agrees_with_emulator']})")
    print(f"  AlexNet-FC cycle-sim: AIDA {sim['alexnet_fc_cycles']} cyc "
          f"({sim['alexnet_fc_inf_per_s']:.0f} inf/s) vs "
          f"EIE {sim['eie_alexnet_fc_cycles']} cyc "
          f"({sim['eie_alexnet_fc_inf_per_s']:.0f} inf/s)")
    print(f"  -> wrote {out_path}")
    return data


def main() -> int:
    t0 = time.time()
    if "--api-only" in sys.argv:
        print("=" * 72)
        print("API — unified facade backend benchmark (repro.api.Engine)")
        print("=" * 72)
        bench_api(out_path=_out_path())
        print(f"\n[benchmarks] done in {time.time()-t0:.0f}s")
        return 0
    from benchmarks import fig5, kernels_bench, table1

    print("=" * 72)
    print("TABLE 1 — AIDA vs EIE (calibrated analytical simulators)")
    print("=" * 72)
    table1.run()
    ok = table1.validate()
    print(f"\n  -> paper-claim validation (PP 14.5x, thrpt 2.5x, EE, power): "
          f"{'PASS' if ok else 'FAIL'}")

    print()
    print("=" * 72)
    print("FIG 5(a) — area / energy efficiency vs weight sparsity")
    print("=" * 72)
    rows = fig5.sparsity_sweep()
    lin = all(r2["rel_area"] > r1["rel_area"]
              for r1, r2 in zip(rows, rows[1:]))
    print(f"  -> area grows monotonically with density (linear-in-sparsity "
          f"claim): {'PASS' if lin else 'FAIL'}")

    print()
    print("=" * 72)
    print("FIG 5(b) — area / energy efficiency vs wordlength")
    print("=" * 72)
    rows = fig5.precision_sweep()
    mono = all(r1["rel_ee"] >= r2["rel_ee"] for r1, r2
               in zip(rows, rows[1:]))
    quad = rows[-1]["mult_cycles"] / rows[2]["mult_cycles"] > 8  # 16b vs 4b
    print(f"  -> EE best at binary/ternary and monotone in wordlength: "
          f"{'PASS' if mono else 'FAIL'}; multiply-stage cycles quadratic "
          f"(16b/4b > 8x): {'PASS' if quad else 'FAIL'}\n"
          f"     (note: END-TO-END EE gain is sub-quadratic because the "
          f"soft reduction, not the multiply, dominates at short "
          f"wordlengths — see EXPERIMENTS.md)")

    print()
    print("=" * 72)
    print("§4.3 — broadcast/M×V overlap scalability")
    print("=" * 72)
    ov = fig5.overlap_scalability()
    ov_ok = 1.3 < ov["best_speedup"] <= 2.0 and 0.2 < ov["area_overhead"] < 0.6
    print(f"  -> 'up to 1.86x at +28% area': "
          f"{'PASS' if ov_ok else 'FAIL'} "
          f"(model: {ov['best_speedup']:.2f}x, +{ov['area_overhead']:.0%})")

    print()
    print("=" * 72)
    print("KERNELS — compression dividend (HBM bytes) + host wall-clock")
    print("=" * 72)
    kernels_bench.bytes_model()
    print("\nwall-clock (host CPU, interpret-mode kernels — correctness "
          "path, not TPU perf):")
    kernels_bench.wallclock()
    kernels_bench.attention_bench()

    print()
    print("=" * 72)
    print("API — unified facade backend benchmark (repro.api.Engine)")
    print("=" * 72)
    bench_api(out_path=_out_path())

    print(f"\n[benchmarks] done in {time.time()-t0:.0f}s")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
