"""Recurrent mixers: RWKV6 (Finch) time/channel mix and Mamba (hymba's SSM
heads).  Train paths run the differentiable scan ops over the full sequence;
decode paths carry O(1) state — these archs are what make `long_500k`
feasible (state size is sequence-independent).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.layers import COMPUTE_DTYPE, dense, dense_init


# ------------------------------------------------------------------ RWKV6
def rwkv6_time_mix_init(key, d: int, d_head: int = 64, lora: int = 64):
    h = d // d_head
    ks = jax.random.split(key, 10)
    return {
        "mu": jax.random.uniform(ks[0], (5, d), jnp.float32),  # r,k,v,g,w
        "w0": jnp.zeros((d,), jnp.float32) - 4.0,
        "w_A": dense_init(ks[1], d, lora, scale=0.01),
        "w_B": dense_init(ks[2], lora, d, scale=0.01),
        "wr": dense_init(ks[3], d, d),
        "wk": dense_init(ks[4], d, d),
        "wv": dense_init(ks[5], d, d),
        "wg": dense_init(ks[6], d, d),
        "u": jax.random.normal(ks[7], (h, d_head), jnp.float32) * 0.1,
        "ln_scale": jnp.ones((d,), jnp.float32),
        "ln_bias": jnp.zeros((d,), jnp.float32),
        "wo": dense_init(ks[8], d, d),
    }


def _shift(x, prev):
    """Token shift: x_{t-1} (prev carries the last token of the previous
    segment; zeros at sequence start)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _heads(x, d_head):
    b, t, d = x.shape
    return x.reshape(b, t, d // d_head, d_head).transpose(0, 2, 1, 3)


def rwkv6_time_mix(p, x, prev_x, *, d_head: int = 64):
    """x [B,T,D]; prev_x [B,D] (last token before this segment).
    Returns (out [B,T,D], new_prev [B,D])."""
    xs = _shift(x, prev_x)
    mu = p["mu"][:, None, None, :]
    mix = lambda i: (x + (xs - x) * mu[i]).astype(COMPUTE_DTYPE)
    xr, xk, xv, xg, xw = (mix(i) for i in range(5))
    r = dense(xr, p["wr"])
    k = dense(xk, p["wk"])
    v = dense(xv, p["wv"])
    g = dense(xg, p["wg"])
    # data-dependent decay (the Finch contribution)
    wlog = p["w0"] + jnp.tanh(
        xw.astype(jnp.float32) @ p["w_A"]) @ p["w_B"]
    w = jnp.exp(-jnp.exp(wlog))                            # (0,1), [B,T,D]
    o = ops.rwkv6(_heads(r, d_head), _heads(k, d_head), _heads(v, d_head),
                  _heads(w, d_head), p["u"])               # [B,H,T,dh]
    b, h, t, dh = o.shape
    o = o.transpose(0, 2, 1, 3).reshape(b, t, h * dh)
    o = _group_norm(o, p["ln_scale"], p["ln_bias"], h)
    o = o * jax.nn.silu(g.astype(jnp.float32))
    return dense(o.astype(COMPUTE_DTYPE), p["wo"]), x[:, -1, :]


def _group_norm(x, scale, bias, groups, eps=1e-5):
    b, t, d = x.shape
    xg = x.astype(jnp.float32).reshape(b, t, groups, d // groups)
    mu = xg.mean(-1, keepdims=True)
    var = xg.var(-1, keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return xg.reshape(b, t, d) * scale + bias


def rwkv6_time_mix_decode(p, state: Dict, x, *, d_head: int = 64):
    """One token. x [B,1,D]; state {prev [B,D], S [B,H,dh,dh]}."""
    xs = state["prev"][:, None, :]
    mu = p["mu"][:, None, None, :]
    mix = lambda i: (x + (xs - x) * mu[i]).astype(COMPUTE_DTYPE)
    xr, xk, xv, xg, xw = (mix(i) for i in range(5))
    r = dense(xr, p["wr"])[:, 0]
    k = dense(xk, p["wk"])[:, 0]
    v = dense(xv, p["wv"])[:, 0]
    g = dense(xg, p["wg"])[:, 0]
    wlog = p["w0"] + jnp.tanh(
        xw.astype(jnp.float32) @ p["w_A"]) @ p["w_B"]
    w = jnp.exp(-jnp.exp(wlog))[:, 0]
    b, d = r.shape
    h = d // d_head
    hview = lambda z: z.reshape(b, h, d_head).astype(jnp.float32)
    S, o = ops.rwkv6_decode_step(state["S"], hview(r), hview(k), hview(v),
                                 hview(w), p["u"])
    o = o.reshape(b, 1, d)
    o = _group_norm(o, p["ln_scale"], p["ln_bias"], h)
    o = o * jax.nn.silu(g.astype(jnp.float32))[:, None, :]
    out = dense(o.astype(COMPUTE_DTYPE), p["wo"])
    return {"prev": x[:, 0, :], "S": S}, out


def rwkv6_channel_mix_init(key, d: int, f: int):
    ks = jax.random.split(key, 3)
    return {"mu": jax.random.uniform(ks[0], (2, d), jnp.float32),
            "wk": dense_init(ks[1], d, f),
            "wv": dense_init(ks[2], f, d),
            "wr": dense_init(jax.random.fold_in(key, 7), d, d)}


def rwkv6_channel_mix(p, x, prev_x):
    xs = _shift(x, prev_x)
    mu = p["mu"][:, None, None, :]
    xk = (x + (xs - x) * mu[0]).astype(COMPUTE_DTYPE)
    xr = (x + (xs - x) * mu[1]).astype(COMPUTE_DTYPE)
    k = jnp.square(jax.nn.relu(dense(xk, p["wk"]).astype(jnp.float32)))
    out = jax.nn.sigmoid(dense(xr, p["wr"]).astype(jnp.float32)) \
        * dense(k.astype(COMPUTE_DTYPE), p["wv"]).astype(jnp.float32)
    return out.astype(COMPUTE_DTYPE), x[:, -1, :]


def rwkv6_channel_mix_decode(p, prev, x):
    xs = prev[:, None, :]
    mu = p["mu"][:, None, None, :]
    xk = (x + (xs - x) * mu[0]).astype(COMPUTE_DTYPE)
    xr = (x + (xs - x) * mu[1]).astype(COMPUTE_DTYPE)
    k = jnp.square(jax.nn.relu(dense(xk, p["wk"]).astype(jnp.float32)))
    out = jax.nn.sigmoid(dense(xr, p["wr"]).astype(jnp.float32)) \
        * dense(k.astype(COMPUTE_DTYPE), p["wv"]).astype(jnp.float32)
    return x[:, 0, :], out.astype(COMPUTE_DTYPE)


# ------------------------------------------------------------------ Mamba
def mamba_init(key, d: int, state: int = 16, conv_k: int = 4,
               dt_rank: int = None):
    dt_rank = max(1, d // 16) if dt_rank is None else dt_rank
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d, 2 * d),       # x, z
        "conv": jax.random.normal(ks[1], (conv_k, d), jnp.float32) * 0.2,
        "x_db": dense_init(ks[2], d, dt_rank + 2 * state),
        "dt_proj": dense_init(ks[3], dt_rank, d, scale=dt_rank ** -0.5),
        "dt_bias": jnp.full((d,), -3.0, jnp.float32),  # softplus ≈ 0.05
        "A_log": jnp.log(jnp.tile(jnp.arange(1, state + 1,
                                             dtype=jnp.float32), (d, 1))),
        "D": jnp.ones((d,), jnp.float32),
        "out_proj": dense_init(ks[4], d, d),
    }


def _causal_conv(x, w):
    """Depthwise causal conv1d. x [B,T,D], w [K,D]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return out


def mamba_apply(p, x, *, state: int = 16):
    """x [B,T,D] -> y [B,T,D] (training / prefill)."""
    dt_rank = p["dt_proj"].shape[0]
    xz = dense(x, p["in_proj"]).astype(jnp.float32)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = jax.nn.silu(_causal_conv(xi, p["conv"]))
    dbc = xi.astype(COMPUTE_DTYPE) @ p["x_db"].astype(COMPUTE_DTYPE)
    dt_in, B, C = jnp.split(dbc.astype(jnp.float32),
                            [dt_rank, dt_rank + state], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y = ops.mamba(xi, dt, A, B, C) + xi * p["D"]
    y = y * jax.nn.silu(z)
    return dense(y.astype(COMPUTE_DTYPE), p["out_proj"])


def mamba_decode(p, st: Dict, x, *, state: int = 16):
    """One token. x [B,1,D]; st {conv [B,K-1,D], h [B,D,N]}."""
    dt_rank = p["dt_proj"].shape[0]
    xz = dense(x, p["in_proj"]).astype(jnp.float32)
    xi, z = jnp.split(xz[:, 0], 2, axis=-1)                # [B, D]
    conv_buf = jnp.concatenate([st["conv"], xi[:, None, :]], axis=1)
    w = p["conv"]
    xi = jax.nn.silu((conv_buf * w[None]).sum(axis=1))
    dbc = xi.astype(COMPUTE_DTYPE) @ p["x_db"].astype(COMPUTE_DTYPE)
    dt_in, B, C = jnp.split(dbc.astype(jnp.float32),
                            [dt_rank, dt_rank + state], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    h, y = ops.mamba_decode_step(st["h"], xi, dt, A, B, C)
    y = (y + xi * p["D"]) * jax.nn.silu(z)
    out = dense(y[:, None, :].astype(COMPUTE_DTYPE), p["out_proj"])
    return {"conv": conv_buf[:, 1:], "h": h}, out
