"""Sharded, async, atomic checkpointing with elastic resharding.

Layout:  <dir>/step_<N>/
           manifest.json          tree structure, shapes, dtypes, mesh info
           arr_<idx>.npy          one file per leaf (per-host shard in a real
                                  multi-host job; full array here)
           .COMMITTED             written last — restore ignores uncommitted
                                  (partially-written / preempted) checkpoints

Fault-tolerance contract:
  * atomic: tmp-dir + rename, .COMMITTED marker written last;
  * async: save() snapshots to host RAM synchronously (cheap) and writes in
    a background thread — training never blocks on storage;
  * elastic: restore() returns host arrays; the caller re-device_puts them
    with the CURRENT mesh's NamedShardings, so a checkpoint written on a
    (2,16,16) mesh restores onto (16,16) or (4,8,8) unchanged — resharding
    is free because shards are reassembled to logical arrays at save time.
  * retention: keep_last newest checkpoints survive garbage collection.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        leaves, treedef = _flatten(tree)
        host = [np.asarray(x) for x in leaves]      # snapshot (sync, cheap)
        self.wait()                                  # one writer at a time
        self._thread = threading.Thread(
            target=self._write, args=(step, host, str(treedef), extra),
            daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step, host, treedef_str, extra):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "n_leaves": len(host),
                    "treedef": treedef_str, "extra": extra or {},
                    "shapes": [list(a.shape) for a in host],
                    "dtypes": [str(a.dtype) for a in host]}
        for i, a in enumerate(host):
            np.save(os.path.join(tmp, f"arr_{i}.npy"), a)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, ".COMMITTED"), "w") as f:
            f.write("ok")
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.list_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def list_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            if os.path.exists(os.path.join(self.dir, name, ".COMMITTED")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, Dict]:
        """Restore into `template`'s tree structure.  If `shardings` (a
        matching tree of NamedSharding) is given, leaves are device_put with
        it — this is the elastic-rescale path."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError("no committed checkpoint found")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = _flatten(template)
        assert manifest["n_leaves"] == len(leaves), \
            "checkpoint/template structure mismatch"
        host = [np.load(os.path.join(d, f"arr_{i}.npy"))
                for i in range(len(leaves))]
        for h, t in zip(host, leaves):
            assert tuple(h.shape) == tuple(t.shape), \
                f"shape mismatch {h.shape} vs {t.shape}"
        if shardings is not None:
            shard_leaves = jax.tree.flatten(shardings)[0]
            out = [jax.device_put(h, s) for h, s in zip(host, shard_leaves)]
        else:
            out = [jax.device_put(h.astype(t.dtype))
                   for h, t in zip(host, leaves)]
        return jax.tree.unflatten(treedef, out), manifest["extra"]
