"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, asserting output shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, cell_supported, get, names, reduced
from repro.data.pipeline import PipelineConfig, make_batch
from repro.models import model as M
from repro.train import trainer

ALL_ARCHS = names()


def smoke_cfg(name):
    cfg = reduced(get(name))
    if cfg.frontend == "vision":
        cfg = dataclasses.replace(cfg, n_img_tokens=8)
    return cfg


def smoke_batch(cfg, b=2, s=32):
    pc = PipelineConfig(seed=0, global_batch=b, seq_len=s)
    return {k: jnp.asarray(v) for k, v in make_batch(cfg, pc, 0).items()}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = smoke_cfg(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = smoke_batch(cfg)
    logits, aux = M.forward(cfg, params, batch, remat="none")
    assert logits.shape == (2, 32, cfg.vocab_padded)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_no_nan(arch):
    cfg = smoke_cfg(arch)
    state = trainer.init_state(cfg, jax.random.PRNGKey(0))
    tc = trainer.TrainConfig(remat="none")
    step = jax.jit(trainer.make_train_step(cfg, tc))
    state, metrics = step(state, smoke_batch(cfg))
    assert float(metrics["loss"]) > 0 and np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    delta = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x))),
        jax.tree.map(jnp.subtract, state.params,
                     trainer.init_state(cfg, jax.random.PRNGKey(0)).params),
        0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if get(a).has_decode])
def test_decode_matches_forward(arch):
    cfg = smoke_cfg(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    if cfg.frontend == "vision":
        pytest.skip("decode-vs-forward needs pure-text prefix")
    full, _ = M.forward(cfg, params, {"tokens": toks}, remat="none")
    state = M.init_decode_state(cfg, B, S)
    outs = []
    for t in range(S):
        state, lg = M.decode_step(cfg, params, state, toks[:, t])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert float(jnp.max(jnp.abs(dec - full))) / scale < 0.02


def test_cell_matrix_counts():
    """40 cells total; 34 runnable; the 6 documented skips."""
    total, ok, skips = 0, 0, []
    for a in ALL_ARCHS:
        for s in SHAPES.values():
            total += 1
            good, why = cell_supported(get(a), s)
            if good:
                ok += 1
            else:
                skips.append((a, s.name, why))
    assert total == 40
    assert ok == 34
    skip_set = {(a, s) for a, s, _ in skips}
    assert ("hubert-xlarge", "decode_32k") in skip_set
    assert ("hubert-xlarge", "long_500k") in skip_set
    assert ("llama3-8b", "long_500k") in skip_set
    assert ("qwen1.5-0.5b", "long_500k") in skip_set
    assert ("phi-3-vision-4.2b", "long_500k") in skip_set
    assert ("dbrx-132b", "long_500k") in skip_set


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_param_specs_cover_params(arch):
    """Sharding specs tree must exactly match the param tree structure
    (checked via eval_shape — no allocation of the full config)."""
    cfg = get(arch)
    shapes = jax.eval_shape(lambda k: M.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    specs = M.param_specs(cfg, {"data": 16, "model": 16})
    jax.tree.map(lambda sh, sp: None, shapes, specs,
                 is_leaf=lambda x: hasattr(x, "shape") or x is None)
    # every spec'd axis must divide the corresponding dim on a 16×16 mesh
    from jax.sharding import PartitionSpec
    flat_sh = jax.tree.leaves(shapes)
    flat_sp = jax.tree.flatten(specs,
                               is_leaf=lambda x: isinstance(x, PartitionSpec))[0]
    sizes = {"data": 16, "model": 16}
    for sh, sp in zip(flat_sh, flat_sp):
        for dim, axis in zip(sh.shape, tuple(sp)):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            need = 1
            for a in axes:
                need *= sizes[a]
            assert dim % need == 0, (arch, sh.shape, tuple(sp))
