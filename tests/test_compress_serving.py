"""Model-level compression (Deep-Compression → AIDA serving format)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get, reduced
from repro.models import model as M
from repro.api.compress import compress_params

CFG = reduced(get("llama3-8b"), n_layers=2, d_model=128, d_ff=256, vocab=256)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.mark.parametrize("mode,min_ratio", [
    ("int8", 1.8), ("codebook4", 3.5), ("aida", 3.0),
])
def test_compression_ratio(params, mode, min_ratio):
    _, stats = compress_params(params, mode=mode, density=0.1, verbose=None)
    assert stats["n_compressed"] > 0
    assert stats["ratio"] >= min_ratio, stats


def test_int8_decode_matches_dense(params):
    cparams, _ = compress_params(params, mode="int8", verbose=None)
    B, S = 2, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, CFG.vocab)
    std = M.init_decode_state(CFG, B, 8)
    stc = M.init_decode_state(CFG, B, 8)
    for t in range(S):
        std, ld = M.decode_step(CFG, params, std, toks[:, t])
        stc, lc = M.decode_step(CFG, cparams, stc, toks[:, t])
    assert float((ld.argmax(-1) == lc.argmax(-1)).mean()) == 1.0
    assert float(jnp.mean(jnp.abs(ld - lc))) < 0.05


def test_compressed_decode_is_jittable_and_finite(params):
    cparams, _ = compress_params(params, mode="aida", density=0.2,
                                 verbose=None)
    step = jax.jit(lambda p, s, t: M.decode_step(CFG, p, s, t))
    st = M.init_decode_state(CFG, 2, 4)
    st, lg = step(cparams, st, jnp.asarray([1, 2], jnp.int32))
    assert bool(jnp.isfinite(lg).all())


def test_compression_skips_norms_and_embeddings(params):
    cparams, _ = compress_params(params, mode="int8", verbose=None)
    # norms / embed untouched (still raw arrays)
    assert isinstance(cparams["embed"]["table"], jax.Array)
    l0 = cparams["layers"]["ln1"]["scale"]
    assert isinstance(l0, jax.Array)
    # projections ARE CompressedFC
    assert type(cparams["layers"]["attn"]["wq"]).__name__ == "CompressedFC"
