"""Mixtral-8x7B — 8 experts top-2, sliding window. [arXiv:2401.04088]"""
from repro.configs.base import ArchConfig, MoECfg, register

CFG = register(ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
    vocab=32000, d_head=128, window=4096, rope_theta=1_000_000.0,
    moe=MoECfg(n_experts=8, top_k=2), tie_embeddings=False,
    source="arXiv:2401.04088"))
