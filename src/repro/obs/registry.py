"""Typed metrics registry + run provenance.

`sched/metrics.py` is rewritten on top of these primitives: a
:class:`Counter` for monotonically increasing totals, a :class:`Gauge`
for point-in-time values, and a :class:`Histogram` whose ``summary()``
is the mean/p50/p99 shape every BENCH section reports.  The registry is
deliberately tiny — no labels, no time series — because the stack's
clock is the scheduler tick and the per-tick stream lives in
``obs.trace``; this layer only aggregates.

:func:`provenance` stamps the run context (config, mode, seed, backend,
jax version, git sha, timestamp) into BENCH sections so a regression is
attributable to the run that produced it.
"""
from __future__ import annotations

import datetime
import os
import subprocess
from typing import Dict, List, Optional


def percentile(values: List[float], q: float) -> Optional[float]:
    """Nearest-rank percentile; None on empty input."""
    if not values:
        return None
    xs = sorted(values)
    idx = max(0, min(len(xs) - 1, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[idx]


class Counter:
    """Monotonically increasing total."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Point-in-time value (last write wins)."""

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None

    def set(self, v: Optional[float]) -> None:
        self.value = v


class Histogram:
    """Value distribution; ``summary()`` is the canonical BENCH shape
    ``{"mean","p50","p99"}`` (rounded, None when empty)."""

    def __init__(self, name: str):
        self.name = name
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    def observe_many(self, vs) -> None:
        self.values.extend(float(v) for v in vs)

    def summary(self, scale: float = 1.0, digits: int = 4) -> Optional[dict]:
        if not self.values:
            return None
        xs = [v * scale for v in self.values]
        return {
            "mean": round(sum(xs) / len(xs), digits),
            "p50": round(percentile(xs, 50), digits),
            "p99": round(percentile(xs, 99), digits),
        }


class Registry:
    """Get-or-create namespace of typed metrics.

    One registry per summarize() call / serve run; ``snapshot()``
    returns plain dicts so callers can json-dump it directly.
    """

    def __init__(self):
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        if name not in self.counters:
            self.counters[name] = Counter(name)
        return self.counters[name]

    def gauge(self, name: str) -> Gauge:
        if name not in self.gauges:
            self.gauges[name] = Gauge(name)
        return self.gauges[name]

    def histogram(self, name: str) -> Histogram:
        if name not in self.histograms:
            self.histograms[name] = Histogram(name)
        return self.histograms[name]

    def snapshot(self) -> dict:
        return {
            "counters": {k: c.value for k, c in sorted(self.counters.items())},
            "gauges": {k: g.value for k, g in sorted(self.gauges.items())},
            "histograms": {k: h.summary() for k, h
                           in sorted(self.histograms.items())},
        }


def _git_sha() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        sha = out.stdout.strip()
        return sha or None
    except Exception:
        return None


def provenance(config: Optional[str] = None, mode: Optional[str] = None,
               seed: Optional[int] = None, backend: Optional[str] = None,
               **extra) -> dict:
    """Run-context header stamped into BENCH sections and ``--json``
    dumps: enough to attribute a number to the run that produced it."""
    try:
        import jax
        jax_version = jax.__version__
    except Exception:
        jax_version = None
    info = {
        "config": config,
        "mode": mode,
        "seed": seed,
        "backend": backend,
        "jax": jax_version,
        "git_sha": _git_sha(),
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }
    info.update(extra)
    return info
