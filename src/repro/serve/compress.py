"""DEPRECATED — model-level compression moved to `repro.api` (PR 1).

Use `repro.api.Engine.compress(CompressionSpec(...))` or
`repro.api.compress_params`.  This shim keeps old imports working for
one PR.
"""
from __future__ import annotations

import warnings

from repro.api.compress import (SKIP_SUBSTR, TARGET_SUFFIXES,  # noqa: F401
                                _stack_compressed)
from repro.api.compress import compress_params as _compress_params


def compress_params(params, mode: str = "aida", density: float = 0.10,
                    k: int = 16, verbose=print):
    """Deprecated alias of `repro.api.compress_params`."""
    warnings.warn(
        "repro.serve.compress.compress_params is deprecated; use "
        "repro.api.Engine.compress or repro.api.compress_params",
        DeprecationWarning, stacklevel=2)
    return _compress_params(params, mode=mode, density=density, k=k,
                            verbose=verbose)
