"""Analytical AIDA simulator — performance / power / area model (§4).

The paper evaluates AIDA with "a custom simulator ... for performance and
power simulation and design space exploration".  This module is that
simulator, rebuilt from the Fig. 3 algorithm:

* **cycles** — closed-form counts derived op-by-op from the emulator's
  micro-operations.  With the ``EMULATOR`` microcode preset the closed form
  equals `aida_fc.aida_fc_layer`'s measured cycle counter EXACTLY
  (tests/test_aida_sim.py asserts this).  The ``PAPER`` preset uses the
  more aggressive microcode the paper's headline numbers imply (fused
  compare+write in the reduction move loop, 8-cycle full adder, 16-bit
  saturating accumulator, broadcast overlapped with M×V per §4.3) and is
  used to reproduce Table 1.
* **energy/power** — per-cycle CAM/TAG activity model calibrated against
  the paper's published cell figures (TAG 7.1 µm² & 5.6 fJ, 10T NOR CAM
  bitcell 0.135 µm² @28 nm) and reported alongside the claimed 7.15 W.
* **area/memory** — rows × (bits × cell area + TAG) with periphery factor.

Conventions reverse-engineered from Table 1 (documented in EXPERIMENTS.md):
AIDA EE = PP/Power on *sparse* ops (1474/7.15 = 206.2 ✓); EIE's listed EE
counts *dense-equivalent* ops (≈10× sparsity: 102.4×10/0.37 = 2768 ≈ 2756 ✓).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

from repro.core.associative import move_cycles


# ------------------------------------------------------------ microcode
@dataclasses.dataclass(frozen=True)
class Microcode:
    """Per-primitive cycle costs + controller policy knobs."""
    c_and: int = 2            # perfect-induction bitwise AND
    c_fulladd: int = 10       # snapshot(4) + 6 written truth-table entries
    c_halfadd: int = 6
    fused_reduce_move: bool = False  # compare+write fused around tag moves
    kc_fixed: Optional[int] = None   # None = exact-width accumulator
    overlap_broadcast: bool = False  # §4.3 two-subarray pipelining
    freq_hz: float = 1.0e9           # Table 1: 1000 MHz


EMULATOR = Microcode()
PAPER = Microcode(c_fulladd=8, c_halfadd=5, fused_reduce_move=True,
                  kc_fixed=16, overlap_broadcast=True)


# ------------------------------------------------------------ cycle model
def acc_kc(m: int, n: int, max_row_nnz: int, mc: Microcode,
           prod_bits: Optional[int] = None) -> int:
    """Accumulator width: product bits + tree headroom + sign."""
    if mc.kc_fixed is not None:
        return mc.kc_fixed
    pb = (m + n) if prod_bits is None else prod_bits
    acc = math.ceil(math.log2(max_row_nnz)) if max_row_nnz > 1 else 0
    return pb + acc + 1


def reduction_rounds(max_row_nnz: int) -> int:
    return max(1, math.ceil(math.log2(max_row_nnz))) if max_row_nnz > 1 else 1


def cycles_broadcast(nnz_b: int) -> int:
    """Lines 2–5: one fused compare+write per nonzero activation."""
    return nnz_b


def cycles_multiply_bitserial(m: int, n: int, kc: int, mc: Microcode) -> int:
    """Lines 7–12 + sign fix: schoolbook bit-serial multiply."""
    inner = n * m * (mc.c_and + mc.c_fulladd)
    ripple = mc.c_halfadd * (n * (n + 1) // 2)
    sign = 3 + 4 * kc + (2 + mc.c_fulladd + mc.c_halfadd * (kc - 1) + 1)
    return inner + ripple + sign


def cycles_multiply_coded(cw_bits: int, ca_bits: int) -> int:
    """Bit-parallel perfect induction: every nonzero code combination."""
    return ((1 << cw_bits) - 1) * ((1 << ca_bits) - 1)


def cycles_reduction(kc: int, max_row_nnz: int, mc: Microcode) -> int:
    """Lines 14–26: binary-tree segmented accumulation."""
    total = 0
    for t in range(reduction_rounds(max_row_nnz)):
        mcyc = move_cycles(1 << t)
        per_bit = (1 if mc.fused_reduce_move else 2) + mcyc
        total += (1                       # clear MV
                  + (kc + 1) * per_bit    # tag, shift, deposit (C bits + flag)
                  + mc.c_fulladd * kc + 1  # C += MV, clear carry
                  + 1 + 1 + 2)            # fold LAST, kill senders, check
    return total


def cycles_relu() -> int:
    return 1  # lines 28–29, fused compare+write


@dataclasses.dataclass
class FCPhases:
    broadcast: int
    multiply: int
    reduce: int
    act: int

    @property
    def compute(self) -> int:  # everything that cannot overlap broadcast
        return self.multiply + self.reduce + self.act

    def total(self, mc: Microcode) -> int:
        if mc.overlap_broadcast:  # §4.3 two-subarray pipelining
            return max(self.broadcast, self.compute)
        return self.broadcast + self.compute


def cycles_fc(n_in: int, nnz_b: int, max_row_nnz: int, mc: Microcode,
              mode: str = "coded", m: int = 4, n: int = 4,
              prod_bits: int = 16) -> FCPhases:
    """Full FC-layer cycle breakdown.

    mode="coded": m/n are the CODE widths (4-bit), prod_bits the product
    wordlength (16-bit values — Table 1's 'Quant 16/16').
    mode="bitserial": m/n are the operand wordlengths.
    """
    del n_in
    if mode == "coded":
        kc = acc_kc(m, n, max_row_nnz, mc, prod_bits=prod_bits)
        mul = cycles_multiply_coded(m, n)
    elif mode == "bitserial":
        kc = acc_kc(m, n, max_row_nnz, mc)
        mul = cycles_multiply_bitserial(m, n, kc, mc)
    else:
        raise ValueError(mode)
    red = cycles_reduction(kc, max_row_nnz, mc)
    return FCPhases(broadcast=cycles_broadcast(nnz_b), multiply=mul,
                    reduce=red, act=cycles_relu())


# ---------------------------------------------------------- energy / area
@dataclasses.dataclass(frozen=True)
class Tech:
    """28nm figures; cell numbers from the paper, activity factors
    calibrated once against Table 1's 7.15 W (see EXPERIMENTS.md)."""
    a_cam_cell_um2: float = 0.135   # paper §4.2: 10T NOR CAM bitcell
    a_tag_um2: float = 7.1          # paper §4.2: synthesized TAG cell
    e_tag_fj: float = 5.6           # paper §4.2: average TAG energy
    e_cmp_fj_per_bit: float = 0.07  # match-line bitcell compare (calibrated)
    e_wr_fj_per_bit: float = 0.30   # write driver per bitcell
    tag_activity: float = 0.03      # fraction of TAGs toggling per compare
    write_sel_frac: float = 0.15    # average fraction of rows tagged
    avg_cmp_bits: float = 9.0       # average masked-key width
    avg_wr_bits: float = 8.0
    periphery: float = 1.15         # drivers/decoders/controller overhead


TECH = Tech()


def row_energy_per_cycle_fj(tech: Tech = TECH) -> float:
    """Average CAM energy per PU row per controller cycle (fJ)."""
    return (tech.avg_cmp_bits * tech.e_cmp_fj_per_bit
            + tech.tag_activity * tech.e_tag_fj
            + tech.write_sel_frac * tech.avg_wr_bits * tech.e_wr_fj_per_bit)


def power_w(active_rows: int, mc: Microcode, tech: Tech = TECH) -> float:
    return active_rows * row_energy_per_cycle_fj(tech) * 1e-15 * mc.freq_hz


def area_mm2(rows: int, bits_per_row: int, tech: Tech = TECH,
             dual_tag: bool = False) -> float:
    tag = tech.a_tag_um2 * (2 if dual_tag else 1)
    return rows * (bits_per_row * tech.a_cam_cell_um2 + tag) \
        * tech.periphery / 1e6


def memory_mbytes(rows: int, stored_bits: int) -> float:
    """On-chip capacity counting STORED fields (flag+rel-col+W code)."""
    return rows * stored_bits / 8 / 1e6


# ------------------------------------------------------------- workloads
@dataclasses.dataclass(frozen=True)
class FCLayerSpec:
    name: str
    n_out: int
    n_in: int
    w_density: float     # Deep-Compression weight density
    a_density: float     # input activation density
    row_max_factor: float = 2.0  # max-row-nnz / mean-row-nnz (imbalance)

    @property
    def nnz(self) -> int:
        return int(self.n_out * self.n_in * self.w_density)

    @property
    def nnz_b(self) -> int:
        return int(self.n_in * self.a_density)

    @property
    def max_row_nnz(self) -> int:
        return max(1, min(self.n_in,
                          int(self.n_in * self.w_density
                              * self.row_max_factor)))


def alexnet_fc() -> List[FCLayerSpec]:
    """AlexNet FC6/7/8 with Deep-Compression densities (EIE Table II:
    9%/9%/25% weights, ~35% activations)."""
    return [FCLayerSpec("FC6", 4096, 9216, 0.09, 0.35),
            FCLayerSpec("FC7", 4096, 4096, 0.09, 0.35),
            FCLayerSpec("FC8", 1000, 4096, 0.25, 0.38)]


def ctc_lstm() -> List[FCLayerSpec]:
    """CTC-3L-421H-UNI (Graves 2013): 3 unidirectional LSTM layers,
    421 hidden; the recurrent+input FC block per layer is one 1684×842
    (4 gates × 421 out, 421+421 in) M×V.  10% weights; LSTM hidden
    activations are near-dense (0.9) and pruned-LSTM rows are close to
    uniform (max/mean ≈ 1.3) — both calibrated against the EIE/AIDA
    Table-1 throughput rows (see EXPERIMENTS.md §Calibration)."""
    gates = 4 * 421
    return [FCLayerSpec(f"LSTM{i}", gates, 842, 0.10, 0.90,
                        row_max_factor=1.3) for i in range(3)]


# ------------------------------------------------------------ aggregates
@dataclasses.dataclass
class NetworkReport:
    name: str
    layers: List[FCLayerSpec]
    phases: List[FCPhases]
    cycles_total: int           # single-frame latency (sequential layers)
    cycles_pipe: int            # pipelined initiation interval (max layer)
    nnz_total: int
    gops_latency: float         # 2·nnz / latency
    gops_pipelined: float       # 2·nnz / II  == peak performance
    inf_per_s: float
    power_w: float
    ee_gop_per_j: float


def evaluate_network(name: str, layers: Sequence[FCLayerSpec],
                     mc: Microcode = PAPER, mode: str = "coded",
                     m: int = 4, n: int = 4, prod_bits: int = 16,
                     tech: Tech = TECH) -> NetworkReport:
    phases = [cycles_fc(l.n_in, l.nnz_b, l.max_row_nnz, mc, mode=mode,
                        m=m, n=n, prod_bits=prod_bits) for l in layers]
    totals = [p.total(mc) for p in phases]
    cyc_total = sum(totals)
    cyc_pipe = max(totals)
    nnz = sum(l.nnz for l in layers)
    t_total = cyc_total / mc.freq_hz
    t_pipe = cyc_pipe / mc.freq_hz
    pw = power_w(nnz, mc, tech)
    gops_pipe = 2 * nnz / t_pipe / 1e9
    return NetworkReport(
        name=name, layers=list(layers), phases=phases,
        cycles_total=cyc_total, cycles_pipe=cyc_pipe, nnz_total=nnz,
        gops_latency=2 * nnz / t_total / 1e9,
        gops_pipelined=gops_pipe,
        inf_per_s=1.0 / t_total,
        power_w=pw,
        ee_gop_per_j=gops_pipe / pw)


def peak_gops(layers: Sequence[FCLayerSpec], mc: Microcode = PAPER,
              mode: str = "coded", m: int = 4, n: int = 4,
              prod_bits: int = 16) -> float:
    """Peak performance: best per-layer rate over the compute phases
    (multiply + soft reduction — every resident PU busy; the broadcast is
    I/O and is excluded from the *peak* figure, matching how 1474 GOP/s
    relates to the Fig.-3 compute stages)."""
    best = 0.0
    for l in layers:
        ph = cycles_fc(l.n_in, l.nnz_b, l.max_row_nnz, mc, mode=mode,
                       m=m, n=n, prod_bits=prod_bits)
        rate = 2 * l.nnz / ((ph.multiply + ph.reduce) / mc.freq_hz) / 1e9
        best = max(best, rate)
    return best


def aida_table1(mc: Microcode = PAPER, tech: Tech = TECH) -> dict:
    """Reproduce AIDA's Table-1 column: PP over the AlexNet FC compute
    phases, throughput on CTC frames (broadcast overlapped, §4.3)."""
    alex = evaluate_network("AlexNet-FC", alexnet_fc(), mc, tech=tech)
    ctc = evaluate_network("CTC-3L-421H-UNI", ctc_lstm(), mc, tech=tech)
    nnz_all = alex.nnz_total + ctc.nnz_total
    pp_gops = peak_gops(alexnet_fc(), mc)
    pw = power_w(nnz_all, mc, tech)
    stored_bits = 2 + 4 + 4  # flag + EIE-style relative col index + W code
    bits_row = 2 + 1 + 10 + 4 + 4 + 4 + 16 + 17 + 6  # full compute layout
    return dict(
        alexnet=alex, ctc=ctc,
        pp_gops=pp_gops,
        thrpt_inf_s=ctc.inf_per_s,
        power_w=pw,
        ee_gop_per_j=pp_gops / pw,
        area_mm2=area_mm2(nnz_all, bits_row, tech),
        area_mm2_maxlayer=area_mm2(
            max(l.nnz for l in alexnet_fc()), bits_row, tech),
        memory_mb=memory_mbytes(nnz_all, stored_bits),
        nnz_total=nnz_all,
    )
