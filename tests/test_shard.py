"""Mesh-aware serving (`repro.shard`): sharded vs single-device parity.

Multi-device cases spawn subprocesses that set
``--xla_force_host_platform_device_count=8`` (the main test process must
keep 1 device, per the dry-run isolation rule — see tests/test_distributed).

Covers: greedy token parity of `Engine.session(mesh=...)` vs the
single-device path (llama3-smoke + mixtral-smoke MoE, acsr / int8 /
paged-bf16 modes, chunked prefill included), allocator/refcount
invariants under sharded page pools (preemption + drain, zero leaks), a
hypothesis sweep over (n_model, chunk, policy), and single-device unit
tests of the plan/partition machinery (padding, local views, fit
fallback, host-mesh validation).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

HEADER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.api import CompressionSpec, Engine, Request
from repro.configs import get, reduced
from repro.launch.mesh import make_host_mesh

def smoke(arch):
    return reduced(get(arch), n_layers=2, d_model=64, d_ff=128, vocab=256)

REQS = [Request(prompt=[1 + (j * 7 + i) % 200 for j in range(9)],
                max_new=6, rid=i) for i in range(3)]

def engine(cfg, mode):
    eng = Engine(cfg)
    if mode != "dense":
        # block_rows=16 so every smoke projection has >= 4 real row
        # blocks — the shards get real bands, not padding
        eng.compress(CompressionSpec(mode=mode, density=0.25,
                                     block_rows=16), verbose=None)
    return eng

def tokens(eng, reqs, mesh=None, chunk=1, policy="fifo", slots=2,
           pool=None):
    sess = eng.session(batch_slots=slots, max_len=48, mesh=mesh,
                       kv_pool_pages=pool,
                       scheduler={"chunk": chunk, "policy": policy})
    for r in reqs:
        sess.submit(r)
    return sess, [r.tokens for r in sess.run()]
"""

PARITY_SCRIPT = HEADER + r"""
out = {"n_devices": jax.device_count(), "cases": {}}
mesh = make_host_mesh(n_model=4, n_data=2)
# paged-bf16 serving is the default kv cache for these archs, so the
# "dense" mode rows double as the paged-bf16 KV parity check
for arch in ("llama3-8b", "mixtral-8x7b"):
    cfg = smoke(arch)
    for mode in ("dense", "acsr", "int8"):
        eng = engine(cfg, mode)
        _, ref = tokens(eng, REQS, chunk=4)
        _, got = tokens(eng, REQS, mesh=mesh, chunk=4)
        out["cases"][f"{arch}/{mode}/chunk4"] = got == ref
# decode-only path (no chunking) on the compressed headline mode
cfg = smoke("llama3-8b")
eng = engine(cfg, "acsr")
_, ref = tokens(eng, REQS, chunk=1)
sess, got = tokens(eng, REQS, mesh=mesh, chunk=1)
out["cases"]["llama3-8b/acsr/chunk1"] = got == ref
out["params_sharded"] = any(
    getattr(l, "sharding", None) is not None
    and "model" in str(l.sharding.spec)
    for l in jax.tree.leaves(sess.params))
kv = sess.state["layers"]["kv"]
out["kv_heads_local"] = kv.k_pages.addressable_shards[0].data.shape[2]
out["kv_heads_global"] = kv.k_pages.shape[2]
from jax.sharding import PartitionSpec as P
from repro.shard.plan import make_plan
plan = make_plan(mesh)
out["fit_fallback"] = tuple(plan.fit(P("model", None), (7, 4))) \
    == (None, None)
# psum combine policy (int8 input partitioning): same math as the
# single-device kernel up to all-reduce rounding
import numpy as np
import jax.numpy as jnp
from repro.core import sparse_fc as sfc
from repro.shard import apply_fc_sharded, partition
rng = np.random.default_rng(0)
leaf = partition.pad_leaf(
    sfc.compress(rng.normal(size=(30, 32)), mode="int8"), 4)
x = jnp.asarray(rng.normal(size=(2, 32)), jnp.float32)
b = jnp.asarray(rng.normal(size=(30,)), jnp.float32)
ref = np.asarray(sfc.apply_fc(leaf, x, bias=b, activation="relu"))
plan_p = make_plan(mesh, policy={"int8": "psum"})
got = np.asarray(jax.jit(lambda xx: apply_fc_sharded(
    plan_p, leaf, xx, bias=b, activation="relu"))(x))
out["psum_shape_ok"] = got.shape == ref.shape
out["psum_max_err"] = float(np.abs(got - ref).max())
print(json.dumps(out))
"""

ALLOC_SCRIPT = HEADER + r"""
mesh = make_host_mesh(n_model=4, n_data=2)
cfg = smoke("llama3-8b")
eng = engine(cfg, "acsr")
# pool sized under the 3-slot worst case -> preemption must kick in
reqs = [Request(prompt=[2 + i] * 8, max_new=16, rid=i) for i in range(6)]
from repro.sched.scheduler import page_need
need = page_need(8, 16, 48, 16)
_, ref = tokens(eng, reqs, chunk=4, slots=3, pool=1 + 3 * need - 2)
sess, got = tokens(eng, reqs, mesh=mesh, chunk=4, slots=3,
                   pool=1 + 3 * need - 2)
alloc = sess.alloc
print(json.dumps({
    "match": got == ref,
    "completed": len(got),
    "preempted": sess.stats["preemptions"] > 0,
    "free_list_unique": len(set(alloc._free)) == len(alloc._free),
    "free_used_disjoint": not (set(alloc._free) & alloc._used),
    "partition_exact":
        len(alloc._free) + alloc.in_use == alloc.n_pages - 1,
    "pages_leaked": alloc.in_use,
}))
"""

SWEEP_SCRIPT = HEADER + r"""
from hypothesis import given, settings, strategies as st

cfg = smoke("llama3-8b")
eng = engine(cfg, "acsr")
BASE = {}
failures = []

@settings(max_examples=6, deadline=None, derandomize=True)
@given(n_model=st.sampled_from([1, 2, 4]),
       chunk=st.sampled_from([1, 3]),
       policy=st.sampled_from(["fifo", "sjf"]))
def sweep(n_model, chunk, policy):
    if chunk not in BASE:
        BASE[chunk] = tokens(eng, REQS, chunk=chunk)[1]
    mesh = make_host_mesh(n_model=n_model)
    _, got = tokens(eng, REQS, mesh=mesh, chunk=chunk, policy=policy)
    if got != BASE[chunk]:
        failures.append([n_model, chunk, policy])

sweep()
print(json.dumps({"failures": failures}))
"""


PALLAS_KERNEL_SCRIPT = HEADER + r"""
import numpy as np
import jax.numpy as jnp
from repro import kvstore as kvs
from repro.kernels import tune
from repro.shard import (make_plan, paged_attention_chunk_sharded,
                        paged_attention_sharded)

mesh = make_host_mesh(n_model=4, n_data=2)
plan = make_plan(mesh)
out = {"n_devices": jax.device_count(), "cases": {}}

# ---- kernel-level: shard-local Pallas == single-device Pallas, bitwise
B, Hkv, G, Dh, ps, npp, S, C = 2, 4, 2, 8, 4, 3, 10, 4
for kvd in ("bf16", "int8"):
    rng = np.random.default_rng(0)
    pool = kvs.init_pool(1 + B * npp, Hkv, ps, Dh, kv_dtype=kvd)
    table = jnp.asarray(1 + np.arange(B * npp).reshape(B, npp), jnp.int32)
    for t in range(S):
        pool = kvs.update(
            pool, table,
            jnp.asarray(rng.normal(size=(B, Hkv, Dh)), jnp.float32),
            jnp.asarray(rng.normal(size=(B, Hkv, Dh)), jnp.float32),
            jnp.full((B,), t, jnp.int32))
    # pin the Pallas kernel in the tune cache at the GLOBAL geometry —
    # the wrappers must resolve this choice and run it shard-local
    quant = kvd == "int8"
    tune.record(tune.paged_key(Hkv, G, Dh, ps, npp, B, quant, True),
                tune.KernelChoice("pallas", (("pb", 2),)))
    tune.record(tune.paged_chunk_key(Hkv, G, Dh, ps, npp, B, C, quant,
                                     True),
                tune.KernelChoice("pallas", (("pb", 2), ("qt", 2))))
    q = jnp.asarray(rng.normal(size=(B, Hkv * G, Dh)), jnp.float32)
    cur = jnp.full((B,), S - 1, jnp.int32)
    ref = kvs.paged_attention_pallas(q, pool, table, cur, -1, pb=2,
                                     interpret=True)
    got = paged_attention_sharded(plan, q, pool, table, cur, -1)
    out["cases"][f"decode/{kvd}"] = bool(
        (np.asarray(ref) == np.asarray(got)).all())
    qc = jnp.asarray(rng.normal(size=(B, Hkv * G, C, Dh)), jnp.float32)
    q_pos = jnp.broadcast_to(
        jnp.arange(S - C, S, dtype=jnp.int32)[None], (B, C))
    ref_c = kvs.paged_attention_pallas_chunk(qc, pool, table, q_pos, -1,
                                             pb=2, qt=2, interpret=True)
    got_c = paged_attention_chunk_sharded(plan, qc, pool, table, q_pos, -1)
    out["cases"][f"chunk/{kvd}"] = bool(
        (np.asarray(ref_c) == np.asarray(got_c)).all())

# ---- serving-level: force Pallas for the smoke geometry, mesh tokens
# must equal single-device tokens (head-independent kernels + globally
# resolved choice => bit-identical logits)
import importlib
import sys as _sys
importlib.import_module("repro.kvstore.paged_attention")
pa = _sys.modules["repro.kvstore.paged_attention"]
calls = {"pallas": 0, "pallas_chunk": 0}
_orig, _orig_c = pa.paged_attention_pallas, pa.paged_attention_pallas_chunk
def counting(*a, **k):
    calls["pallas"] += 1
    return _orig(*a, **k)
def counting_c(*a, **k):
    calls["pallas_chunk"] += 1
    return _orig_c(*a, **k)
pa.paged_attention_pallas = counting
pa.paged_attention_pallas_chunk = counting_c

cfg = smoke("llama3-8b")
hkv, group, dh = cfg.n_kv, cfg.n_heads // cfg.n_kv, cfg.head_dim
npp_s = -(-48 // 16)           # session max_len=48, page_size=16
tune.record(tune.paged_key(hkv, group, dh, 16, npp_s, 2, False, True),
            tune.KernelChoice("pallas", (("pb", 2),)))
tune.record(tune.paged_chunk_key(hkv, group, dh, 16, npp_s, 2, 4, False,
                                 True),
            tune.KernelChoice("pallas", (("pb", 2), ("qt", 2))))
eng = engine(cfg, "dense")
_, ref_t = tokens(eng, REQS, chunk=4)
_, got_t = tokens(eng, REQS, mesh=mesh, chunk=4)
out["serving_parity"] = got_t == ref_t
out["pallas_calls"] = calls
print(json.dumps(out))
"""


def run_sub(script, timeout=1200):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# ------------------------------------------------------------ multi-device
def test_mesh_token_parity_across_modes():
    """(model=4, data=2) mesh greedy decode == single device, llama3 +
    mixtral MoE, dense(paged-bf16)/acsr/int8, chunked prefill + decode."""
    r = run_sub(PARITY_SCRIPT)
    assert r["n_devices"] == 8
    bad = [k for k, ok in r["cases"].items() if not ok]
    assert not bad, f"token mismatch on {bad}"
    # and it is REAL sharding, not replication
    assert r["params_sharded"]
    assert r["kv_heads_local"] * 4 == r["kv_heads_global"]
    # non-divisible dims fall back to replication instead of erroring
    assert r["fit_fallback"]
    # the int8 psum policy agrees with the single-device kernel
    assert r["psum_shape_ok"] and r["psum_max_err"] < 1e-4


def test_shard_map_pallas_kernels_token_identical():
    """The Pallas paged kernels (decode + chunk) running shard-local via
    shard_map on the 8-device interpret mesh are BIT-identical to the
    single-device kernels — and a serving session with the Pallas impl
    pinned in the tune cache produces token-identical greedy output on
    the mesh (no more forced-XLA fallback under a ShardingPlan)."""
    r = run_sub(PALLAS_KERNEL_SCRIPT)
    assert r["n_devices"] == 8
    bad = [k for k, ok in r["cases"].items() if not ok]
    assert not bad, f"shard-local kernel mismatch: {bad}"
    assert r["serving_parity"], \
        "mesh serving with Pallas paged kernels diverged"
    # the counters prove the Pallas path actually traced (both kernels)
    assert r["pallas_calls"]["pallas"] > 0
    assert r["pallas_calls"]["pallas_chunk"] > 0


def test_sharded_pool_allocator_invariants():
    """Preemption under page pressure on the mesh: same tokens as the
    single-device run, allocator free/used partition intact, no leaks."""
    r = run_sub(ALLOC_SCRIPT)
    assert r["match"], "preempted mesh serve diverged from single-device"
    assert r["completed"] == 6 and r["preempted"]
    assert r["free_list_unique"] and r["free_used_disjoint"]
    assert r["partition_exact"] and r["pages_leaked"] == 0


def test_mesh_sweep_n_model_chunk_policy():
    pytest.importorskip("hypothesis")
    r = run_sub(SWEEP_SCRIPT)
    assert r["failures"] == [], \
        f"(n_model, chunk, policy) mismatches: {r['failures']}"


# ------------------------------------------------------- single-device unit
def test_pad_leaf_and_local_view_roundtrip():
    jax = pytest.importorskip("jax")
    from repro.core import sparse_fc as sfc
    from repro.shard import partition
    rng = np.random.default_rng(0)
    w = rng.normal(size=(48, 32)) * (rng.random((48, 32)) < 0.3)
    leaf = sfc.compress(w, mode="acsr", density=0.3, block_rows=16)
    assert partition.row_axis_len(leaf) == 3           # 48 / 16
    padded = partition.pad_leaf(leaf, 4)
    assert partition.row_axis_len(padded) == 4
    assert padded.shape == (48, 32)                    # true rows kept
    # padded leaf still applies exactly (padding rows are inert)
    x = np.asarray(rng.normal(size=(2, 32)), np.float32)
    y0 = np.asarray(sfc.apply_fc(leaf, jax.numpy.asarray(x)))
    y1 = np.asarray(sfc.apply_fc(padded, jax.numpy.asarray(x)))
    assert y1.shape == (2, 48)
    np.testing.assert_allclose(y0, y1, rtol=1e-6)
    # local views tile the padded row space
    views = [partition.local_view(leaf, 4, shard=s) for s in range(4)]
    dense_parts = np.concatenate(
        [sfc.dense_equivalent(v) for v in views])[:48]
    np.testing.assert_allclose(dense_parts, sfc.dense_equivalent(leaf),
                               rtol=1e-6)


def test_int8_pad_and_apply():
    jax = pytest.importorskip("jax")
    from repro.core import sparse_fc as sfc
    from repro.shard import partition
    rng = np.random.default_rng(1)
    w = rng.normal(size=(30, 16))
    leaf = sfc.compress(w, mode="int8")
    padded = partition.pad_leaf(leaf, 8)               # 30 -> 32 rows
    x = jax.numpy.asarray(rng.normal(size=(3, 16)), "float32")
    b = jax.numpy.asarray(rng.normal(size=(30,)), "float32")
    y0 = np.asarray(sfc.apply_fc(leaf, x, bias=b, activation="relu"))
    y1 = np.asarray(sfc.apply_fc(padded, x, bias=b, activation="relu"))
    assert y1.shape == (3, 30)
    np.testing.assert_allclose(y0, y1, rtol=1e-6, atol=1e-6)


def test_plan_basics_single_device():
    jax = pytest.importorskip("jax")
    from repro.shard.plan import make_plan
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = make_plan(mesh)
    assert plan.tp == 1 and plan.dp == 1
    assert plan.policy_for("acsr") == "gather"
    with pytest.raises(ValueError):
        make_plan(jax.make_mesh((1,), ("data",)))


def test_make_host_mesh_validation():
    jax = pytest.importorskip("jax")
    from repro.launch.mesh import make_host_mesh
    n = jax.device_count()
    mesh = make_host_mesh()
    assert dict(zip(mesh.axis_names, mesh.devices.shape)) == \
        {"data": 1, "model": n}
    with pytest.raises(ValueError):
        make_host_mesh(n_model=n + 1)
    with pytest.raises(ValueError):
        make_host_mesh(n_model=0, n_data=n)


def test_compression_spec_shards_validation():
    from repro.api import CompressionSpec
    with pytest.raises(ValueError):
        CompressionSpec(shards=0)
    assert CompressionSpec(shards=4).shards == 4
