import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each admissible cell this AOT-compiles the real `train_step` /
`serve_step` (the same functions the trainer/engine jit) against
ShapeDtypeStruct inputs on the production meshes — proving the sharding
config is coherent (no mismatched collectives, divisibility holes, or
compile-time OOMs) without touching hardware — and records
memory_analysis / cost_analysis / per-collective bytes for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh single,multi [--attn-impl einsum] [--json out]
"""

import argparse
import json
import sys
import time
import traceback
from functools import partial

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.api import get_backend
from repro.configs import SHAPES, cell_supported, get, names
from repro.configs.shapes import input_specs
from repro.launch import mesh as mesh_lib
from repro.models import model as M
from repro.optim import adamw
from repro.roofline import analysis as RA
from repro.train import trainer


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))


def batch_specs(cfg, shape, dp, dpax):
    """PartitionSpecs for the input batch of this cell."""
    dp_ok = shape.batch % max(dp, 1) == 0 and dp > 1
    bspec = dpax if dp_ok else None
    specs = {}
    for k, v in input_specs(cfg, shape).items():
        specs[k] = P(bspec, *([None] * (len(v.shape) - 1)))
    return specs, dp_ok


def _cast_tree_bf16(shapes):
    import jax.numpy as jnp
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
        shapes)


def _lower(cfg, shape, mesh, attn_impl, remat, microbatches, dpax, dp,
           unroll, streamed_loss=False, cast_params=False,
           serve_bf16=False):
    bspecs, dp_ok = batch_specs(cfg, shape, dp, dpax)
    dp_spec = dpax if dp_ok else None
    mdict = mesh_lib.mesh_shape_dict(mesh)
    if shape.kind == "train":
        tc = trainer.TrainConfig(remat=remat, attn_impl=attn_impl,
                                 microbatches=microbatches,
                                 streamed_loss=streamed_loss,
                                 cast_params_bf16=cast_params)
        step = trainer.make_train_step(cfg, tc, dp_spec=dp_spec,
                                       unroll=unroll)
        state_shapes = jax.eval_shape(
            partial(trainer.init_state, cfg), jax.random.PRNGKey(0))
        sspecs = trainer.state_specs(cfg, mdict)
        in_sh = (_named(mesh, sspecs), _named(mesh, bspecs))
        # production semantics: the step donates its state buffers
        return jax.jit(step, in_shardings=in_sh,
                       donate_argnums=(0,)).lower(
            state_shapes, input_specs(cfg, shape))
    if shape.kind == "prefill":
        def prefill_step(params, batch):
            logits, _ = M.forward(cfg, params, batch, remat="none",
                                  attn_impl=attn_impl, dp_spec=dp_spec,
                                  unroll=unroll)
            return logits
        pspecs = M.param_specs(cfg, mdict)
        pshapes = jax.eval_shape(partial(M.init_params, cfg),
                                 jax.random.PRNGKey(0))
        in_sh = (_named(mesh, pspecs), _named(mesh, bspecs))
        return jax.jit(prefill_step, in_shardings=in_sh).lower(
            pshapes, input_specs(cfg, shape))
    # decode — the same step the serving facade compiles (repro.api)
    serve_step = get_backend("jax-dense").make_decode_step(cfg,
                                                           unroll=unroll)
    pspecs = M.param_specs(cfg, mdict)
    pshapes = jax.eval_shape(partial(M.init_params, cfg),
                             jax.random.PRNGKey(0))
    if serve_bf16:  # serving checkpoints are bf16 (§Perf)
        pshapes = _cast_tree_bf16(pshapes)
    st_shapes = jax.eval_shape(
        partial(M.init_decode_state, cfg, shape.batch, shape.seq))
    st_specs = M.state_specs(cfg, shape.batch, dp_ok, dpax)
    tok_spec = P(dpax if dp_ok else None)
    in_sh = (_named(mesh, pspecs), _named(mesh, st_specs),
             NamedSharding(mesh, tok_spec))
    # production semantics: the decode state is donated every step
    return jax.jit(serve_step, in_shardings=in_sh,
                   donate_argnums=(1,)).lower(
        pshapes, st_shapes, input_specs(cfg, shape)["tokens"])


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               attn_impl: str = "einsum", remat: str = "dots",
               microbatches: int = 1, verbose: bool = True,
               cost_unroll: bool = False, streamed_loss: bool = False,
               cast_params: bool = False, serve_bf16: bool = False):
    cfg = get(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip", "reason": why}
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multi" if multi_pod else "single"
    chips = mesh.devices.size
    dp = mesh_lib.dp_size(mesh)
    dpax = mesh_lib.dp_axes(mesh)

    t0 = time.perf_counter()
    with mesh:
        # the deliverable: the production (scanned) program must compile
        compiled = _lower(cfg, shape, mesh, attn_impl, remat, microbatches,
                          dpax, dp, unroll=False,
                          streamed_loss=streamed_loss,
                          cast_params=cast_params,
                          serve_bf16=serve_bf16).compile()
    t1 = time.perf_counter()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    unroll_s = None
    if cost_unroll:
        # roofline extraction: unrolled lowering so loop bodies are counted
        with mesh:
            compiled_u = _lower(cfg, shape, mesh, attn_impl, remat,
                                microbatches, dpax, dp, unroll=True,
                                streamed_loss=streamed_loss,
                                cast_params=cast_params,
                                serve_bf16=serve_bf16).compile()
        unroll_s = round(time.perf_counter() - t1, 1)
        cost = compiled_u.cost_analysis()
        hlo = compiled_u.as_text()
    roof = RA.from_compiled(arch, shape_name, mesh_name, chips, cost, hlo,
                            RA.model_flops(cfg, shape), mem)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "chips": chips,
        "compile_s": round(t1 - t0, 1), "unroll_compile_s": unroll_s,
        "hlo_flops": roof.hlo_flops,
        "hlo_bytes": roof.hlo_bytes,
        "coll_bytes": roof.coll_bytes,
        "model_flops": roof.model_flops,
        "t_compute": roof.t_compute, "t_memory": roof.t_memory,
        "t_collective": roof.t_collective,
        "bottleneck": roof.bottleneck,
        "useful_flops_frac": roof.useful_flops_frac,
        "roofline_frac": roof.roofline_frac,
        "arg_bytes": mem.argument_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "out_bytes": mem.output_size_in_bytes,
        "bytes_per_device": roof.bytes_per_device,
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: OK "
              f"({rec['compile_s']}s compile, "
              f"args {mem.argument_size_in_bytes/2**30:.2f} GiB/dev, "
              f"temp {mem.temp_size_in_bytes/2**30:.2f} GiB/dev)")
        print("         " + roof.row())
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--attn-impl", default="einsum")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--cost-unroll", action="store_true",
                    help="also lower unrolled for roofline cost extraction")
    ap.add_argument("--streamed-loss", action="store_true")
    ap.add_argument("--json", default=None, help="append records to file")
    args = ap.parse_args(argv)
    archs = names() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")

    records, failures = [], []
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                try:
                    rec = lower_cell(arch, shape, mesh_name == "multi",
                                     attn_impl=args.attn_impl,
                                     remat=args.remat,
                                     microbatches=args.microbatches,
                                     cost_unroll=args.cost_unroll,
                                     streamed_loss=args.streamed_loss)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "fail", "error": repr(e)[:500]}
                    failures.append(rec)
                records.append(rec)
                if args.json:
                    with open(args.json, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skip" for r in records)
    print(f"\n[dryrun] {n_ok} ok, {n_skip} skipped (documented), "
          f"{len(failures)} FAILED of {len(records)}")
    for f_ in failures:
        print("  FAIL:", f_["arch"], f_["shape"], f_["mesh"], f_["error"])
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
