"""Production training launcher.

Multi-host: every host runs this same script; `jax.distributed.initialize`
wires the pods together (env: COORDINATOR_ADDR, NUM_PROCESSES, PROCESS_ID).
The mesh/shardings are identical to the dry-run's — what compiled there
runs here.  Single host (no env): degrades to local devices for smoke use.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
      --steps 100 --global-batch 256 --seq 4096 [--multi-pod] \
      [--microbatches 4] [--grad-compression int8] [--ckpt-dir /ckpts]
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get
from repro.data.pipeline import DataIterator, PipelineConfig
from repro.launch import mesh as mesh_lib
from repro.optim.adamw import AdamWConfig
from repro.runtime.fault_tolerance import RestartLoop, StragglerDetector
from repro.train import trainer


def maybe_init_distributed():
    if "COORDINATOR_ADDR" in os.environ:
        jax.distributed.initialize(
            coordinator_address=os.environ["COORDINATOR_ADDR"],
            num_processes=int(os.environ["NUM_PROCESSES"]),
            process_id=int(os.environ["PROCESS_ID"]))
        return True
    return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--grad-compression", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--local-mesh", action="store_true",
                    help="use whatever local devices exist (smoke mode)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    distributed = maybe_init_distributed()
    cfg = get(args.arch)
    if args.local_mesh or (not distributed
                           and jax.device_count() < 256):
        n = jax.device_count()
        mesh = jax.make_mesh((1, n), ("data", "model"))
        print(f"[launch] local mesh 1x{n} (smoke mode)")
    else:
        mesh = mesh_lib.make_production_mesh(multi_pod=args.multi_pod)
        print(f"[launch] production mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")
    mdict = mesh_lib.mesh_shape_dict(mesh)
    dpax = mesh_lib.dp_axes(mesh)

    tc = trainer.TrainConfig(
        remat=args.remat, microbatches=args.microbatches,
        grad_compression=args.grad_compression,
        opt=AdamWConfig(lr=args.lr, total_steps=args.steps))
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    straggler = StragglerDetector()

    with mesh:
        sspecs = trainer.state_specs(cfg, mdict)
        named = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                             is_leaf=lambda x: isinstance(x, P))
        step_fn = jax.jit(
            trainer.make_train_step(cfg, tc, dp_spec=dpax),
            in_shardings=(named, None), donate_argnums=(0,))

        start = 0
        if mgr is not None and mgr.latest_step() is not None:
            template = jax.eval_shape(
                lambda k: trainer.init_state(cfg, k), jax.random.PRNGKey(0))
            host_template = jax.tree.map(
                lambda s: np.zeros(s.shape, s.dtype), template)
            state, extra = mgr.restore(host_template, shardings=named)
            start = extra["data"]["step"]
            print(f"[launch] restored step {start}")
        else:
            init = jax.jit(lambda k: trainer.init_state(cfg, k),
                           out_shardings=named)
            state = init(jax.random.PRNGKey(0))

        data = DataIterator(cfg, PipelineConfig(
            seed=0, global_batch=args.global_batch, seq_len=args.seq),
            start_step=start)

        def run_once(_resume):
            nonlocal state, data
            for i in range(start, args.steps):
                t0 = time.perf_counter()
                batch = {k: jnp.asarray(v) for k, v in next(data).items()}
                state, metrics = step_fn(state, batch)
                dt = time.perf_counter() - t0
                if straggler.record(dt):
                    print(f"[ft] straggler step ({dt:.2f}s)")
                if i % 10 == 0:
                    print(f"step {i} loss={float(metrics['loss']):.4f} "
                          f"({dt*1e3:.0f} ms)")
                if mgr and (i + 1) % args.ckpt_every == 0:
                    mgr.save(i + 1, state, extra={"data": data.state()})

        if mgr is not None:
            RestartLoop(mgr).supervise(run_once)
            mgr.wait()
        else:
            run_once(None)


if __name__ == "__main__":
    main()
