"""Block definitions + scan-over-layers stacks for every arch family.

One homogeneous block per family so the layer stack is a single
`jax.lax.scan` over stacked parameters — compact HLO (fast AOT compiles for
the 512-device dry-run), natural remat boundaries, and per-layer variation
(attention windows) threaded as scanned data, not structure.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import kvstore as kvs
from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import kvcache as kvc
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.layers import (COMPUTE_DTYPE, layer_norm, layer_norm_init,
                                 mlp, mlp_init, rms_norm, rms_norm_init)


def _norm(cfg: ArchConfig):
    return rms_norm if cfg.norm == "rms" else layer_norm


def _norm_init(cfg: ArchConfig, d: int):
    return rms_norm_init(d) if cfg.norm == "rms" else layer_norm_init(d)


# -------------------------------------------------------------- layer init
def layer_init(cfg: ArchConfig, key) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 8)
    if cfg.family == "rwkv6":
        return {"ln1": _norm_init(cfg, d), "ln2": _norm_init(cfg, d),
                "tm": ssm.rwkv6_time_mix_init(ks[0], d, cfg.rwkv_head_dim),
                "cm": ssm.rwkv6_channel_mix_init(ks[1], d, f)}
    p = {"ln1": _norm_init(cfg, d), "ln2": _norm_init(cfg, d),
         "attn": attn.attn_init(ks[0], d, cfg.n_heads, cfg.n_kv,
                                cfg.head_dim, cfg.qkv_bias)}
    if cfg.post_norms:
        p["ln1p"] = _norm_init(cfg, d)
        p["ln2p"] = _norm_init(cfg, d)
    if cfg.moe:
        p["moe"] = moe_mod.moe_init(ks[1], d, f, cfg.moe.n_experts)
    else:
        p["mlp"] = mlp_init(ks[1], d, f, cfg.gated_mlp, cfg.act)
    if cfg.family == "hymba":
        p["mamba"] = ssm.mamba_init(ks[2], d, cfg.ssm_state)
        p["ln_ssm"] = _norm_init(cfg, d)
    return p


def stack_init(cfg: ArchConfig, key):
    layers = [layer_init(cfg, jax.random.fold_in(key, i))
              for i in range(cfg.n_layers)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


# ------------------------------------------------------------ train blocks
def _attn_kwargs(cfg: ArchConfig):
    return dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.head_dim,
                cap=cfg.attn_softcap, theta=cfg.rope_theta,
                scale=cfg.attn_scale)


def block_forward(cfg: ArchConfig, p: Dict, x, positions, window,
                  attn_impl: str = "einsum",
                  unroll: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One layer, training/prefill. Returns (x, aux_loss)."""
    nrm = _norm(cfg)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "rwkv6":
        b, _, d = x.shape
        zeros = jnp.zeros((b, d), x.dtype)
        h, _ = ssm.rwkv6_time_mix(p["tm"], nrm(x, p["ln1"]), zeros,
                                  d_head=cfg.rwkv_head_dim)
        x = x + h
        h, _ = ssm.rwkv6_channel_mix(p["cm"], nrm(x, p["ln2"]), zeros)
        return x + h, aux

    h = attn.attn_apply(p["attn"], nrm(x, p["ln1"]), positions,
                        window=window, causal=cfg.causal,
                        impl=attn_impl, unroll=unroll, **_attn_kwargs(cfg))
    if cfg.family == "hymba":
        hs = ssm.mamba_apply(p["mamba"], nrm(x, p["ln1"]),
                             state=cfg.ssm_state)
        h = 0.5 * (nrm(h, p["ln_ssm"]) + hs.astype(COMPUTE_DTYPE))
    if cfg.post_norms:
        h = nrm(h, p["ln1p"])
    x = x + h
    if cfg.moe:
        h, aux = moe_mod.moe_apply(
            p["moe"], nrm(x, p["ln2"]), n_experts=cfg.moe.n_experts,
            top_k=cfg.moe.top_k, group_size=cfg.moe.group_size,
            capacity_factor=cfg.moe.capacity_factor)
    else:
        h = mlp(nrm(x, p["ln2"]), p["mlp"], cfg.act)
    if cfg.post_norms:
        h = nrm(h, p["ln2p"])
    return x + h, aux


def stack_forward(cfg: ArchConfig, stacked: Dict, x, positions,
                  remat: str = "dots", attn_impl: str = "einsum",
                  unroll: bool = False):
    """Scan the layer stack. Returns (x, total_aux).

    unroll=True inlines every layer (used by the roofline cost extraction:
    XLA cost_analysis counts a while-loop body ONCE, so the scanned form
    under-reports flops by ~n_layers)."""
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)

    def body(carry, inp):
        xc, auxc = carry
        p, win = inp
        xo, aux = block_forward(cfg, p, xc, positions, win, attn_impl,
                                unroll=unroll)
        return (xo, auxc + aux), None

    if remat == "full":
        body = jax.checkpoint(body, policy=None)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (stacked, windows),
                               unroll=cfg.n_layers if unroll else 1)
    return x, aux


# ----------------------------------------------------------- decode blocks
def _any_global(cfg: ArchConfig) -> bool:
    return any(w < 0 for w in cfg.layer_windows())


def init_layer_state(cfg: ArchConfig, batch: int, slots_full: int,
                     kv_cache: str = "full", page_size: int = 16,
                     kv_pool_pages: Optional[int] = None,
                     kv_dtype: str = "int8") -> Dict:
    """Per-layer decode state template (one layer; caller stacks L)."""
    if cfg.family == "rwkv6":
        h = cfg.d_model // cfg.rwkv_head_dim
        return {"tm_prev": jnp.zeros((batch, cfg.d_model), COMPUTE_DTYPE),
                "cm_prev": jnp.zeros((batch, cfg.d_model), COMPUTE_DTYPE),
                "S": jnp.zeros((batch, h, cfg.rwkv_head_dim,
                                cfg.rwkv_head_dim), jnp.float32)}
    st = {}
    if kv_cache == "paged":
        # O(used pages): every layer owns pool arrays of the same shape,
        # all indexed through the one shared per-sequence page table
        npp = -(-slots_full // page_size)
        n_pages = (1 + batch * npp if kv_pool_pages is None
                   else kv_pool_pages)
        st["kv"] = kvs.init_pool(n_pages, cfg.n_kv, page_size,
                                 cfg.head_dim, kv_dtype=kv_dtype)
    else:
        # local layers ring-cache `window` slots; global layers need
        # slots_full.  scan homogeneity: all layers share the max slot
        # count, rings mask.
        slots = slots_full if _any_global(cfg) \
            else min(cfg.window, slots_full)
        st["kv"] = kvc.init_cache(batch, cfg.n_kv, slots, cfg.head_dim)
    if cfg.family == "hymba":
        st["mamba"] = {"conv": jnp.zeros((batch, 3, cfg.d_model),
                                         jnp.float32),
                       "h": jnp.zeros((batch, cfg.d_model, cfg.ssm_state),
                                      jnp.float32)}
    return st


def init_stack_state(cfg: ArchConfig, batch: int, slots_full: int,
                     **kv_kw):
    one = init_layer_state(cfg, batch, slots_full, **kv_kw)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape),
        one)


def block_decode(cfg: ArchConfig, p: Dict, st: Dict, x, cur_pos, window,
                 page_table=None, plan=None):
    """One layer, one token. x [B,1,D].  ``plan`` (shard.ShardingPlan)
    threads through to every projection so compressed FC runs
    shard-local tensor-parallel; None = the replicated single-device
    path, byte-identical to before."""
    nrm = _norm(cfg)
    if cfg.family == "rwkv6":
        tm_st = {"prev": st["tm_prev"], "S": st["S"]}
        tm_st, h = ssm.rwkv6_time_mix_decode(p["tm"], tm_st,
                                             nrm(x, p["ln1"]),
                                             d_head=cfg.rwkv_head_dim)
        x = x + h
        cm_prev, h = ssm.rwkv6_channel_mix_decode(p["cm"], st["cm_prev"],
                                                  nrm(x, p["ln2"]))
        return {"tm_prev": tm_st["prev"], "cm_prev": cm_prev,
                "S": tm_st["S"]}, x + h

    if page_table is not None:
        cache, h = attn.attn_decode_paged(p["attn"], st["kv"], page_table,
                                          nrm(x, p["ln1"]), cur_pos,
                                          window=window, plan=plan,
                                          **_attn_kwargs(cfg))
    else:
        cache, h = attn.attn_decode(p["attn"], st["kv"], nrm(x, p["ln1"]),
                                    cur_pos, window=window,
                                    ring=not _any_global(cfg), plan=plan,
                                    **_attn_kwargs(cfg))
    new_st = dict(st)
    new_st["kv"] = cache
    if cfg.family == "hymba":
        mst, hs = ssm.mamba_decode(p["mamba"], st["mamba"],
                                   nrm(x, p["ln1"]), state=cfg.ssm_state)
        new_st["mamba"] = mst
        h = 0.5 * (nrm(h, p["ln_ssm"]) + hs.astype(COMPUTE_DTYPE))
    if cfg.post_norms:
        h = nrm(h, p["ln1p"])
    x = x + h
    if cfg.moe:
        h, _ = moe_mod.moe_apply(
            p["moe"], nrm(x, p["ln2"]), n_experts=cfg.moe.n_experts,
            top_k=cfg.moe.top_k, group_size=cfg.moe.group_size,
            capacity_factor=cfg.moe.capacity_factor)
    else:
        h = mlp(nrm(x, p["ln2"]), p["mlp"], cfg.act, plan=plan)
    if cfg.post_norms:
        h = nrm(h, p["ln2p"])
    return new_st, x + h


def stack_decode(cfg: ArchConfig, stacked: Dict, states, x, cur_pos,
                 unroll: bool = False, page_table=None, plan=None):
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)

    def body(xc, inp):
        p, st, win = inp
        new_st, xo = block_decode(cfg, p, st, xc, cur_pos, win,
                                  page_table=page_table, plan=plan)
        return xo, new_st

    x, new_states = jax.lax.scan(body, x, (stacked, states, windows),
                                 unroll=cfg.n_layers if unroll else 1)
    return new_states, x
