"""Three-term roofline from compiled dry-run artifacts (TPU v5e targets).

  compute    = HLO_FLOPs          / (chips × 197 TFLOP/s bf16)
  memory     = HLO_bytes_accessed / (chips × 819 GB/s HBM)
  collective = collective_bytes   / (chips × 50 GB/s/link ICI)

cost_analysis() provides FLOPs and bytes; collective bytes are parsed from
the compiled/optimized HLO text by summing the operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
MODEL_FLOPS (6·N·D train, 2·N·D inference; N_active for MoE) is compared
against HLO FLOPs to expose remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.configs.base import ArchConfig
from repro.configs.shapes import Shape

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"(bf16|f64|f32|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                      r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def _type_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_OP_RE = re.compile(
    r"=\s*(?P<res>\([^)]*\)|[a-z0-9_]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(line: str, default: int = 2) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes-on-the-wire per collective kind, from the optimized
    (per-partition) HLO.  Result shapes are on the lhs; operand sizes follow
    from the op semantics, and wire bytes use ring formulas:

      all-reduce       operand = result;  wire = 2·size·(g-1)/g
      all-gather       operand = result/g; wire = size·(g-1)/g  (size=result)
      reduce-scatter   operand = result·g; wire = operand·(g-1)/g
      all-to-all       operand = result;  wire = size·(g-1)/g
      collective-permute operand = result; wire = size
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or m.group("suffix") == "-done":
            continue
        kind = m.group("kind")
        size = sum(_type_bytes(t.group(1), t.group(2))
                   for t in _TYPE_RE.finditer(m.group("res")))
        g = _group_size(line)
        ring = (g - 1) / g
        if kind == "all-reduce":
            wire = 2 * size * ring
        elif kind == "all-gather":
            wire = size * ring
        elif kind == "reduce-scatter":
            wire = size * g * ring
        elif kind == "all-to-all":
            wire = size * ring
        else:  # collective-permute
            wire = size
        out[kind] += int(wire)
    return out


def model_flops(cfg: ArchConfig, shape: Shape) -> float:
    """6·N·D (train) / 2·N·D (inference) with N = active non-embedding-ish
    params (standard MFU convention; attention FLOPs excluded → the ratio
    vs HLO slightly undercounts, noted in EXPERIMENTS)."""
    n = cfg.active_params_count()
    if shape.kind == "train":
        return 6.0 * n * shape.batch * shape.seq
    if shape.kind == "prefill":
        return 2.0 * n * shape.batch * shape.seq
    return 2.0 * n * shape.batch  # decode: one token per sequence


@dataclasses.dataclass
class Roofline:
    """NOTE on units: XLA cost_analysis() of an SPMD-partitioned module
    reports PER-PARTITION flops/bytes, and compiled.as_text() is the
    per-device program — so hlo_flops / hlo_bytes / coll_bytes here are all
    per-chip, and the spec's formula `HLO_FLOPs / (chips × peak)` is applied
    as per-chip / peak.  model_flops stays GLOBAL (divided by chips where
    compared).  The scanned layer stack under-counts loop bodies ×n_layers;
    the dry-run therefore extracts costs from an UNROLLED lowering."""
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float             # per chip
    hlo_bytes: float             # per chip
    coll_bytes: Dict[str, int]   # per chip
    model_flops: float           # global
    bytes_per_device: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return sum(self.coll_bytes.values()) / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> float:
        return (self.model_flops / self.chips) / max(self.hlo_flops, 1.0)

    @property
    def roofline_frac(self) -> float:
        """Fraction of the compute roofline achievable: useful compute time
        over the max term (what an ideal overlap schedule is limited by)."""
        t_useful = self.model_flops / (self.chips * PEAK_FLOPS)
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / max(t_bound, 1e-30)

    def row(self) -> str:
        cb = sum(self.coll_bytes.values())
        return (f"{self.arch:18s} {self.shape:11s} {self.mesh:9s} "
                f"compute={self.t_compute*1e3:9.3f}ms "
                f"memory={self.t_memory*1e3:9.3f}ms "
                f"coll={self.t_collective*1e3:9.3f}ms "
                f"[{self.bottleneck:10s}] useful={self.useful_flops_frac:6.1%} "
                f"roofline={self.roofline_frac:6.1%} "
                f"collB={cb/1e9:8.3f}G")


def from_compiled(arch: str, shape_name: str, mesh_name: str, chips: int,
                  cost: Dict, hlo_text: str, mflops: float,
                  mem=None) -> Roofline:
    if isinstance(cost, (list, tuple)):  # jax >= 0.4.35 wraps it in a list
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = parse_collective_bytes(hlo_text)
    bpd = None
    if mem is not None:
        bpd = float(mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes)
    return Roofline(arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
                    hlo_flops=flops, hlo_bytes=byts, coll_bytes=coll,
                    model_flops=mflops, bytes_per_device=bpd)
