"""Table 1 reproduction: AIDA vs EIE (peak perf, throughput, power, EE,
area, memory) via the calibrated analytical simulators.

Paper claims (abstract + §4.2): 14.5× peak performance, 2.5× throughput,
7.7× worse energy efficiency.  Note: the paper's own Table 1 numbers imply
2756/206 = 13.4× EE, not the 7.7× quoted in the text — the text figure only
reproduces with EIE's 45nm (unscaled) power; both are reported.
"""
from __future__ import annotations

from repro.core import aida_sim as S
from repro.core import eie_sim as E

PAPER = {
    "aida_pp_gops": 1474.0, "aida_thrpt": 204515.0, "aida_power": 7.15,
    "aida_ee": 206.0, "aida_area": 44.5, "aida_mem_mb": 6.4,
    "eie_pp_gops": 102.0, "eie_thrpt": 81967.0, "eie_ee": 2756.0,
    "pp_ratio": 14.5, "thrpt_ratio": 2.5,
}


def run(log=print) -> dict:
    a = S.aida_table1()
    e = E.eie_table1()
    rows = [
        ("AIDA PP (GOP/s)", a["pp_gops"], PAPER["aida_pp_gops"]),
        ("AIDA thrpt (inf/s)", a["thrpt_inf_s"], PAPER["aida_thrpt"]),
        ("AIDA power (W)", a["power_w"], PAPER["aida_power"]),
        ("AIDA EE (GOP/J)", a["ee_gop_per_j"], PAPER["aida_ee"]),
        ("AIDA area (mm^2, all-resident)", a["area_mm2"], PAPER["aida_area"]),
        ("AIDA area (mm^2, max-layer)", a["area_mm2_maxlayer"],
         PAPER["aida_area"]),
        ("AIDA memory (MB)", a["memory_mb"], PAPER["aida_mem_mb"]),
        ("EIE PP (GOP/s)", e["pp_gops"], PAPER["eie_pp_gops"]),
        ("EIE thrpt (inf/s)", e["thrpt_inf_s"], PAPER["eie_thrpt"]),
        ("PP ratio (x)", a["pp_gops"] / e["pp_gops"], PAPER["pp_ratio"]),
        ("Thrpt ratio (x)", a["thrpt_inf_s"] / e["thrpt_inf_s"],
         PAPER["thrpt_ratio"]),
        ("EE ratio (x, table convention)",
         PAPER["eie_ee"] / a["ee_gop_per_j"], 13.4),
    ]
    log(f"{'metric':34s} {'model':>12s} {'paper':>12s} {'err':>8s}")
    out = {}
    for name, got, want in rows:
        err = (got - want) / want
        log(f"{name:34s} {got:12.1f} {want:12.1f} {err:+8.1%}")
        out[name] = (got, want, err)
    return out


def validate() -> bool:
    out = run(log=lambda *a: None)
    checks = [
        abs(out["AIDA PP (GOP/s)"][2]) < 0.15,
        abs(out["AIDA thrpt (inf/s)"][2]) < 0.10,
        abs(out["AIDA power (W)"][2]) < 0.10,
        abs(out["AIDA EE (GOP/J)"][2]) < 0.15,
        abs(out["PP ratio (x)"][2]) < 0.20,
        abs(out["Thrpt ratio (x)"][2]) < 0.15,
    ]
    return all(checks)


if __name__ == "__main__":
    run()
    print("\nvalidates paper claims:", validate())
