"""Blockwise online-softmax attention (flash) — fwd + bwd Pallas kernels.

Prefill/training hot path.  Features needed by the assigned archs:
  * GQA (kv heads < q heads) via BlockSpec index folding — no k/v repeat,
  * causal masking, sliding-window (SWA: danube/mixtral/hymba, gemma2 local),
  * logit softcapping (gemma2), custom scale (gemma2 query_pre_attn_scalar).

Grid layout (canonical Pallas revisiting pattern): (B, H, nq, nk) with the
kv index innermost; running (m, l, acc) live in VMEM scratch and the output
block is finalized on the last kv step.  The backward pass is two kernels
(dq over kv blocks; dk/dv over group×query blocks) using the saved LSE plus
delta = rowsum(dO∘O), the standard recompute formulation (no O(T²) residual).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mask(iq, jk, bq, bk, tq, causal, window):
    qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    ki = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = jnp.ones((bq, bk), jnp.bool_)
    del tq
    if causal:
        m &= ki <= qi
    if window is not None:
        m &= ki > qi - window
    return m


def _scores(q, k, scale, softcap):
    s = jax.lax.dot_general(q.astype(jnp.float32), k.astype(jnp.float32),
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    return s


# ------------------------------------------------------------------ fwd
def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr, *,
                scale, causal, window, softcap, nk, bq, bk, tq):
    iq, jk = pl.program_id(2), pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    s = _scores(q, k, scale, softcap)
    s = jnp.where(_mask(iq, jk, bq, bk, tq, causal, window), s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(jk == nk - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / l)[None, None]
        lse_ref[...] = (m_scr[...] + jnp.log(l))[None, None, :, 0][..., None]


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "scale",
                              "bq", "bk", "interpret"))
def flash_attention_fwd(q, k, v, *, causal=True, window=None, softcap=None,
                        scale=None, bq=128, bk=128, interpret=True):
    """q [B,H,T,D], k/v [B,Hkv,T,D] -> (o [B,H,T,D] f32, lse [B,H,T,1])."""
    b, h, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    assert tq == tk, "self-attention kernel (decode uses the JAX path)"
    group = h // hkv
    scale = (d ** -0.5) if scale is None else scale
    bq, bk = min(bq, tq), min(bk, tk)
    assert tq % bq == 0 and tk % bk == 0
    nq, nk = tq // bq, tk // bk
    kern = functools.partial(_fwd_kernel, scale=scale, causal=causal,
                             window=window, softcap=softcap, nk=nk,
                             bq=bq, bk=bk, tq=tq)
    o, lse = pl.pallas_call(
        kern,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, iq, jk: (bi, hi, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, iq, jk, g=group: (bi, hi // g, jk, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, iq, jk, g=group: (bi, hi // g, jk, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, iq, jk: (bi, hi, iq, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda bi, hi, iq, jk: (bi, hi, iq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, tq, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, tq, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o, lse


# ------------------------------------------------------------------ bwd
def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref, dq_ref, acc_scr,
               *, scale, causal, window, softcap, nk, bq, bk, tq):
    iq, jk = pl.program_id(2), pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]
    delta = dl_ref[0, 0]
    s_pre = jax.lax.dot_general(q.astype(jnp.float32), k.astype(jnp.float32),
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
    s = softcap * jnp.tanh(s_pre / softcap) if softcap is not None else s_pre
    msk = _mask(iq, jk, bq, bk, tq, causal, window)
    p = jnp.exp(jnp.where(msk, s, NEG_INF) - lse)
    dp = jax.lax.dot_general(do, v.astype(jnp.float32),
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta)
    if softcap is not None:
        ds = ds * (1.0 - (s / softcap) ** 2)  # d softcap / d s_pre
    acc_scr[...] += jax.lax.dot_general(
        ds, k.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale

    @pl.when(jk == nk - 1)
    def _done():
        dq_ref[...] = acc_scr[...][None, None]


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *,
                scale, causal, window, softcap, group, nq, bq, bk, tq):
    jk, g, iq = pl.program_id(2), pl.program_id(3), pl.program_id(4)

    @pl.when((g == 0) & (iq == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0, 0]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]
    delta = dl_ref[0, 0]
    s_pre = jax.lax.dot_general(q.astype(jnp.float32), k.astype(jnp.float32),
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
    s = softcap * jnp.tanh(s_pre / softcap) if softcap is not None else s_pre
    msk = _mask(iq, jk, bq, bk, tq, causal, window)
    p = jnp.exp(jnp.where(msk, s, NEG_INF) - lse)          # [bq, bk]
    dv_scr[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # [bk, d]
    dp = jax.lax.dot_general(do, v.astype(jnp.float32),
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta)
    if softcap is not None:
        ds = ds * (1.0 - (s / softcap) ** 2)
    dk_scr[...] += jax.lax.dot_general(
        ds, q.astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale        # [bk, d]

    @pl.when((g == group - 1) & (iq == nq - 1))
    def _done():
        dk_ref[...] = dk_scr[...][None, None]
        dv_ref[...] = dv_scr[...][None, None]


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "scale",
                              "bq", "bk", "interpret"))
def flash_attention_bwd(q, k, v, o, lse, do, *, causal=True, window=None,
                        softcap=None, scale=None, bq=128, bk=128,
                        interpret=True):
    b, h, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    group = h // hkv
    scale = (d ** -0.5) if scale is None else scale
    bq, bk = min(bq, tq), min(bk, tk)
    nq, nk = tq // bq, tk // bk
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1, keepdims=True)                # [B,H,T,1]

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, nk=nk,
                          bq=bq, bk=bk, tq=tq),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, iq, jk: (bi, hi, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, iq, jk, g=group: (bi, hi // g, jk, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, iq, jk, g=group: (bi, hi // g, jk, 0)),
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, iq, jk: (bi, hi, iq, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda bi, hi, iq, jk: (bi, hi, iq, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda bi, hi, iq, jk: (bi, hi, iq, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bi, hi, iq, jk: (bi, hi, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, tq, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, group=group,
                          nq=nq, bq=bq, bk=bk, tq=tq),
        grid=(b, hkv, nk, group, nq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda bi, hk, jk, g, iq, G=group: (bi, hk * G + g, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hk, jk, g, iq: (bi, hk, jk, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hk, jk, g, iq: (bi, hk, jk, 0)),
            pl.BlockSpec((1, 1, bq, d),
                         lambda bi, hk, jk, g, iq, G=group: (bi, hk * G + g, iq, 0)),
            pl.BlockSpec((1, 1, bq, 1),
                         lambda bi, hk, jk, g, iq, G=group: (bi, hk * G + g, iq, 0)),
            pl.BlockSpec((1, 1, bq, 1),
                         lambda bi, hk, jk, g, iq, G=group: (bi, hk * G + g, iq, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, d), lambda bi, hk, jk, g, iq: (bi, hk, jk, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hk, jk, g, iq: (bi, hk, jk, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, tk, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, tk, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bk, d), jnp.float32),
                        pltpu.VMEM((bk, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
