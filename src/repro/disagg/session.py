"""Disaggregated prefill/decode serving: two engine roles, one model.

The co-located `api.Session` time-slices prefill chunks and decode steps
through one batch on one pool, so a long prompt admitted mid-stream
stalls every decoder sharing the batch (TTFT and TPOT fight for the same
step budget).  Disaggregation splits the session into two *roles*:

* a **prefill role** (`PrefillSession`) that only runs prompt
  processing — its own slots, its own `PagedKV` pool and allocator,
  chunked prefill at the configured chunk size.  The step it would have
  emitted the first token, it *hands the request off* instead: the
  sampled first token, the lifecycle record, and the prompt's pages
  leave the role through the router's handoff queue.
* a **decode role** (`DecodeSession`) that only runs continuous-batching
  decode — admission happens from the handoff queue, never from the
  request queue.  Admission allocates fresh decode-pool pages, copies
  the prompt pages over (`disagg.migrate` — bf16 bit-exact, int8
  codes+scales verbatim), remaps the slot's page table, and resumes at
  the handoff position.  Decode-role admission *reserves* every page a
  request can ever need, so decoders are never preempted: pool pressure
  propagates backwards as back-pressure on prefill admission
  (`DisaggRouter`) instead of forwards as wasted recompute.

`DisaggSession` owns both roles plus the router and drives them on a
shared tick: each tick runs at most one decode step and one prefill
step (the two batches would overlap on disjoint devices in a real
deployment — and do, when the roles are built on disjoint meshes).
Because greedy sampling is deterministic, pages migrate bit-exact, and
decode never preempts, the disaggregated token streams are identical to
the co-located paged engine's for every scheduling order.

Requires a paged KV cache on an arch whose per-request state lives
entirely in KV pages (`sched.supports_chunked_prefill`): recurrent
per-token state (rwkv6/hymba) cannot ride a page migration.
"""
from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from typing import Callable, Deque, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro import kvstore as kvs
from repro import obs as obs_mod
from repro import resil as rsl
from repro import sched as schd
from repro.api.session import Request, Result, Session, _unserved_record
from repro.disagg.migrate import Handoff, migrate_kv
from repro.disagg.router import DisaggRouter


@dataclasses.dataclass
class DisaggConfig:
    """Two-role topology knobs.  Pool sizes default to the engine-side
    heuristics when None; ``max_backlog=None`` tracks decode_slots (one
    queued handoff per decode slot before prefill admission stalls)."""
    prefill_slots: int = 2
    decode_slots: int = 4
    prefill_pool_pages: Optional[int] = None
    decode_pool_pages: Optional[int] = None
    max_backlog: Optional[int] = None
    prefill_devices: Optional[int] = None   # mesh roles (launch.mesh)
    decode_devices: Optional[int] = None

    def __post_init__(self):
        if self.prefill_slots < 1 or self.decode_slots < 1:
            raise ValueError("each role needs at least one batch slot")
        if (self.prefill_devices is None) != (self.decode_devices is None):
            raise ValueError("set prefill_devices and decode_devices "
                             "together (or neither)")

    @classmethod
    def coerce(cls, val) -> "DisaggConfig":
        if val is None or val is True:
            return cls()
        if isinstance(val, cls):
            return val
        if isinstance(val, dict):
            return cls(**val)
        raise TypeError(f"cannot make a DisaggConfig from {val!r}")


class PrefillSession(Session):
    """The prefill role: a Session whose scheduler is the shared router
    and whose requests leave through the handoff queue the moment they
    emit their first token.  ``max_new == 1`` requests never reach the
    decode role at all — their single token completes here."""

    def __init__(self, *args, router: DisaggRouter,
                 on_handoff: Callable[[Handoff], None], **kw):
        kw["scheduler"] = router.cfg
        super().__init__(*args, **kw)
        if self.kv_cache != "paged" or \
                not schd.supports_chunked_prefill(self.cfg):
            raise ValueError(
                "disaggregated serving needs a paged KV cache on an arch "
                "whose per-request state is entirely KV pages "
                f"(family {self.cfg.family!r} keeps per-token recurrent "
                "state that cannot ride a page migration)")
        self.sched = router            # same cfg, shared queue + backlog
        if self.tracer.enabled:
            self._wire_obs()           # re-attach hooks to the router
        self._on_handoff = on_handoff
        self.tick = 0                  # orchestrator clock (stamps handoffs)

    def _page_need(self, entry: schd.SchedEntry) -> int:
        # prompt-only residency: generated tokens land in the decode pool
        req = entry.req
        return schd.scheduler.page_need(
            len(req.prompt) + len(entry.out), 0, self.max_len,
            self.page_size)

    def _emit(self, i: int, logits_i: np.ndarray, now: float):
        entry = self.slot_entry[i]
        super()._emit(i, logits_i, now)
        # every prefill-role emit IS a first token (tick-denominated
        # twin of the record's first_token_step stamp); a retried entry
        # keeps its original stamp (TTFT measures the first delivery)
        if entry.record.get("first_token_tick") is None:
            entry.record["first_token_tick"] = self.tick
        if self.slot_entry[i] is None:
            return                     # max_new == 1: finished at prefill
        # first token emitted — detach the slot and hand the request off.
        # Prompt pages get pinned into the prefix cache first (the slot
        # row is about to be cleared), then ownership of the row moves to
        # the Handoff: the table is wiped WITHOUT freeing, and the
        # orchestrator frees the prefill-side refs once migration lands.
        if self.prefix is not None:
            self._insert_slot_prefix(i, entry)
        entry.out = list(self.slot_out[i])
        pages = [int(p) for p in self.host_table[i]]
        self.host_table[i] = -1
        self.state["page_table"] = self.state["page_table"].at[i].set(
            jnp.int32(kvs.NO_PAGE))
        rec = entry.record
        rec["prefill_done_time"] = now
        rec["prefill_done_tick"] = self.tick
        self.slot_entry[i] = None
        self.slot_pending[i] = []
        self.slot_out[i] = []
        self._on_handoff(Handoff(entry=entry, pages=pages,
                                 pos=self.slot_pos[i], tick=self.tick))


class DecodeSession(Session):
    """The decode role: a Session that never touches its own request
    queue — slots fill from handoffs, and admission reserves the full
    worst-case page need so running decoders are never preempted."""

    def __init__(self, *args, **kw):
        kw["scheduler"] = {"policy": "fifo", "chunk": 1}
        super().__init__(*args, **kw)
        assert self.kv_cache == "paged"
        self.stats.update({"handoffs": 0, "migrated_pages": 0,
                           "migrated_bytes": 0})

    def _fits(self, entry: schd.SchedEntry) -> bool:
        # resil fallback admission (co-located prefill on the decode
        # role) must honor the same reservation discipline as handoffs —
        # otherwise a fallback prompt could steal pages an admitted
        # decoder is guaranteed, making decode preemption possible again
        return self._page_need(entry) <= \
            self.alloc.available - self._reserved_future()

    # ------------------------------------------------------- admission
    def _reserved_future(self) -> int:
        """Pages the active slots may still allocate, worst case.  Holes
        reclaimed by SWA only shrink the real number — counting held
        pages from the table keeps this an overestimate."""
        res = 0
        for i, entry in enumerate(self.slot_entry):
            if entry is None:
                continue
            held = int((self.host_table[i] >= 0).sum())
            res += max(0, self._page_need(entry) - held)
        return res

    def fits_handoff(self, h: Handoff) -> bool:
        """Worst-case admission: the request's total page need must fit
        what is free AFTER honoring every admitted decoder's outstanding
        reservation — this is what makes decode OutOfPages (and hence
        decode preemption) impossible."""
        need = self._page_need(h.entry)
        return need <= self.alloc.available - self._reserved_future()

    def admit_handoff(self, i: int, h: Handoff, src_state: dict,
                      now: Optional[float] = None, tick: int = 0) -> int:
        """Install handoff ``h`` into free slot ``i``: allocate decode
        pages, migrate the prompt's KV, remap the table, resume at
        ``h.pos``.  Returns migrated bytes.  All-or-nothing: allocation
        is atomic (`alloc_many`) and the table is written only after the
        copy, so a failure cannot strand half a request."""
        assert self.slot_entry[i] is None
        entry = h.entry
        live = h.live()
        dst = self.alloc.alloc_many(len(live))
        sh = self._state_sh["layers"]["kv"] if self._state_sh else None
        self.state, moved = migrate_kv(
            src_state, self.state, [p for _, p in live], dst,
            dst_shardings=sh)
        self._reset_slot_state(i)      # clears table row, pos, slot leaves
        row = np.full(self.host_table.shape[1], -1, np.int64)
        for (j, _), pid in zip(live, dst):
            row[j] = pid
        self.host_table[i] = row
        self.state["page_table"] = self.state["page_table"].at[i].set(
            jnp.asarray(np.where(row >= 0, row, kvs.NO_PAGE), jnp.int32))
        self.slot_pos[i] = h.pos
        self.state["pos"] = self.state["pos"].at[i].set(h.pos)
        self.slot_entry[i] = entry
        self.slot_out[i] = list(entry.out)
        self.slot_pending[i] = []
        self.slot_cache_j[i] = 0
        entry.seq = self.sched._seq    # admission age (youngest)
        self.sched._seq += 1
        now = time.perf_counter() if now is None else now
        rec = entry.record
        rec["handoff_latency_s"] = now - rec["prefill_done_time"]
        rec["handoff_ticks"] = tick - rec["prefill_done_tick"]
        rec["migrated_pages"] = len(live)
        rec["migrated_bytes"] = moved
        self.stats["fills"] += 1
        self.stats["handoffs"] += 1
        self.stats["migrated_pages"] += len(live)
        self.stats["migrated_bytes"] += moved
        self.stats["page_allocs"] = self.alloc.total_allocs
        self.stats["pages_in_use"] = self.alloc.in_use
        self.stats["pages_peak"] = self.alloc.peak
        return moved


class DisaggSession:
    """Orchestrates the two roles on a shared tick clock.

    The public surface mirrors `api.Session` (`submit`, `run`,
    `run_workload`, `results`, `records`, `stats`) so workloads, metrics
    and benchmarks drive either engine shape unchanged.  Arrival steps
    are interpreted in ticks (the co-located session interprets them in
    model calls — both are "scheduling opportunities")."""

    def __init__(self, cfg, params, *, disagg: "DisaggConfig",
                 max_len: int = 256, seed: int = 0, backend=None,
                 page_size: int = 16, kv_dtype: Optional[str] = None,
                 scheduler=None, prefill_plan=None, decode_plan=None,
                 resil=None, obs=None):
        d = DisaggConfig.coerce(disagg)
        self.dcfg = d
        backlog = d.max_backlog if d.max_backlog is not None \
            else d.decode_slots
        self.router = DisaggRouter(schd.SchedConfig.coerce(scheduler),
                                   max_backlog=backlog)
        # one shared ResilState: both roles and the orchestrator count
        # into the same stats, and the fault plan is consulted once
        if resil is None or isinstance(resil, rsl.ResilState):
            self.resil = resil
        else:
            self.resil = rsl.ResilState(rsl.ResilConfig.coerce(resil))
        # one shared tracer: both roles and the orchestrator stamp events
        # into the same timeline (per-role pids in the Chrome export)
        self.tracer = obs if obs is not None else obs_mod.NULL
        self.pre = PrefillSession(
            cfg, params, batch_slots=d.prefill_slots, max_len=max_len,
            seed=seed, backend=backend, kv_cache="paged",
            page_size=page_size, kv_pool_pages=d.prefill_pool_pages,
            kv_dtype=kv_dtype, plan=prefill_plan,
            router=self.router, on_handoff=self._on_handoff,
            resil=self.resil, obs=obs)
        # decode shares the prefill role's (possibly shard-prepared)
        # params — one model, two pools
        self.dec = DecodeSession(
            cfg, params if decode_plan is not None else self.pre.params,
            batch_slots=d.decode_slots, max_len=max_len, seed=seed,
            backend=backend, kv_cache="paged", page_size=page_size,
            kv_pool_pages=d.decode_pool_pages, kv_dtype=kv_dtype,
            plan=decode_plan, resil=self.resil, obs=obs)
        self.pre.role = "prefill"
        self.dec.role = "decode"
        self._role_fail = {"prefill": 0, "decode": 0}  # fault streaks
        self.results: List[Result] = []   # merged at drain
        self.records = self.pre.records   # all requests enter via prefill
        self.ticks = 0
        self.stats = {"ticks": 0, "prefill_busy_ticks": 0,
                      "decode_busy_ticks": 0, "handoffs": 0,
                      "migrated_bytes": 0}

    # ------------------------------------------------------------ public
    def submit(self, req: Request) -> None:
        self.pre.submit(req)
        # tick-denominated lifecycle: comparable with the co-located
        # engine's step clock (metrics.summarize prefers these fields)
        self.records[-1]["submit_tick"] = self.ticks

    def run(self, max_steps: int = 10_000,
            on_incomplete: str = "raise") -> List[Result]:
        return self.run_workload([], max_steps=max_steps,
                                 on_incomplete=on_incomplete)

    def run_workload(self, arrivals: Sequence[Tuple[int, Request]],
                     max_steps: int = 10_000,
                     on_incomplete: str = "raise") -> List[Result]:
        """Drive both roles on the shared tick clock.  A terminal
        HealthError/OutOfPages dumps the flight recorder (when one is
        attached to the tracer) before propagating."""
        try:
            return self._run_loop(arrivals, max_steps, on_incomplete)
        except (rsl.HealthError, kvs.OutOfPages) as e:
            self.tracer.crash(type(e).__name__, tick=self.ticks,
                              error=str(e))
            raise

    def _run_loop(self, arrivals: Sequence[Tuple[int, Request]],
                  max_steps: int, on_incomplete: str) -> List[Result]:
        pending: Deque[Tuple[int, Request]] = collections.deque(
            sorted(arrivals, key=lambda a: a[0]))
        clock = self.ticks
        for _ in range(max_steps):
            self.pre.tick = self.dec.tick = self.ticks
            while pending and pending[0][0] <= clock:
                self.submit(pending.popleft()[1])
            if self.resil is not None:
                self._resil_tick()
            self._admit_handoffs()
            if self.dec.sched.queue:   # resil handoff-timeout fallback
                self.dec._fill_slots()
            dec_busy = any(e is not None for e in self.dec.slot_entry)
            dec_ran = dec_busy and self._step_role(self.dec, "decode")
            self.pre._fill_slots()
            pre_busy = any(e is not None for e in self.pre.slot_entry)
            pre_ran = pre_busy and self._step_role(self.pre, "prefill")
            self.ticks += 1
            self.stats["ticks"] = self.ticks
            self.stats["prefill_busy_ticks"] += int(pre_ran)
            self.stats["decode_busy_ticks"] += int(dec_ran)
            if not (pre_busy or dec_busy):
                if self.resil is not None and self._fault_waiting():
                    # idleness is injected (spike window / handoff not
                    # yet redelivered) — let the clock run it out
                    self.resil.count("wait_ticks")
                    clock += 1
                    continue
                self.ticks -= 1        # idle: that tick did no work
                self.stats["ticks"] = self.ticks
                if self.router.handoff:
                    # both roles idle yet a handoff cannot land: the
                    # decode pool cannot hold even this one request
                    h = self.router.handoff[0]
                    msg = (f"decode page pool too small: request "
                           f"{h.entry.req.rid} needs "
                           f"{self.dec._page_need(h.entry)} pages, pool "
                           f"has {self.dec.alloc.n_pages - 1} usable")
                    if on_incomplete == "warn":
                        # structured failure: drop the handoff, free its
                        # prefill-side pages, keep serving the rest
                        self.router.handoff.popleft()
                        self.pre.alloc.free(p for p in h.pages if p >= 0)
                        self.pre.stats["pages_in_use"] = \
                            self.pre.alloc.in_use
                        self.tracer.instant(
                            "handoff.oversized", tick=self.ticks,
                            role="decode", rid=h.entry.req.rid,
                            need=self.dec._page_need(h.entry))
                        self.pre._fail_entry(h.entry, "oversized")
                        warnings.warn(msg, RuntimeWarning, stacklevel=3)
                        continue
                    raise kvs.OutOfPages(msg)
                if len(self.router) or self.dec.sched.queue:
                    self._incomplete(on_incomplete, blocked=True,
                                     pending=pending)
                    break
                if pending:            # idle until the next arrival
                    clock = pending[0][0]
                    continue
                break
            clock += 1
        else:
            self._incomplete(on_incomplete, blocked=False, pending=pending)
        self.stats["handoffs"] = self.router.stats["handoffs"]
        self.stats["migrated_bytes"] = self.dec.stats["migrated_bytes"]
        self.results = sorted(self.pre.results + self.dec.results,
                              key=lambda r: r.rid)
        return self.results

    @property
    def failed(self) -> List[rsl.RequestFailed]:
        """Structured failed-request results from both roles, rid order."""
        return sorted(self.pre.failed + self.dec.failed,
                      key=lambda f: f.rid)

    def resil_summary(self) -> Optional[dict]:
        return None if self.resil is None else self.resil.summary()

    def role_stats(self) -> dict:
        """Per-role counters in the shape sched.metrics.summarize folds
        into the ``"roles"`` record."""
        return {"prefill": {"steps": self.pre.stats["steps"],
                            "busy_ticks": self.stats["prefill_busy_ticks"]},
                "decode": {"steps": self.dec.stats["steps"],
                           "busy_ticks": self.stats["decode_busy_ticks"]},
                "_ticks": self.ticks}

    # --------------------------------------------------------- internals
    def _on_handoff(self, h: Handoff) -> None:
        """Router enqueue seam: the fault plan may drop the handoff
        (redelivered ``redeliver_after`` ticks later, bounded by the
        preset's ``max_drops``) or delay its visibility.  The full
        delivery schedule is resolved here, once — replay-deterministic
        and immune to how often admission polls the queue."""
        plan = self.resil.plan if self.resil is not None else None
        if plan is not None:
            rid = h.entry.req.rid
            while plan.drop_handoff(rid, h.drops):
                h.drops += 1
                h.ready_tick = h.tick + h.drops * plan.redeliver_after
            delay = plan.handoff_delay(rid)
            if delay:
                h.ready_tick = max(h.ready_tick, h.tick + delay)
        self.router.push_handoff(h)
        self.tracer.instant(
            "handoff.enqueue", tick=self.ticks, role="prefill",
            rid=h.entry.req.rid, pages=sum(1 for p in h.pages if p >= 0),
            drops=h.drops, ready_tick=h.ready_tick,
            backlog=len(self.router.handoff))

    def _step_role(self, sess: Session, name: str) -> bool:
        """Advance one role for one tick; injected faults burn the tick
        (and feed the wedge detector), a spike-throttled pool waits the
        window out.  Returns whether the step actually ran."""
        try:
            sess._advance()
            self._role_fail[name] = 0
            return True
        except rsl.InjectedFault:
            self._role_faulted(name)
            return False
        except kvs.OutOfPages:
            if sess.alloc is not None and sess.alloc.holdback > 0:
                self.resil.count("wait_ticks")
                return False
            raise

    def _fault_waiting(self) -> bool:
        """Idle because of an injected condition that time will clear."""
        if self.pre.alloc.holdback > 0 or self.dec.alloc.holdback > 0:
            return True
        # >= not >: self.ticks was already incremented for this (idle)
        # tick, and the next iteration's _admit_handoffs compares against
        # the same value — a handoff that just became ready is one loop
        # away from landing, not wedged
        return any(h.ready_tick >= self.ticks for h in self.router.handoff)

    def _role_faulted(self, name: str) -> None:
        self.resil.count("fault_steps")
        self._role_fail[name] += 1
        r = self.resil
        if r.watchdog is None or self._role_fail[name] < r.cfg.wedge_ticks:
            return
        self._drain_role(name)
        self._role_fail[name] = 0

    def _drain_role(self, name: str) -> None:
        """Wedged-role recovery: evict every active slot back through the
        retry path (recompute via prefill — greedy decode makes the
        resumed stream token-identical), bounded by ``max_retries``."""
        sess = self.pre if name == "prefill" else self.dec
        r = self.resil
        r.count("watchdog_recoveries")
        for i in reversed(range(sess.slots)):  # appendleft keeps order
            e = sess.slot_entry[i]
            if e is None:
                continue
            e.out = list(sess.slot_out[i])
            sess._release_slot_pages(i)
            sess.slot_entry[i] = None
            sess.slot_pending[i] = []
            sess.slot_out[i] = []
            e.retries += 1
            if e.record is not None:
                e.record["retries"] = e.retries
            if e.retries > r.cfg.max_retries:
                sess._fail_entry(e, "retries_exhausted")
                continue
            r.count("retries")
            self.router.queue.appendleft(e)

    def _resil_tick(self) -> None:
        """Orchestrator-side per-tick policy: role pool holdbacks,
        deadline expiry everywhere a request can wait (router queue,
        handoff queue, both roles' slots, the fallback queue), load
        shedding against the decode pool, the degradation ladder,
        handoff-timeout fallback, and the watchdog audit."""
        r, t = self.resil, self.ticks
        if r.plan is not None:
            self.pre.alloc.holdback = r.plan.page_holdback(
                self.pre.alloc.n_pages - 1, t, role="prefill")
            self.dec.alloc.holdback = r.plan.page_holdback(
                self.dec.alloc.n_pages - 1, t, role="decode")
        self.pre._expire_queue_deadlines(t)    # router queue
        self.dec._expire_queue_deadlines(t)    # fallback queue
        self._expire_handoff_deadlines(t)
        self.pre._expire_slot_deadlines(t)
        self.dec._expire_slot_deadlines(t)
        if r.cfg.shed_watermark is not None:
            self._shed_load(t)
        if r.degrade is not None:
            usable = max(1, self.dec.alloc.n_pages - 1)
            if r.degrade.update(self.dec.alloc.available / usable) >= 1 \
                    and self.pre.prefix is not None:
                self.pre.prefix.release(self.pre.alloc, 1)
        if r.cfg.handoff_timeout is not None:
            self._handoff_timeouts(t)
        if r.watchdog is not None and r.watchdog.due(t):
            r.count("watchdog_audits")
            extra: dict = {}
            for h in self.router.handoff:
                for p in h.pages:
                    if p >= 0:
                        extra[p] = extra.get(p, 0) + 1
            r.watchdog.audit(self.pre, extra_refs=extra)
            r.watchdog.audit(self.dec)

    def _expire_handoff_deadlines(self, t: int) -> None:
        q = self.router.handoff
        keep: Deque[Handoff] = collections.deque()
        while q:
            h = q.popleft()
            e = h.entry
            if e.deadline_tick is not None and t > e.deadline_tick:
                self.pre.alloc.free(p for p in h.pages if p >= 0)
                self.pre.stats["pages_in_use"] = self.pre.alloc.in_use
                self.resil.count("deadline_miss")
                self.pre._fail_entry(e, "deadline")
            else:
                keep.append(h)
        q.extend(keep)

    def _shed_load(self, t: int) -> None:
        """Shed never-admitted queued prompts, youngest first, while the
        decode-pool demand (queued + in-flight handoffs, worst case)
        exceeds the watermark fraction of the decode pool."""
        r = self.resil
        limit = r.cfg.shed_watermark * max(1, self.dec.alloc.n_pages - 1)
        total = sum(self.dec._page_need(h.entry)
                    for h in self.router.handoff)
        total += sum(self.dec._page_need(e) for e in self.router.queue)
        while total > limit:
            e = self.router.shed_youngest()
            if e is None:
                break
            total -= self.dec._page_need(e)
            r.count("shed")
            self.pre._fail_entry(e, "shed")

    def _handoff_timeouts(self, t: int) -> None:
        """Graceful degradation: a handoff stuck past ``handoff_timeout``
        falls back to co-located prefill on the decode role — its
        prefill-side pages are freed and the entry re-enters through the
        decode session's own scheduler (recompute, reservation-checked
        admission, so decode still never preempts)."""
        timeout = self.resil.cfg.handoff_timeout
        q = self.router.handoff
        keep: Deque[Handoff] = collections.deque()
        while q:
            h = q.popleft()
            if t - h.tick > timeout:
                self.pre.alloc.free(p for p in h.pages if p >= 0)
                self.pre.stats["pages_in_use"] = self.pre.alloc.in_use
                e = h.entry
                if e.record is not None:
                    e.record["degraded"] = "colocated-prefill"
                self.resil.count("handoff_fallbacks")
                self.tracer.instant(
                    "handoff.fallback", tick=self.ticks, role="decode",
                    rid=e.req.rid, waited=t - h.tick)
                self.dec.sched.queue.append(e)
            else:
                keep.append(h)
        q.extend(keep)

    def _admit_handoffs(self) -> None:
        """Land queued handoffs FIFO into free decode slots; the first
        *ready* head that does not fit blocks (order stays
        deterministic), fault-delayed entries are looked past.  Prefill-
        side page refs are released only after the migration lands — a
        handoff in flight can always be replayed."""
        q = self.router.handoff
        i = 0
        while i < len(q):
            h = q[i]
            if h.ready_tick > self.ticks:
                i += 1                 # dropped/delayed: not visible yet
                continue
            slot = next((s for s, e in enumerate(self.dec.slot_entry)
                         if e is None), None)
            if slot is None or not self.dec.fits_handoff(h):
                break
            del q[i]
            moved = self.dec.admit_handoff(slot, h, self.pre.state,
                                           tick=self.ticks)
            self.pre.alloc.free(p for p in h.pages if p >= 0)
            self.pre.stats["pages_in_use"] = self.pre.alloc.in_use
            rec = h.entry.record
            self.tracer.instant(
                "handoff.deliver", tick=self.ticks, role="decode",
                slot=slot, rid=h.entry.req.rid,
                waited=self.ticks - h.tick, drops=h.drops)
            self.tracer.instant(
                "handoff.migrate", tick=self.ticks, role="decode",
                slot=slot, rid=h.entry.req.rid,
                pages=rec["migrated_pages"], bytes=moved)

    def _incomplete(self, on_incomplete: str, blocked: bool,
                    pending: Sequence[Tuple[int, Request]] = ()) -> None:
        live = [e for e in self.pre.slot_entry if e is not None]
        live += [e for e in self.dec.slot_entry if e is not None]
        live += list(self.router.queue)
        live += list(self.dec.sched.queue)
        live += [h.entry for h in self.router.handoff]
        for e in live:
            if e.record is not None and e.record.get("state") == "queued":
                e.record["state"] = "unserved"
        for _, req in pending:
            self.records.append(_unserved_record(req))
        unfinished = [e.req.rid for e in live]
        unfinished += [req.rid for _, req in pending]
        if not unfinished or on_incomplete == "ignore":
            return
        why = ("prefill admission blocked (page pool too small for the "
               "head-of-line request's prompt)" if blocked
               else "max_steps exhausted")
        done = len(self.pre.results) + len(self.dec.results)
        msg = (f"DisaggSession.run stopped with {len(unfinished)} "
               f"unfinished request(s) {sorted(unfinished)}: {why}; "
               f"{done} completed")
        if on_incomplete == "warn":
            warnings.warn(msg, RuntimeWarning, stacklevel=3)
            return
        raise kvs.OutOfPages(msg) if blocked else RuntimeError(msg)
