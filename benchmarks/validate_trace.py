"""Trace smoke gate: validate a repro.obs Chrome/Perfetto trace export.

  python benchmarks/validate_trace.py TRACE.json [TRACE2.json]

Checks (all deterministic — this is a CI gate, not a heuristic):

* the file is Chrome ``trace_event`` JSON object format
  (``{"traceEvents": [...]}``) that https://ui.perfetto.dev loads;
* every event row is schema-complete for its phase: ``X`` (complete)
  rows carry ``ts``/``dur``, ``i`` (instant) rows carry scope ``s``,
  ``M`` (metadata) rows name a process or thread;
* pids/tids are consistent: every event's pid has a ``process_name``
  metadata row, every nonzero tid a ``thread_name`` row;
* timestamps are tick-derived (non-negative multiples of the tracer's
  TICK_US) and every event row echoes its tick in ``args`` — the
  property that makes same-seed replays byte-comparable;
* the serving stack actually traced: at least one step span and one
  request-lifecycle event, and every event name is a known seam
  (``repro.obs.trace.EVENT_NAMES``).

With a second path, additionally require the two files byte-identical
(the same-seed replay gate — run both serves with REPRO_AUTOTUNE=0 so
per-process autotune timing cannot pick different kernels).  On a
mismatch the first diverging traceEvent row is printed, so the CI log
names the seam that went nondeterministic instead of just "differs".

Exit codes, one per failure class (CI scripts can branch on them):

  0  all checks passed
  2  usage error
  3  schema violation (format / missing fields / unknown seam / pids)
  4  tick-derivation violation (ts not a tick multiple, args.tick echo)
  5  replay mismatch (two inputs not byte-identical)

When multiple classes fail, the smallest (most fundamental) code wins:
schema beats ticks beats replay.
"""
from __future__ import annotations

import json
import sys

sys.path.insert(0, "src")

from repro.obs.trace import EVENT_NAMES, TICK_US  # noqa: E402

KNOWN = set(EVENT_NAMES)

EXIT_OK = 0
EXIT_USAGE = 2
EXIT_SCHEMA = 3
EXIT_TICKS = 4
EXIT_REPLAY = 5


def validate(path: str, log=print):
    """-> set of failed classes, subset of {"schema", "ticks"}; empty
    means the file passed."""
    with open(path) as f:
        doc = json.load(f)
    errs = []             # (class, message)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        log(f"  {path}: not object-format trace_event JSON")
        return {"schema"}
    evs = doc["traceEvents"]
    procs, threads = set(), set()
    names = set()
    n_spans = n_instants = 0
    for i, ev in enumerate(evs):
        ph = ev.get("ph")
        if ph == "M":
            if ev.get("name") == "process_name":
                procs.add(ev.get("pid"))
            elif ev.get("name") == "thread_name":
                threads.add((ev.get("pid"), ev.get("tid")))
            else:
                errs.append(("schema",
                             f"event {i}: unknown metadata {ev.get('name')}"))
            continue
        if ph not in ("X", "i"):
            errs.append(("schema", f"event {i}: unknown phase {ph!r}"))
            continue
        for field in ("name", "pid", "tid", "ts", "args"):
            if field not in ev:
                errs.append(("schema", f"event {i} ({ev.get('name')}): "
                             f"missing {field}"))
        if ev.get("name") not in KNOWN:
            errs.append(("schema",
                         f"event {i}: unknown seam {ev.get('name')!r}"))
        names.add(ev.get("name"))
        ts = ev.get("ts", -1)
        if ts < 0 or ts % TICK_US != 0:
            errs.append(("ticks", f"event {i} ({ev.get('name')}): ts {ts} "
                         f"is not a non-negative multiple of "
                         f"TICK_US={TICK_US}"))
        if ev.get("args", {}).get("tick") != ts // TICK_US:
            errs.append(("ticks", f"event {i} ({ev.get('name')}): "
                         f"args.tick {ev.get('args', {}).get('tick')} != "
                         "ts/TICK_US"))
        if ph == "X":
            n_spans += 1
            if ev.get("dur", 0) <= 0:
                errs.append(("schema",
                             f"event {i}: span without positive dur"))
        else:
            n_instants += 1
            if ev.get("s") != "t":
                errs.append(("schema",
                             f"event {i}: instant without thread scope"))
        if ev.get("pid") not in procs:
            errs.append(("schema", f"event {i}: pid {ev.get('pid')} has "
                         "no process_name metadata"))
        if ev.get("tid") and (ev.get("pid"), ev.get("tid")) not in threads:
            errs.append(("schema", f"event {i}: tid {ev.get('tid')} has "
                         "no thread_name metadata"))
    if n_spans == 0:
        errs.append(("schema",
                     "no step spans — the serving loop did not trace"))
    if not names & {"req.submit", "req.first_token", "req.finish"}:
        errs.append(("schema", "no request-lifecycle events"))
    for _, e in errs[:20]:
        log(f"  {path}: {e}")
    if not errs:
        log(f"  {path}: {len(evs)} events ({n_spans} spans, "
            f"{n_instants} instants, {len(procs)} roles, "
            f"{sorted(names)}) OK")
    return {cls for cls, _ in errs}


def first_divergence(path_a: str, path_b: str, log=print) -> None:
    """Name the first traceEvent row where two parsed traces differ —
    the diagnostic for a replay-mismatch failure."""
    try:
        with open(path_a) as f:
            a = json.load(f).get("traceEvents", [])
        with open(path_b) as f:
            b = json.load(f).get("traceEvents", [])
    except (json.JSONDecodeError, AttributeError):
        log("  (unparseable input; cannot locate diverging event)")
        return
    for i, (ea, eb) in enumerate(zip(a, b)):
        if ea != eb:
            log(f"  first diverging event: index {i}")
            log(f"    {path_a}: {json.dumps(ea, sort_keys=True)}")
            log(f"    {path_b}: {json.dumps(eb, sort_keys=True)}")
            return
    if len(a) != len(b):
        n = min(len(a), len(b))
        longer, path = (a, path_a) if len(a) > len(b) else (b, path_b)
        log(f"  event counts differ: {len(a)} vs {len(b)}; first extra "
            f"event (index {n}) in {path}:")
        log(f"    {json.dumps(longer[n], sort_keys=True)}")
        return
    log("  traceEvents parse equal — divergence is formatting/metadata "
        "only (whitespace, key order, or displayTimeUnit)")


def main() -> int:
    if len(sys.argv) not in (2, 3):
        print(__doc__)
        return EXIT_USAGE
    failed = validate(sys.argv[1])
    if len(sys.argv) == 3:
        failed |= validate(sys.argv[2])
        with open(sys.argv[1], "rb") as a, open(sys.argv[2], "rb") as b:
            if a.read() != b.read():
                print(f"  REPLAY DIVERGED: {sys.argv[1]} != {sys.argv[2]} "
                      "(same-seed traces must be byte-identical)")
                first_divergence(sys.argv[1], sys.argv[2])
                failed.add("replay")
            else:
                print("  replay byte-identical OK")
    print("PASS" if not failed else "FAIL " + "+".join(sorted(failed)))
    if "schema" in failed:
        return EXIT_SCHEMA
    if "ticks" in failed:
        return EXIT_TICKS
    if "replay" in failed:
        return EXIT_REPLAY
    return EXIT_OK


if __name__ == "__main__":
    sys.exit(main())
