"""`repro.api` — the unified engine facade.

THE way to compress, load, run and benchmark a model at any of the paper's
operating points (dense / int8 / codebook4 / acsr / aida), on any
registered backend (`jax-dense`, `pallas`, `ap-emulator`, `cycle-sim`)::

    from repro.api import Engine, Request, CompressionSpec

    eng = Engine(cfg).compress(CompressionSpec(mode="aida", density=0.25))
    results = eng.serve([Request(prompt=[1, 2, 3], max_new=8)])
    table1 = eng.estimate(backend="cycle-sim", workload="table1")

The light value types (CompressionSpec, FCProblem, registry) import
eagerly; Engine/Session (which pull in jax + the model zoo) load lazily via
PEP 562 so that `models.layers` can import `repro.api.dispatch` at module
scope without a cycle.
"""
from repro.api.registry import (BackendRegistry, Capabilities,  # noqa: F401
                                CapabilityError, Executor, backend_names,
                                get_backend, register_backend)
from repro.api.spec import (MODES, WORKLOADS, CompressionSpec,  # noqa: F401
                            FCProblem)

__all__ = [
    "Engine", "Session", "Request", "Result", "compress_params",
    "CompressionSpec", "FCProblem", "MODES", "WORKLOADS",
    "BackendRegistry", "Capabilities", "CapabilityError", "Executor",
    "backend_names", "get_backend", "register_backend",
    "RequestFailed", "ResilConfig", "FaultPlan",
]

_LAZY = {
    "Engine": ("repro.api.engine", "Engine"),
    "Session": ("repro.api.session", "Session"),
    "Request": ("repro.api.session", "Request"),
    "Result": ("repro.api.session", "Result"),
    "compress_params": ("repro.api.compress", "compress_params"),
    # resilience layer (Engine.session(resil=...)) — re-exported for the
    # common "catch structured failures / build a fault plan" imports
    "RequestFailed": ("repro.resil", "RequestFailed"),
    "ResilConfig": ("repro.resil", "ResilConfig"),
    "FaultPlan": ("repro.resil", "FaultPlan"),
}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        module, attr = _LAZY[name]
        return getattr(importlib.import_module(module), attr)
    raise AttributeError(f"module 'repro.api' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
