"""Top-level model API: init / sharding specs / forward / loss / decode.

Everything is pure-functional and eval_shape-friendly: the dry-run lowers
`train_step` / `serve_step` against ShapeDtypeStructs produced by
`jax.eval_shape(init_params, ...)` — no parameter is ever materialized for
the full-size configs.

Sharding (GSPMD): parameters carry PartitionSpecs (FSDP over `data`, TP over
`model`, EP over `model` when expert counts divide); batch/cache specs adapt
per shape cell (batch shards over ("pod","data") when divisible, KV caches
shard their *sequence* dimension over `model` — distributed flash-decode —
falling back to ("data","model") sequence sharding for batch-1 long-context).
Cross-entropy is vocab-parallel: logits stay vocab-sharded, the label pick
and logsumexp reduce via one-hot contractions (psum), never gathering [B,S,V].
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import transformer as tfm
from repro.models.layers import (COMPUTE_DTYPE, dense, dense_init, embed,
                                 embed_init, softcap, unembed)
from repro.models.transformer import _norm, _norm_init


# ---------------------------------------------------------------- params
def init_params(cfg: ArchConfig, key) -> Dict:
    ks = jax.random.split(key, 4)
    p = {"embed": embed_init(ks[0], cfg.vocab_padded, cfg.d_model),
         "final_norm": _norm_init(cfg, cfg.d_model),
         "layers": tfm.stack_init(cfg, ks[1])}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab_padded)
    if cfg.frontend == "audio":
        p["frontend"] = dense_init(ks[3], cfg.audio_in_dim, cfg.d_model)
    return p


def _layer_specs(cfg: ArchConfig, mesh_shape: Dict[str, int]) -> Dict:
    """PartitionSpecs for ONE layer (leading scan dim added by caller)."""
    fsdp, tp = "data", "model"
    norm = {"scale": P()} if cfg.norm == "rms" else \
        {"scale": P(), "bias": P()}
    if cfg.family == "rwkv6":
        return {
            "ln1": dict(norm), "ln2": dict(norm),
            "tm": {"mu": P(), "w0": P(), "w_A": P(fsdp, None),
                   "w_B": P(None, tp), "wr": P(fsdp, tp), "wk": P(fsdp, tp),
                   "wv": P(fsdp, tp), "wg": P(fsdp, tp), "u": P(tp, None),
                   "ln_scale": P(), "ln_bias": P(), "wo": P(tp, fsdp)},
            "cm": {"mu": P(), "wk": P(fsdp, tp), "wv": P(tp, fsdp),
                   "wr": P(fsdp, tp)},
        }
    sp = {"ln1": dict(norm), "ln2": dict(norm),
          "attn": {"wq": P(fsdp, tp), "wk": P(fsdp, tp), "wv": P(fsdp, tp),
                   "wo": P(tp, fsdp)}}
    if cfg.qkv_bias:
        sp["attn"].update({"bq": P(tp), "bk": P(tp), "bv": P(tp)})
    if cfg.post_norms:
        sp["ln1p"] = dict(norm)
        sp["ln2p"] = dict(norm)
    if cfg.moe:
        ep = cfg.moe.n_experts % mesh_shape.get(tp, 1) == 0
        if ep:
            sp["moe"] = {"router": P(), "gate": P(tp, fsdp, None),
                         "up": P(tp, fsdp, None), "down": P(tp, None, fsdp)}
        else:
            sp["moe"] = {"router": P(), "gate": P(None, fsdp, tp),
                         "up": P(None, fsdp, tp), "down": P(None, tp, fsdp)}
    else:
        mlp_sp = {"up": P(fsdp, tp), "down": P(tp, fsdp)}
        if cfg.gated_mlp:
            mlp_sp["gate"] = P(fsdp, tp)
        sp["mlp"] = mlp_sp
    if cfg.family == "hymba":
        sp["mamba"] = {"in_proj": P(fsdp, tp), "conv": P(None, tp),
                       "x_db": P(tp, None), "dt_proj": P(None, tp),
                       "dt_bias": P(tp), "A_log": P(tp, None), "D": P(tp),
                       "out_proj": P(tp, fsdp)}
        sp["ln_ssm"] = dict(norm)
    return sp


def param_specs(cfg: ArchConfig, mesh_shape: Dict[str, int]) -> Dict:
    add_l = lambda spec: P(*((None,) + tuple(spec)))
    layer = jax.tree.map(add_l, _layer_specs(cfg, mesh_shape),
                         is_leaf=lambda x: isinstance(x, P))
    sp = {"embed": {"table": P("model", None)},
          "final_norm": {"scale": P()} if cfg.norm == "rms"
          else {"scale": P(), "bias": P()},
          "layers": layer}
    if not cfg.tie_embeddings:
        sp["lm_head"] = P(None, "model")
    if cfg.frontend == "audio":
        sp["frontend"] = P(None, None)
    return sp


# --------------------------------------------------------------- forward
def _constrain(x, spec: Optional[P]):
    """with_sharding_constraint that no-ops outside a mesh (unit tests)."""
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def forward(cfg: ArchConfig, params: Dict, batch: Dict, *,
            remat: str = "dots", attn_impl: str = "einsum",
            dp_spec: Optional[Tuple] = None, unroll: bool = False,
            return_hidden: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (logits [B,S,Vpad] f32, aux). batch keys per frontend:
    tokens [B,S] | tokens+img_embeds (vision) | frames (audio).
    dp_spec: tuple of mesh axes the batch dim shards over (None = no mesh)."""
    if cfg.frontend == "audio":
        x = dense(batch["frames"].astype(COMPUTE_DTYPE), params["frontend"])
    else:
        x = embed(batch["tokens"], params["embed"])
        if cfg.frontend == "vision":
            img = batch["img_embeds"].astype(COMPUTE_DTYPE)
            x = jnp.concatenate([img, x], axis=1)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, COMPUTE_DTYPE)
    b, s, _ = x.shape
    x = _constrain(x, P(dp_spec, None, None) if dp_spec else None)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x, aux = tfm.stack_forward(cfg, params["layers"], x, positions,
                               remat=remat, attn_impl=attn_impl,
                               unroll=unroll)
    x = _norm(cfg)(x, params["final_norm"])
    if return_hidden:
        return x, aux
    if cfg.tie_embeddings:
        logits = unembed(x, params["embed"])
    else:
        logits = jnp.matmul(x, params["lm_head"].astype(COMPUTE_DTYPE),
                            preferred_element_type=jnp.float32)
    logits = softcap(logits, cfg.final_softcap)
    logits = _constrain(
        logits, P(dp_spec, None, "model") if dp_spec else None)
    return logits, aux


def _xent(logits: jnp.ndarray, labels: jnp.ndarray,
          mask: jnp.ndarray, vocab: int) -> jnp.ndarray:
    """Vocab-parallel-safe CE: one-hot contractions, no [B,S,V] gather.
    Padded vocab columns (vocab..vpad) are masked out of the logsumexp."""
    vpad = logits.shape[-1]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, vpad), 2)
    logits = jnp.where(col < vocab, logits, -1e30)
    lmax = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = logits - lmax
    lse = jnp.log(jnp.exp(shifted).sum(axis=-1)) + lmax[..., 0]
    onehot = jax.nn.one_hot(labels, vpad, dtype=logits.dtype)
    picked = (shifted * onehot).sum(axis=-1) + lmax[..., 0]
    nll = (lse - picked) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def _xent_streamed(cfg: ArchConfig, params, x, labels, mask,
                   chunk: int = 512, unroll: bool = False) -> jnp.ndarray:
    """CE over SEQ chunks: the [B, S, Vpad] logits tensor never exists —
    per chunk only [B, c, Vpad/tp] lives (§Perf: cuts train temp memory by
    the vocab factor; the psum'd (lse, picked) are [B, c])."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    rem = s % chunk
    if rem:  # pad seq to a chunk multiple; padded positions masked out
        pad = chunk - rem
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        s += pad
    n = s // chunk
    table = params["embed"]["table"] if cfg.tie_embeddings \
        else params["lm_head"]

    def one(ci):
        xs = jax.lax.dynamic_slice_in_dim(x, ci * chunk, chunk, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, ci * chunk, chunk, axis=1)
        if cfg.tie_embeddings:
            lg = jnp.matmul(xs, table.T.astype(COMPUTE_DTYPE),
                            preferred_element_type=jnp.float32)
        else:
            lg = jnp.matmul(xs, table.astype(COMPUTE_DTYPE),
                            preferred_element_type=jnp.float32)
        lg = softcap(lg, cfg.final_softcap)
        vpad = lg.shape[-1]
        col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, vpad), 2)
        lg = jnp.where(col < cfg.vocab, lg, -1e30)
        lmax = jax.lax.stop_gradient(lg.max(axis=-1, keepdims=True))
        lse = jnp.log(jnp.exp(lg - lmax).sum(axis=-1)) + lmax[..., 0]
        onehot = jax.nn.one_hot(ls, vpad, dtype=lg.dtype)
        picked = ((lg - lmax) * onehot).sum(axis=-1) + lmax[..., 0]
        return lse - picked                                # [B, chunk]

    _, nll = jax.lax.scan(lambda c, ci: (c, one(ci)), (), jnp.arange(n),
                          unroll=n if unroll else 1)
    nll = jnp.moveaxis(nll, 0, 1).reshape(b, s)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(cfg: ArchConfig, params: Dict, batch: Dict, *,
            remat: str = "dots", attn_impl: str = "einsum",
            dp_spec: Optional[Tuple] = None, unroll: bool = False,
            streamed_loss: bool = False,
            loss_chunk: int = 512) -> Tuple[jnp.ndarray, Dict]:
    if streamed_loss and cfg.causal and cfg.family != "encoder":
        x, aux = forward(cfg, params, batch, remat=remat,
                         attn_impl=attn_impl, dp_spec=dp_spec,
                         unroll=unroll, return_hidden=True)
        tokens = batch["tokens"]
        if cfg.frontend == "vision":
            x = x[:, -tokens.shape[1]:]
        labels = tokens[:, 1:]
        mask = (labels >= 0).astype(jnp.float32)
        ce = _xent_streamed(cfg, params, x[:, :-1],
                            jnp.maximum(labels, 0), mask,
                            chunk=loss_chunk, unroll=unroll)
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux}
    logits, aux = forward(cfg, params, batch, remat=remat,
                          attn_impl=attn_impl, dp_spec=dp_spec,
                          unroll=unroll)
    if cfg.family == "encoder" or not cfg.causal:
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        ce = _xent(logits, jnp.maximum(labels, 0), mask, cfg.vocab)
    else:
        tokens = batch["tokens"]
        if cfg.frontend == "vision":  # labels only over the text tail
            logits = logits[:, -tokens.shape[1]:]
        labels = tokens[:, 1:]
        mask = (labels >= 0).astype(jnp.float32)
        ce = _xent(logits[:, :-1], jnp.maximum(labels, 0), mask, cfg.vocab)
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------- decode
def init_decode_state(cfg: ArchConfig, batch: int, max_len: int,
                      kv_cache: str = "full", page_size: int = 16,
                      kv_pool_pages: Optional[int] = None,
                      kv_dtype: str = "int8") -> Dict:
    """Decode state.  kv_cache="paged" swaps the dense per-slot KV cache
    for the kvstore page pool + a shared per-sequence page table (the
    table lives at the top level: one table drives every layer's pool)."""
    if kv_cache == "paged":
        if cfg.family == "rwkv6":
            raise ValueError("paged KV cache needs attention layers; "
                             f"{cfg.name} is attention-free")
        layers = tfm.init_stack_state(cfg, batch, max_len,
                                      kv_cache="paged",
                                      page_size=page_size,
                                      kv_pool_pages=kv_pool_pages,
                                      kv_dtype=kv_dtype)
        from repro import kvstore as kvs
        return {"layers": layers,
                "pos": jnp.zeros((batch,), jnp.int32),
                "page_table": kvs.init_table(batch, max_len, page_size)}
    return {"layers": tfm.init_stack_state(cfg, batch, max_len),
            "pos": jnp.zeros((batch,), jnp.int32)}


def decode_step(cfg: ArchConfig, params: Dict, state: Dict,
                tokens: jnp.ndarray, unroll: bool = False,
                plan=None) -> Tuple[Dict, jnp.ndarray]:
    """tokens [B] -> (state', logits [B, Vpad]).  ``plan`` = the serving
    ShardingPlan threaded down to every projection (None = replicated)."""
    x = embed(tokens[:, None], params["embed"])
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, COMPUTE_DTYPE)
    table = state.get("page_table")        # paged route (static branch)
    new_layers, x = tfm.stack_decode(cfg, params["layers"], state["layers"],
                                     x, state["pos"], unroll=unroll,
                                     page_table=table, plan=plan)
    x = _norm(cfg)(x, params["final_norm"])
    if cfg.tie_embeddings:
        logits = unembed(x, params["embed"])
    else:
        logits = jnp.matmul(x, params["lm_head"].astype(COMPUTE_DTYPE),
                            preferred_element_type=jnp.float32)
    new_state = {"layers": new_layers, "pos": state["pos"] + 1}
    if table is not None:
        new_state["page_table"] = table
    logits = softcap(logits, cfg.final_softcap)
    return new_state, logits[:, 0, :]


def state_specs(cfg: ArchConfig, batch: int, dp_ok: bool,
                dpax: Tuple[str, ...] = ("data",),
                kv_cache: str = "full", kv_dtype: str = "int8") -> Dict:
    """PartitionSpecs for the decode state (stacked over layers).

    dp_ok: batch divisible by the dp submesh — else batch replicates and the
    cache sequence dim shards over ("data","model") (batch-1 long-context).
    kv_dtype matters for treedef parity: bf16 pools carry None scale
    leaves, so their specs must too.
    """
    bdim = dpax if dp_ok else None
    seq = "model" if dp_ok else ("data", "model")
    if kv_cache == "paged":
        from repro.kvstore import PagedKV
        # pages replicate over data (any sequence may own any page);
        # kv heads shard over model like the dense cache's head dim
        scale_sp = P(None, None, "model") if kv_dtype == "int8" else None
        layers = {"kv": PagedKV(
            k_pages=P(None, None, "model", None, None),
            v_pages=P(None, None, "model", None, None),
            k_scale=scale_sp,
            v_scale=scale_sp)}
        if cfg.family == "hymba":
            layers["mamba"] = {"conv": P(None, bdim, None, "model"),
                               "h": P(None, bdim, "model", None)}
        return {"layers": layers, "pos": P(bdim),
                "page_table": P(bdim, None)}
    if cfg.family == "rwkv6":
        layers = {"tm_prev": P(None, bdim, "model"),
                  "cm_prev": P(None, bdim, "model"),
                  "S": P(None, bdim, "model", None, None)}
    else:
        from repro.models.kvcache import KVCache
        layers = {"kv": KVCache(k=P(None, bdim, None, seq, None),
                                v=P(None, bdim, None, seq, None),
                                pos=P(None, bdim, seq))}
        if cfg.family == "hymba":
            layers["mamba"] = {"conv": P(None, bdim, None, "model"),
                               "h": P(None, bdim, "model", None)}
    return {"layers": layers, "pos": P(bdim)}
