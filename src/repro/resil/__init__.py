"""repro.resil — deterministic fault injection, request deadlines/retry,
and graceful degradation for the serving stack.

Three layers:

- :mod:`repro.resil.faults` — seeded :class:`FaultPlan` presets
  (drop-handoff, role-stall, page-spike, straggler), replayable from
  ``(seed, preset)``.
- :mod:`repro.resil.policy` — :class:`ResilConfig` (deadlines, bounded
  retry, load shedding, degradation ladder) and the structured
  :class:`RequestFailed` terminal result.
- :mod:`repro.resil.health` — allocator/slot invariant audits and the
  :class:`Watchdog`.

Activate via ``Engine.session(resil=...)`` — a ResilConfig, a dict of
its fields, or a bare ``"preset:seed"`` fault-plan string. ``resil=None``
(the default) leaves serving behavior exactly as before.
"""

from repro.resil.faults import PRESETS, FaultPlan, InjectedFault
from repro.resil.health import HealthError, Watchdog, audit_allocator, \
    audit_session
from repro.resil.policy import DegradeState, RequestFailed, ResilConfig, \
    ResilState

__all__ = [
    "PRESETS", "FaultPlan", "InjectedFault",
    "HealthError", "Watchdog", "audit_allocator", "audit_session",
    "DegradeState", "RequestFailed", "ResilConfig", "ResilState",
]
