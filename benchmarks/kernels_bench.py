"""Kernel microbenchmarks: HBM-byte and FLOP accounting for the AIDA
kernels vs their dense equivalents (the in-memory-compression dividend),
plus wall-clock on this host (interpret mode — correctness path, NOT TPU
performance; the byte model is the TPU-relevant number)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparse_fc as sfc
from repro.kernels import ops, ref


def bytes_model(n=4096, k=4096, density=0.1, log=print):
    """Weights-at-rest and weights-moved-per-matvec for each FC mode."""
    dense_bf16 = n * k * 2
    rows = [
        ("dense bf16", dense_bf16),
        ("int8", n * k * 1),
        ("codebook4 (packed)", n * k // 2 + 64),
        ("acsr f32 (val+idx)", int(n * k * density) * 8),
        ("aida (4b codes + idx)", int(n * k * density) * 5),  # 4b+32b idx
    ]
    log(f"FC {n}x{k}, density {density:.0%} — HBM bytes per matvec:")
    out = {}
    for name, b in rows:
        log(f"  {name:24s} {b/1e6:10.2f} MB   ({dense_bf16/b:5.1f}x less"
            f" than dense bf16)" if b else "")
        out[name] = b
    return out


def wallclock(log=print):
    rng = np.random.default_rng(0)
    n, k, b = 1024, 2048, 8
    w = rng.normal(size=(n, k)).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(b, k)).astype(np.float32))
    rows = []
    for mode in sfc.MODES:
        layer = sfc.compress(w, mode=mode, density=0.1)
        f = jax.jit(lambda xx, l=layer: sfc.apply_fc(l, xx))
        f(x).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            f(x).block_until_ready()
        us = (time.perf_counter() - t0) / 5 * 1e6
        rows.append((f"fc_{mode}", us))
        log(f"  fc_{mode:10s} {us:12.0f} us/call")
    return rows


def attention_bench(log=print):
    rng = np.random.default_rng(1)
    B, H, T, D = 1, 8, 1024, 64
    q = jnp.asarray(rng.normal(size=(B, H, T, D)).astype(np.float32)) * 0.3
    k = jnp.asarray(rng.normal(size=(B, H, T, D)).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.normal(size=(B, H, T, D)).astype(np.float32))
    rows = []
    for impl in ("ref",):
        f = jax.jit(lambda a, b_, c: ops.attention(a, b_, c, impl=impl))
        f(q, k, v).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            f(q, k, v).block_until_ready()
        us = (time.perf_counter() - t0) / 3 * 1e6
        rows.append((f"attention_{impl}", us))
        log(f"  attention_{impl:6s} {us:12.0f} us/call  "
            f"({4*B*H*T*T*D/ (us*1e-6) /1e9:.1f} GFLOP/s host)")
    return rows


if __name__ == "__main__":
    bytes_model()
    print("\nwall-clock (host CPU, interpret-mode kernels):")
    wallclock()
    attention_bench()
