"""Blocked-ACSR sparse matvec/matmul — the paper's algorithm, TPU-native.

The per-nnz stream (value, col_idx, seg_id) is regrouped into row blocks:
``block_rows`` consecutive matrix rows contribute one padded entry stream of
length ``me`` (max entries per row-block, padded with seg_local=block_rows).
Each grid step then IS the paper's Fig. 3 pipeline for its block:

  activation broadcast → gather x[col_idx]   (VMEM gather; x stays resident)
  multiplication       → values * gathered   (VPU, all lanes in parallel)
  soft reduction       → one-hot(seg_local)ᵀ @ products on the MXU —
                         a segmented sum computed as a [me, bn+1] matmul;
                         the MXU's systolic reduction replaces the CAM's
                         tag-shift binary tree (log-depth in both cases).

Supports matvec (x: [K]) and multi-activation matmul (x: [K, B]), plus
codebook-coded values (values are uint8 codes dequantized against a
16-entry table in VMEM — combine with sparsity for the full AIDA mode).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import acsr as acsr_mod


# --------------------------------------------------------------- format
@dataclasses.dataclass
class BlockedACSR:
    """Row-blocked ACSR with static shapes (TPU layout of the paper's Fig. 2).

    values:    [nblocks, me] f32 (or uint8 codes if ``coded``)
    col_idx:   [nblocks, me] int32
    seg_local: [nblocks, me] int32 in [0, block_rows]; block_rows = padding

    Registered as a pytree (arrays = leaves, geometry = static) so
    compressed weights can live INSIDE jitted model params.
    """
    values: jnp.ndarray
    col_idx: jnp.ndarray
    seg_local: jnp.ndarray
    shape: Tuple[int, int]
    block_rows: int
    nnz: int
    centroids: Optional[jnp.ndarray] = None  # set when values are codes

    @property
    def nblocks(self) -> int:
        return int(self.values.shape[0])

    @property
    def me(self) -> int:
        return int(self.values.shape[1])


def _bacsr_flatten(b: "BlockedACSR"):
    return ((b.values, b.col_idx, b.seg_local, b.centroids),
            (b.shape, b.block_rows, b.nnz))


def _bacsr_unflatten(aux, children):
    values, col_idx, seg_local, centroids = children
    shape, block_rows, nnz = aux
    return BlockedACSR(values=values, col_idx=col_idx, seg_local=seg_local,
                       shape=shape, block_rows=block_rows, nnz=nnz,
                       centroids=centroids)


jax.tree_util.register_pytree_node(BlockedACSR, _bacsr_flatten,
                                   _bacsr_unflatten)


def block_encode(dense: np.ndarray, block_rows: int = 128,
                 lane_pad: int = 128) -> BlockedACSR:
    """Re-block a dense matrix's nonzeros by groups of ``block_rows`` rows."""
    dense = np.asarray(dense)
    n_rows, n_cols = dense.shape
    nblocks = (n_rows + block_rows - 1) // block_rows
    per_block = []
    me = lane_pad
    for bidx in range(nblocks):
        rows = slice(bidx * block_rows, min((bidx + 1) * block_rows, n_rows))
        sub = dense[rows]
        r, c = np.nonzero(sub)
        order = np.lexsort((c, r))
        per_block.append((sub[r, c][order], c[order], r[order]))
        me = max(me, len(order))
    me = ((me + lane_pad - 1) // lane_pad) * lane_pad
    # compact index types — the memory footprint IS the paper's argument
    col_t = np.int16 if n_cols < 2 ** 15 else np.int32
    seg_t = np.uint8 if block_rows < 2 ** 8 else np.int32
    vals = np.zeros((nblocks, me), np.float32)
    cols = np.zeros((nblocks, me), col_t)
    segs = np.full((nblocks, me), block_rows, seg_t)
    nnz = 0
    for bidx, (v, c, r) in enumerate(per_block):
        k = len(v)
        nnz += k
        vals[bidx, :k] = v
        cols[bidx, :k] = c
        segs[bidx, :k] = r
    return BlockedACSR(values=jnp.asarray(vals), col_idx=jnp.asarray(cols),
                       seg_local=jnp.asarray(segs), shape=(n_rows, n_cols),
                       block_rows=block_rows, nnz=int(nnz))


def block_encode_coded(dense: np.ndarray, centroids: np.ndarray,
                       block_rows: int = 128,
                       lane_pad: int = 128) -> BlockedACSR:
    """Sparse + codebook: store the nonzeros' 4-bit codes, not values."""
    b = block_encode(dense, block_rows, lane_pad)
    cents = np.asarray(centroids, np.float32)
    vals = np.asarray(b.values)
    codes = np.abs(vals[..., None] - cents[None, None, :]).argmin(-1)
    codes[vals == 0.0] = int(np.abs(cents).argmin())  # padding → zero-ish code
    return dataclasses.replace(
        b, values=jnp.asarray(codes.astype(np.uint8)),
        centroids=jnp.asarray(cents))


# --------------------------------------------------------------- kernel
def _spmv_kernel(vals_ref, cols_ref, segs_ref, x_ref, o_ref, *,
                 block_rows: int, coded: bool, cents_ref=None):
    vals = vals_ref[...]                                  # [1, me]
    if coded:
        vals = jnp.take(cents_ref[0], vals.astype(jnp.int32), axis=0)
    cols = cols_ref[...][0].astype(jnp.int32)             # [me]
    segs = segs_ref[...][0].astype(jnp.int32)             # [me]
    x = x_ref[...]                                        # [K, B]
    gathered = jnp.take(x, cols, axis=0)                  # broadcast: [me, B]
    prod = vals.reshape(-1, 1).astype(jnp.float32) * gathered.astype(jnp.float32)
    # soft reduction on the MXU: segmented sum as one-hot matmul
    onehot = (segs[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, block_rows), 1)
              ).astype(jnp.float32)                       # [me, bn]
    o_ref[...] = jax.lax.dot_general(
        onehot, prod, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[None]         # [1, bn, B]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _spmv_call(values, col_idx, seg_local, x2d, centroids, *,
               block_rows: int, interpret: bool):
    nblocks, me = values.shape
    k, bsz = x2d.shape
    coded = centroids is not None
    kern = functools.partial(_spmv_kernel, block_rows=block_rows,
                             coded=coded)
    in_specs = [
        pl.BlockSpec((1, me), lambda i: (i, 0)),
        pl.BlockSpec((1, me), lambda i: (i, 0)),
        pl.BlockSpec((1, me), lambda i: (i, 0)),
        pl.BlockSpec((k, bsz), lambda i: (0, 0)),   # x resident in VMEM
    ]
    args = [values, col_idx, seg_local, x2d]
    if coded:
        cents2d = centroids.reshape(1, -1)
        def kern(vals_ref, cols_ref, segs_ref, x_ref, cents_ref, o_ref):
            _spmv_kernel(vals_ref, cols_ref, segs_ref, x_ref, o_ref,
                         block_rows=block_rows, coded=True,
                         cents_ref=cents_ref)
        in_specs.append(pl.BlockSpec((1, cents2d.shape[1]), lambda i: (0, 0)))
        args.append(cents2d)
    return pl.pallas_call(
        kern,
        grid=(nblocks,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_rows, bsz), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, block_rows, bsz),
                                       jnp.float32),
        interpret=interpret,
    )(*args)


def acsr_spmv(b: BlockedACSR, x: jnp.ndarray,
              interpret: bool = True) -> jnp.ndarray:
    """Sparse (optionally coded) matmul: returns W @ x, [n_rows] / [n_rows,B]."""
    squeeze = x.ndim == 1
    x2d = x[:, None] if squeeze else x
    out = _spmv_call(b.values, b.col_idx, b.seg_local, x2d, b.centroids,
                     block_rows=b.block_rows, interpret=interpret)
    out = out.reshape(b.nblocks * b.block_rows, -1)[: b.shape[0]]
    return out[:, 0] if squeeze else out
