"""Bit-level associative processor (AP) emulator — paper §2.1–2.2, Fig. 1.

Faithful functional model of AIDA's hardware primitives:

* a CAM array of ``rows × bits`` cells (one data element per row = one PU),
* ``compare(cols, key)``  — match key against the unmasked columns of EVERY
  row simultaneously; matching rows are sampled into the TAG register,
* ``write(cols, bits)``   — parallel write into the unmasked columns of all
  tagged rows (compare+write pairs execute in the same cycle, §2.2),
* ``move(direction, step)`` — shift the TAG vector by ``short_step`` (1) or
  ``long_step`` (16) positions (Fig. 1(c)),
* ``if_match``            — global OR of the TAG vector.

The emulator is a *host-side validation artifact* (numpy): it exists to prove
the Fig. 3 algorithm correct bit-for-bit and to count cycles/energy exactly.
The production TPU path (kernels/, models/) shares oracles with it.

Cycle accounting follows the paper: a compare immediately followed by a
dependent parallel write counts as ONE cycle (simultaneous execution, §2.2);
standalone compares, writes and each tag move count one cycle each.
Crucially the *controller is SIMD*: every op sequence is data-independent
(worst-case), so cycle counts are closed-form functions of the operand widths
— `aida_sim.py` reproduces them analytically and tests assert equality.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

SHORT_STEP = 1
LONG_STEP = 16  # Fig. 1(c)


@dataclasses.dataclass
class Field:
    """A named bit-column range [base, base+width); LSB first."""
    base: int
    width: int

    def cols(self, lo: int = 0, hi: Optional[int] = None) -> np.ndarray:
        hi = self.width if hi is None else hi
        assert 0 <= lo <= hi <= self.width
        return np.arange(self.base + lo, self.base + hi)

    def col(self, i: int) -> int:
        assert 0 <= i < self.width
        return self.base + i


class AP:
    """The CAM array + TAG logic + op/energy counters."""

    def __init__(self, rows: int, bits: int):
        self.rows = rows
        self.bits = bits
        self.cam = np.zeros((rows, bits), dtype=np.uint8)
        self.tag = np.zeros(rows, dtype=bool)
        self.counters: Dict[str, int] = dict(
            cycles=0, compare=0, write=0, move=0, if_match=0,
            compare_bitcells=0, write_bitcells=0, tag_events=0)

    # ------------------------------------------------------------------ ops
    def compare(self, cols: Sequence[int], key: Sequence[int],
                fuse_write: bool = False) -> np.ndarray:
        """Match ``key`` against columns ``cols`` of every row → TAG.

        ``fuse_write=True`` marks this compare as the first half of a fused
        compare+write pair; the cycle is charged by the write.
        """
        cols = np.asarray(cols, dtype=np.int64)
        key = np.asarray(key, dtype=np.uint8)
        assert cols.shape == key.shape
        if cols.size == 0:
            self.tag = np.ones(self.rows, dtype=bool)
        else:
            self.tag = (self.cam[:, cols] == key[None, :]).all(axis=1)
        self.counters["compare"] += 1
        self.counters["compare_bitcells"] += self.rows * cols.size
        self.counters["tag_events"] += self.rows
        if not fuse_write:
            self.counters["cycles"] += 1
        return self.tag.copy()

    def write(self, cols: Sequence[int], bits: Sequence[int],
              fused: bool = False) -> None:
        """Parallel write of ``bits`` into columns ``cols`` of tagged rows."""
        cols = np.asarray(cols, dtype=np.int64)
        bits = np.asarray(bits, dtype=np.uint8)
        assert cols.shape == bits.shape
        idx = np.nonzero(self.tag)[0]
        if cols.size and idx.size:
            self.cam[np.ix_(idx, cols)] = bits[None, :]
        self.counters["write"] += 1
        self.counters["write_bitcells"] += int(idx.size) * cols.size
        self.counters["cycles"] += 1  # fused pair charged once, here
        del fused

    def compare_write(self, ccols, ckey, wcols, wbits) -> None:
        """Fused compare+write (one cycle, §2.2)."""
        self.compare(ccols, ckey, fuse_write=True)
        self.write(wcols, wbits, fused=True)

    def move(self, direction: str, step: int) -> None:
        """Shift TAG by ``step`` rows; 'up' = toward row 0 (paper Fig. 3)."""
        assert step in (SHORT_STEP, LONG_STEP)
        t = np.zeros_like(self.tag)
        if direction == "up":
            t[:-step or None] = self.tag[step:]
        elif direction == "down":
            t[step:] = self.tag[:-step]
        else:
            raise ValueError(direction)
        self.tag = t
        self.counters["move"] += 1
        self.counters["cycles"] += 1
        self.counters["tag_events"] += self.rows

    def move_by(self, direction: str, dist: int) -> int:
        """Decompose an arbitrary distance into long/short steps (Fig. 1(c)).

        Returns the number of move cycles spent.
        """
        n_long, rem = divmod(dist, LONG_STEP)
        for _ in range(n_long):
            self.move(direction, LONG_STEP)
        for _ in range(rem):
            self.move(direction, SHORT_STEP)
        return n_long + rem

    def if_match(self) -> bool:
        self.counters["if_match"] += 1
        self.counters["cycles"] += 1
        return bool(self.tag.any())

    def set_tag(self, tag: np.ndarray) -> None:
        """Load TAG directly (test scaffolding only — not a hardware op)."""
        self.tag = tag.astype(bool).copy()

    # ------------------------------------------------------------ host I/O
    def load_field(self, row: int, field: Field, value: int,
                   width: Optional[int] = None) -> None:
        """Host-side CAM image initialization (DMA load, not cycle-counted)."""
        width = field.width if width is None else width
        for i in range(width):
            self.cam[row, field.base + i] = (value >> i) & 1

    def read_field(self, row: int, field: Field,
                   signed: bool = False) -> int:
        v = 0
        for i in range(field.width):
            v |= int(self.cam[row, field.base + i]) << i
        if signed and v >= (1 << (field.width - 1)):
            v -= 1 << field.width
        return v

    def read_column(self, col: int) -> np.ndarray:
        return self.cam[:, col].copy()


def move_cycles(dist: int) -> int:
    """Closed-form cycle cost of move_by (for the analytical simulator)."""
    n_long, rem = divmod(dist, LONG_STEP)
    return n_long + rem
