"""Shared-prefix page cache: prefill a common prompt prefix ONCE.

Serving traffic is dominated by near-identical prompt heads (system
prompts, few-shot preambles).  Because the page-table index IS the
absolute position and RoPE is applied at write time, a KV page written
for prompt positions [j*ps, (j+1)*ps) is a pure function of the prompt
tokens up to and including that page — so full prompt pages are
content-addressed by a chain hash over their token prefix and *shared*
across sequences: a new request whose prompt starts with a cached prefix
attaches the existing page ids into its page table (one `ref()` per
page, per `PageAllocator` refcounts) and starts prefilling after them.

Safety rules that keep sharing sound:

* only FULL pages of PROMPT tokens are ever cached — generated tokens
  depend on sampling, partial pages would be written into;
* a request reuses at most the pages strictly before its LAST prompt
  token (`usable_prefix_pages`): the final prompt token must be
  re-forwarded to produce first-token logits, and its write must not
  land in a shared page;
* the cache holds its own reference on every cached page, so cached
  pages survive request completion; under pool pressure the Session
  releases cache pins LRU-first *before* preempting a live request.
"""
from __future__ import annotations

import collections
import hashlib
from typing import List, Optional, Sequence


def page_hashes(prompt: Sequence[int], page_size: int) -> List[bytes]:
    """Chain hash per full prompt page: hashes[j] identifies prompt
    tokens [0, (j+1)*page_size) — page content depends on the whole
    prefix (attention is causal), so the chain, not the page's own
    tokens, is the identity."""
    out: List[bytes] = []
    h = hashlib.sha1(str(page_size).encode())
    for j in range(len(prompt) // page_size):
        for t in prompt[j * page_size:(j + 1) * page_size]:
            h.update(int(t).to_bytes(8, "little", signed=True))
        out.append(h.digest())
    return out


def usable_prefix_pages(prompt_len: int, page_size: int) -> int:
    """Pages a request may ATTACH from the cache: full pages strictly
    before the last prompt token (which must be re-fed — its logits seed
    generation — and must not write into a shared page)."""
    return max(0, (prompt_len - 1) // page_size)


class PrefixCache:
    """hash -> page id, LRU-ordered.  One refcount per cached page is
    held by the cache itself (the pin); lookups/attachments add their
    own via the allocator."""

    def __init__(self, capacity_pages: Optional[int] = None):
        self._entries: "collections.OrderedDict[bytes, int]" = \
            collections.OrderedDict()
        self.capacity = capacity_pages
        self.hits = 0
        self.misses = 0
        self.inserted = 0
        self.released = 0
        # observability seam: a ``(name, **args)`` emitter (obs.Tracer
        # .hook) attached by the owning Session; None = no tracing.
        self.obs = None

    # ------------------------------------------------------------ queries
    @property
    def pages(self) -> int:
        return len(self._entries)

    def stats(self) -> dict:
        return {"pages": self.pages, "hits": self.hits,
                "misses": self.misses, "inserted": self.inserted,
                "released": self.released}

    def peek(self, h: bytes) -> Optional[int]:
        """Like lookup but with no LRU touch / stats — admission planning."""
        return self._entries.get(h)

    def releasable(self, allocator, exclude=()) -> int:
        """Pages the cache could free RIGHT NOW if pressured: entries
        whose only remaining owner is the cache pin itself.  ``exclude``
        masks pages the caller intends to attach (they would gain an
        owner, not free up)."""
        ex = set(exclude)
        return sum(1 for pid in self._entries.values()
                   if allocator.refcount(pid) == 1 and pid not in ex)

    # --------------------------------------------------------------- ops
    def lookup(self, h: bytes) -> Optional[int]:
        """Page id for a prefix hash (LRU-touched), or None.  The caller
        must `allocator.ref()` the page before using it."""
        pid = self._entries.get(h)
        if pid is None:
            self.misses += 1
            return None
        self._entries.move_to_end(h)
        self.hits += 1
        if self.obs is not None:
            self.obs("prefix.hit", page=pid)
        return pid

    def insert(self, h: bytes, pid: int, allocator) -> bool:
        """Pin a freshly-prefilled full prompt page.  First writer wins —
        a concurrent identical prefill keeps its own (identical) copy
        unshared rather than re-pinning a second id under the same hash."""
        if h in self._entries:
            return False
        allocator.ref(pid)
        self._entries[h] = pid
        self.inserted += 1
        if self.obs is not None:
            self.obs("prefix.pin", page=pid, pinned=len(self._entries))
        if self.capacity is not None and len(self._entries) > self.capacity:
            self.release(allocator, 1)
        return True

    def release(self, allocator, n: int = 1) -> int:
        """Drop up to ``n`` LRU pins (pool pressure / capacity).  Pages
        still referenced by live sequences stay resident until those
        sequences free them; the cache entry is gone either way, so no
        stale lookups."""
        dropped = 0
        while self._entries and dropped < n:
            _, pid = self._entries.popitem(last=False)
            allocator.free([pid])
            dropped += 1
        self.released += dropped
        if self.obs is not None and dropped:
            self.obs("prefix.release", n=dropped,
                     pinned=len(self._entries))
        return dropped

    def clear(self, allocator) -> int:
        return self.release(allocator, len(self._entries))
