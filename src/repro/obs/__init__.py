"""`repro.obs` — unified observability for the serving stack.

Three layers, one package:

* **trace** — deterministic tick-clock event stream (spans + instants)
  from every seam of the stack, exported as Chrome/Perfetto
  ``trace_event`` JSON; byte-identical across same-seed replays.
* **registry** — typed counter/gauge/histogram aggregation that
  `sched/metrics.summarize()` is built on, plus :func:`provenance`
  run-context headers for BENCH sections.
* **recorder** — bounded flight-recorder ring of recent events, dumped
  to disk automatically on ``HealthError`` / ``RequestFailed`` /
  ``OutOfPages``.

* **analyze** — trace analytics: fold the event stream (live or an
  exported file) into a :class:`TraceReport` — per-request critical
  path, queueing split, role utilization, page-pool pressure — and
  score it against a declarative :class:`SLOSpec`.

Plus :func:`timeit` (the one best-of-N wall timer) and
:func:`profile_trace` (optional ``jax.profiler`` hook).
"""
from repro.obs.analyze import SLOSpec, TraceReport, analyze, load_trace
from repro.obs.recorder import FlightRecorder
from repro.obs.registry import (Counter, Gauge, Histogram, Registry,
                                percentile, provenance)
from repro.obs.timing import timeit
from repro.obs.trace import (NULL, NullTracer, Tracer, WallTimers,
                             profile_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "percentile",
    "provenance", "FlightRecorder", "timeit", "NULL", "NullTracer",
    "Tracer", "WallTimers", "profile_trace",
    "SLOSpec", "TraceReport", "analyze", "load_trace",
]
