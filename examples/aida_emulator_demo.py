"""Run the paper's Fig. 3 algorithm bit-for-bit on the CAM emulator, and
show the cycle model + Table-1-style projections for a real FC layer —
everything through the `repro.api.Engine` facade (`ap-emulator` and
`cycle-sim` backends).

  PYTHONPATH=src python examples/aida_emulator_demo.py
"""
import numpy as np

from repro.api import Engine, FCProblem


def main():
    rng = np.random.default_rng(0)
    eng = Engine()

    print("== bit-serial mode (Fig. 3 verbatim) ==")
    W = rng.integers(-15, 16, size=(12, 16)) * (rng.random((12, 16)) < 0.4)
    b = rng.integers(-15, 16, size=(16,)) * (rng.random(16) < 0.6)
    prob = FCProblem(w=W, b=b, m=4, n=4)
    res = eng.estimate(backend="ap-emulator", workload=prob)
    print(f"  C = relu(W x B): emulator == oracle: {res['exact']}")
    print(f"  cycles={res['cycles']} (broadcast {res['nnz_b']} nnz acts, "
          f"{res['rounds']} soft-reduction rounds)")
    print(f"  compare ops={res['counters']['compare']} "
          f"writes={res['counters']['write']} "
          f"tag moves={res['counters']['move']}")
    sim = eng.estimate(backend="cycle-sim", workload=prob)
    print(f"  cycle-sim closed form: {sim['cycles']} cycles — "
          f"{'EXACT match' if sim['cycles'] == res['cycles'] else 'MISMATCH'}")

    print("\n== coded mode (bit-parallel perfect induction, 4-bit) ==")
    cw = np.concatenate([[0], rng.integers(-99, 100, 15)])
    ca = np.concatenate([[0], rng.integers(-99, 100, 15)])
    Wc = rng.integers(0, 16, size=(12, 16)) * (rng.random((12, 16)) < 0.4)
    bc = rng.integers(0, 16, size=(16,)) * (rng.random(16) < 0.6)
    cprob = FCProblem(w=Wc, b=bc, m=4, n=4, coded=True,
                      cents_w=cw, cents_a=ca)
    res = eng.estimate(backend="ap-emulator", workload=cprob)
    print(f"  emulator == oracle: {res['exact']}")
    print(f"  cycles={res['cycles']} — the multiply stage is 225 cycles "
          f"for ANY layer size (perfect induction)")

    print("\n== projected to AlexNet-FC6 (closed-form model) ==")
    alex = eng.estimate(backend="cycle-sim", workload="alexnet-fc")
    ph = alex["report"].phases[0]
    mc_total = alex["report"].cycles_total
    print(f"  FC6 broadcast={ph.broadcast} multiply={ph.multiply} "
          f"reduce={ph.reduce} cycles; network total={mc_total} "
          f"@1GHz = {mc_total/1e3:.1f} us")
    t1 = eng.estimate(backend="cycle-sim", workload="table1")
    a, e = t1["aida"], t1["eie"]
    print(f"  AIDA {a['pp_gops']:.0f} GOP/s vs EIE {e['pp_gops']:.0f} "
          f"-> {a['pp_gops']/e['pp_gops']:.1f}x (paper: 14.5x)")


if __name__ == "__main__":
    main()
