"""Executor protocol + backend registry — the facade's pluggable spine.

A backend is a named `Executor` advertising `Capabilities`; the registry
maps names to instances.  Heavy backends live in `repro.api.backends` and
are imported lazily on first lookup (same pattern as the arch-config
registry), so importing this module costs nothing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


class CapabilityError(NotImplementedError):
    """Raised when a backend is asked for a surface it does not implement."""


@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What a backend can do; the Engine routes on these flags."""
    batched_decode: bool = False       # make_decode_step() works
    cycle_accounting: bool = False     # estimate() returns cycle counts
    per_layer_override: bool = False   # honours CompressionSpec.overrides
    modes: Tuple[str, ...] = ()        # FC modes the backend executes


class Executor:
    """Common protocol for every execution backend.

    Subclasses override the surfaces their capability flags advertise;
    the base implementations raise CapabilityError with a pointer to a
    backend that does support the surface.
    """
    name: str = "abstract"
    caps: Capabilities = Capabilities()

    # ---- batched decode (serving) -------------------------------------
    def make_decode_step(self, cfg, unroll: bool = False, plan=None):
        """-> step(params, state, tokens) -> (state', logits [B, Vpad]).
        ``plan``: an optional shard.ShardingPlan the step must thread to
        its projections (mesh sessions pass it; None = replicated)."""
        raise CapabilityError(
            f"backend {self.name!r} has no batched decode; use one of "
            f"{_REGISTRY.supporting('batched_decode')}")

    # ---- single FC layer ----------------------------------------------
    def run_fc(self, layer, x):
        """Apply one (possibly compressed) FC layer: y = x @ W.T."""
        raise CapabilityError(
            f"backend {self.name!r} cannot run FC layers directly")

    # ---- cycle accounting ---------------------------------------------
    def estimate(self, workload, **kw) -> dict:
        """Cycle/perf estimate for a workload (FCProblem or named)."""
        raise CapabilityError(
            f"backend {self.name!r} has no cycle accounting; use one of "
            f"{_REGISTRY.supporting('cycle_accounting')}")

    def __repr__(self):
        return f"<Executor {self.name!r} caps={self.caps}>"


class BackendRegistry:
    """Name -> Executor mapping with capability queries."""

    def __init__(self):
        self._backends: Dict[str, Executor] = {}

    def register(self, backend: Executor) -> Executor:
        self._backends[backend.name] = backend
        return backend

    def get(self, name: str) -> Executor:
        if name not in self._backends:
            from repro.api import backends  # noqa: F401  (self-registers)
        if name not in self._backends:
            raise KeyError(f"unknown backend {name!r}; "
                           f"registered: {self.names()}")
        return self._backends[name]

    def names(self) -> List[str]:
        if not self._backends:
            from repro.api import backends  # noqa: F401
        return sorted(self._backends)

    def supporting(self, capability: str) -> List[str]:
        return [n for n in self.names()
                if getattr(self._backends[n].caps, capability)]


#: Process-wide default registry (backends self-register on import).
_REGISTRY = BackendRegistry()


def register_backend(backend: Executor) -> Executor:
    return _REGISTRY.register(backend)


def get_backend(name: str) -> Executor:
    return _REGISTRY.get(name)


def backend_names() -> List[str]:
    return _REGISTRY.names()
