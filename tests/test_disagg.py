"""repro.disagg: disaggregated prefill/decode serving.

Covers: greedy token parity of the two-role engine vs the single-engine
serial oracle (llama3 + mixtral smoke, chunk 1 and 4, fifo and sjf),
randomized handoff orderings over seeded workloads, allocator zero-leak
on BOTH pools after drain, int8 page migration exactness (codes and
scales move verbatim — bitwise, stronger than the established ~1-LSB
bound), decode-side back-pressure blocking prefill admission instead of
preempting decoders, the deterministic scheduling-clock TTFT win on the
burst preset, and cross-pool `copy_pages` / `alloc_many` unit behavior.

The mesh case (disjoint tensor-parallel role meshes) runs in a
subprocess that sets ``--xla_force_host_platform_device_count=8``; the
main pytest process keeps 1 device (dry-run isolation rule, see
tests/test_distributed).
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro import kvstore as kvs
from repro import sched as schd
from repro.api import Engine, Request
from repro.api.session import Session
from repro.configs import get, reduced
from repro.disagg import DisaggConfig, DisaggSession
from repro.models import model as M

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CFG = reduced(get("llama3-8b"), n_layers=2, d_model=64, d_ff=128,
              vocab=256)
PS = 4          # page size: small, so short prompts still span pages
ML = 48         # max_len


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def serial_baseline(cfg, params, reqs, kv_dtype=None):
    """Each request alone, one token at a time — the oracle schedule."""
    out = {}
    for r in reqs:
        sess = Session(cfg, params, batch_slots=1, max_len=ML,
                       page_size=PS, kv_dtype=kv_dtype)
        sess.submit(dataclasses.replace(r, rid=0))
        out[r.rid] = sess.run()[0].tokens
    return [out[r.rid] for r in sorted(reqs, key=lambda r: r.rid)]


def alloc_invariant(alloc: kvs.PageAllocator):
    assert len(set(alloc._free)) == len(alloc._free)
    assert not set(alloc._free) & alloc._used
    assert len(alloc._free) + alloc.in_use == alloc.n_pages - 1


def drained(d: DisaggSession):
    """Both pools empty, both allocators internally consistent, and the
    decode role never preempted (back-pressure, not eviction)."""
    for alloc in (d.pre.alloc, d.dec.alloc):
        alloc_invariant(alloc)
        assert alloc.in_use == 0
    assert d.dec.stats["preemptions"] == 0


def mk_reqs(n=5, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=[int(t) for t in
                            rng.integers(1, CFG.vocab, 3 + 2 * i)],
                    max_new=int(rng.integers(2, 8)), rid=i)
            for i in range(n)]


# -------------------------------------------------------- token parity
@pytest.mark.parametrize("chunk", [1, 4])
@pytest.mark.parametrize("policy", ["fifo", "sjf"])
def test_disagg_matches_serial(params, chunk, policy):
    reqs = mk_reqs()
    base = serial_baseline(CFG, params, reqs)
    d = DisaggSession(CFG, params,
                      disagg=DisaggConfig(prefill_slots=2, decode_slots=3),
                      max_len=ML, page_size=PS,
                      scheduler={"policy": policy, "chunk": chunk})
    for r in reqs:
        d.submit(r)
    got = [r.tokens for r in d.run()]
    assert got == base
    drained(d)
    assert d.stats["handoffs"] == len(reqs)
    assert d.stats["migrated_bytes"] > 0


@pytest.mark.parametrize("chunk", [1, 4])
def test_mixtral_disagg_matches_serial(chunk):
    cfg = reduced(get("mixtral-8x7b"), n_layers=2, d_model=64, d_ff=128,
                  vocab=256)
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = mk_reqs(n=3, seed=1)
    base = serial_baseline(cfg, p, reqs)
    d = DisaggSession(cfg, p, disagg=True, max_len=ML, page_size=PS,
                      scheduler={"chunk": chunk})
    for r in reqs:
        d.submit(r)
    assert [r.tokens for r in d.run()] == base
    drained(d)


@pytest.mark.parametrize("seed", [1, 2, 3])
def test_randomized_handoff_orderings(params, seed):
    """Seeded workload traffic (bursty arrivals, mixed lengths, tight
    pools) shuffles which requests are mid-prefill, queued for handoff,
    and decoding at any tick — every ordering must produce the oracle's
    tokens and drain without leaking on either pool."""
    rng = np.random.default_rng(seed)
    wl = schd.WorkloadSpec.preset(
        "burst" if seed % 2 else "heterogeneous", n_requests=8,
        vocab=CFG.vocab, seed=seed, prompt_len=(3, 12), max_new=(1, 6))
    arrivals = schd.generate(wl)
    base = serial_baseline(CFG, params, [r for _, r in arrivals])
    d = DisaggSession(
        CFG, params,
        disagg=DisaggConfig(prefill_slots=int(rng.integers(1, 4)),
                            decode_slots=int(rng.integers(1, 4)),
                            decode_pool_pages=40,
                            max_backlog=int(rng.integers(1, 4))),
        max_len=ML, page_size=PS,
        scheduler={"policy": ["fifo", "sjf"][seed % 2],
                   "chunk": int(rng.integers(1, 5))})
    got = [r.tokens for r in d.run_workload(arrivals)]
    assert got == base
    drained(d)


@pytest.mark.parametrize("seed", [1, 2, 3])
def test_churn_handoff_retry_faults_no_leaks(params, seed):
    """Satellite churn sweep: interleave admission, handoff drops/
    delays/timeouts, role faults, and retry across seeded traffic with
    tight pools and backlog bounds.  Whatever completes must carry
    oracle tokens, the failed census must account for the rest, and
    both pools must drain with exact refcounts (zero leaks)."""
    from repro import resil as rsl
    rng = np.random.default_rng(seed)
    wl = schd.WorkloadSpec.preset(
        "burst" if seed % 2 else "heterogeneous", n_requests=8,
        vocab=CFG.vocab, seed=seed, prompt_len=(3, 12), max_new=(1, 6))
    arrivals = schd.generate(wl)
    base = serial_baseline(CFG, params, [r for _, r in arrivals])
    oracle = {r.rid: t for (_, r), t in
              zip(sorted(arrivals, key=lambda a: a[1].rid), base)}
    preset = ["drop-handoff", "role-stall", "straggler"][seed % 3]
    d = DisaggSession(
        CFG, params,
        disagg=DisaggConfig(prefill_slots=int(rng.integers(1, 3)),
                            decode_slots=int(rng.integers(2, 4)),
                            decode_pool_pages=40,
                            max_backlog=int(rng.integers(1, 4))),
        max_len=ML, page_size=PS,
        scheduler={"chunk": int(rng.integers(1, 5))},
        resil={"fault_plan": f"{preset}:{seed}", "max_retries": 2,
               "watchdog_every": 3,
               "handoff_timeout": int(rng.integers(4, 9))})
    got = d.run_workload(arrivals, on_incomplete="warn")
    assert all(oracle[r.rid] == r.tokens for r in got)
    assert len(got) + len(d.failed) == 8          # full census
    drained(d)
    assert rsl.audit_session(d.pre) == []
    assert rsl.audit_session(d.dec) == []


# ---------------------------------------------------------- int8 moves
def test_int8_migration_token_parity(params):
    reqs = mk_reqs(n=4, seed=2)
    base = serial_baseline(CFG, params, reqs, kv_dtype="int8")
    d = DisaggSession(CFG, params, disagg=True, max_len=ML, page_size=PS,
                      kv_dtype="int8", scheduler={"chunk": 2})
    for r in reqs:
        d.submit(r)
    assert [r.tokens for r in d.run()] == base
    drained(d)


def test_copy_pages_moves_int8_codes_and_scales_verbatim():
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    dst = kvs.init_pool(8, 2, PS, 4, kv_dtype="int8")
    src = kvs.init_pool(8, 2, PS, 4, kv_dtype="int8")._replace(
        k_pages=jnp.asarray(
            rng.integers(-127, 128, (8, 2, PS, 4)), jnp.int8),
        v_pages=jnp.asarray(
            rng.integers(-127, 128, (8, 2, PS, 4)), jnp.int8),
        k_scale=jnp.asarray(rng.random((8, 2)), jnp.float32),
        v_scale=jnp.asarray(rng.random((8, 2)), jnp.float32))
    out, moved = kvs.copy_pages(src, dst, [3, 5], [1, 2])
    for s_id, d_id in ((3, 1), (5, 2)):
        np.testing.assert_array_equal(out.k_pages[d_id],
                                      src.k_pages[s_id])
        np.testing.assert_array_equal(out.v_pages[d_id],
                                      src.v_pages[s_id])
        np.testing.assert_array_equal(out.k_scale[d_id],
                                      src.k_scale[s_id])
        np.testing.assert_array_equal(out.v_scale[d_id],
                                      src.v_scale[s_id])
    assert moved > 0
    # untouched destination pages stay zero
    assert not np.asarray(out.k_pages[4]).any()


def test_copy_pages_rejects_geometry_mismatch():
    a = kvs.init_pool(4, 2, PS, 4, kv_dtype="bf16")
    b = kvs.init_pool(4, 2, 2 * PS, 4, kv_dtype="bf16")
    with pytest.raises(ValueError):
        kvs.copy_pages(a, b, [1], [1])
    with pytest.raises(ValueError):
        kvs.copy_pages(a, a, [1, 2], [1])


def test_alloc_many_is_atomic():
    alloc = kvs.PageAllocator(5)      # 4 usable
    got = alloc.alloc_many(2)
    assert len(got) == 2 and alloc.in_use == 2
    with pytest.raises(kvs.OutOfPages):
        alloc.alloc_many(3)           # only 2 left: all-or-nothing
    assert alloc.in_use == 2 and alloc.available == 2
    alloc.free(got)
    alloc_invariant(alloc)


# -------------------------------------------------------- back-pressure
def test_backpressure_blocks_prefill_not_decoders(params):
    """A slow decode side (1 slot, backlog bound 1) must stall *prefill
    admission* — queued requests wait, admitted decoders never get
    preempted, and everything still completes with oracle tokens."""
    reqs = [Request(prompt=[2 + i] * 6, max_new=8, rid=i)
            for i in range(6)]
    base = serial_baseline(CFG, params, reqs)
    d = DisaggSession(CFG, params,
                      disagg=DisaggConfig(prefill_slots=2, decode_slots=1,
                                          max_backlog=1),
                      max_len=ML, page_size=PS, scheduler={"chunk": 4})
    for r in reqs:
        d.submit(r)
    assert [r.tokens for r in d.run()] == base
    assert d.router.stats["backpressure_blocks"] > 0
    assert d.dec.stats["preemptions"] == 0
    drained(d)


def test_decode_pool_too_small_raises(params):
    d = DisaggSession(CFG, params,
                      disagg=DisaggConfig(decode_pool_pages=4),
                      max_len=ML, page_size=PS)
    d.submit(Request(prompt=list(range(1, 21)), max_new=8, rid=0))
    with pytest.raises(kvs.OutOfPages, match="decode page pool"):
        d.run()


def test_max_new_one_finishes_at_prefill(params):
    reqs = [Request(prompt=[3 + i] * 5, max_new=1, rid=i)
            for i in range(3)]
    base = serial_baseline(CFG, params, reqs)
    d = DisaggSession(CFG, params, disagg=True, max_len=ML, page_size=PS,
                      scheduler={"chunk": 4})
    for r in reqs:
        d.submit(r)
    assert [r.tokens for r in d.run()] == base
    assert d.stats["handoffs"] == 0          # nothing decode-bound
    assert d.dec.stats["steps"] == 0
    drained(d)


# ------------------------------------------------- scheduling-clock TTFT
def test_burst_ttft_sched_no_worse_than_colocated(params):
    """The deterministic form of the disaggregation win: with matched
    slot widths, scheduling-clock TTFT on the burst preset is no worse
    disaggregated — decoders never occupy prompt-admission slots."""
    wl = schd.WorkloadSpec.preset("burst", n_requests=12,
                                  vocab=CFG.vocab, seed=0)
    arrivals = schd.generate(wl)

    def replay():
        return [(t, dataclasses.replace(r)) for t, r in arrivals]

    co = Session(CFG, params, batch_slots=4, max_len=ML, page_size=PS,
                 scheduler={"chunk": 4})
    co.run_workload(replay())
    d = DisaggSession(CFG, params,
                      disagg=DisaggConfig(prefill_slots=4, decode_slots=4),
                      max_len=ML, page_size=PS, scheduler={"chunk": 4})
    d.run_workload(replay())
    m_co = schd.summarize(co.records, 1.0, co.stats["steps"])
    m_d = schd.summarize(d.records, 1.0, d.pre.stats["steps"],
                         roles=d.role_stats())
    assert m_d["ttft_sched"]["p99"] <= m_co["ttft_sched"]["p99"]
    assert m_d["handoff"]["count"] > 0
    assert m_d["roles"]["decode"]["utilization"] is not None


# ------------------------------------------------------------ validation
def test_disagg_rejects_recurrent_arch():
    cfg = reduced(get("rwkv6-7b"))
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="KV pages"):
        DisaggSession(cfg, p, disagg=True, max_len=ML)


def test_engine_disagg_validation(params):
    eng = Engine(CFG, params=params)
    with pytest.raises(ValueError, match="mutually exclusive"):
        eng.session(disagg=True, mesh=object())
    with pytest.raises(ValueError, match="kv_cache"):
        eng.session(disagg=True, kv_cache="full")
    with pytest.raises(ValueError, match="together"):
        DisaggConfig(prefill_devices=2, decode_devices=None)
    with pytest.raises(ValueError, match="slot"):
        DisaggConfig(prefill_slots=0)


def test_role_mesh_validation():
    from repro.launch.mesh import make_role_meshes
    with pytest.raises(ValueError, match=">= 1 device"):
        make_role_meshes(0, 1)
    with pytest.raises(ValueError, match="device"):
        # the single-device pytest process cannot host 8+8
        make_role_meshes(8, 8)


# ------------------------------------------------------------ mesh roles
MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.api import Engine, Request
from repro.configs import get, reduced

cfg = reduced(get("llama3-8b"), n_layers=2, d_model=64, d_ff=128,
              vocab=256)
eng = Engine(cfg)
reqs = [Request(prompt=[1 + (j * 7 + i) % 200 for j in range(9)],
                max_new=6, rid=i) for i in range(4)]

def run(disagg):
    sess = eng.session(batch_slots=2, max_len=48, page_size=4,
                       scheduler={"chunk": 4}, disagg=disagg)
    for r in reqs:
        sess.submit(Request(prompt=list(r.prompt), max_new=r.max_new,
                            rid=r.rid))
    toks = [r.tokens for r in sess.run()]
    return sess, toks

_, ref = run(None)
sess, got = run({"prefill_slots": 2, "decode_slots": 2,
                 "prefill_devices": 4, "decode_devices": 4})
kv = sess.dec.state["layers"]["kv"]
print(json.dumps({
    "n_devices": jax.device_count(),
    "match": got == ref,
    "pre_devices": len(jax.tree.leaves(
        sess.pre.params)[0].sharding.device_set),
    "role_sets_disjoint": not (
        jax.tree.leaves(sess.pre.params)[0].sharding.device_set
        & jax.tree.leaves(sess.dec.params)[0].sharding.device_set),
    "kv_heads_local": kv.k_pages.addressable_shards[0].data.shape[2],
    "kv_heads_global": kv.k_pages.shape[2],
    "handoffs": sess.stats["handoffs"],
    "leaked": sess.pre.alloc.in_use + sess.dec.alloc.in_use,
}))
"""


def run_sub(script, timeout=1200):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_disagg_role_meshes_token_parity():
    """Prefill on devices 0-3, decode on devices 4-7 (tp=4 each): page
    migration crosses device sets, greedy tokens match the single-device
    co-located engine, and both pools drain clean."""
    r = run_sub(MESH_SCRIPT)
    assert r["n_devices"] == 8
    assert r["match"], "mesh-role disagg diverged from co-located"
    assert r["pre_devices"] == 4
    assert r["role_sets_disjoint"]
    assert r["kv_heads_local"] * 4 == r["kv_heads_global"]
    assert r["handoffs"] == 4
    assert r["leaked"] == 0
